"""AOT path: HLO text generation + manifest ABI consistency."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import MICRO, TINY, param_order

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestHloText:
    def test_simple_fn_lowers_to_hlo_text(self):
        fn = lambda x: (x * 2.0 + 1.0,)  # noqa: E731
        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "f32[4]" in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        from compile import kernels

        qs = jax.ShapeDtypeStruct((64, 32), jnp.int8)
        sc = jax.ShapeDtypeStruct((64, 1), jnp.float32)
        x = jax.ShapeDtypeStruct((32,), jnp.float32)
        lowered = jax.jit(lambda a, b, c: (kernels.qgemv(a, b, c),)).lower(qs, sc, x)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        # interpret=True must not leave an unexecutable custom-call target
        assert "mosaic" not in text.lower()


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(ARTIFACT_DIR, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_schema(self, manifest):
        assert manifest["format"] == "hlo-text"
        assert manifest["quant"] == {"scheme": "q4_0", "qk": 32}
        for key in ("tiny_decode", "tiny_prefill", "micro_decode", "micro_prefill", "qgemv", "qgemm"):
            assert key in manifest["artifacts"], key

    def test_files_exist(self, manifest):
        for name, art in manifest["artifacts"].items():
            path = os.path.join(ARTIFACT_DIR, art["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(4096)
            assert "ENTRY" in head or "HloModule" in head

    @pytest.mark.parametrize("cfg_name,cfg", [("tiny", TINY), ("micro", MICRO)])
    def test_model_param_abi(self, manifest, cfg_name, cfg):
        """Manifest parameter list == 4 leading args + param_order(cfg)."""
        for which in ("decode", "prefill"):
            art = manifest["artifacts"][f"{cfg_name}_{which}"]
            meta = art["params"]
            expected_lead = 4  # token(s), pos, kv_k, kv_v
            order = param_order(cfg)
            assert len(meta) == expected_lead + len(order)
            for (name, shape, dtype), entry in zip(order, meta[expected_lead:]):
                assert entry["name"] == name
                assert tuple(entry["shape"]) == tuple(shape)
                assert entry["dtype"] == dtype
            kv_shape = [cfg.n_layers, cfg.n_heads, cfg.t_max, cfg.head_dim]
            assert meta[2]["shape"] == kv_shape and meta[3]["shape"] == kv_shape

    def test_model_metadata(self, manifest):
        m = manifest["artifacts"]["tiny_decode"]["model"]
        assert m["vocab"] == TINY.vocab and m["n_layers"] == TINY.n_layers

    def test_outputs_declared(self, manifest):
        for key in ("tiny_decode", "micro_prefill"):
            outs = manifest["artifacts"][key]["outputs"]
            assert [o["name"] for o in outs] == ["logits", "kv_k", "kv_v"]
