"""Q4_0 / Q8-dynamic quantization semantics — the cross-language ABI."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant


def _rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(np.float32)


class TestQ4_0:
    def test_shapes(self):
        qs, sc = quant.quantize_q4_0(_rand((8, 64)))
        assert qs.shape == (8, 64) and qs.dtype == np.int8
        assert sc.shape == (8, 2) and sc.dtype == np.float32

    def test_codes_in_range(self):
        qs, _ = quant.quantize_q4_0(_rand((16, 128), seed=3, scale=5.0))
        assert qs.min() >= 0 and qs.max() <= 15

    def test_roundtrip_error_bound(self):
        w = _rand((32, 256), seed=1)
        qs, sc = quant.quantize_q4_0(w)
        deq = quant.dequantize_q4_0(qs, sc)
        # max quantization step is |d| = absmax/8; error ≤ |d| (floor+0.5 bias)
        blocks = np.abs(w.reshape(32, -1, quant.QK)).max(axis=-1) / 8.0
        step = np.repeat(blocks, quant.QK, axis=-1)
        assert np.all(np.abs(deq - w) <= step + 1e-6)

    def test_zero_block(self):
        w = np.zeros((1, 32), dtype=np.float32)
        qs, sc = quant.quantize_q4_0(w)
        assert np.all(sc == 0.0)
        assert np.all(quant.dequantize_q4_0(qs, sc) == 0.0)

    def test_extreme_element_is_exact(self):
        # The element with the largest magnitude maps to code 0 (q = -8),
        # so it is reconstructed as -8 * (max / -8) = max up to f16 rounding.
        w = _rand((4, 64), seed=7)
        qs, sc = quant.quantize_q4_0(w)
        deq = quant.dequantize_q4_0(qs, sc)
        blocks_w = w.reshape(4, 2, 32)
        blocks_d = deq.reshape(4, 2, 32)
        idx = np.argmax(np.abs(blocks_w), axis=-1)
        mx_w = np.take_along_axis(blocks_w, idx[..., None], -1)
        mx_d = np.take_along_axis(blocks_d, idx[..., None], -1)
        assert np.allclose(mx_w, mx_d, rtol=2e-3)

    def test_scale_is_f16_representable(self):
        _, sc = quant.quantize_q4_0(_rand((8, 96), seed=5))
        assert np.array_equal(sc, sc.astype(np.float16).astype(np.float32))

    def test_rejects_bad_k(self):
        with pytest.raises(ValueError):
            quant.quantize_q4_0(_rand((4, 33)))

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError):
            quant.quantize_q4_0(np.zeros(64, dtype=np.float32))

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(1, 16),
        kb=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
        scale=st.floats(1e-3, 1e3),
    )
    def test_roundtrip_property(self, n, kb, seed, scale):
        w = _rand((n, kb * quant.QK), seed=seed, scale=scale)
        qs, sc = quant.quantize_q4_0(w)
        deq = quant.dequantize_q4_0(qs, sc)
        amax = np.abs(w).max()
        if amax > 0:
            assert np.abs(deq - w).max() <= amax / 8.0 * 1.01 + 1e-6
        assert qs.min() >= 0 and qs.max() <= 15


class TestQ8Dynamic:
    def test_roundtrip(self):
        x = _rand((4, 64), seed=2, scale=3.0)
        q, s = quant.quantize_q8_dynamic(x)
        deq = q.astype(np.float32) * s[:, None]
        assert np.abs(deq - x).max() <= np.abs(x).max() / 127.0 * 0.51 + 1e-6

    def test_rank1(self):
        x = _rand(64, seed=4)
        q, s = quant.quantize_q8_dynamic(x)
        assert q.shape == (64,) and np.isscalar(float(s))

    def test_zero_row(self):
        q, s = quant.quantize_q8_dynamic(np.zeros((2, 32), dtype=np.float32))
        assert np.all(q == 0) and np.all(s == 1.0)

    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(1, 128), seed=st.integers(0, 10**6))
    def test_codes_bounded(self, k, seed):
        x = _rand(k, seed=seed, scale=100.0)
        q, s = quant.quantize_q8_dynamic(x)
        assert q.min() >= -127 and q.max() <= 127


class TestCrossLanguageGolden:
    """Golden values pinned in rust/src/quant/{q4_0,q8}.rs::golden_tests —
    the two quantizers must stay bit-identical (they are the weights ABI
    between the native engine and the PJRT artifacts)."""

    def test_q4_golden(self):
        x = (np.sin(np.arange(1, 65, dtype=np.float32)) * np.float32(2.0)).reshape(1, 64)
        qs, sc = quant.quantize_q4_0(x)
        assert list(qs[0][:16]) == [15, 15, 9, 2, 0, 6, 13, 15, 11, 4, 0, 4, 11, 15, 13, 6]
        bits = [int(np.float32(s).astype(np.float16).view(np.uint16)) for s in sc[0]]
        assert bits == [0x3400, 0xB400]

    def test_q8_golden(self):
        x = np.sin(np.arange(1, 33, dtype=np.float32)).astype(np.float32)
        q, s = quant.quantize_q8_dynamic(x)
        assert list(q[:8]) == [107, 115, 18, -96, -122, -35, 83, 126]
        assert abs(float(s) - 0.0078739384) < 1e-9
