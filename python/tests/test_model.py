"""L2 model semantics: pallas path ≡ oracle path, prefill ≡ sequential decode."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import weights
from compile.model import (
    MICRO,
    TINY,
    ModelConfig,
    decode_step,
    flatten_params,
    init_kv,
    param_order,
    prefill_chunk,
    unflatten_params,
)


@pytest.fixture(scope="module")
def micro_params():
    return {k: jnp.asarray(v) for k, v in weights.init_params(MICRO, seed=1).items()}


class TestParamABI:
    def test_order_is_deterministic(self):
        assert param_order(MICRO) == param_order(MICRO)

    def test_flatten_roundtrip(self, micro_params):
        flat = flatten_params(MICRO, micro_params)
        back = unflatten_params(MICRO, flat)
        assert set(back) == set(micro_params)
        for k in micro_params:
            assert back[k] is micro_params[k]

    def test_unflatten_rejects_wrong_arity(self, micro_params):
        flat = flatten_params(MICRO, micro_params)
        with pytest.raises(ValueError):
            unflatten_params(MICRO, flat[:-1])

    def test_qs_tensors_paired_with_scales(self):
        order = param_order(TINY)
        names = [n for n, _, _ in order]
        for n in names:
            if n.endswith(".qs"):
                assert n[:-3] + ".sc" in names

    def test_shapes_match_config(self):
        for name, shape, dtype in param_order(MICRO):
            if name == "embed":
                assert shape == (MICRO.vocab, MICRO.d_model) and dtype == "f32"
            if name.endswith(".qs"):
                assert dtype == "i8" and shape[1] % 32 == 0

    def test_configs_validate(self):
        TINY.validate()
        MICRO.validate()

    def test_bad_config_rejected(self):
        with pytest.raises(AssertionError):
            ModelConfig(d_model=100).validate()  # not divisible by 64


class TestDecode:
    def test_pallas_matches_oracle(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        lp, kp, vp = decode_step(MICRO, micro_params, jnp.int32(3), jnp.int32(0), kv_k, kv_v, True)
        lr, kr, vr = decode_step(MICRO, micro_params, jnp.int32(3), jnp.int32(0), kv_k, kv_v, False)
        np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(kp, kr, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(vp, vr, rtol=1e-4, atol=1e-5)

    def test_kv_written_only_at_pos(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        pos = 5
        _, kp, vp = decode_step(
            MICRO, micro_params, jnp.int32(7), jnp.int32(pos), kv_k, kv_v, False
        )
        kp, vp = np.asarray(kp), np.asarray(vp)
        mask = np.ones(MICRO.t_max, dtype=bool)
        mask[pos] = False
        assert np.all(kp[:, :, mask, :] == 0) and np.all(vp[:, :, mask, :] == 0)
        assert np.any(kp[:, :, pos, :] != 0)

    def test_logits_shape_and_finite(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        logits, _, _ = decode_step(
            MICRO, micro_params, jnp.int32(1), jnp.int32(0), kv_k, kv_v, False
        )
        assert logits.shape == (MICRO.vocab,)
        assert np.all(np.isfinite(logits))

    def test_different_tokens_different_logits(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        l1, _, _ = decode_step(MICRO, micro_params, jnp.int32(1), jnp.int32(0), kv_k, kv_v, False)
        l2, _, _ = decode_step(MICRO, micro_params, jnp.int32(2), jnp.int32(0), kv_k, kv_v, False)
        assert not np.allclose(l1, l2)

    def test_history_affects_logits(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        _, k1, v1 = decode_step(MICRO, micro_params, jnp.int32(5), jnp.int32(0), kv_k, kv_v, False)
        la, _, _ = decode_step(MICRO, micro_params, jnp.int32(9), jnp.int32(1), k1, v1, False)
        _, k2, v2 = decode_step(MICRO, micro_params, jnp.int32(6), jnp.int32(0), kv_k, kv_v, False)
        lb, _, _ = decode_step(MICRO, micro_params, jnp.int32(9), jnp.int32(1), k2, v2, False)
        assert not np.allclose(la, lb)


class TestPrefill:
    def test_prefill_equals_sequential_decode(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        toks = np.array([3, 7, 11, 2, 9, 4, 1, 8], dtype=np.int32)
        lp, kp, vp = prefill_chunk(
            MICRO, micro_params, jnp.asarray(toks), jnp.int32(0), kv_k, kv_v, True
        )
        kk, vv = kv_k, kv_v
        for i, t in enumerate(toks):
            ld, kk, vv = decode_step(
                MICRO, micro_params, jnp.int32(int(t)), jnp.int32(i), kk, vv, False
            )
        np.testing.assert_allclose(lp, ld, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(kp, kk, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(vp, vv, rtol=1e-4, atol=1e-5)

    def test_chunked_prefill_continues(self, micro_params):
        """Two consecutive chunks == one longer sequential decode."""
        kv_k, kv_v = init_kv(MICRO)
        toks = np.arange(16, dtype=np.int32) % MICRO.vocab
        _, k1, v1 = prefill_chunk(
            MICRO, micro_params, jnp.asarray(toks[:8]), jnp.int32(0), kv_k, kv_v, False
        )
        l2, k2, v2 = prefill_chunk(
            MICRO, micro_params, jnp.asarray(toks[8:]), jnp.int32(8), k1, v1, False
        )
        kk, vv = kv_k, kv_v
        for i, t in enumerate(toks):
            ld, kk, vv = decode_step(
                MICRO, micro_params, jnp.int32(int(t)), jnp.int32(i), kk, vv, False
            )
        np.testing.assert_allclose(l2, ld, rtol=1e-3, atol=1e-4)

    def test_pallas_matches_oracle(self, micro_params):
        kv_k, kv_v = init_kv(MICRO)
        toks = jnp.asarray(np.array([1, 2, 3, 4, 5, 6, 7, 8], dtype=np.int32))
        lp, _, _ = prefill_chunk(MICRO, micro_params, toks, jnp.int32(0), kv_k, kv_v, True)
        lr, _, _ = prefill_chunk(MICRO, micro_params, toks, jnp.int32(0), kv_k, kv_v, False)
        np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-5)
