"""Pallas kernels vs pure-jnp oracles — the L1 correctness signal.

Hypothesis sweeps shapes (multiples of the tiling constraints); every kernel
must match its oracle to tight tolerances on every draw.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels, quant
from compile.kernels import ref


def _rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(np.float32)


def _qweight(n, k, seed=0):
    qs, sc = quant.quantize_q4_0(_rand((n, k), seed=seed))
    return jnp.asarray(qs), jnp.asarray(sc)


class TestQMatmul:
    def test_gemv_matches_ref(self):
        qs, sc = _qweight(128, 96, seed=1)
        x = jnp.asarray(_rand(96, seed=2))
        np.testing.assert_allclose(
            kernels.qgemv(qs, sc, x), ref.ref_qgemv(qs, sc, x), rtol=1e-5, atol=1e-5
        )

    def test_gemm_matches_ref(self):
        qs, sc = _qweight(192, 64, seed=3)
        x = jnp.asarray(_rand((8, 64), seed=4))
        np.testing.assert_allclose(
            kernels.qmatmul(qs, sc, x), ref.ref_qmatmul(qs, sc, x), rtol=1e-5, atol=1e-5
        )

    @pytest.mark.parametrize("block_n", [64, 128])
    def test_block_n_invariance(self, block_n):
        qs, sc = _qweight(256, 64, seed=5)
        x = jnp.asarray(_rand((2, 64), seed=6))
        np.testing.assert_allclose(
            kernels.qmatmul(qs, sc, x, block_n=block_n),
            ref.ref_qmatmul(qs, sc, x),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_rejects_untiled_n(self):
        qs, sc = _qweight(96, 64)
        with pytest.raises(ValueError):
            kernels.qmatmul(qs, sc, jnp.zeros((1, 64)), block_n=64)

    def test_rejects_k_mismatch(self):
        qs, sc = _qweight(64, 64)
        with pytest.raises(ValueError):
            kernels.qmatmul(qs, sc, jnp.zeros((1, 32)))

    @settings(max_examples=20, deadline=None)
    @given(
        nb=st.integers(1, 4),
        kb=st.integers(1, 4),
        s=st.integers(1, 8),
        seed=st.integers(0, 10**6),
    )
    def test_property_matches_ref(self, nb, kb, s, seed):
        n, k = nb * 64, kb * 32
        qs, sc = _qweight(n, k, seed=seed)
        x = jnp.asarray(_rand((s, k), seed=seed + 1))
        np.testing.assert_allclose(
            kernels.qmatmul(qs, sc, x), ref.ref_qmatmul(qs, sc, x), rtol=1e-4, atol=1e-4
        )


class TestGemmI8:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(0, 255, (64, 96), dtype=np.uint8))
        b = jnp.asarray(rng.integers(-127, 127, (96, 128), dtype=np.int8))
        assert (np.asarray(kernels.gemm_i8(a, b)) == np.asarray(ref.ref_gemm_i8(a, b))).all()

    def test_saturating_inputs_exact(self):
        # extreme values: 255 * -128 * K accumulates exactly in i32
        a = jnp.full((64, 64), 255, dtype=jnp.uint8)
        b = jnp.full((64, 64), -128, dtype=jnp.int8)
        out = np.asarray(kernels.gemm_i8(a, b))
        assert (out == 255 * -128 * 64).all()

    def test_rejects_k_mismatch(self):
        with pytest.raises(ValueError):
            kernels.gemm_i8(jnp.zeros((64, 32), jnp.uint8), jnp.zeros((64, 64), jnp.int8))

    def test_rejects_untiled(self):
        with pytest.raises(ValueError):
            kernels.gemm_i8(jnp.zeros((65, 64), jnp.uint8), jnp.zeros((64, 64), jnp.int8))

    @settings(max_examples=15, deadline=None)
    @given(
        mb=st.integers(1, 3), kk=st.integers(1, 96), nb=st.integers(1, 3), seed=st.integers(0, 10**6)
    )
    def test_property_exact(self, mb, kk, nb, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(0, 255, (mb * 64, kk), dtype=np.uint8))
        b = jnp.asarray(rng.integers(-127, 127, (kk, nb * 64), dtype=np.int8))
        assert (np.asarray(kernels.gemm_i8(a, b)) == np.asarray(ref.ref_gemm_i8(a, b))).all()


class TestQGemvInt:
    def test_matches_ref(self):
        qs, sc = _qweight(128, 64, seed=9)
        x = _rand(64, seed=10)
        xq, xs = quant.quantize_q8_dynamic(x)
        got = kernels.qgemv_int(qs, sc, jnp.asarray(xq), jnp.asarray([xs]))
        want = ref.ref_gemv_q8q4(jnp.asarray(xq), jnp.asarray(xs), qs, sc)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_integer_dot_approximates_f32(self):
        # the q8·q4 integer path should track the dequant-f32 path closely
        qs, sc = _qweight(256, 128, seed=11)
        x = _rand(128, seed=12)
        xq, xs = quant.quantize_q8_dynamic(x)
        got = np.asarray(kernels.qgemv_int(qs, sc, jnp.asarray(xq), jnp.asarray([xs])))
        f32 = np.asarray(ref.ref_qgemv(qs, sc, jnp.asarray(x)))
        denom = max(1e-3, float(np.abs(f32).max()))
        assert np.abs(got - f32).max() / denom < 0.02

    @settings(max_examples=15, deadline=None)
    @given(nb=st.integers(1, 4), kb=st.integers(1, 4), seed=st.integers(0, 10**6))
    def test_property_matches_ref(self, nb, kb, seed):
        n, k = nb * 64, kb * 32
        qs, sc = _qweight(n, k, seed=seed)
        xq, xs = quant.quantize_q8_dynamic(_rand(k, seed=seed + 1))
        got = kernels.qgemv_int(qs, sc, jnp.asarray(xq), jnp.asarray([xs]))
        want = ref.ref_gemv_q8q4(jnp.asarray(xq), jnp.asarray(xs), qs, sc)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestAttnDecode:
    def _case(self, h, t, dh, pos, seed=0):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((h, dh)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((h, t, dh)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((h, t, dh)).astype(np.float32))
        mask = jnp.asarray(np.where(np.arange(t) <= pos, 0.0, -1e9).astype(np.float32))
        return q, k, v, mask

    def test_matches_ref(self):
        q, k, v, m = self._case(8, 64, 32, pos=17)
        np.testing.assert_allclose(
            kernels.attn_decode(q, k, v, m), ref.ref_attn_decode(q, k, v, m), rtol=1e-5, atol=1e-5
        )

    def test_mask_pos0_uses_only_first_token(self):
        q, k, v, m = self._case(2, 16, 8, pos=0, seed=3)
        out = np.asarray(kernels.attn_decode(q, k, v, m))
        np.testing.assert_allclose(out, np.asarray(v[:, 0, :]), rtol=1e-5, atol=1e-5)

    def test_output_is_convex_combination(self):
        q, k, v, m = self._case(4, 32, 16, pos=31, seed=4)
        out = np.asarray(kernels.attn_decode(q, k, v, m))
        vmin = np.asarray(v).min(axis=1)
        vmax = np.asarray(v).max(axis=1)
        assert np.all(out >= vmin - 1e-5) and np.all(out <= vmax + 1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        h=st.integers(1, 8),
        t=st.integers(2, 48),
        dh=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 10**6),
    )
    def test_property_matches_ref(self, h, t, dh, seed):
        pos = seed % t
        q, k, v, m = self._case(h, t, dh, pos=pos, seed=seed)
        np.testing.assert_allclose(
            kernels.attn_decode(q, k, v, m), ref.ref_attn_decode(q, k, v, m), rtol=1e-4, atol=1e-4
        )
