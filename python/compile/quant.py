"""Q4_0 block quantization — the reference semantics for the whole repo.

Layout follows llama.cpp's Q4_0: blocks of ``QK = 32`` values, one f16 scale
per block, 4-bit unsigned codes with an implicit offset of 8:

    max  = the element with the largest magnitude in the block (signed)
    d    = max / -8                      (f32, then rounded to f16 storage)
    id   = 1/d if d != 0 else 0          (f32, computed from the *f32* d)
    q    = clamp(floor(x * id + 8.5), 0, 15)
    deq  = (q - 8) * f32(f16(d))

The Rust implementation (``rust/src/quant/q4_0.rs``) mirrors these exact
operations so that the native engine and the AOT PJRT artifacts consume
bit-identical ``(qs, scales)`` tensors and produce matching logits.
"""

from __future__ import annotations

import numpy as np

QK = 32  # block size (values per scale)


def _f16_round(x: np.ndarray) -> np.ndarray:
    """Round f32 → f16 storage → f32, the scale precision used everywhere."""
    return x.astype(np.float16).astype(np.float32)


def quantize_q4_0(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Quantize a 2-D f32 weight matrix ``[N, K]`` row-block-wise.

    Returns ``(qs, scales)`` with ``qs`` int8 in ``[0, 15]`` of shape
    ``[N, K]`` (unpacked codes) and ``scales`` f32 (f16-rounded) of shape
    ``[N, K // QK]``.
    """
    w = np.asarray(w, dtype=np.float32)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got shape {w.shape}")
    n, k = w.shape
    if k % QK != 0:
        raise ValueError(f"K={k} not a multiple of QK={QK}")
    blocks = w.reshape(n, k // QK, QK)
    # Signed element with the largest magnitude per block (first on ties,
    # matching a linear scan).
    idx = np.argmax(np.abs(blocks), axis=-1)
    mx = np.take_along_axis(blocks, idx[..., None], axis=-1)[..., 0]
    d = mx / -8.0
    inv = np.where(d != 0.0, np.float32(1.0) / np.where(d != 0.0, d, 1.0), 0.0)
    q = np.floor(blocks * inv[..., None] + np.float32(8.5))
    qs = np.clip(q, 0.0, 15.0).astype(np.int8).reshape(n, k)
    scales = _f16_round(d)
    return qs, scales


def dequantize_q4_0(qs: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_q4_0` → f32 ``[N, K]``."""
    n, k = qs.shape
    w = (qs.astype(np.float32) - 8.0).reshape(n, k // QK, QK)
    return (w * scales[..., None].astype(np.float32)).reshape(n, k)


def quantize_q8_dynamic(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric int8 dynamic quantization of activations.

    ``x`` is ``[M, K]`` f32 (or ``[K]``). Returns ``(q, scale)`` with ``q``
    int8 in ``[-127, 127]`` and ``scale`` f32 per row such that
    ``x ≈ q * scale``. Used by the INT8-activation GEMV path (the paper's
    "dynamic quantization for the FLOAT32 input tensor").
    """
    x = np.asarray(x, dtype=np.float32)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    amax = np.max(np.abs(x), axis=-1)
    scale = np.where(amax > 0, amax / np.float32(127.0), np.float32(1.0))
    q = np.clip(np.round(x / scale[:, None]), -127, 127).astype(np.int8)
    scale = scale.astype(np.float32)
    if squeeze:
        return q[0], scale[0]
    return q, scale
