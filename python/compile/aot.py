"""AOT entry point: lower the L2 model (+ standalone L1 kernels) to HLO text.

HLO *text* (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Outputs (``--out-dir``, default ``../artifacts``):
    tiny_decode.hlo.txt    decode_step  (S = 1)
    tiny_prefill.hlo.txt   prefill_chunk (S = cfg.prefill_len)
    micro_decode.hlo.txt / micro_prefill.hlo.txt   (smaller test model)
    qgemv.hlo.txt          standalone fused-dequant GEMV  (runtime tests)
    qgemm.hlo.txt          standalone u8×i8→i32 GEMM      (runtime tests)
    manifest.json          parameter ABI for the Rust runtime

Python runs only here (``make artifacts``); never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from . import kernels
from .model import MICRO, TINY, ModelConfig, make_decode_fn, make_prefill_fn, param_order

_DTYPES = {"f32": jnp.float32, "i8": jnp.int8, "i32": jnp.int32, "u8": jnp.uint8}


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned by the parser)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype: str):
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


def _model_entry(cfg: ModelConfig, which: str):
    """Build (fn, arg_specs, param_meta) for decode/prefill of a config."""
    kv = (cfg.n_layers, cfg.n_heads, cfg.t_max, cfg.head_dim)
    params = param_order(cfg)
    flat_specs = [_spec(shape, dt) for _, shape, dt in params]
    if which == "decode":
        fn = make_decode_fn(cfg)
        args = [_spec((), "i32"), _spec((), "i32"), _spec(kv, "f32"), _spec(kv, "f32")]
        arg_meta = [
            {"name": "token", "shape": [], "dtype": "i32"},
            {"name": "pos", "shape": [], "dtype": "i32"},
            {"name": "kv_k", "shape": list(kv), "dtype": "f32"},
            {"name": "kv_v", "shape": list(kv), "dtype": "f32"},
        ]
        outs = [
            {"name": "logits", "shape": [cfg.vocab], "dtype": "f32"},
            {"name": "kv_k", "shape": list(kv), "dtype": "f32"},
            {"name": "kv_v", "shape": list(kv), "dtype": "f32"},
        ]
    else:
        fn = make_prefill_fn(cfg)
        s = cfg.prefill_len
        args = [_spec((s,), "i32"), _spec((), "i32"), _spec(kv, "f32"), _spec(kv, "f32")]
        arg_meta = [
            {"name": "tokens", "shape": [s], "dtype": "i32"},
            {"name": "pos0", "shape": [], "dtype": "i32"},
            {"name": "kv_k", "shape": list(kv), "dtype": "f32"},
            {"name": "kv_v", "shape": list(kv), "dtype": "f32"},
        ]
        outs = [
            {"name": "logits", "shape": [cfg.vocab], "dtype": "f32"},
            {"name": "kv_k", "shape": list(kv), "dtype": "f32"},
            {"name": "kv_v", "shape": list(kv), "dtype": "f32"},
        ]
    param_meta = [
        {"name": name, "shape": list(shape), "dtype": dt} for name, shape, dt in params
    ]
    return fn, args + flat_specs, arg_meta + param_meta, outs


def _kernel_entries():
    """Standalone kernel artifacts for runtime integration tests."""
    n, k = 256, 256
    qgemv_fn = lambda qs, sc, x: (kernels.qgemv(qs, sc, x),)  # noqa: E731
    qgemv_args = [_spec((n, k), "i8"), _spec((n, k // 32), "f32"), _spec((k,), "f32")]
    qgemv_meta = [
        {"name": "qs", "shape": [n, k], "dtype": "i8"},
        {"name": "scales", "shape": [n, k // 32], "dtype": "f32"},
        {"name": "x", "shape": [k], "dtype": "f32"},
    ]
    qgemv_outs = [{"name": "y", "shape": [n], "dtype": "f32"}]

    m, kk, nn = 64, 64, 64
    qgemm_fn = lambda a, b: (kernels.gemm_i8(a, b),)  # noqa: E731
    qgemm_args = [_spec((m, kk), "u8"), _spec((kk, nn), "i8")]
    qgemm_meta = [
        {"name": "a", "shape": [m, kk], "dtype": "u8"},
        {"name": "b", "shape": [kk, nn], "dtype": "i8"},
    ]
    qgemm_outs = [{"name": "c", "shape": [m, nn], "dtype": "i32"}]
    return [
        ("qgemv", qgemv_fn, qgemv_args, qgemv_meta, qgemv_outs),
        ("qgemm", qgemm_fn, qgemm_args, qgemm_meta, qgemm_outs),
    ]


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "quant": {"scheme": "q4_0", "qk": 32}, "artifacts": {}}

    for cfg_name, cfg in (("tiny", TINY), ("micro", MICRO)):
        for which in ("decode", "prefill"):
            fn, specs, arg_meta, outs = _model_entry(cfg, which)
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{cfg_name}_{which}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"][f"{cfg_name}_{which}"] = {
                "file": fname,
                "params": arg_meta,
                "outputs": outs,
                "model": {
                    "vocab": cfg.vocab,
                    "d_model": cfg.d_model,
                    "n_layers": cfg.n_layers,
                    "n_heads": cfg.n_heads,
                    "d_ff": cfg.d_ff,
                    "t_max": cfg.t_max,
                    "prefill_len": cfg.prefill_len,
                    "rope_theta": cfg.rope_theta,
                    "rms_eps": cfg.rms_eps,
                },
            }
            print(f"wrote {fname}: {len(text)} chars, {len(arg_meta)} params")

    for name, fn, specs, arg_meta, outs in _kernel_entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {"file": fname, "params": arg_meta, "outputs": outs}
        print(f"wrote {fname}: {len(text)} chars")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
