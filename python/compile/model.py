"""L2: llama-style transformer forward (prefill + decode) on Q4_0 weights.

This is the compute graph the Rust engine executes through PJRT; every
matmul goes through the L1 Pallas kernels (``kernels.qmatmul``) and decode
attention goes through ``kernels.attn_decode``. A ``use_pallas=False`` twin
path uses the pure-jnp oracles so tests can assert the two agree.

Weights are *parameters* of the lowered HLO (not baked constants): the Rust
side quantizes its own deterministic weights and feeds identical
``(qs, scales)`` tensors to both its native kernels and the PJRT artifact,
which makes the native-vs-PJRT logits parity test meaningful.

KV cache layout: ``[n_layers, n_heads, t_max, head_dim]`` f32, functional
in/out (the caller threads it between steps).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref

QK = 32
NEG_INF = jnp.float32(-1e9)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static llama-style architecture description."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 704
    t_max: int = 64
    prefill_len: int = 16
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0
        assert self.head_dim % 2 == 0, "RoPE needs an even head_dim"
        for dim in (self.d_model, self.d_ff, self.vocab):
            assert dim % 64 == 0, f"dim {dim} must tile by block_n=64"
        assert self.d_model % QK == 0 and self.d_ff % QK == 0


TINY = ModelConfig()
MICRO = ModelConfig(vocab=128, d_model=64, n_layers=2, n_heads=2, d_ff=128, t_max=32, prefill_len=8)


def param_order(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """The canonical flat parameter list: (name, shape, dtype) in order.

    This order is the ABI between ``aot.py`` (manifest), the Rust runtime
    (literal marshalling) and ``flatten_params`` below.
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def q4(name: str, n: int, k: int):
        return [
            (f"{name}.qs", (n, k), "i8"),
            (f"{name}.sc", (n, k // QK), "f32"),
        ]

    out: List[Tuple[str, Tuple[int, ...], str]] = [("embed", (v, d), "f32")]
    for i in range(cfg.n_layers):
        out.append((f"l{i}.attn_norm", (d,), "f32"))
        for w in ("wq", "wk", "wv", "wo"):
            out += q4(f"l{i}.{w}", d, d)
        out.append((f"l{i}.ffn_norm", (d,), "f32"))
        out += q4(f"l{i}.w1", f, d)
        out += q4(f"l{i}.w3", f, d)
        out += q4(f"l{i}.w2", d, f)
    out.append(("final_norm", (d,), "f32"))
    out += q4("lm_head", v, d)
    return out


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[name] for name, _, _ in param_order(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    order = param_order(cfg)
    if len(flat) != len(order):
        raise ValueError(f"expected {len(order)} params, got {len(flat)}")
    return {name: arr for (name, _, _), arr in zip(order, flat)}


# ---------------------------------------------------------------------------
# building blocks (kernel / oracle switchable)
# ---------------------------------------------------------------------------


def _qmm(p, name: str, x2d, use_pallas: bool):
    """x2d [S, K] × Q4_0 weight ``name`` → [S, N]."""
    qs, sc = p[f"{name}.qs"], p[f"{name}.sc"]
    if use_pallas:
        return kernels.qmatmul(qs, sc, x2d)
    return ref.ref_qmatmul(qs, sc, x2d)


def _rmsnorm(x, w, eps):
    return ref.ref_rmsnorm(x, w, eps)  # elementwise; XLA fuses it


def _attention_decode(cfg: ModelConfig, q, k_cache, v_cache, pos, use_pallas: bool):
    """q [H, Dh], caches [H, T, Dh], pos scalar → [H, Dh]."""
    t = cfg.t_max
    mask = jnp.where(jnp.arange(t) <= pos, jnp.float32(0), NEG_INF)
    if use_pallas:
        return kernels.attn_decode(q, k_cache, v_cache, mask)
    return ref.ref_attn_decode(q, k_cache, v_cache, mask)


def _layer_decode(cfg, p, i, x, kv_k, kv_v, pos, use_pallas):
    """One transformer layer, single token. x [D] → [D]; caches updated."""
    h, dh, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    xa = _rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
    x2 = xa[None, :]
    q = _qmm(p, f"l{i}.wq", x2, use_pallas)[0].reshape(h, dh)
    k = _qmm(p, f"l{i}.wk", x2, use_pallas)[0].reshape(h, dh)
    v = _qmm(p, f"l{i}.wv", x2, use_pallas)[0].reshape(h, dh)
    q = ref.ref_rope(q, pos, cfg.rope_theta)
    k = ref.ref_rope(k, pos, cfg.rope_theta)
    # write k, v at position `pos` of layer i's cache
    k_l = jax.lax.dynamic_update_slice(kv_k[i], k[:, None, :], (0, pos, 0))
    v_l = jax.lax.dynamic_update_slice(kv_v[i], v[:, None, :], (0, pos, 0))
    kv_k = kv_k.at[i].set(k_l)
    kv_v = kv_v.at[i].set(v_l)
    attn = _attention_decode(cfg, q, k_l, v_l, pos, use_pallas).reshape(d)
    x = x + _qmm(p, f"l{i}.wo", attn[None, :], use_pallas)[0]
    xf = _rmsnorm(x, p[f"l{i}.ffn_norm"], cfg.rms_eps)
    gate = _qmm(p, f"l{i}.w1", xf[None, :], use_pallas)[0]
    up = _qmm(p, f"l{i}.w3", xf[None, :], use_pallas)[0]
    x = x + _qmm(p, f"l{i}.w2", ref.ref_silu_mul(gate, up)[None, :], use_pallas)[0]
    return x, kv_k, kv_v


def decode_step(cfg: ModelConfig, params, token, pos, kv_k, kv_v, use_pallas: bool = True):
    """One autoregressive step.

    token, pos: i32 scalars; kv_*: f32 [L, H, T, Dh].
    Returns (logits [V], kv_k, kv_v).
    """
    x = jnp.take(params["embed"], token, axis=0)
    for i in range(cfg.n_layers):
        x, kv_k, kv_v = _layer_decode(cfg, params, i, x, kv_k, kv_v, pos, use_pallas)
    x = _rmsnorm(x, params["final_norm"], cfg.rms_eps)
    logits = _qmm(params, "lm_head", x[None, :], use_pallas)[0]
    return logits, kv_k, kv_v


def _layer_prefill(cfg, p, i, xs, kv_k, kv_v, pos0, use_pallas):
    """One layer over a chunk of S tokens. xs [S, D]."""
    s = xs.shape[0]
    h, dh, d, t = cfg.n_heads, cfg.head_dim, cfg.d_model, cfg.t_max
    positions = pos0 + jnp.arange(s)
    xa = _rmsnorm(xs, p[f"l{i}.attn_norm"], cfg.rms_eps)
    q = _qmm(p, f"l{i}.wq", xa, use_pallas).reshape(s, h, dh)
    k = _qmm(p, f"l{i}.wk", xa, use_pallas).reshape(s, h, dh)
    v = _qmm(p, f"l{i}.wv", xa, use_pallas).reshape(s, h, dh)
    q = ref.ref_rope(q, positions, cfg.rope_theta)
    k = ref.ref_rope(k, positions, cfg.rope_theta)
    k_l = jax.lax.dynamic_update_slice(kv_k[i], k.transpose(1, 0, 2), (0, pos0, 0))
    v_l = jax.lax.dynamic_update_slice(kv_v[i], v.transpose(1, 0, 2), (0, pos0, 0))
    kv_k = kv_k.at[i].set(k_l)
    kv_v = kv_v.at[i].set(v_l)
    # causal attention over the cache: row s may attend to t <= pos0 + s
    scores = jnp.einsum("shd,htd->hst", q, k_l) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.where(
        jnp.arange(t)[None, :] <= positions[:, None], jnp.float32(0), NEG_INF
    )  # [S, T]
    p_attn = ref.ref_softmax(scores + mask[None, :, :], axis=-1)
    attn = jnp.einsum("hst,htd->shd", p_attn, v_l).reshape(s, d)
    xs = xs + _qmm(p, f"l{i}.wo", attn, use_pallas)
    xf = _rmsnorm(xs, p[f"l{i}.ffn_norm"], cfg.rms_eps)
    gate = _qmm(p, f"l{i}.w1", xf, use_pallas)
    up = _qmm(p, f"l{i}.w3", xf, use_pallas)
    xs = xs + _qmm(p, f"l{i}.w2", ref.ref_silu_mul(gate, up), use_pallas)
    return xs, kv_k, kv_v


def prefill_chunk(cfg: ModelConfig, params, tokens, pos0, kv_k, kv_v, use_pallas: bool = True):
    """Process a fixed-size chunk of ``prefill_len`` tokens starting at pos0.

    tokens: i32 [S]; returns (logits of the last token [V], kv_k, kv_v).
    """
    xs = jnp.take(params["embed"], tokens, axis=0)
    for i in range(cfg.n_layers):
        xs, kv_k, kv_v = _layer_prefill(cfg, params, i, xs, kv_k, kv_v, pos0, use_pallas)
    x = _rmsnorm(xs[-1], params["final_norm"], cfg.rms_eps)
    logits = _qmm(params, "lm_head", x[None, :], use_pallas)[0]
    return logits, kv_k, kv_v


def init_kv(cfg: ModelConfig):
    shape = (cfg.n_layers, cfg.n_heads, cfg.t_max, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def make_decode_fn(cfg: ModelConfig, use_pallas: bool = True):
    """Flat-signature decode step for AOT lowering."""

    def fn(token, pos, kv_k, kv_v, *flat):
        params = unflatten_params(cfg, flat)
        return decode_step(cfg, params, token, pos, kv_k, kv_v, use_pallas)

    return fn


def make_prefill_fn(cfg: ModelConfig, use_pallas: bool = True):
    """Flat-signature prefill chunk for AOT lowering."""

    def fn(tokens, pos0, kv_k, kv_v, *flat):
        params = unflatten_params(cfg, flat)
        return prefill_chunk(cfg, params, tokens, pos0, kv_k, kv_v, use_pallas)

    return fn
