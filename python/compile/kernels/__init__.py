"""Pallas kernels (L1) + pure-jnp oracles. See DESIGN.md §Hardware-Adaptation."""

from .attn import attn_decode
from .gemm_i8 import gemm_i8
from .qmatmul import qgemv, qgemv_int, qmatmul

__all__ = ["attn_decode", "gemm_i8", "qgemv", "qgemv_int", "qmatmul"]
