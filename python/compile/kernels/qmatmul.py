"""L1 Pallas kernel: fused Q4_0-dequant matmul (decode & prefill hot path).

This is the TPU-minded formulation of the paper's GEMV/GEMM hot spot: the
parallel dimension that the L3 scheduler splits across heterogeneous cores
(rows of the weight matrix, ``N``) becomes the Pallas **grid** dimension;
each grid step dequantizes one ``(block_n, K)`` weight slab in VMEM and
contracts it against the activations. ``interpret=True`` is mandatory on the
CPU PJRT plugin (real TPU lowering emits a Mosaic custom-call).

VMEM budget per grid step (defaults, K = 4096, block_n = 64):
    qs slab   64 × 4096 × 1 B   = 256 KiB
    scales    64 × 128 × 4 B    =  32 KiB
    x         S × 4096 × 4 B    =  16 KiB (S = 1)
    out       S × 64 × 4 B      ≈   0.25 KiB
    total ≈ 0.3 MiB  → fits a ~16 MiB VMEM with deep double-buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QK = 32


def _qmatmul_kernel(x_ref, qs_ref, sc_ref, o_ref, *, block_n: int, k: int):
    """One grid step: o[:, i·bn:(i+1)·bn] = x @ dequant(qs, sc).T."""
    nb = k // QK
    codes = qs_ref[...].astype(jnp.float32) - 8.0  # [bn, K]
    w = codes.reshape(block_n, nb, QK) * sc_ref[...][:, :, None]
    o_ref[...] = x_ref[...] @ w.reshape(block_n, k).T


def qmatmul(qs, scales, x, *, block_n: int = 64):
    """Fused dequant matmul: ``x [S, K] · dequant(qs, scales).T → [S, N]``.

    qs: int8 [N, K] codes in [0, 15]; scales: f32 [N, K // QK].
    ``N`` must be a multiple of ``block_n``.
    """
    n, k = qs.shape
    s = x.shape[0]
    if n % block_n != 0:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")
    if x.shape[1] != k:
        raise ValueError(f"x K={x.shape[1]} != weight K={k}")
    grid = (n // block_n,)
    kernel = functools.partial(_qmatmul_kernel, block_n=block_n, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, k), lambda i: (0, 0)),          # x: whole
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),     # qs slab
            pl.BlockSpec((block_n, k // QK), lambda i: (i, 0)),  # scales slab
        ],
        out_specs=pl.BlockSpec((s, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=True,
    )(x, qs, scales)


def qgemv(qs, scales, x, *, block_n: int = 64):
    """GEMV wrapper: ``x [K] → [N]`` (the decode-phase hot path)."""
    return qmatmul(qs, scales, x[None, :], block_n=block_n)[0]


def _qgemv_int_kernel(xq_ref, xs_ref, qs_ref, sc_ref, o_ref, *, block_n: int, k: int):
    """Integer-dot variant: per-block i32 dot, scaled by d_w · d_x."""
    nb = k // QK
    wq = qs_ref[...].astype(jnp.int32).reshape(block_n, nb, QK) - 8
    xb = xq_ref[...].astype(jnp.int32).reshape(nb, QK)
    # Per-block integer dot (the VNNI vpdpbusd analog), then scale combine.
    bsum = (wq * xb[None, :, :]).sum(axis=-1).astype(jnp.float32)  # [bn, nb]
    o_ref[...] = (bsum * sc_ref[...]).sum(axis=-1) * xs_ref[0]


def qgemv_int(qs, scales, xq, xscale, *, block_n: int = 64):
    """Q8-activation × Q4_0-weight integer GEMV (paper's VNNI decode kernel).

    xq: int8 [K]; xscale: f32 scalar array shape (1,);
    qs: int8 [N, K]; scales: f32 [N, K // QK]. Returns f32 [N].
    """
    n, k = qs.shape
    if n % block_n != 0:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")
    grid = (n // block_n,)
    kernel = functools.partial(_qgemv_int_kernel, block_n=block_n, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k // QK), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(xq, xscale, qs, scales)
