"""L1 Pallas kernel: u8 × i8 → i32 blocked GEMM (prefill hot path).

The AVX-VNNI ``vpdpbusd`` micro-kernel analog, re-thought for the MXU: the
grid tiles ``(M, N)`` into ``(block_m, block_n)`` output tiles with the full
``K`` reduction resident per step — the int8 operands are small enough that
a (128, 4096) u8 A-slab plus a (4096, 128) i8 B-slab is ≈ 1 MiB of VMEM,
i.e. the HBM↔VMEM schedule the paper expressed with threads is expressed
here with BlockSpecs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_i8_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def gemm_i8(a, b, *, block_m: int = 64, block_n: int = 64):
    """``a u8 [M, K] · b i8 [K, N] → i32 [M, N]``.

    M and N must be multiples of the block sizes (K is kept whole per tile).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"K mismatch: {k} vs {k2}")
    if m % block_m != 0 or n % block_n != 0:
        raise ValueError(f"M={m}, N={n} must tile by ({block_m}, {block_n})")
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_gemm_i8_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)
