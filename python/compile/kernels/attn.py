"""L1 Pallas kernel: single-query (decode) attention, one head per grid step.

q [H, Dh] · K-cache [H, T, Dh] → masked softmax → · V-cache [H, T, Dh].
The additive mask (0 attendable / −1e9 future) is computed by the caller
(L2 model), so the kernel stays shape-static and position-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_decode_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, dh: int):
    q = q_ref[0, :]                        # [Dh]
    k = k_ref[0]                           # [T, Dh]
    v = v_ref[0]                           # [T, Dh]
    scores = k @ q / jnp.sqrt(jnp.float32(dh)) + m_ref[...]  # [T]
    mx = scores.max()
    p = jnp.exp(scores - mx)
    p = p / p.sum()
    o_ref[0, :] = p @ v


def attn_decode(q, k, v, mask):
    """q: f32 [H, Dh]; k, v: f32 [H, T, Dh]; mask: f32 [T] → f32 [H, Dh]."""
    h, dh = q.shape
    t = k.shape[1]
    return pl.pallas_call(
        functools.partial(_attn_decode_kernel, dh=dh),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        interpret=True,
    )(q, k, v, mask)
