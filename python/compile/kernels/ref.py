"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

Each function here is the mathematically transparent version of a kernel in
this package; pytest asserts ``assert_allclose(kernel(...), ref(...))`` over
hypothesis-generated shapes. Nothing here is ever lowered into artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

QK = 32


def ref_qmatmul(qs, scales, x):
    """Fused Q4_0 dequant matmul oracle.

    qs:      int8  [N, K]   codes in [0, 15]
    scales:  f32   [N, K/QK]
    x:       f32   [S, K]  (or [K] for GEMV)
    returns  f32   [S, N]  (or [N])
    """
    n, k = qs.shape
    w = (qs.astype(jnp.float32) - 8.0).reshape(n, k // QK, QK)
    w = (w * scales[..., None]).reshape(n, k)
    return x @ w.T


def ref_qgemv(qs, scales, x):
    """GEMV special case of :func:`ref_qmatmul` (x is rank-1)."""
    return ref_qmatmul(qs, scales, x)


def ref_gemm_i8(a, b):
    """u8 × i8 → i32 GEMM oracle (the AVX-VNNI analog).

    a: uint8 [M, K], b: int8 [K, N] → int32 [M, N].
    """
    return jnp.matmul(
        a.astype(jnp.int32), b.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def ref_gemv_q8q4(xq, xscale, qs, scales):
    """Integer-dot Q8-activation × Q4_0-weight GEMV oracle.

    xq: int8 [K] (dynamic-quantized activation), xscale: f32 scalar,
    qs/scales: Q4_0 weight. Per-block integer dot scaled by (d_w * d_x):
        y[n] = sum_b d[n,b] * xscale * sum_i (qs[n,b,i]-8) * xq[b,i]
    """
    n, k = qs.shape
    wq = qs.astype(jnp.int32).reshape(n, k // QK, QK) - 8
    xb = xq.astype(jnp.int32).reshape(k // QK, QK)
    dots = jnp.einsum("nbk,bk->nb", wq, xb).astype(jnp.float32)
    return (dots * scales).sum(axis=-1) * xscale


def ref_attn_decode(q, k, v, mask):
    """Single-token decode attention oracle.

    q: f32 [H, Dh]; k, v: f32 [H, T, Dh]; mask: f32 [T] (0 where attendable,
    a large negative value where masked). Returns f32 [H, Dh].
    """
    dh = q.shape[-1]
    scores = jnp.einsum("hd,htd->ht", q, k) / jnp.sqrt(jnp.float32(dh))
    scores = scores + mask[None, :]
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("ht,htd->hd", p, v)


def ref_rmsnorm(x, w, eps=1e-5):
    """RMSNorm oracle. x: f32 [..., D], w: f32 [D]."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * w


def ref_rope(x, pos, theta=10000.0):
    """Rotary embedding oracle on interleaved pairs.

    x: f32 [..., H, Dh] (Dh even); pos: int32 scalar (or [S] leading axis
    aligned with x's first axis). Pairs (x[2i], x[2i+1]) are rotated by
    angle ``pos / theta^(2i/Dh)``.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2.0 / dh))
    ang = jnp.asarray(pos, dtype=jnp.float32)[..., None] * freqs  # [..., half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x0 = x[..., 0::2]
    x1 = x[..., 1::2]
    # x is [..., H, Dh]; ang broadcasts over the head axis.
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    y0 = x0 * cos - x1 * sin
    y1 = x0 * sin + x1 * cos
    return jnp.stack([y0, y1], axis=-1).reshape(x.shape)


def ref_silu_mul(gate, up):
    """SwiGLU elementwise oracle: silu(gate) * up."""
    return gate / (1.0 + jnp.exp(-gate)) * up


def ref_softmax(x, axis=-1):
    m = x.max(axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / e.sum(axis=axis, keepdims=True)
