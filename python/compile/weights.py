"""Deterministic random weight construction for tests and demos.

Weights are f32, generated from a seeded ``np.random.Generator`` (PCG64),
then Q4_0-quantized via :mod:`compile.quant`. The Rust side has its own
generator; parity across languages is achieved by feeding the *quantized*
tensors through both paths, not by matching RNGs.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from . import quant
from .model import ModelConfig, param_order


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Generate the full flat param dict (quantized where the ABI says i8)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    d = cfg.d_model
    scale = 1.0 / np.sqrt(d)
    pending_f32: Dict[str, np.ndarray] = {}
    for name, shape, dtype in param_order(cfg):
        if name.endswith(".qs"):
            base = name[: -len(".qs")]
            w = (rng.standard_normal(shape, dtype=np.float32) * scale).astype(np.float32)
            qs, sc = quant.quantize_q4_0(w)
            out[name] = qs
            pending_f32[f"{base}.sc"] = sc
        elif name.endswith(".sc"):
            out[name] = pending_f32.pop(name)
        elif name.endswith("norm"):
            out[name] = np.ones(shape, dtype=np.float32)
        else:  # embed
            out[name] = (rng.standard_normal(shape, dtype=np.float32) * scale).astype(
                np.float32
            )
    assert not pending_f32
    return out
