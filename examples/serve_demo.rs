//! Serving demo: start the TCP front-end on an ephemeral port, fire a few
//! concurrent clients at it, and print the streamed responses + server
//! metrics.
//!
//! Run: `cargo run --release --example serve_demo`

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use dynpar::cpu::presets;
use dynpar::engine::Engine;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::server::{serve, ServerOpts};
use dynpar::sim::{SimConfig, SimExecutor};

fn main() {
    let cfg = ModelConfig::micro();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 7));
    let exec = SimExecutor::new(
        presets::ultra_125h(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    let engine =
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default());
    let opts = ServerOpts { max_batch: 4, ..Default::default() };
    let handle = serve("127.0.0.1:0", engine, opts).unwrap();
    println!("serving on {}\n", handle.addr);

    let addr = handle.addr;
    let clients: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                writeln!(
                    stream,
                    r#"{{"id": {i}, "prompt": [{}, {}, 3], "max_new_tokens": 6}}"#,
                    i + 1,
                    i + 2
                )
                .unwrap();
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let line = line.unwrap();
                    println!("client {i} ← {line}");
                    if line.contains("\"done\"") || line.contains("\"error\"") {
                        break;
                    }
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    // query server metrics
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, r#"{{"cmd":"metrics"}}"#).unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    println!("\nserver metrics: {}", line.trim());

    handle.shutdown();
    println!("server shut down cleanly");
}
