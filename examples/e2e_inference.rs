//! End-to-end driver (the DESIGN.md §End-to-end validation run):
//!
//! 1. builds a real tiny llama (4 layers, d=256, vocab 512), Q4_0-quantized;
//! 2. serves a batch of prompts through the **native engine** — every
//!    matmul scheduled by the paper's dynamic method on a simulated
//!    Ultra-125H (virtual time) while actually computing the numbers;
//! 3. runs the same requests through the **PJRT artifacts** (the JAX+Pallas
//!    L2/L1 path compiled by `make artifacts`) and asserts the generated
//!    tokens are identical — proving all three layers compose;
//! 4. reports prefill latency, decode tok/s and bandwidth utilization.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use std::sync::Arc;

use dynpar::cpu::presets;
use dynpar::engine::Engine;
use dynpar::metrics::PhaseMetrics;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::runtime::{artifacts::default_artifact_dir, Manifest, PjrtEngine};
use dynpar::sched::DynamicScheduler;
use dynpar::sim::{SimConfig, SimExecutor};

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::tiny();
    let weights = Arc::new(ModelWeights::random_init(&cfg, 0));
    println!(
        "model: tiny llama ({} layers, d={}, vocab={}), {:.1} KiB packed Q4_0 weights",
        cfg.n_layers,
        cfg.d_model,
        cfg.vocab,
        weights.packed_bytes() as f64 / 1024.0
    );

    // ---- native engine on simulated Ultra-125H ----
    let spec = presets::ultra_125h();
    let exec = SimExecutor::new(
        spec.clone(),
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
    );
    let mut engine = Engine::new(
        cfg.clone(),
        Arc::clone(&weights),
        exec,
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    );

    let requests: Vec<Vec<u32>> = vec![
        (1..17).collect(),                  // 16-token prompt
        vec![100, 200, 300, 400, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16],
        (20..36).collect(),
    ];
    let n_new = 12;

    println!("\n== native engine (scheduled, simulated ultra_125h, virtual time) ==");
    let mut native_outputs = Vec::new();
    let mut total = PhaseMetrics::default();
    for (i, prompt) in requests.iter().enumerate() {
        let mut session = engine.new_session();
        let (tokens, m) = engine.generate(&mut session, prompt, n_new);
        println!(
            "req {i}: prefill {:6.3} ms ({} tok) | decode {:5.3} ms/tok | {:5.1} tok/s | out {:?}",
            m.prefill_secs * 1e3,
            m.prompt_tokens,
            m.decode_latency() * 1e3,
            m.decode_tokens_per_sec(),
            &tokens[..4.min(tokens.len())],
        );
        total.merge(&m);
        native_outputs.push(tokens);
    }
    println!(
        "batch: {} prompt tok, {} decoded tok, mean decode {:.3} ms/tok (virtual)",
        total.prompt_tokens,
        total.decoded_tokens,
        total.decode_latency() * 1e3
    );

    // ---- the same requests through the PJRT artifacts ----
    println!("\n== PJRT artifact engine (jax+pallas AOT → xla/PJRT CPU) ==");
    let manifest = Manifest::load(default_artifact_dir())?;
    let mut pjrt = PjrtEngine::load(&manifest, "tiny", &weights)?;
    for (i, prompt) in requests.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let tokens = pjrt.generate(prompt, n_new)?;
        pjrt.reset()?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "req {i}: {:.1} ms wall | out {:?}",
            dt * 1e3,
            &tokens[..4.min(tokens.len())]
        );
        assert_eq!(
            tokens, native_outputs[i],
            "req {i}: PJRT and native engines disagree"
        );
    }
    println!("\n[parity] all {} requests: native and PJRT tokens identical ✓", requests.len());
    println!("(three layers composed: Pallas kernels → JAX model → Rust coordinator)");
    Ok(())
}
