//! Scheduler × CPU sweep: how much each dispatch policy recovers of the
//! hybrid CPU's theoretical throughput, for a compute-bound GEMM and a
//! memory-bound GEMV (the two regimes of the paper's evaluation), plus a
//! homogeneous-CPU control where dynamic ≡ static.
//!
//! Run: `cargo run --release --example hybrid_sweep`

use dynpar::bench_harness::{report::Table, sim_runtime};
use dynpar::cpu::{presets, Isa};
use dynpar::exec::PhantomWork;
use dynpar::kernels::cost;
use dynpar::perf::PerfConfig;
use dynpar::sim::SimConfig;

fn main() {
    let cpus = ["core_12900k", "ultra_125h", "homogeneous_16"];
    let scheds = ["static", "workstealing", "guided", "dynamic"];

    for (label, work) in [
        ("compute-bound: INT8 GEMM 1024x4096x4096", cost::gemm_i8_cost(1024, 4096, 4096)),
        ("memory-bound: INT4 GEMV 1x4096x4096", cost::gemv_q4_cost(4096, 4096)),
    ] {
        println!("\n== {label} ==");
        let mut t = Table::new(&["cpu", "scheduler", "latency", "efficiency_vs_ideal"]);
        for cpu in cpus {
            let spec = presets::preset_by_name(cpu).unwrap();
            // ideal: all compute rates summed (compute) or full bus (memory)
            let ideal_secs = if work.intensity() > 50.0 {
                work.total_ops() / spec.total_compute_rate(Isa::AvxVnni)
            } else {
                work.total_bytes() / (spec.bus_bw_gbps * 1e9)
            };
            for sched in scheds {
                let mut rt =
                    sim_runtime(spec.clone(), sched, SimConfig::noiseless(), PerfConfig::default());
                let w = PhantomWork::new(work);
                let mut wall = 0.0;
                for _ in 0..15 {
                    wall = rt.run(&w).wall_secs;
                }
                t.row(vec![
                    cpu.to_string(),
                    sched.to_string(),
                    format!("{:.1} µs", wall * 1e6),
                    format!("{:.1}%", ideal_secs / wall * 100.0),
                ]);
            }
        }
        print!("{}", t.render());
    }
    println!("\nOn the homogeneous control the dynamic method matches static (no");
    println!("imbalance to exploit) — the gains are specific to hybrid CPUs,");
    println!("which is exactly the paper's claim.");
}
