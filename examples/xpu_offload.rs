//! Extension demo (paper §4 future work): dispatch the prefill GEMM
//! across hybrid compute units — CPU cores + NPU + iGPU — with the same
//! ratio-learning split applied at device granularity.
//!
//! Run: `cargo run --release --example xpu_offload`

use dynpar::cpu::{presets, Isa};
use dynpar::kernels::{cost, KernelClass};
use dynpar::sim::xpu::{AcceleratorSpec, XpuSim};
use dynpar::sim::SimConfig;

fn main() {
    let spec = presets::ultra_125h();
    let cpu_ratios = spec.ideal_ratios(Isa::AvxVnni);
    let mut x = XpuSim::new(
        spec,
        SimConfig::noiseless(),
        vec![AcceleratorSpec::npu(), AcceleratorSpec::igpu()],
    );

    println!("prefill GEMM 1024x4096x4096 on ultra_125h + NPU + iGPU\n");
    let c = cost::gemm_i8_cost(1024, 4096, 4096);
    let cpu_only = x.cpu_only(&c, &cpu_ratios);
    println!("CPU-only (dynamic over cores): {:.2} ms", cpu_only * 1e3);

    println!("\niter  wall      cpu/npu/igpu units      device ratios (gemm_i8 row)");
    for i in 0..12 {
        let res = x.execute(&c, &cpu_ratios);
        let dr = x.device_ratios(KernelClass::GemmI8).to_vec();
        println!(
            "{i:>4}  {:>6.2} ms  {:>4}/{:>4}/{:>4}          [{:.2}, {:.2}, {:.2}]",
            res.wall_secs * 1e3,
            res.device_units[0],
            res.device_units[1],
            res.device_units[2],
            dr[0],
            dr[1],
            dr[2],
        );
    }
    let final_wall = x.execute(&c, &cpu_ratios).wall_secs;
    println!(
        "\nconverged hybrid-unit speedup vs CPU-only: x{:.2}",
        cpu_only / final_wall
    );

    // the memory-bound decode GEMV barely gains: same bus, no new bandwidth
    let g = cost::gemv_q4_cost(4096, 4096);
    let mut x2 = XpuSim::new(
        presets::ultra_125h(),
        SimConfig::noiseless(),
        vec![AcceleratorSpec::npu()],
    );
    let cpu_g = x2.cpu_only(&g, &cpu_ratios);
    let mut wall_g = f64::INFINITY;
    for _ in 0..15 {
        wall_g = x2.execute(&g, &cpu_ratios).wall_secs;
    }
    println!(
        "decode GEMV (memory-bound): x{:.2} — shared bus adds no bandwidth,\nwhich is why the paper targets the *prefill* phase with hybrid units.",
        cpu_g / wall_g
    );
}
