//! Multi-stream serving demo: the coordinator leases disjoint,
//! topology-aware core subsets to two concurrent decode streams, beats the
//! one-big-engine baseline on aggregate throughput, detects a background
//! load from measured per-core times and rebalances the leases around it,
//! shows continuous batching cutting time-to-first-token against the
//! run-to-completion baseline under scripted Poisson arrivals — and
//! finishes with a heterogeneous lease: one stream owning "2 P-cores + the
//! NPU" (`XpuAffinity::Floating`) out-running the best cores-only split.
//!
//! Run: `cargo run --release --example multi_stream`

use std::sync::Arc;

use dynpar::bench_harness::pr3::sustained_rate;
use dynpar::coordinator::{bus_share, AllocPolicy, Coordinator, Lease, XpuAffinity};
use dynpar::cpu::{presets, CoreKind, CpuSpec};
use dynpar::engine::phantom::{decode_invocations, PhantomSystem};
use dynpar::engine::Engine;
use dynpar::exec::{ParallelRuntime, PhantomWork};
use dynpar::kernels::cost;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::server::protocol::Request;
use dynpar::server::testing::{poisson_arrivals, run_single, AdmitMode, TraceEvent};
use dynpar::server::{BatcherOpts, LeaseBatcher};
use dynpar::sim::xpu::AcceleratorSpec;
use dynpar::sim::{NoiseConfig, SimConfig, SimExecutor};

fn lease_runtime(
    machine: &CpuSpec,
    lease: &Lease,
    degraded: &[usize],
) -> ParallelRuntime<SimExecutor> {
    let noise = NoiseConfig {
        sigma: 0.0,
        background: lease.background_for(degraded, 0.5),
        ..NoiseConfig::disabled()
    };
    ParallelRuntime::new(
        lease.sim_executor(machine, SimConfig { noise, ..SimConfig::noiseless() }),
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    )
}

fn lease_label(machine: &CpuSpec, lease: &Lease) -> String {
    let cores = lease.cores();
    let p = cores.iter().filter(|&&c| machine.cores[c].kind == CoreKind::Performance).count();
    let e = cores.iter().filter(|&&c| machine.cores[c].kind == CoreKind::Efficiency).count();
    let npu = if lease.accels().is_empty() { "" } else { " + NPU" };
    format!("stream {} → cores {cores:?} ({p}P+{e}E{npu})", lease.stream)
}

fn main() {
    let machine = presets::core_12900k();
    let cfg = ModelConfig::micro();
    let sys = PhantomSystem::neural_speed();
    let steps = 32;

    println!("machine: {} ({} cores)\n", machine.name, machine.n_cores());

    // ---- part 1: two concurrent decode streams vs one big engine ----
    let mut serial = ParallelRuntime::new(
        SimExecutor::new(machine.clone(), SimConfig::noiseless()),
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    );
    for _ in 0..2 {
        for step in 0..steps {
            for c in decode_invocations(&cfg, &sys, step) {
                serial.run(&PhantomWork::new(c));
            }
        }
    }
    let t_serial = serial.exec.sim.now;
    println!("one all-core engine, 2 streams serialized: {:.3} ms", t_serial * 1e3);

    let mut coord = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
    coord.admit(0);
    coord.admit(1);
    let leases: Vec<Lease> = coord.leases().cloned().collect();
    let mut walls = Vec::new();
    for lease in &leases {
        println!("  {}", lease_label(&machine, lease));
        let mut rt = lease_runtime(&machine, lease, &[]);
        for step in 0..steps {
            for c in decode_invocations(&cfg, &sys, step) {
                rt.run(&PhantomWork::new(c));
            }
        }
        walls.push(rt.exec.sim.now);
    }
    let t_coord = walls.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "coordinated leases, 2 streams concurrent:  {:.3} ms  → aggregate speedup x{:.2}\n",
        t_coord * 1e3,
        t_serial / t_coord
    );

    // ---- part 2: background load hits stream 0's P-cores; rebalance ----
    let probe = PhantomWork::new(cost::gemm_i8_cost(256, 1024, 1024));
    let degraded: Vec<usize> = leases[0]
        .cores()
        .into_iter()
        .filter(|&g| machine.cores[g].kind == CoreKind::Performance)
        .collect();
    println!("background process steals 50% of cores {degraded:?} (stream 0's P-cores)");

    let mut last = Vec::new();
    for lease in &leases {
        let mut rt = lease_runtime(&machine, lease, &degraded);
        let mut wall = 0.0;
        for _ in 0..12 {
            let res = rt.run(&probe);
            coord.observe(lease, &res);
            wall = res.wall_secs;
        }
        last.push(wall);
    }
    println!(
        "before rebalance: stream 0 kernel {:.1} µs, stream 1 kernel {:.1} µs (x{:.2} skew)",
        last[0] * 1e6,
        last[1] * 1e6,
        last[0] / last[1]
    );

    coord.rebalance();
    let new_leases: Vec<Lease> = coord.leases().cloned().collect();
    println!("rebalanced from measured per-core strength:");
    let mut post = Vec::new();
    for lease in &new_leases {
        println!("  {}", lease_label(&machine, lease));
        let mut rt = lease_runtime(&machine, lease, &degraded);
        let mut wall = 0.0;
        for _ in 0..12 {
            let res = rt.run(&probe);
            coord.observe(lease, &res);
            wall = res.wall_secs;
        }
        post.push(wall);
    }
    println!(
        "after rebalance:  stream 0 kernel {:.1} µs, stream 1 kernel {:.1} µs",
        post[0] * 1e6,
        post[1] * 1e6
    );
    let pre_max = last[0].max(last[1]);
    let post_max = post[0].max(post[1]);
    println!(
        "slowest stream improved x{:.2}; the degraded cores are now shared evenly,\nso no tenant is stuck behind the background load.",
        pre_max / post_max
    );

    // ---- part 3: continuous batching vs run-to-completion on one lease ----
    println!("\ncontinuous batching under scripted Poisson arrivals (virtual time):");
    let weights = Arc::new(ModelWeights::random_init(&cfg, 7));
    let engine = || {
        Engine::new(
            cfg.clone(),
            Arc::clone(&weights),
            SimExecutor::new(
                machine.clone(),
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            ),
            Box::new(DynamicScheduler),
            PerfConfig::default(),
        )
    };
    let arrivals = poisson_arrivals(93, 12, 8e-4);
    let script: Vec<TraceEvent> = arrivals
        .iter()
        .enumerate()
        .map(|(i, &at)| {
            TraceEvent::arrive(
                at,
                0,
                Request {
                    id: i as u64,
                    prompt: vec![1 + i as u32, 2, 3],
                    max_new_tokens: 12 + (i % 4) * 4,
                },
            )
        })
        .collect();
    let opts = BatcherOpts { max_batch: 4, prefill_chunk: 4 };
    let cont = run_single(
        LeaseBatcher::new(engine(), None, opts),
        AdmitMode::Continuous,
        64,
        script.clone(),
    );
    let rtc = run_single(
        LeaseBatcher::new(engine(), None, opts),
        AdmitMode::RunToCompletion,
        64,
        script,
    );
    println!(
        "  run-to-completion: mean TTFT {:7.1} µs  at {:6.0} tok/s",
        rtc.mean_ttft() * 1e6,
        rtc.throughput()
    );
    println!(
        "  continuous:        mean TTFT {:7.1} µs  at {:6.0} tok/s  (TTFT -{:.0}%, same throughput)",
        cont.mean_ttft() * 1e6,
        cont.throughput(),
        (1.0 - cont.mean_ttft() / rtc.mean_ttft()) * 100.0
    );

    // ---- part 4: heterogeneous leases — "2 P-cores + the NPU" ----
    // 4 P-cores of the 125H plus its NPU, two streams: under Floating
    // affinity one lease owns two cores and the device; the device-level
    // ratio table (same eq. 2 EWMA, one row per kernel class) learns how
    // to split each prefill GEMM between them.
    println!("\nheterogeneous leases: cores + NPU under one coordinator (ultra_125h):");
    let ultra = presets::ultra_125h();
    let p_cores = [0usize, 1, 2, 3];
    let mini = ultra.subset(&p_cores, bus_share(&ultra, &p_cores));
    let accels = vec![AcceleratorSpec::npu()];
    let mut hcoord = Coordinator::with_accelerators(
        mini.clone(),
        accels.clone(),
        AllocPolicy::Balanced,
        XpuAffinity::Floating,
    );
    hcoord.admit(0);
    hcoord.admit(1);
    let hleases: Vec<Lease> = hcoord.leases().cloned().collect();
    let probe4 = PhantomWork::new(cost::gemm_i8_cost(512, 2048, 2048));
    let mut hetero_rates = Vec::new();
    for lease in &hleases {
        let p = lease.n_cores();
        let npu = if lease.accels().is_empty() { "" } else { " + NPU" };
        let exec = lease.xpu_executor(&mini, &accels, SimConfig::noiseless());
        let (rate, _) = sustained_rate(exec, &probe4, 15);
        hetero_rates.push(rate);
        println!("  stream {} → {p} P-cores{npu}: prefill GEMM {rate:8.0} units/s", lease.stream);
    }
    let mut cores_rates = Vec::new();
    for lease in &hleases {
        let spec = mini.subset(&lease.cores(), bus_share(&mini, &lease.cores()));
        let exec = SimExecutor::new(spec, SimConfig::noiseless());
        cores_rates.push(sustained_rate(exec, &probe4, 15).0);
    }
    let hetero: f64 = hetero_rates.iter().sum();
    let cores: f64 = cores_rates.iter().sum();
    println!(
        "  aggregate: {hetero:.0} units/s with the NPU leased vs {cores:.0} for the best \
         cores-only split → x{:.2};\n  the accelerator is just another unit the coordinator \
         hands out, observes and rebalances.",
        hetero / cores
    );
}
