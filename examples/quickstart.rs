//! Quickstart: schedule one INT8 GEMM across a simulated hybrid CPU with
//! the paper's dynamic method and watch the ratio table converge.
//!
//! Run: `cargo run --release --example quickstart`

use dynpar::cpu::{presets, Isa};
use dynpar::exec::{ParallelRuntime, PhantomWork};
use dynpar::kernels::{cost, KernelClass};
use dynpar::perf::PerfConfig;
use dynpar::sched::DynamicScheduler;
use dynpar::sim::{SimConfig, SimExecutor};

fn main() {
    // 1. a hybrid CPU: Intel Core Ultra 7 125H (4 P + 8 E + 2 LP-E cores)
    let spec = presets::ultra_125h();
    println!("CPU: {} with {} cores", spec.name, spec.n_cores());

    // 2. the paper's loop: dynamic scheduler + per-core ratio table
    let mut rt = ParallelRuntime::new(
        SimExecutor::new(spec, SimConfig::noiseless()),
        Box::new(DynamicScheduler),
        PerfConfig::default(), // α = 0.3, ratios start at 1.0
    );

    // 3. the paper's Figure-2 GEMM: 1024×4096×4096 int8
    let work = PhantomWork::new(cost::gemm_i8_cost(1024, 4096, 4096));

    println!("\niter  latency      imbalance  P-core ratio");
    for i in 0..10 {
        let res = rt.run(&work);
        let ratio = rt
            .relative_ratios(KernelClass::GemmI8, Isa::AvxVnni)
            .map(|r| r[0])
            .unwrap_or(1.0);
        println!(
            "{i:>4}  {:>9.3} ms  {:>8.3}  {ratio:>6.2}",
            res.wall_secs * 1e3,
            res.imbalance()
        );
    }
    println!("\nThe first iteration splits evenly (ratios = 1), so the E-cores");
    println!("drag the wall time; after one measurement the table learns the");
    println!("~2.9× P:E ratio and every core finishes simultaneously.");
}
