//! Figure-4 reproduction as a runnable example: trace one P-core's
//! AVX-VNNI performance ratio through prefill → decode and render it as
//! ASCII art next to the paper's description.
//!
//! Run: `cargo run --release --example ratio_trace [-- --alpha 0.3]`

use dynpar::bench_harness::fig4;
use dynpar::util::argparse::Args;

fn main() {
    let args = Args::from_env();
    let p = fig4::Fig4Params {
        alpha: args.f64_or("alpha", 0.3),
        init_ratio: args.f64_or("init", 5.0),
        prompt_len: args.usize_or("prompt", 512),
        n_decode: args.usize_or("decode", 48),
        ..Default::default()
    };
    println!(
        "tracing P-core 0 on {} (alpha={}, init={}, prompt={}, decode={})\n",
        p.cpu, p.alpha, p.init_ratio, p.prompt_len, p.n_decode
    );
    let trace = fig4::run(&p);

    // vertical ASCII plot, ratio axis 0..5.5
    println!("ratio");
    for s in trace.samples.iter().step_by(4) {
        let col = (s.ratio * 10.0).round() as usize;
        let marker = if s.phase == "prefill" { '*' } else { 'o' };
        println!("{:>5.2} |{}{}", s.ratio, " ".repeat(col.min(60)), marker);
    }
    println!("        (*) prefill   (o) decode\n");
    println!(
        "prefill mean {:.2} — paper: \"stabilized between 3 and 3.5\"",
        trace.phase_mean("prefill").unwrap()
    );
    println!(
        "decode  mean {:.2} — paper: \"different bottlenecks, resulting in different ratios\"",
        trace.phase_mean("decode").unwrap()
    );
    let csv = "ratio_trace.csv";
    std::fs::write(csv, trace.to_csv()).unwrap();
    println!("\nfull series written to {csv}");
}
