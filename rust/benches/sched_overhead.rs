//! `cargo bench --bench sched_overhead` — host wall-clock cost of the
//! dynamic-parallel control loop itself: partition computation (eq. 3),
//! ratio update (eq. 2 + EWMA), and a full dispatch through the real
//! thread pool. The paper's method is only viable if this overhead is
//! negligible next to kernel time (target: < 2 µs for plan+update).

use dynpar::kernels::KernelClass;
use dynpar::cpu::Isa;
use dynpar::perf::{PerfConfig, PerfTable};
use dynpar::sched::{DynamicScheduler, Scheduler};
use dynpar::util::bench::{black_box, BenchOpts, BenchReport};

fn main() {
    let mut report = BenchReport::new("sched_overhead (host wall-clock)");
    let opts = BenchOpts { warmup_iters: 10, iters: 50 };

    // eq. 3 partition for 16 cores over 4096 rows
    let ratios: Vec<f64> = (0..16).map(|i| if i < 8 { 2.65 } else { 1.0 }).collect();
    let sched = DynamicScheduler;
    report.bench("partition_16c_4096rows_x1000", &opts, || {
        for _ in 0..1000 {
            black_box(sched.plan(black_box(4096), 1, black_box(&ratios)));
        }
    });

    // eq. 2 + EWMA update for 16 cores
    let mut table = PerfTable::new(16, PerfConfig::default());
    let times: Vec<Option<f64>> = (0..16).map(|i| Some(1.0 + i as f64 * 0.01)).collect();
    report.bench("ratio_update_16c_x1000", &opts, || {
        for _ in 0..1000 {
            table.update(KernelClass::GemvQ4, Isa::AvxVnni, black_box(&times));
        }
    });

    // full dispatch round-trip through the real pool (4 workers, no-op work)
    let mut pool = dynpar::pool::HostPool::new(4);
    let work = dynpar::exec::FnWork::new(
        dynpar::kernels::cost::elementwise_cost(1024, 1.0, 1.0),
        1,
        |_w, r| {
            black_box(r.len());
        },
    );
    let plan = sched.plan(4, 1, &[1.0; 4]);
    report.bench("pool_dispatch_roundtrip_4w", &opts, || {
        use dynpar::exec::Executor;
        black_box(pool.execute(&work, &plan));
    });

    println!("\nnote: partition+update are per-kernel costs; at ~1 µs they are");
    println!("<1% of even the 133 µs GEMV decode kernel (see fig2_gemv).");
}
