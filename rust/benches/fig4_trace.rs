//! `cargo bench --bench fig4_trace` — regenerates Figure 4: the relative
//! performance ratio of P-core 0 (AVX-VNNI) across prefill → decode on the
//! Ultra-125H, α = 0.3, stale initial ratio 5.

use dynpar::bench_harness::fig4;

fn main() {
    println!("=== fig4_trace: P-core AVX-VNNI ratio, ultra_125h, alpha=0.3, init=5 ===");
    let p = fig4::Fig4Params::default();
    let trace = fig4::run(&p);
    println!("phase      idx   ratio");
    for s in trace.samples.iter().step_by(8) {
        let bar = "#".repeat((s.ratio * 8.0) as usize);
        println!("{:<8} {:>5}   {:>5.2} {}", s.phase, s.kernel_idx, s.ratio, bar);
    }
    println!(
        "\nfirst sample: {:.2} (seeded at 5, adapting immediately)",
        trace.samples[0].ratio
    );
    println!(
        "prefill mean ratio: {:.2} (paper: stabilizes between 3 and 3.5)",
        trace.phase_mean("prefill").unwrap()
    );
    println!(
        "decode mean ratio:  {:.2} (paper: shifts to a different, lower level)",
        trace.phase_mean("decode").unwrap()
    );
    std::fs::write("fig4_trace.csv", trace.to_csv()).ok();
    println!("full trace written to fig4_trace.csv");
}
