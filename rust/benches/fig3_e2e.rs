//! `cargo bench --bench fig3_e2e` — regenerates Figure 3: llama2-7B Q4_0
//! end-to-end prefill/decode latency for llama.cpp, Neural Speed + OpenMP
//! and Neural Speed + dynamic on both hybrid CPUs (prompt 1024).

use dynpar::bench_harness::fig3;

fn main() {
    println!("=== fig3_e2e: llama2-7B Q4_0, prompt 1024, 32 decode tokens (virtual time) ===");
    let results = fig3::run(&["ultra_125h", "core_12900k"], 1024, 32, false);
    println!("{}", fig3::table(&results).render());
    for cpu in ["ultra_125h", "core_12900k"] {
        let ns = fig3::find(&results, cpu, "ns_openmp").unwrap();
        let dy = fig3::find(&results, cpu, "ns_dynamic").unwrap();
        let lc = fig3::find(&results, cpu, "llama.cpp").unwrap();
        println!(
            "{cpu}: prefill -{:.0}% vs NS-OpenMP (paper 20-30%), decode -{:.0}% (paper 9-22%), x{:.2} vs llama.cpp prefill",
            (1.0 - dy.metrics.prefill_secs / ns.metrics.prefill_secs) * 100.0,
            (1.0 - dy.metrics.decode_secs / ns.metrics.decode_secs) * 100.0,
            lc.metrics.prefill_secs / dy.metrics.prefill_secs,
        );
    }
}
