//! `cargo bench --bench kernels_host` — native kernel throughput on the
//! *host* CPU (wall clock, single core in this sandbox). These numbers
//! feed the §Perf roofline discussion in EXPERIMENTS.md.

use dynpar::kernels::{gemm_i8, gemv_q4};
use dynpar::quant::{quantize_q8_dynamic, MatQ4};
use dynpar::tensor::{MatI8, MatU8};
use dynpar::util::bench::{black_box, BenchOpts, BenchReport};
use dynpar::util::rng::Rng;

fn main() {
    let mut report = BenchReport::new("kernels_host (wall clock, host CPU)");
    let opts = BenchOpts { warmup_iters: 3, iters: 10 };
    let mut rng = Rng::new(1);

    // Q4_0 GEMV 4096x4096 — the decode hot path
    let (n, k) = (4096, 4096);
    let mut wdata = vec![0.0f32; n * k];
    rng.fill_normal_f32(&mut wdata, 1.0);
    let w = MatQ4::quantize(&wdata, n, k);
    let mut x = vec![0.0f32; k];
    rng.fill_normal_f32(&mut x, 1.0);
    let bytes = w.packed_bytes() as u64;

    let mut y = vec![0.0f32; n];
    let r = report.bench("gemv_q4_f32_4096x4096", &opts, || {
        gemv_q4::gemv_q4_f32_range(&w, &x, &mut y, 0..n);
        black_box(&y);
    });
    let f32_p50 = r.summary().p50;
    println!("  → streams {:.2} GB/s of packed weights", bytes as f64 / f32_p50 / 1e9);

    let xq = quantize_q8_dynamic(&x);
    let r = report.bench("gemv_q8q4_int_4096x4096", &opts, || {
        gemv_q4::gemv_q8q4_range(&w, &xq, &mut y, 0..n);
        black_box(&y);
    });
    println!("  → streams {:.2} GB/s of packed weights", bytes as f64 / r.summary().p50 / 1e9);

    // INT8 GEMM 256x1024x1024 (scaled-down prefill tile; full 1024³·4 is
    // too slow for a single sandbox core)
    let (m, kk, nn) = (256, 1024, 1024);
    let mut a = MatU8::zeros(m, kk);
    rng.fill_u8(&mut a.data, 0, 256);
    let mut bt = MatI8::zeros(nn, kk);
    rng.fill_i8(&mut bt.data, -127, 128);
    let mut c = vec![0i32; m * nn];
    let ops = (m * kk * nn) as f64;
    let r = report.bench("gemm_i8_256x1024x1024", &opts, || {
        gemm_i8::gemm_i8_range(&a, &bt, &mut c, nn, 0..m);
        black_box(&c);
    });
    println!("  → {:.2} Gmac/s", ops / r.summary().p50 / 1e9);

    // quantization itself
    let r = report.bench("quantize_q4_0_4096x4096", &opts, || {
        black_box(MatQ4::quantize(&wdata, n, k));
    });
    println!(
        "  → {:.2} GB/s of f32 input",
        (n * k * 4) as f64 / r.summary().p50 / 1e9
    );
}
