//! `cargo bench --bench fig2_gemv` — regenerates Figure 2-right:
//! INT4 GEMV 1×4096×4096 achieved bandwidth vs the MLC-like reference.

use dynpar::bench_harness::{fig2, FIG2_SCHEDULERS, PAPER_CPUS};
use dynpar::util::bench::BenchReport;

fn main() {
    let mut report = BenchReport::new("fig2_gemv: INT4 GEMV 1x4096x4096 (virtual time)");
    let results = fig2::run_gemv(&PAPER_CPUS, &FIG2_SCHEDULERS, 4096, 4096, 20, 30, false);
    for r in &results {
        report.record(
            &format!("{}/{}", r.cpu, r.scheduler),
            vec![r.latency.min, r.latency.p50, r.latency.max],
            Some((r.bandwidth_gbps * r.latency.p50 * 1e9) as u64),
            None,
        );
    }
    println!("\n{}", fig2::gemv_table(&results).render());
    for cpu in PAPER_CPUS {
        let d = results.iter().find(|r| r.cpu == cpu && r.scheduler == "dynamic").unwrap();
        println!(
            "{cpu}: dynamic achieves {:.1}% of MLC reference (paper: >90%)",
            d.bandwidth_utilization() * 100.0
        );
    }
}
