//! `cargo bench --bench fig2_gemm` — regenerates Figure 2-left:
//! INT8 GEMM 1024×4096×4096 latency for every scheduler on both hybrid
//! CPUs (simulated, virtual time; see DESIGN.md substitution table).

use dynpar::bench_harness::{fig2, FIG2_SCHEDULERS, PAPER_CPUS};
use dynpar::util::bench::BenchReport;

fn main() {
    let mut report = BenchReport::new("fig2_gemm: INT8 GEMM 1024x4096x4096 (virtual time)");
    let results = fig2::run_gemm(&PAPER_CPUS, &FIG2_SCHEDULERS, 1024, 4096, 4096, 20, 30, false);
    for r in &results {
        report.record(
            &format!("{}/{}", r.cpu, r.scheduler),
            vec![r.latency.min, r.latency.p50, r.latency.max],
            None,
            Some((r.gops * r.latency.p50 * 1e9) as u64),
        );
    }
    println!("\n{}", fig2::gemm_table(&results).render());
    for cpu in PAPER_CPUS {
        let sp = fig2::speedup_vs_static(&results, cpu, "dynamic").unwrap();
        println!(
            "{cpu}: dynamic vs static speedup x{sp:.2} (paper: x1.65 on 125H, x1.85 on 12900K)"
        );
    }
}
