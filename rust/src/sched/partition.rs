//! Proportional range partitioning with largest-remainder rounding.
//!
//! Implements eq. 3 of the paper: worker i receives
//! `s_i = round(pr_i / Σ pr · s)` units, where rounding happens in units
//! of `grain` and the largest-remainder method guarantees Σ s_i = s.

use std::ops::Range;

/// Reusable working buffers for the `_into` split variants: after warm-up
/// (first call at a given worker count) a split performs no allocations.
#[derive(Debug, Default)]
pub struct SplitScratch {
    counts: Vec<usize>,
    fracs: Vec<(f64, usize)>,
    flat: Vec<f64>,
}

impl SplitScratch {
    /// Borrow an all-ones weight vector of length `n` (grow-only buffer) —
    /// lets equal-share schedulers plan without allocating. The buffer is
    /// moved out and restored by the caller so it can coexist with a
    /// mutable borrow of the rest of the scratch.
    pub fn take_flat(&mut self, n: usize) -> Vec<f64> {
        self.flat.resize(n, 1.0);
        std::mem::take(&mut self.flat)
    }

    /// Return the buffer from [`SplitScratch::take_flat`].
    pub fn restore_flat(&mut self, flat: Vec<f64>) {
        self.flat = flat;
    }
}

/// Split `total` units into consecutive ranges proportional to `weights`,
/// aligned to `grain` (every boundary except the final `total` is a grain
/// multiple). Zero-weight workers receive empty ranges.
pub fn proportional_split(total: usize, grain: usize, weights: &[f64]) -> Vec<Range<usize>> {
    let mut out = Vec::with_capacity(weights.len());
    proportional_split_into(total, grain, weights, &mut SplitScratch::default(), &mut out);
    out
}

/// Allocation-free core of [`proportional_split`]: writes the ranges into
/// `out` (cleared first), using `scratch` for the remainder bookkeeping.
pub fn proportional_split_into(
    total: usize,
    grain: usize,
    weights: &[f64],
    scratch: &mut SplitScratch,
    out: &mut Vec<Range<usize>>,
) {
    assert!(!weights.is_empty(), "no workers");
    let grain = grain.max(1);
    // number of grain-units (the last one may be partial)
    let units = total.div_ceil(grain);
    largest_remainder_split_into(units, weights, scratch);
    out.clear();
    let mut cursor_units = 0usize;
    for &c in &scratch.counts {
        let start = (cursor_units * grain).min(total);
        let end = ((cursor_units + c) * grain).min(total);
        out.push(start..end);
        cursor_units += c;
    }
}

/// Allocate `units` integer slots proportionally to `weights` (largest-
/// remainder / Hamilton method). Guarantees the counts sum to `units`.
pub fn largest_remainder_split(units: usize, weights: &[f64]) -> Vec<usize> {
    let mut scratch = SplitScratch::default();
    largest_remainder_split_into(units, weights, &mut scratch);
    scratch.counts
}

/// Allocation-free core of [`largest_remainder_split`]: the result lands in
/// `scratch.counts`. Identical arithmetic and tie-breaking to the
/// allocating version (the sort comparator is a deterministic total order
/// over distinct indices, so `sort_unstable_by` yields the same order).
fn largest_remainder_split_into(units: usize, weights: &[f64], scratch: &mut SplitScratch) {
    let n = weights.len();
    let wsum: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    scratch.counts.clear();
    scratch.counts.resize(n, 0);
    scratch.fracs.clear();
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        // degenerate all-zero weights fall back to a flat split
        let exact = if wsum <= 0.0 {
            units as f64 / n as f64
        } else {
            units as f64 * w.max(0.0) / wsum
        };
        let floor = exact.floor() as usize;
        scratch.counts[i] = floor;
        assigned += floor;
        scratch.fracs.push((exact - floor as f64, i));
    }
    // distribute the remainder to the largest fractional parts;
    // ties break toward the lower index (deterministic)
    let mut rem = units - assigned;
    scratch
        .fracs
        .sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let mut k = 0;
    while rem > 0 {
        scratch.counts[scratch.fracs[k % n].1] += 1;
        rem -= 1;
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_proportions() {
        assert_eq!(largest_remainder_split(100, &[3.0, 1.0]), vec![75, 25]);
        assert_eq!(largest_remainder_split(10, &[1.0, 1.0]), vec![5, 5]);
    }

    #[test]
    fn remainder_goes_to_largest_fraction() {
        // 10 units over [1,1,1]: 3.33 each → 4,3,3 (first index wins the tie-ish)
        let c = largest_remainder_split(10, &[1.0, 1.0, 1.0]);
        assert_eq!(c.iter().sum::<usize>(), 10);
        assert!(c.iter().all(|&x| x == 3 || x == 4));
    }

    #[test]
    fn zero_weight_gets_zero() {
        let c = largest_remainder_split(10, &[1.0, 0.0]);
        assert_eq!(c, vec![10, 0]);
    }

    #[test]
    fn all_zero_weights_fall_back_to_flat() {
        let c = largest_remainder_split(9, &[0.0, 0.0, 0.0]);
        assert_eq!(c.iter().sum::<usize>(), 9);
    }

    #[test]
    fn split_covers_and_aligns() {
        let rs = proportional_split(100, 8, &[2.0, 1.0, 1.0]);
        assert_eq!(rs.len(), 3);
        let mut cursor = 0;
        for r in &rs {
            assert_eq!(r.start, cursor);
            assert!(r.start % 8 == 0 || r.start == 100);
            cursor = r.end;
        }
        assert_eq!(cursor, 100);
    }

    #[test]
    fn more_workers_than_units() {
        let rs = proportional_split(3, 1, &[1.0; 8]);
        let total: usize = rs.iter().map(|r| r.len()).sum();
        assert_eq!(total, 3);
        assert_eq!(rs.iter().filter(|r| !r.is_empty()).count(), 3);
    }

    #[test]
    fn single_worker_takes_all() {
        assert_eq!(proportional_split(42, 5, &[7.0]), vec![0..42]);
    }

    #[test]
    fn prop_partition_invariants() {
        prop::check("partition_invariants", |rng| {
            let n = 1 + rng.below(16) as usize;
            let total = rng.below(10_000) as usize;
            let grain = 1 + rng.below(64) as usize;
            let weights: Vec<f64> = (0..n).map(|_| rng.uniform(0.01, 10.0)).collect();
            let rs = proportional_split(total, grain, &weights);
            if rs.len() != n {
                return Err("wrong worker count".into());
            }
            let mut cursor = 0;
            for r in &rs {
                if r.start != cursor || r.end < r.start {
                    return Err(format!("bad ranges {rs:?}"));
                }
                if r.start % grain != 0 && r.start != total {
                    return Err(format!("unaligned start {rs:?} grain={grain}"));
                }
                cursor = r.end;
            }
            if cursor != total {
                return Err(format!("covers {cursor} of {total}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_in_weight() {
        // a strictly heavier worker never gets fewer units (same unit pool)
        prop::check("partition_monotone", |rng| {
            let n = 2 + rng.below(8) as usize;
            let units = 100 + rng.below(1000) as usize;
            let weights: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 5.0)).collect();
            let counts = largest_remainder_split(units, &weights);
            for i in 0..n {
                for j in 0..n {
                    if weights[i] > weights[j] && counts[i] + 1 < counts[j] {
                        return Err(format!(
                            "w[{i}]={} > w[{j}]={} but c[{i}]={} < c[{j}]={}",
                            weights[i], weights[j], counts[i], counts[j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
