//! The paper's **thread scheduler** (§2.2) and the baselines it is
//! evaluated against.
//!
//! A scheduler turns `(total units, grain, per-core ratios)` into a
//! [`DispatchPlan`]: either a *partition* (one contiguous range per core —
//! the paper's method and the OpenMP-static baseline) or a *chunk policy*
//! (OpenMP dynamic/guided work-stealing baselines, where cores claim
//! chunks at runtime).

pub mod partition;

use std::ops::Range;

pub use partition::{
    largest_remainder_split, proportional_split, proportional_split_into, SplitScratch,
};

/// How a kernel's parallel dimension is dispatched to cores.
#[derive(Clone, Debug, PartialEq)]
pub enum DispatchPlan {
    /// `ranges[i]` is core i's contiguous slice (possibly empty).
    Partitioned(Vec<Range<usize>>),
    /// cores repeatedly claim `chunk` units from a shared counter.
    Chunked { chunk: usize },
    /// OpenMP guided: claim `max(remaining / (2·n_workers), min_chunk)`.
    Guided { min_chunk: usize },
}

impl DispatchPlan {
    /// Units assigned per worker, if statically known.
    pub fn assigned_units(&self) -> Option<Vec<usize>> {
        match self {
            DispatchPlan::Partitioned(rs) => Some(rs.iter().map(|r| r.len()).collect()),
            _ => None,
        }
    }
}

/// A task scheduler (paper §2.2).
pub trait Scheduler: Send + Sync {
    fn name(&self) -> &'static str;

    /// Plan the dispatch of `total` units (aligned to `grain` where
    /// possible) over `ratios.len()` cores with the given performance
    /// ratios.
    fn plan(&self, total: usize, grain: usize, ratios: &[f64]) -> DispatchPlan;

    /// Allocation-free planning: write the plan into `out`, reusing its
    /// buffers and `scratch`. The default delegates to [`Scheduler::plan`]
    /// (allocating); the hot-path schedulers override it so steady-state
    /// token rounds plan without touching the heap.
    fn plan_into(
        &self,
        total: usize,
        grain: usize,
        ratios: &[f64],
        scratch: &mut SplitScratch,
        out: &mut DispatchPlan,
    ) {
        let _ = scratch;
        *out = self.plan(total, grain, ratios);
    }
}

/// Shared override body for the partitioning schedulers: reuse `out`'s
/// range vector when it is already a `Partitioned` plan.
fn plan_partitioned_into(
    total: usize,
    grain: usize,
    weights: &[f64],
    scratch: &mut SplitScratch,
    out: &mut DispatchPlan,
) {
    if !matches!(out, DispatchPlan::Partitioned(_)) {
        *out = DispatchPlan::Partitioned(Vec::new());
    }
    let DispatchPlan::Partitioned(ranges) = out else { unreachable!() };
    proportional_split_into(total, grain, weights, scratch, ranges);
}

/// The paper's dynamic proportional scheduler (eq. 3):
/// `s_i = pr_i / Σ pr · s`, rounded to grain multiples with the largest-
/// remainder method so that Σ s_i = s exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct DynamicScheduler;

impl Scheduler for DynamicScheduler {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn plan(&self, total: usize, grain: usize, ratios: &[f64]) -> DispatchPlan {
        DispatchPlan::Partitioned(proportional_split(total, grain, ratios))
    }

    fn plan_into(
        &self,
        total: usize,
        grain: usize,
        ratios: &[f64],
        scratch: &mut SplitScratch,
        out: &mut DispatchPlan,
    ) {
        plan_partitioned_into(total, grain, ratios, scratch, out);
    }
}

/// OpenMP `schedule(static)` analog: equal shares regardless of ratios.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticEven;

impl Scheduler for StaticEven {
    fn name(&self) -> &'static str {
        "static"
    }

    fn plan(&self, total: usize, grain: usize, ratios: &[f64]) -> DispatchPlan {
        let flat = vec![1.0; ratios.len()];
        DispatchPlan::Partitioned(proportional_split(total, grain, &flat))
    }

    fn plan_into(
        &self,
        total: usize,
        grain: usize,
        ratios: &[f64],
        scratch: &mut SplitScratch,
        out: &mut DispatchPlan,
    ) {
        let flat = scratch.take_flat(ratios.len());
        plan_partitioned_into(total, grain, &flat, scratch, out);
        scratch.restore_flat(flat);
    }
}

/// OpenMP `schedule(dynamic, chunk)` analog: fixed-size chunk stealing.
#[derive(Clone, Copy, Debug)]
pub struct WorkStealing {
    pub chunk: usize,
}

impl Default for WorkStealing {
    fn default() -> Self {
        WorkStealing { chunk: 16 }
    }
}

impl Scheduler for WorkStealing {
    fn name(&self) -> &'static str {
        "workstealing"
    }

    fn plan(&self, _total: usize, grain: usize, _ratios: &[f64]) -> DispatchPlan {
        DispatchPlan::Chunked { chunk: self.chunk.max(grain) }
    }
}

/// OpenMP `schedule(guided)` analog.
#[derive(Clone, Copy, Debug)]
pub struct GuidedSched {
    pub min_chunk: usize,
}

impl Default for GuidedSched {
    fn default() -> Self {
        GuidedSched { min_chunk: 8 }
    }
}

impl Scheduler for GuidedSched {
    fn name(&self) -> &'static str {
        "guided"
    }

    fn plan(&self, _total: usize, grain: usize, _ratios: &[f64]) -> DispatchPlan {
        DispatchPlan::Guided { min_chunk: self.min_chunk.max(grain) }
    }
}

/// Look up a scheduler by CLI name.
pub fn scheduler_by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name {
        "dynamic" => Some(Box::new(DynamicScheduler)),
        "static" => Some(Box::new(StaticEven)),
        "workstealing" | "ws" => Some(Box::new(WorkStealing::default())),
        "guided" => Some(Box::new(GuidedSched::default())),
        _ => None,
    }
}

pub const SCHEDULER_NAMES: [&str; 4] = ["dynamic", "static", "workstealing", "guided"];

#[cfg(test)]
mod tests {
    use super::*;

    fn check_partition(ranges: &[Range<usize>], total: usize) {
        // disjoint, consecutive, covering
        let mut cursor = 0;
        for r in ranges {
            assert_eq!(r.start, cursor, "non-consecutive: {ranges:?}");
            cursor = r.end;
        }
        assert_eq!(cursor, total, "doesn't cover: {ranges:?}");
    }

    #[test]
    fn dynamic_splits_proportionally() {
        let s = DynamicScheduler;
        let plan = s.plan(100, 1, &[3.0, 1.0]);
        match plan {
            DispatchPlan::Partitioned(rs) => {
                check_partition(&rs, 100);
                assert_eq!(rs[0].len(), 75);
                assert_eq!(rs[1].len(), 25);
            }
            _ => panic!("expected partition"),
        }
    }

    #[test]
    fn static_ignores_ratios() {
        let s = StaticEven;
        let plan = s.plan(64, 1, &[100.0, 1.0]);
        if let DispatchPlan::Partitioned(rs) = plan {
            assert_eq!(rs[0].len(), 32);
            assert_eq!(rs[1].len(), 32);
        } else {
            panic!()
        }
    }

    #[test]
    fn workstealing_and_guided_respect_grain() {
        let ws = WorkStealing { chunk: 3 };
        assert_eq!(ws.plan(100, 8, &[1.0; 4]), DispatchPlan::Chunked { chunk: 8 });
        let g = GuidedSched { min_chunk: 2 };
        assert_eq!(g.plan(100, 16, &[1.0; 4]), DispatchPlan::Guided { min_chunk: 16 });
    }

    #[test]
    fn by_name_roundtrip() {
        for name in SCHEDULER_NAMES {
            assert_eq!(scheduler_by_name(name).unwrap().name(), name);
        }
        assert!(scheduler_by_name("nope").is_none());
    }

    #[test]
    fn dynamic_grain_alignment() {
        let s = DynamicScheduler;
        if let DispatchPlan::Partitioned(rs) = s.plan(128, 32, &[2.0, 1.0, 1.0]) {
            check_partition(&rs, 128);
            for r in &rs {
                assert_eq!(r.start % 32, 0, "{rs:?}");
            }
        } else {
            panic!()
        }
    }

    #[test]
    fn plan_into_matches_plan_for_all_schedulers() {
        // the allocation-free path must be plan-for-plan identical to the
        // allocating one, including buffer reuse across differing shapes
        let mut scratch = SplitScratch::default();
        let ratios = [2.0, 1.0, 4.5, 1.0];
        for name in SCHEDULER_NAMES {
            let s = scheduler_by_name(name).unwrap();
            let mut out = DispatchPlan::Chunked { chunk: 1 };
            for total in [0usize, 7, 100, 4096] {
                s.plan_into(total, 8, &ratios, &mut scratch, &mut out);
                assert_eq!(out, s.plan(total, 8, &ratios), "{name} total={total}");
            }
        }
    }

    #[test]
    fn assigned_units_only_for_partitions() {
        assert!(DispatchPlan::Chunked { chunk: 4 }.assigned_units().is_none());
        let p = DispatchPlan::Partitioned(vec![0..3, 3..10]);
        assert_eq!(p.assigned_units().unwrap(), vec![3, 7]);
    }
}
