//! Deterministic random-init quantized weights.
//!
//! The same quantized tensors are used by the native engine (packed Q4_0
//! blocks) and, via [`crate::quant::MatQ4::unpack`], as PJRT artifact
//! parameters — which is what makes native-vs-PJRT logits comparable.

use super::config::ModelConfig;
use crate::quant::MatQ4;
use crate::tensor::MatF32;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub wq: MatQ4,
    pub wk: MatQ4,
    pub wv: MatQ4,
    pub wo: MatQ4,
    pub ffn_norm: Vec<f32>,
    /// gate projection [d_ff, d_model]
    pub w1: MatQ4,
    /// up projection [d_ff, d_model]
    pub w3: MatQ4,
    /// down projection [d_model, d_ff]
    pub w2: MatQ4,
}

#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub embed: MatF32,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: MatQ4,
}

fn rand_q4(rng: &mut Rng, rows: usize, cols: usize, sigma: f32) -> MatQ4 {
    let m = MatF32::randn(rows, cols, sigma, rng);
    MatQ4::quantize(&m.data, rows, cols)
}

impl ModelWeights {
    /// Deterministic init: N(0, 1/√d) matmuls, unit norms — the same
    /// distribution as `python/compile/weights.py` (values differ; the
    /// ABI is the *quantized tensors*, which Rust sends to PJRT itself).
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let sigma = 1.0 / (d as f32).sqrt();
        let embed = MatF32::randn(cfg.vocab, d, sigma, &mut rng);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: vec![1.0; d],
                wq: rand_q4(&mut rng, d, d, sigma),
                wk: rand_q4(&mut rng, d, d, sigma),
                wv: rand_q4(&mut rng, d, d, sigma),
                wo: rand_q4(&mut rng, d, d, sigma),
                ffn_norm: vec![1.0; d],
                w1: rand_q4(&mut rng, cfg.d_ff, d, sigma),
                w3: rand_q4(&mut rng, cfg.d_ff, d, sigma),
                w2: rand_q4(&mut rng, d, cfg.d_ff, sigma),
            })
            .collect();
        ModelWeights {
            embed,
            layers,
            final_norm: vec![1.0; d],
            lm_head: rand_q4(&mut rng, cfg.vocab, d, sigma),
        }
    }

    /// Total packed Q4_0 bytes (the decode-phase streaming footprint).
    pub fn packed_bytes(&self) -> usize {
        let mut total = self.lm_head.packed_bytes();
        for l in &self.layers {
            total += l.wq.packed_bytes()
                + l.wk.packed_bytes()
                + l.wv.packed_bytes()
                + l.wo.packed_bytes()
                + l.w1.packed_bytes()
                + l.w3.packed_bytes()
                + l.w2.packed_bytes();
        }
        total
    }

    /// Flat quantized tensors in the artifact parameter order
    /// (mirrors `python/compile/model.py::param_order`): for each matmul a
    /// `(codes, scales)` pair; norms and embed as f32.
    pub fn to_flat_params(&self, cfg: &ModelConfig) -> Vec<FlatParam> {
        let mut out = Vec::new();
        out.push(FlatParam::F32 {
            name: "embed".into(),
            shape: vec![cfg.vocab, cfg.d_model],
            data: self.embed.data.clone(),
        });
        for (i, l) in self.layers.iter().enumerate() {
            let d = vec![cfg.d_model];
            out.push(FlatParam::f32_vec(format!("l{i}.attn_norm"), d, &l.attn_norm));
            for (nm, m) in [("wq", &l.wq), ("wk", &l.wk), ("wv", &l.wv), ("wo", &l.wo)] {
                push_q4(&mut out, format!("l{i}.{nm}"), m);
            }
            out.push(FlatParam::f32_vec(format!("l{i}.ffn_norm"), vec![cfg.d_model], &l.ffn_norm));
            push_q4(&mut out, format!("l{i}.w1"), &l.w1);
            push_q4(&mut out, format!("l{i}.w3"), &l.w3);
            push_q4(&mut out, format!("l{i}.w2"), &l.w2);
        }
        out.push(FlatParam::f32_vec("final_norm".into(), vec![cfg.d_model], &self.final_norm));
        push_q4(&mut out, "lm_head".into(), &self.lm_head);
        out
    }
}

fn push_q4(out: &mut Vec<FlatParam>, name: String, m: &MatQ4) {
    let (codes, scales) = m.unpack();
    out.push(FlatParam::I8 {
        name: format!("{name}.qs"),
        shape: vec![m.rows, m.cols],
        data: codes,
    });
    out.push(FlatParam::F32 {
        name: format!("{name}.sc"),
        shape: vec![m.rows, m.cols / 32],
        data: scales,
    });
}

/// One flattened parameter in artifact ABI order.
#[derive(Clone, Debug)]
pub enum FlatParam {
    F32 { name: String, shape: Vec<usize>, data: Vec<f32> },
    I8 { name: String, shape: Vec<usize>, data: Vec<i8> },
}

impl FlatParam {
    fn f32_vec(name: String, shape: Vec<usize>, data: &[f32]) -> FlatParam {
        FlatParam::F32 { name, shape, data: data.to_vec() }
    }

    pub fn name(&self) -> &str {
        match self {
            FlatParam::F32 { name, .. } => name,
            FlatParam::I8 { name, .. } => name,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            FlatParam::F32 { shape, .. } => shape,
            FlatParam::I8 { shape, .. } => shape,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic() {
        let cfg = ModelConfig::micro();
        let a = ModelWeights::random_init(&cfg, 42);
        let b = ModelWeights::random_init(&cfg, 42);
        assert_eq!(a.embed.data, b.embed.data);
        assert_eq!(a.layers[0].wq.blocks, b.layers[0].wq.blocks);
        let c = ModelWeights::random_init(&cfg, 43);
        assert_ne!(a.embed.data, c.embed.data);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ModelConfig::micro();
        let w = ModelWeights::random_init(&cfg, 1);
        assert_eq!(w.layers.len(), cfg.n_layers);
        assert_eq!((w.embed.rows, w.embed.cols), (cfg.vocab, cfg.d_model));
        let l = &w.layers[0];
        assert_eq!((l.wq.rows, l.wq.cols), (cfg.d_model, cfg.d_model));
        assert_eq!((l.w1.rows, l.w1.cols), (cfg.d_ff, cfg.d_model));
        assert_eq!((l.w2.rows, l.w2.cols), (cfg.d_model, cfg.d_ff));
        assert_eq!((w.lm_head.rows, w.lm_head.cols), (cfg.vocab, cfg.d_model));
    }

    #[test]
    fn flat_param_order_matches_python_abi() {
        // python order: embed, per layer [attn_norm, wq.qs/sc, wk, wv, wo,
        // ffn_norm, w1, w3, w2], final_norm, lm_head
        let cfg = ModelConfig::micro();
        let w = ModelWeights::random_init(&cfg, 2);
        let flat = w.to_flat_params(&cfg);
        let names: Vec<&str> = flat.iter().map(|p| p.name()).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "l0.attn_norm");
        assert_eq!(names[2], "l0.wq.qs");
        assert_eq!(names[3], "l0.wq.sc");
        assert_eq!(*names.last().unwrap(), "lm_head.sc");
        // total count: 1 + L·(2 + 7·2) + 1 + 2
        assert_eq!(flat.len(), 1 + cfg.n_layers * 16 + 3);
    }

    #[test]
    fn packed_bytes_counts_all_matmuls() {
        let cfg = ModelConfig::micro();
        let w = ModelWeights::random_init(&cfg, 3);
        let d = cfg.d_model;
        let expect = (cfg.n_layers * (4 * d * d + 3 * cfg.d_ff * d) + cfg.vocab * d) / 32 * 18;
        assert_eq!(w.packed_bytes(), expect);
    }
}
