//! Model architecture configs — must stay in lock-step with
//! `python/compile/model.py` (the `tiny`/`micro` values are the artifact
//! ABI; `llama2_7b` drives the simulator-scale experiments).

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub t_max: usize,
    pub prefill_len: usize,
    pub rope_theta: f32,
    pub rms_eps: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// matches python `TINY`
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab: 512,
            d_model: 256,
            n_layers: 4,
            n_heads: 8,
            d_ff: 704,
            t_max: 64,
            prefill_len: 16,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        }
    }

    /// matches python `MICRO`
    pub fn micro() -> ModelConfig {
        ModelConfig {
            name: "micro".into(),
            vocab: 128,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_ff: 128,
            t_max: 32,
            prefill_len: 8,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        }
    }

    /// llama2-7B (the paper's evaluation model) — simulator-scale only.
    pub fn llama2_7b() -> ModelConfig {
        ModelConfig {
            name: "llama2_7b".into(),
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 11008,
            t_max: 2048,
            prefill_len: 1024,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(Self::tiny()),
            "micro" => Some(Self::micro()),
            "llama2_7b" | "7b" => Some(Self::llama2_7b()),
            _ => None,
        }
    }

    /// Parse the `model` block of an artifact manifest entry.
    pub fn from_manifest_json(name: &str, v: &Json) -> Result<ModelConfig, String> {
        let get = |k: &str| v.get(k).and_then(Json::as_usize).ok_or_else(|| format!("missing {k}"));
        Ok(ModelConfig {
            name: name.to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            t_max: get("t_max")?,
            prefill_len: get("prefill_len")?,
            rope_theta: v.get("rope_theta").and_then(Json::as_f64).unwrap_or(10000.0) as f32,
            rms_eps: v.get("rms_eps").and_then(Json::as_f64).unwrap_or(1e-5) as f32,
        })
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.d_model % self.n_heads != 0 {
            return Err("d_model must divide by n_heads".into());
        }
        if self.head_dim() % 2 != 0 {
            return Err("head_dim must be even for RoPE".into());
        }
        for (nm, v) in [("d_model", self.d_model), ("d_ff", self.d_ff), ("vocab", self.vocab)] {
            if v % 32 != 0 {
                return Err(format!("{nm} must be a multiple of QK=32"));
            }
        }
        Ok(())
    }

    /// Total Q4_0 weight bytes streamed per decoded token (the decode-
    /// phase memory traffic that bounds tokens/s).
    pub fn decode_weight_bytes(&self) -> usize {
        let per_weight_num = |n: usize, k: usize| n * k / 32 * 18; // 18 B / 32 weights
        let per_layer = 4 * per_weight_num(self.d_model, self.d_model)
            + 2 * per_weight_num(self.d_ff, self.d_model)
            + per_weight_num(self.d_model, self.d_ff);
        self.n_layers * per_layer + per_weight_num(self.vocab, self.d_model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_configs_validate() {
        for name in ["tiny", "micro", "llama2_7b"] {
            ModelConfig::by_name(name).unwrap().validate().unwrap();
        }
        assert!(ModelConfig::by_name("gpt5").is_none());
    }

    #[test]
    fn tiny_matches_python_abi() {
        let c = ModelConfig::tiny();
        assert_eq!((c.vocab, c.d_model, c.n_layers, c.n_heads, c.d_ff), (512, 256, 4, 8, 704));
        assert_eq!(c.head_dim(), 32);
        assert_eq!((c.t_max, c.prefill_len), (64, 16));
    }

    #[test]
    fn llama7b_decode_bytes_near_3_7_gb() {
        // the paper's 4-bit llama2-7B streams ~3.7 GB of weights per token
        let gb = ModelConfig::llama2_7b().decode_weight_bytes() as f64 / 1e9;
        assert!((3.5..4.0).contains(&gb), "gb={gb}");
    }

    #[test]
    fn manifest_roundtrip() {
        let j = Json::parse(
            r#"{"vocab":512,"d_model":256,"n_layers":4,"n_heads":8,"d_ff":704,
                "t_max":64,"prefill_len":16,"rope_theta":10000.0,"rms_eps":1e-5}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest_json("tiny", &j).unwrap();
        assert_eq!(c, ModelConfig::tiny());
    }

    #[test]
    fn invalid_config_rejected() {
        let mut c = ModelConfig::tiny();
        c.d_model = 100; // not multiple of 32
        assert!(c.validate().is_err());
    }
}
