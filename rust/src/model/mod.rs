//! Llama-style model: configuration, quantized weights, KV cache and the
//! *serial* reference forward pass (the scheduled/parallel forward lives in
//! [`crate::engine`]; this module is the ground truth it is tested against).

pub mod config;
pub mod weights;

pub use config::ModelConfig;
pub use weights::{LayerWeights, ModelWeights};

use crate::kernels::attention::KvLayer;
use crate::kernels::{elementwise, gemv_q4, rope};

/// Per-request generation state: one KV cache per layer plus the cursor.
#[derive(Clone, Debug)]
pub struct Session {
    pub kv: Vec<KvLayer>,
    pub pos: usize,
}

impl Session {
    pub fn new(cfg: &ModelConfig) -> Session {
        let kv = (0..cfg.n_layers)
            .map(|_| KvLayer::new(cfg.n_heads, cfg.t_max, cfg.head_dim()))
            .collect();
        Session { kv, pos: 0 }
    }

    pub fn remaining_capacity(&self, cfg: &ModelConfig) -> usize {
        cfg.t_max - self.pos
    }
}

/// Serial single-threaded decode step — the correctness oracle for the
/// scheduled engine and the PJRT artifact. Mirrors
/// `python/compile/model.py::decode_step` op for op.
pub fn decode_step_serial(
    cfg: &ModelConfig,
    w: &ModelWeights,
    session: &mut Session,
    token: u32,
) -> Vec<f32> {
    let d = cfg.d_model;
    let (h, dh) = (cfg.n_heads, cfg.head_dim());
    let pos = session.pos;
    assert!(pos < cfg.t_max, "KV cache exhausted");
    let mut x = w.embed.row(token as usize).to_vec();

    for (li, layer) in w.layers.iter().enumerate() {
        // attention block
        let mut xa = vec![0.0f32; d];
        elementwise::rmsnorm(&x, &layer.attn_norm, cfg.rms_eps, &mut xa);
        let mut q = gemv_q4::gemv_q4_f32(&layer.wq, &xa);
        let mut k = gemv_q4::gemv_q4_f32(&layer.wk, &xa);
        let v = gemv_q4::gemv_q4_f32(&layer.wv, &xa);
        rope::rope_heads(&mut q, h, dh, pos as i32, cfg.rope_theta);
        rope::rope_heads(&mut k, h, dh, pos as i32, cfg.rope_theta);
        let cache = &mut session.kv[li];
        for head in 0..h {
            cache.write(head, pos, &k[head * dh..(head + 1) * dh], &v[head * dh..(head + 1) * dh]);
        }
        let attn = crate::kernels::attention::attention_decode(&q, cache, pos);
        let proj = gemv_q4::gemv_q4_f32(&layer.wo, &attn);
        elementwise::add_inplace(&mut x, &proj);

        // FFN block
        let mut xf = vec![0.0f32; d];
        elementwise::rmsnorm(&x, &layer.ffn_norm, cfg.rms_eps, &mut xf);
        let gate = gemv_q4::gemv_q4_f32(&layer.w1, &xf);
        let up = gemv_q4::gemv_q4_f32(&layer.w3, &xf);
        let mut act = vec![0.0f32; cfg.d_ff];
        elementwise::silu_mul(&gate, &up, &mut act);
        let down = gemv_q4::gemv_q4_f32(&layer.w2, &act);
        elementwise::add_inplace(&mut x, &down);
    }

    let mut xn = vec![0.0f32; d];
    elementwise::rmsnorm(&x, &w.final_norm, cfg.rms_eps, &mut xn);
    session.pos += 1;
    gemv_q4::gemv_q4_f32(&w.lm_head, &xn)
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_setup() -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::micro();
        let w = ModelWeights::random_init(&cfg, 7);
        (cfg, w)
    }

    #[test]
    fn decode_produces_finite_logits() {
        let (cfg, w) = tiny_setup();
        let mut s = Session::new(&cfg);
        let logits = decode_step_serial(&cfg, &w, &mut s, 3);
        assert_eq!(logits.len(), cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(s.pos, 1);
    }

    #[test]
    fn different_tokens_different_logits() {
        let (cfg, w) = tiny_setup();
        let mut s1 = Session::new(&cfg);
        let mut s2 = Session::new(&cfg);
        let l1 = decode_step_serial(&cfg, &w, &mut s1, 1);
        let l2 = decode_step_serial(&cfg, &w, &mut s2, 2);
        assert_ne!(l1, l2);
    }

    #[test]
    fn history_affects_output() {
        let (cfg, w) = tiny_setup();
        let mut s1 = Session::new(&cfg);
        decode_step_serial(&cfg, &w, &mut s1, 5);
        let a = decode_step_serial(&cfg, &w, &mut s1, 9);
        let mut s2 = Session::new(&cfg);
        decode_step_serial(&cfg, &w, &mut s2, 6);
        let b = decode_step_serial(&cfg, &w, &mut s2, 9);
        assert_ne!(a, b);
    }

    #[test]
    fn determinism_across_runs() {
        let (cfg, w) = tiny_setup();
        let mut s1 = Session::new(&cfg);
        let mut s2 = Session::new(&cfg);
        for t in [1u32, 4, 2] {
            let a = decode_step_serial(&cfg, &w, &mut s1, t);
            let b = decode_step_serial(&cfg, &w, &mut s2, t);
            assert_eq!(a, b);
        }
    }

    #[test]
    #[should_panic(expected = "KV cache exhausted")]
    fn cache_overflow_panics() {
        let (cfg, w) = tiny_setup();
        let mut s = Session::new(&cfg);
        for t in 0..=cfg.t_max {
            decode_step_serial(&cfg, &w, &mut s, (t % cfg.vocab) as u32);
        }
    }

    #[test]
    fn argmax_finds_peak() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
