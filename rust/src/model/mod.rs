//! Llama-style model: configuration, quantized weights, KV cache and the
//! *serial* reference forward pass (the scheduled/parallel forward lives in
//! [`crate::engine`]; this module is the ground truth it is tested against).

pub mod config;
pub mod weights;

pub use config::ModelConfig;
pub use weights::{LayerWeights, ModelWeights};

use crate::kernels::attention::KvLayer;
use crate::kernels::{elementwise, gemv_q4, rope};

/// Per-request generation state: one KV cache per layer plus the cursor.
#[derive(Clone, Debug)]
pub struct Session {
    pub kv: Vec<KvLayer>,
    pub pos: usize,
    /// KV-slot id when the session was leased from a [`SessionPool`]
    /// (`usize::MAX` for standalone sessions).
    pub slot: usize,
}

impl Session {
    pub fn new(cfg: &ModelConfig) -> Session {
        let kv = (0..cfg.n_layers)
            .map(|_| KvLayer::new(cfg.n_heads, cfg.t_max, cfg.head_dim()))
            .collect();
        Session { kv, pos: 0, slot: usize::MAX }
    }

    pub fn remaining_capacity(&self, cfg: &ModelConfig) -> usize {
        cfg.t_max - self.pos
    }

    /// Rewind for reuse by a fresh request. Only the cursor needs to move:
    /// positions are always written (prefill/decode) before attention reads
    /// them, so stale KV contents past the cursor are never observed.
    pub fn reset(&mut self) {
        self.pos = 0;
    }
}

/// Where a pool's KV slot lives relative to the memory bus: the compute
/// lease's stream it is pinned under and the slice of that lease's bus
/// share its decode traffic can count on. Kept as plain ids/numbers so the
/// model layer stays independent of the coordinator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlotPlacement {
    /// coordinator stream id the owning lease serves
    pub stream: u64,
    /// even split of the lease's `bus_share_gbps` across the pool's slots —
    /// the per-slot bandwidth budget a saturated batch leaves each request
    pub bus_share_gbps: f64,
    /// bandwidth of the link the KV cache sits behind when it is *not*
    /// local to the compute lease (a far NUMA node or another socket).
    /// `0.0` means local — remote reads cost nothing extra. When positive,
    /// the serving layer charges decode-attention KV reads against this
    /// link instead of treating placement as free.
    pub remote_bw_gbps: f64,
}

/// Fixed-capacity KV-slot allocator: sessions (with their per-layer KV
/// buffers) are leased to requests and returned on retirement, so a
/// continuously-batching engine reuses at most `capacity` slots instead of
/// reallocating KV caches per request. Retired slots are always reused
/// before a fresh slot is allocated. Pools built from a compute lease
/// ([`SessionPool::with_lease`]) additionally record bus-aware slot
/// placement for bandwidth accounting.
#[derive(Debug)]
pub struct SessionPool {
    cfg: ModelConfig,
    free: Vec<Session>,
    allocated: usize,
    capacity: usize,
    /// lease placement shared by every slot (`None` for standalone pools)
    placement: Option<SlotPlacement>,
}

impl SessionPool {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> SessionPool {
        assert!(capacity > 0, "empty session pool");
        SessionPool { cfg: cfg.clone(), free: Vec::new(), allocated: 0, capacity, placement: None }
    }

    /// Pool whose slots are placed under a compute lease: each of the
    /// `capacity` KV slots is budgeted an even share of the lease's bus
    /// allocation, so per-request bandwidth expectations follow the lease.
    pub fn with_lease(
        cfg: &ModelConfig,
        capacity: usize,
        stream: u64,
        bus_share_gbps: f64,
    ) -> SessionPool {
        let mut pool = SessionPool::new(cfg, capacity);
        pool.placement = Some(SlotPlacement {
            stream,
            bus_share_gbps: bus_share_gbps / capacity as f64,
            remote_bw_gbps: 0.0,
        });
        pool
    }

    /// Mark every slot of a leased pool as remote: KV reads cross a link
    /// of `gbps` bandwidth. Panics on standalone pools — placement is a
    /// lease-level property.
    pub fn set_remote_kv(&mut self, gbps: f64) {
        assert!(gbps > 0.0, "remote link needs positive bandwidth");
        let p = self.placement.as_mut().expect("standalone pools have no placement to move");
        p.remote_bw_gbps = gbps;
    }

    /// Placement of slot `slot`: `Some` for in-range slots of a leased
    /// pool, `None` for standalone pools and foreign (`usize::MAX`) slots.
    pub fn placement_of(&self, slot: usize) -> Option<SlotPlacement> {
        if slot < self.capacity {
            self.placement
        } else {
            None
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Slots ever allocated (≤ capacity); stays at the peak concurrency the
    /// pool has served, since free slots are reused before new allocation.
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    /// Slots on the free list, ready for reuse without allocation.
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Lease a slot: a retired one when available, else a freshly allocated
    /// one while under capacity, else `None` (the batch is full).
    pub fn acquire(&mut self) -> Option<Session> {
        if let Some(s) = self.free.pop() {
            return Some(s);
        }
        if self.allocated < self.capacity {
            let mut s = Session::new(&self.cfg);
            s.slot = self.allocated;
            self.allocated += 1;
            return Some(s);
        }
        None
    }

    /// Return a retired session's slot for reuse (buffers kept, cursor
    /// reset). A session migrated in from another pool (slot tag
    /// `usize::MAX`) is absorbed only while this pool is under capacity,
    /// and is re-tagged with a fresh slot id so ids stay unique within
    /// the pool and `allocated()` keeps meaning peak concurrency.
    pub fn release(&mut self, mut session: Session) {
        session.reset();
        if session.slot >= self.capacity {
            if self.allocated < self.capacity {
                session.slot = self.allocated;
                self.allocated += 1;
                self.free.push(session);
            }
            return;
        }
        if self.free.len() < self.capacity {
            self.free.push(session);
        }
    }

    /// Hand a live session off to another pool **mid-request**: the KV
    /// contents travel with the session (its cursor is NOT reset — a
    /// prefilled cache must replay bit-identically on the adopting side),
    /// while this pool's slot is reclaimed immediately by pushing a fresh
    /// same-shape session under the departing slot id. Without the
    /// replacement every handoff would leak one unit of capacity until the
    /// donor pool starved. The detached session is re-tagged `usize::MAX`
    /// so the adopting pool absorbs it like any migrated-in session.
    pub fn detach(&mut self, session: &mut Session) {
        if session.slot < self.capacity {
            let mut replacement = Session::new(&self.cfg);
            replacement.slot = session.slot;
            if self.free.len() < self.capacity {
                self.free.push(replacement);
            }
        }
        session.slot = usize::MAX;
    }
}

/// Serial single-threaded decode step — the correctness oracle for the
/// scheduled engine and the PJRT artifact. Mirrors
/// `python/compile/model.py::decode_step` op for op.
pub fn decode_step_serial(
    cfg: &ModelConfig,
    w: &ModelWeights,
    session: &mut Session,
    token: u32,
) -> Vec<f32> {
    let d = cfg.d_model;
    let (h, dh) = (cfg.n_heads, cfg.head_dim());
    let pos = session.pos;
    assert!(pos < cfg.t_max, "KV cache exhausted");
    let mut x = w.embed.row(token as usize).to_vec();

    for (li, layer) in w.layers.iter().enumerate() {
        // attention block
        let mut xa = vec![0.0f32; d];
        elementwise::rmsnorm(&x, &layer.attn_norm, cfg.rms_eps, &mut xa);
        let mut q = gemv_q4::gemv_q4_f32(&layer.wq, &xa);
        let mut k = gemv_q4::gemv_q4_f32(&layer.wk, &xa);
        let v = gemv_q4::gemv_q4_f32(&layer.wv, &xa);
        rope::rope_heads(&mut q, h, dh, pos as i32, cfg.rope_theta);
        rope::rope_heads(&mut k, h, dh, pos as i32, cfg.rope_theta);
        let cache = &mut session.kv[li];
        for head in 0..h {
            cache.write(head, pos, &k[head * dh..(head + 1) * dh], &v[head * dh..(head + 1) * dh]);
        }
        let attn = crate::kernels::attention::attention_decode(&q, cache, pos);
        let proj = gemv_q4::gemv_q4_f32(&layer.wo, &attn);
        elementwise::add_inplace(&mut x, &proj);

        // FFN block
        let mut xf = vec![0.0f32; d];
        elementwise::rmsnorm(&x, &layer.ffn_norm, cfg.rms_eps, &mut xf);
        let gate = gemv_q4::gemv_q4_f32(&layer.w1, &xf);
        let up = gemv_q4::gemv_q4_f32(&layer.w3, &xf);
        let mut act = vec![0.0f32; cfg.d_ff];
        elementwise::silu_mul(&gate, &up, &mut act);
        let down = gemv_q4::gemv_q4_f32(&layer.w2, &act);
        elementwise::add_inplace(&mut x, &down);
    }

    let mut xn = vec![0.0f32; d];
    elementwise::rmsnorm(&x, &w.final_norm, cfg.rms_eps, &mut xn);
    session.pos += 1;
    gemv_q4::gemv_q4_f32(&w.lm_head, &xn)
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_setup() -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::micro();
        let w = ModelWeights::random_init(&cfg, 7);
        (cfg, w)
    }

    #[test]
    fn decode_produces_finite_logits() {
        let (cfg, w) = tiny_setup();
        let mut s = Session::new(&cfg);
        let logits = decode_step_serial(&cfg, &w, &mut s, 3);
        assert_eq!(logits.len(), cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(s.pos, 1);
    }

    #[test]
    fn different_tokens_different_logits() {
        let (cfg, w) = tiny_setup();
        let mut s1 = Session::new(&cfg);
        let mut s2 = Session::new(&cfg);
        let l1 = decode_step_serial(&cfg, &w, &mut s1, 1);
        let l2 = decode_step_serial(&cfg, &w, &mut s2, 2);
        assert_ne!(l1, l2);
    }

    #[test]
    fn history_affects_output() {
        let (cfg, w) = tiny_setup();
        let mut s1 = Session::new(&cfg);
        decode_step_serial(&cfg, &w, &mut s1, 5);
        let a = decode_step_serial(&cfg, &w, &mut s1, 9);
        let mut s2 = Session::new(&cfg);
        decode_step_serial(&cfg, &w, &mut s2, 6);
        let b = decode_step_serial(&cfg, &w, &mut s2, 9);
        assert_ne!(a, b);
    }

    #[test]
    fn determinism_across_runs() {
        let (cfg, w) = tiny_setup();
        let mut s1 = Session::new(&cfg);
        let mut s2 = Session::new(&cfg);
        for t in [1u32, 4, 2] {
            let a = decode_step_serial(&cfg, &w, &mut s1, t);
            let b = decode_step_serial(&cfg, &w, &mut s2, t);
            assert_eq!(a, b);
        }
    }

    #[test]
    #[should_panic(expected = "KV cache exhausted")]
    fn cache_overflow_panics() {
        let (cfg, w) = tiny_setup();
        let mut s = Session::new(&cfg);
        for t in 0..=cfg.t_max {
            decode_step_serial(&cfg, &w, &mut s, (t % cfg.vocab) as u32);
        }
    }

    #[test]
    fn argmax_finds_peak() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn session_reset_replays_identically() {
        let (cfg, w) = tiny_setup();
        let mut fresh = Session::new(&cfg);
        let a = decode_step_serial(&cfg, &w, &mut fresh, 3);
        let mut reused = Session::new(&cfg);
        // pollute with a different history, then reset and replay
        decode_step_serial(&cfg, &w, &mut reused, 9);
        decode_step_serial(&cfg, &w, &mut reused, 1);
        reused.reset();
        assert_eq!(reused.pos, 0);
        let b = decode_step_serial(&cfg, &w, &mut reused, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn session_pool_reuses_before_allocating() {
        let cfg = ModelConfig::micro();
        let mut pool = SessionPool::new(&cfg, 3);
        let a = pool.acquire().unwrap();
        let b = pool.acquire().unwrap();
        assert_eq!((a.slot, b.slot), (0, 1));
        assert_eq!(pool.allocated(), 2);
        // release → the freed slot comes back before slot 2 is ever created
        pool.release(a);
        assert_eq!(pool.idle(), 1);
        let c = pool.acquire().unwrap();
        assert_eq!(c.slot, 0);
        assert_eq!(pool.allocated(), 2);
        // exhausting the pool caps at capacity
        let d = pool.acquire().unwrap();
        assert_eq!(d.slot, 2);
        assert!(pool.acquire().is_none());
        assert_eq!(pool.allocated(), 3);
    }

    #[test]
    fn session_pool_absorbs_foreign_sessions_with_fresh_slots() {
        let cfg = ModelConfig::micro();
        let mut pool = SessionPool::new(&cfg, 2);
        let native = pool.acquire().unwrap();
        assert_eq!(native.slot, 0);
        // a session migrated in from another pool gets a fresh unique slot
        let foreign = Session::new(&cfg);
        assert_eq!(foreign.slot, usize::MAX);
        pool.release(foreign);
        let absorbed = pool.acquire().unwrap();
        assert_eq!(absorbed.slot, 1);
        assert_eq!(pool.allocated(), 2);
        // at capacity, further foreign sessions are dropped, not absorbed
        pool.release(Session::new(&cfg));
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.allocated(), 2);
        pool.release(native);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn session_pool_detach_keeps_kv_and_reclaims_the_slot() {
        let (cfg, w) = tiny_setup();
        let mut donor = SessionPool::new(&cfg, 1);
        let mut adopter = SessionPool::new(&cfg, 2);
        let mut s = donor.acquire().unwrap();
        let mid = decode_step_serial(&cfg, &w, &mut s, 5);
        // detach mid-request: cursor and KV stay with the session...
        donor.detach(&mut s);
        assert_eq!(s.pos, 1);
        assert_eq!(s.slot, usize::MAX);
        // ...the donor immediately regains its capacity...
        assert_eq!(donor.idle(), 1);
        assert!(donor.acquire().is_some());
        // ...and the adopting side continues the stream bit-identically
        let cont = decode_step_serial(&cfg, &w, &mut s, 9);
        let mut oracle = Session::new(&cfg);
        decode_step_serial(&cfg, &w, &mut oracle, 5);
        let oracle_cont = decode_step_serial(&cfg, &w, &mut oracle, 9);
        assert_eq!(mid.len(), cont.len());
        assert_eq!(cont, oracle_cont);
        adopter.release(s);
        assert_eq!(adopter.allocated(), 1);
    }

    #[test]
    fn leased_pool_places_slots_bus_aware() {
        let cfg = ModelConfig::micro();
        let mut pool = SessionPool::with_lease(&cfg, 4, 7, 34.0);
        for slot in 0..4 {
            let p = pool.placement_of(slot).unwrap();
            assert_eq!(p.stream, 7);
            assert!((p.bus_share_gbps - 8.5).abs() < 1e-12);
            // placement is local until told otherwise
            assert_eq!(p.remote_bw_gbps, 0.0);
        }
        pool.set_remote_kv(12.0);
        assert_eq!(pool.placement_of(0).unwrap().remote_bw_gbps, 12.0);
        // out-of-range and foreign slots have no placement
        assert_eq!(pool.placement_of(4), None);
        assert_eq!(pool.placement_of(usize::MAX), None);
        // standalone pools never report one
        assert_eq!(SessionPool::new(&cfg, 4).placement_of(0), None);
    }

    #[test]
    fn session_pool_release_resets_cursor() {
        let (cfg, w) = tiny_setup();
        let mut pool = SessionPool::new(&cfg, 1);
        let mut s = pool.acquire().unwrap();
        decode_step_serial(&cfg, &w, &mut s, 5);
        assert_eq!(s.pos, 1);
        pool.release(s);
        let s = pool.acquire().unwrap();
        assert_eq!(s.pos, 0);
        assert_eq!(s.slot, 0);
    }
}
