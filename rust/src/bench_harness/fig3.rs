//! Figure 3: end-to-end llama2-7B (Q4_0) inference latency, prompt 1024:
//! llama.cpp vs Neural Speed + OpenMP-static vs Neural Speed + dynamic,
//! on both hybrid CPUs. Paper bands: prefill −20–30 % vs NS-OpenMP,
//! decode −9–22 %, ≈16 tokens/s, up to 3.7× vs llama.cpp.

use crate::engine::phantom::{decode_total_bytes_at, run_phantom_generation, PhantomSystem};
use crate::cpu::presets::preset_by_name;
use crate::metrics::{self, PhaseMetrics};
use crate::model::ModelConfig;
use crate::perf::PerfConfig;
use crate::sim::{HybridSim, SimConfig};

use super::{report::Table, sim_runtime};

/// One (cpu, system) end-to-end measurement.
#[derive(Clone, Debug)]
pub struct E2eResult {
    pub cpu: String,
    pub system: String,
    pub metrics: PhaseMetrics,
    pub decode_bandwidth_gbps: f64,
    pub mlc_gbps: f64,
}

impl E2eResult {
    pub fn decode_tps(&self) -> f64 {
        self.metrics.decode_tokens_per_sec()
    }
}

/// The three systems of Figure 3.
pub fn systems() -> Vec<(String, PhantomSystem, &'static str)> {
    vec![
        ("llama.cpp".into(), PhantomSystem::llama_cpp(), "static"),
        ("ns_openmp".into(), PhantomSystem::neural_speed(), "static"),
        ("ns_dynamic".into(), PhantomSystem::neural_speed(), "dynamic"),
    ]
}

/// Run the figure: each system generates `n_decode` tokens after a
/// `prompt_len` prefill (one warmup generation first so the dynamic
/// table has converged, as in the paper's steady-state measurement).
pub fn run(cpus: &[&str], prompt_len: usize, n_decode: usize, noisy: bool) -> Vec<E2eResult> {
    let cfg = ModelConfig::llama2_7b();
    let mut out = Vec::new();
    for cpu in cpus {
        let spec = preset_by_name(cpu).unwrap_or_else(|| panic!("unknown preset {cpu}"));
        let mlc = HybridSim::new(spec.clone(), SimConfig::noiseless()).mlc_bandwidth();
        for (name, sys, sched) in systems() {
            let sim_cfg = if noisy { SimConfig::default() } else { SimConfig::noiseless() };
            let mut rt = sim_runtime(spec.clone(), sched, sim_cfg, PerfConfig::default());
            // warmup: let the ratio table converge (no-op for static)
            let _ = run_phantom_generation(&mut rt, &cfg, &sys, prompt_len.min(64), 2);
            let m = run_phantom_generation(&mut rt, &cfg, &sys, prompt_len, n_decode);
            // total decode traffic = weights + growing KV-cache reads
            let bytes: f64 =
                (0..n_decode).map(|i| decode_total_bytes_at(&cfg, prompt_len + i)).sum();
            out.push(E2eResult {
                cpu: cpu.to_string(),
                system: name,
                decode_bandwidth_gbps: metrics::bandwidth_gbps(bytes, m.decode_secs),
                mlc_gbps: mlc,
                metrics: m,
            });
        }
    }
    out
}

pub fn find<'a>(results: &'a [E2eResult], cpu: &str, system: &str) -> Option<&'a E2eResult> {
    results.iter().find(|r| r.cpu == cpu && r.system == system)
}

pub fn table(results: &[E2eResult]) -> Table {
    let mut t = Table::new(&[
        "cpu",
        "system",
        "prefill",
        "decode/token",
        "tokens/s",
        "decode_bw_gbps",
        "bw_util",
    ]);
    for r in results {
        t.row(vec![
            r.cpu.clone(),
            r.system.clone(),
            super::report::fmt_secs(r.metrics.prefill_secs),
            super::report::fmt_secs(r.metrics.decode_latency()),
            format!("{:.1}", r.decode_tps()),
            format!("{:.1}", r.decode_bandwidth_gbps),
            format!("{:.1}%", r.decode_bandwidth_gbps / r.mlc_gbps * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_bands_match_paper() {
        // smaller prompt than the paper's 1024 keeps the test quick while
        // staying compute-bound (same regime)
        let res = run(&["ultra_125h"], 256, 4, false);
        let lc = find(&res, "ultra_125h", "llama.cpp").unwrap();
        let ns = find(&res, "ultra_125h", "ns_openmp").unwrap();
        let dy = find(&res, "ultra_125h", "ns_dynamic").unwrap();

        // prefill: dynamic 20–30% faster than NS-OpenMP (ratio 1.25–1.75 on 125H)
        let prefill_gain = ns.metrics.prefill_secs / dy.metrics.prefill_secs;
        assert!(prefill_gain > 1.2, "prefill gain {prefill_gain}");
        // decode: dynamic 9–22% faster than NS-OpenMP
        let decode_gain = ns.metrics.decode_secs / dy.metrics.decode_secs;
        assert!((1.02..1.40).contains(&decode_gain), "decode gain {decode_gain}");
        // llama.cpp is the slowest system
        assert!(lc.metrics.prefill_secs > ns.metrics.prefill_secs);
        // dynamic decode uses >90% of the MLC reference bandwidth
        assert!(
            dy.decode_bandwidth_gbps / dy.mlc_gbps > 0.9,
            "bw util {}",
            dy.decode_bandwidth_gbps / dy.mlc_gbps
        );
    }

    #[test]
    fn decode_speed_is_paper_scale_16_tps() {
        let res = run(&["core_12900k"], 16, 4, false);
        let dy = find(&res, "core_12900k", "ns_dynamic").unwrap();
        let tps = dy.decode_tps();
        assert!((10.0..25.0).contains(&tps), "tokens/s {tps}");
    }

    #[test]
    fn table_renders_all_systems() {
        let res = run(&["ultra_125h"], 32, 2, false);
        let s = table(&res).render();
        for name in ["llama.cpp", "ns_openmp", "ns_dynamic"] {
            assert!(s.contains(name), "{s}");
        }
    }
}
