//! Figure-regeneration harness: the code behind `cargo bench` targets and
//! the `dynpar bench` CLI. One module per figure of the paper; see the
//! experiment index in DESIGN.md.

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod pr3;
pub mod pr4;
pub mod pr7;
pub mod pr8;
pub mod pr9;
pub mod pr10;
pub mod report;

use crate::cpu::CpuSpec;
use crate::exec::ParallelRuntime;
use crate::perf::PerfConfig;
use crate::sched::scheduler_by_name;
use crate::sim::{SimConfig, SimExecutor};

/// Build a simulator-backed runtime for (cpu, scheduler).
pub fn sim_runtime(
    spec: CpuSpec,
    sched: &str,
    sim_cfg: SimConfig,
    perf: PerfConfig,
) -> ParallelRuntime<SimExecutor> {
    ParallelRuntime::new(
        SimExecutor::new(spec, sim_cfg),
        scheduler_by_name(sched).unwrap_or_else(|| panic!("unknown scheduler {sched}")),
        perf,
    )
}

/// The two hybrid CPUs evaluated in the paper.
pub const PAPER_CPUS: [&str; 2] = ["ultra_125h", "core_12900k"];

/// The scheduler line-up for figure 2 (paper compares OpenMP vs ours;
/// work-stealing and guided are the extra baselines we ablate).
pub const FIG2_SCHEDULERS: [&str; 4] = ["static", "workstealing", "guided", "dynamic"];
