//! Figure 4: the performance ratio of one P-core (AVX-VNNI) over the
//! course of an inference — seeded at a stale value of 5, stabilizing at
//! ~3–3.5 during the compute-bound prefill, then shifting to a lower
//! level when the decode phase's memory-bound bottleneck takes over.
//!
//! Phase hand-off: §2.2 says "the performance ratio will be distributed
//! among different schedulers" — we model that by seeding the decode
//! kernel's (GemvQ4, AVX-VNNI) row from the converged prefill row at the
//! phase boundary, which is what produces the visible "second change" in
//! the paper's trace.

use crate::cpu::{presets::preset_by_name, Isa};
use crate::engine::phantom::{decode_invocations, prefill_invocations, PhantomSystem};
use crate::exec::PhantomWork;
use crate::kernels::KernelClass;
use crate::perf::PerfConfig;
use crate::sim::SimConfig;
use crate::trace::RatioTrace;

/// Parameters of the trace experiment.
#[derive(Clone, Debug)]
pub struct Fig4Params {
    pub cpu: String,
    /// EWMA gain (paper: 0.3)
    pub alpha: f64,
    /// stale initial ratio of the traced P-core (paper: 5)
    pub init_ratio: f64,
    /// traced core id (0 = first P-core)
    pub core: usize,
    pub prompt_len: usize,
    pub n_decode: usize,
    /// prefill is chunked so the table updates several times
    pub prefill_chunk: usize,
    pub noisy: bool,
}

impl Default for Fig4Params {
    fn default() -> Self {
        Fig4Params {
            cpu: "ultra_125h".into(),
            alpha: 0.3,
            init_ratio: 5.0,
            core: 0,
            prompt_len: 1024,
            n_decode: 64,
            prefill_chunk: 64,
            noisy: true,
        }
    }
}

/// Run the trace. Returns the per-kernel-invocation relative ratio of the
/// traced core (prefill samples keyed on the GEMM row, decode samples on
/// the GEMV row — both AVX-VNNI, as in the paper).
pub fn run(p: &Fig4Params) -> RatioTrace {
    let spec = preset_by_name(&p.cpu).unwrap_or_else(|| panic!("unknown preset {}", p.cpu));
    let n = spec.n_cores();
    let sim_cfg = if p.noisy { SimConfig::default() } else { SimConfig::noiseless() };
    let mut rt = super::sim_runtime(
        spec,
        "dynamic",
        sim_cfg,
        PerfConfig { alpha: p.alpha, init_ratio: 1.0 },
    );
    // stale table: the traced core starts at `init_ratio`, everyone else at 1
    let mut seed = vec![1.0; n];
    seed[p.core] = p.init_ratio;
    rt.table.set_ratios(KernelClass::GemmI8, Isa::AvxVnni, seed);

    let cfg = crate::model::ModelConfig::llama2_7b();
    let sys = PhantomSystem::neural_speed();
    let mut trace = RatioTrace::new(p.core, KernelClass::GemmI8, Isa::AvxVnni);

    // ---- prefill, chunked so the table updates repeatedly ----
    let mut done = 0;
    while done < p.prompt_len {
        let s = p.prefill_chunk.min(p.prompt_len - done);
        for c in prefill_invocations(&cfg, &sys, s) {
            rt.run(&PhantomWork::new(c));
            if c.class == KernelClass::GemmI8 {
                trace.record(&rt.table, rt.exec.sim.now, "prefill");
            }
        }
        done += s;
    }

    // ---- phase hand-off: decode GEMV row inherits the converged ratios ----
    let converged = rt.table.ratios(KernelClass::GemmI8, Isa::AvxVnni).to_vec();
    rt.table.set_ratios(KernelClass::GemvQ4, Isa::AvxVnni, converged);
    trace.class = KernelClass::GemvQ4;

    for step in 0..p.n_decode {
        for c in decode_invocations(&cfg, &sys, p.prompt_len + step) {
            rt.run(&PhantomWork::new(c));
            if c.class == KernelClass::GemvQ4 {
                trace.record(&rt.table, rt.exec.sim.now, "decode");
            }
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params() -> Fig4Params {
        Fig4Params {
            prompt_len: 256,
            n_decode: 24,
            prefill_chunk: 64,
            noisy: false,
            ..Default::default()
        }
    }

    #[test]
    fn trace_reproduces_fig4_shape() {
        let trace = run(&quick_params());
        let prefill: Vec<f64> = trace
            .samples
            .iter()
            .filter(|s| s.phase == "prefill")
            .map(|s| s.ratio)
            .collect();
        let decode_mean = trace.phase_mean("decode").unwrap();

        // change 1: starts high (stale 5), stabilizes in the 3–3.5 band
        assert!(prefill[0] > 3.4, "first sample {}", prefill[0]);
        let tail = &prefill[prefill.len() / 2..];
        let tail_mean: f64 = tail.iter().sum::<f64>() / tail.len() as f64;
        assert!((2.8..3.5).contains(&tail_mean), "prefill tail mean {tail_mean}");

        // change 2: decode settles at a *different* (lower) ratio
        assert!(decode_mean < tail_mean - 0.2, "decode {decode_mean} vs prefill {tail_mean}");
    }

    #[test]
    fn alpha_zero_converges_fastest() {
        let mut p = quick_params();
        p.alpha = 0.0;
        let fast = run(&p);
        p.alpha = 0.9;
        let slow = run(&p);
        // after the very first update, α=0 must be closer to the ideal ~2.9
        let f0 = fast.samples[0].ratio;
        let s0 = slow.samples[0].ratio;
        assert!((f0 - 2.9).abs() < (s0 - 2.9).abs(), "f0={f0} s0={s0}");
    }

    #[test]
    fn csv_has_both_phases() {
        let trace = run(&quick_params());
        let csv = trace.to_csv();
        assert!(csv.contains("prefill") && csv.contains("decode"));
    }
}
