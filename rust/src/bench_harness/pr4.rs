//! PR-4 perf trajectory: what `ExecMode::AsyncBatch` buys over the
//! intra-kernel split on a bandwidth-rich hetero machine.
//!
//! One scripted mixed prefill+decode trace (24 requests, chunked 8-token
//! prompts, 96 decode rounds each) is served twice through the
//! deterministic harness on the same two-LPE + NPU machine:
//!
//! * **intra-kernel** — the PR-3 baseline: every kernel is split across
//!   cores *and* the device, so each decode round pays the device launch
//!   overhead on the critical path and the batch advances at the pace of
//!   the slowest partition.
//! * **async-batch** — the tentpole: the lease's admissions are routed
//!   between a CpuOnly and a DeviceOnly batcher by the coordinator's
//!   learned split ratio, so the two sides decode *concurrently* — whole
//!   batches per side, no per-kernel synchronization — while paired
//!   per-round timings keep re-learning the ratio online
//!   ([`crate::coordinator::Coordinator::observe_round`]), with no
//!   one-shot profiling phase.
//!
//! The machine is deliberately bandwidth-rich (per-core and device memory
//! bandwidth scaled so decode is compute-bound): that is the regime the
//! paper's §5 targets, where the device can actually add throughput
//! instead of fighting the cores for the bus.
//!
//! `dynpar bench pr4 [--out BENCH_pr4.json]` renders the JSON trajectory.

use crate::coordinator::{bus_share, AllocPolicy, Coordinator, ExecMode, XpuAffinity};
use crate::cpu::{presets, CpuSpec};
use crate::model::ModelConfig;
use crate::server::fleet::DriftMonitor;
use crate::server::protocol::Request;
use crate::server::testing::{HarnessReport, TraceEvent};
use crate::server::BatcherOpts;
use crate::sim::xpu::AcceleratorSpec;
use crate::sim::SimConfig;
use crate::util::json::Json;

use super::common;

const WEIGHTS_SEED: u64 = 17;
const N_REQ: u64 = 24;
const MAX_NEW: usize = 96;

/// Two of the 125H's LP E-cores plus its NPU, both with memory bandwidth
/// scaled ×50 (and the bus to match): a stand-in for a package where
/// decode at this model size is compute-bound, so the async split's
/// concurrency — not the bus — decides throughput.
fn machine() -> (CpuSpec, Vec<AcceleratorSpec>) {
    let ultra = presets::ultra_125h();
    let lpe = [12usize, 13];
    let mut spec = ultra.subset(&lpe, bus_share(&ultra, &lpe));
    for c in &mut spec.cores {
        c.mem_bw_gbps *= 50.0;
    }
    spec.bus_bw_gbps = 3600.0;
    let mut npu = AcceleratorSpec::npu();
    npu.mem_bw_gbps *= 50.0;
    (spec, vec![npu])
}

/// Small-vocab 2-layer model at d_model 2048: per-round kernels large
/// enough that the NPU's launch overhead amortizes, small enough that the
/// cost-model-only run (`execute_real: false`) stays fast.
fn model() -> ModelConfig {
    common::bench_model("pr4", 2048, 2048, 16, 2048, 8)
}

/// Frozen arrival script: one stream, 24 near-simultaneous requests —
/// 8-token prompts (one prefill chunk) then 96 decode rounds each, enough
/// rounds that the online ratio's convergence transient washes out.
fn trace() -> Vec<TraceEvent> {
    let reqs = (0..N_REQ)
        .map(|i| Request {
            id: i,
            prompt: vec![
                1 + (i as u32 * 7) % 2000,
                9,
                4,
                7,
                2,
                11,
                5,
                (i as u32 * 3) % 2000,
            ],
            max_new_tokens: MAX_NEW,
        })
        .collect();
    common::streamed_trace(1, 2.0e-4, reqs)
}

/// Serve the frozen trace under one execution mode.
fn scenario(mode: ExecMode) -> HarnessReport {
    let (spec, accels) = machine();
    let mut coord = Coordinator::with_accelerators(
        spec.clone(),
        accels.clone(),
        AllocPolicy::Balanced,
        XpuAffinity::Floating,
    );
    coord.set_exec_mode(mode);
    // timing comes from the cost model alone: the trace decodes ~2300
    // tokens of a d_model-2048 model, real matmuls would dominate bench
    // wall-clock without changing any timing
    let factory =
        common::xpu_factory(spec, accels, model(), WEIGHTS_SEED, SimConfig::noiseless(), true);
    let rep = common::serve_xpu(
        coord,
        &factory,
        BatcherOpts { max_batch: 4, prefill_chunk: 8 },
        DriftMonitor::disabled(),
        trace(),
    );
    assert_eq!(rep.total_decoded, N_REQ as usize * MAX_NEW, "tokens went missing");
    rep
}

/// Full PR-4 trajectory as JSON.
pub fn run() -> Json {
    let intra = scenario(ExecMode::IntraKernel);
    let async_ = scenario(ExecMode::AsyncBatch);
    let speedup = async_.throughput() / intra.throughput();
    let r_final = async_.split_ratios.first().copied().unwrap_or(f64::NAN);
    let side = |rep: &HarnessReport| Json::obj(common::side_fields(rep));
    Json::obj(vec![
        ("bench", Json::str("pr4")),
        ("machine", Json::str("ultra_125h[2LPE,bw*50] + npu[bw*50]")),
        ("model", Json::str("pr4 (d2048, 2L, cost-model timing)")),
        ("trace", Json::str("24 req x (8 prompt + 96 decode), 1 stream")),
        ("intra_kernel", side(&intra)),
        ("async_batch", side(&async_)),
        ("speedup", Json::num(speedup)),
        ("learned_device_share", Json::num(r_final)),
        ("observations", Json::num(async_.observations_accepted as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr4_async_batch_beats_intra_kernel_by_1_5x() {
        let j = run();
        let speedup = j.get("speedup").unwrap().as_f64().unwrap();
        assert!(
            speedup >= 1.5,
            "async-batch speedup {speedup:.3} fell below the 1.5x floor"
        );
        // the online loop must actually have learned the split: the two
        // scaled LPE cores and the scaled NPU land near a 50/50 share,
        // far from the strength-prior transient (~0.95)
        let r = j.get("learned_device_share").unwrap().as_f64().unwrap();
        assert!((0.3..=0.7).contains(&r), "learned device share {r:.3} out of band");
        let obs = j.get("observations").unwrap().as_f64().unwrap();
        assert!(obs >= 10.0, "only {obs} paired rounds folded — ratio never re-learned");
    }
}
