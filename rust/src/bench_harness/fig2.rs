//! Figure 2: kernel-level comparison on both hybrid CPUs.
//!
//! Left panel — INT8 GEMM 1024×4096×4096 latency per scheduler (paper:
//! dynamic is +65 % over OpenMP-static on Ultra-125H, +85 % on 12900K).
//! Right panel — INT4 GEMV 1×4096×4096 achieved bandwidth vs the MLC
//! reference (paper: +19 % on 125H; >90 % of MLC with the dynamic method).

use crate::cpu::presets::preset_by_name;
use crate::exec::PhantomWork;
use crate::kernels::cost;
use crate::metrics;
use crate::perf::PerfConfig;
use crate::sim::{HybridSim, SimConfig};
use crate::util::stats::Summary;

use super::{sim_runtime, report::Table};

/// One (cpu, scheduler) measurement.
#[derive(Clone, Debug)]
pub struct KernelBenchResult {
    pub cpu: String,
    pub scheduler: String,
    pub latency: Summary,
    /// achieved GB/s (meaningful for the GEMV panel)
    pub bandwidth_gbps: f64,
    /// the simulator's MLC-like reference for this CPU
    pub mlc_gbps: f64,
    /// effective compute rate (Gops/s, meaningful for the GEMM panel)
    pub gops: f64,
}

impl KernelBenchResult {
    pub fn bandwidth_utilization(&self) -> f64 {
        metrics::bandwidth_utilization(self.bandwidth_gbps, self.mlc_gbps)
    }
}

/// Run one phantom kernel repeatedly through the full dynamic loop and
/// summarize per-iteration latency (after `warmup` table-learning passes).
fn measure(
    cpu: &str,
    sched: &str,
    c: crate::kernels::WorkCost,
    warmup: usize,
    iters: usize,
    noisy: bool,
) -> KernelBenchResult {
    let spec = preset_by_name(cpu).unwrap_or_else(|| panic!("unknown preset {cpu}"));
    let sim_cfg = if noisy { SimConfig::default() } else { SimConfig::noiseless() };
    let mlc = HybridSim::new(spec.clone(), SimConfig::noiseless()).mlc_bandwidth();
    let mut rt = sim_runtime(spec, sched, sim_cfg, PerfConfig::default());
    let work = PhantomWork::new(c);
    for _ in 0..warmup {
        rt.run(&work);
    }
    let samples: Vec<f64> = (0..iters).map(|_| rt.run(&work).wall_secs).collect();
    let latency = Summary::of(&samples);
    KernelBenchResult {
        cpu: cpu.to_string(),
        scheduler: sched.to_string(),
        bandwidth_gbps: metrics::bandwidth_gbps(c.total_bytes(), latency.p50),
        gops: c.total_ops() / latency.p50 / 1e9,
        mlc_gbps: mlc,
        latency,
    }
}

/// Figure 2-left: INT8 GEMM.
pub fn run_gemm(
    cpus: &[&str],
    scheds: &[&str],
    m: usize,
    k: usize,
    n: usize,
    warmup: usize,
    iters: usize,
    noisy: bool,
) -> Vec<KernelBenchResult> {
    let c = cost::gemm_i8_cost(m, k, n);
    let mut out = Vec::new();
    for cpu in cpus {
        for sched in scheds {
            out.push(measure(cpu, sched, c, warmup, iters, noisy));
        }
    }
    out
}

/// Figure 2-right: INT4 (q8-act × q4-weight) GEMV.
pub fn run_gemv(
    cpus: &[&str],
    scheds: &[&str],
    k: usize,
    n: usize,
    warmup: usize,
    iters: usize,
    noisy: bool,
) -> Vec<KernelBenchResult> {
    let c = cost::gemv_q4_cost(k, n);
    let mut out = Vec::new();
    for cpu in cpus {
        for sched in scheds {
            out.push(measure(cpu, sched, c, warmup, iters, noisy));
        }
    }
    out
}

/// Speedup of `sched` vs the static baseline on the same CPU.
pub fn speedup_vs_static(results: &[KernelBenchResult], cpu: &str, sched: &str) -> Option<f64> {
    let base = results.iter().find(|r| r.cpu == cpu && r.scheduler == "static")?;
    let target = results.iter().find(|r| r.cpu == cpu && r.scheduler == sched)?;
    Some(base.latency.p50 / target.latency.p50)
}

/// Render the GEMM panel as a table.
pub fn gemm_table(results: &[KernelBenchResult]) -> Table {
    let mut t = Table::new(&["cpu", "scheduler", "latency_p50", "gops", "speedup_vs_static"]);
    for r in results {
        let sp = speedup_vs_static(results, &r.cpu, &r.scheduler).unwrap_or(1.0);
        t.row(vec![
            r.cpu.clone(),
            r.scheduler.clone(),
            super::report::fmt_secs(r.latency.p50),
            format!("{:.0}", r.gops),
            format!("{sp:.2}x"),
        ]);
    }
    t
}

/// Render the GEMV panel as a table.
pub fn gemv_table(results: &[KernelBenchResult]) -> Table {
    let mut t = Table::new(&[
        "cpu",
        "scheduler",
        "latency_p50",
        "bandwidth_gbps",
        "mlc_gbps",
        "utilization",
        "speedup_vs_static",
    ]);
    for r in results {
        let sp = speedup_vs_static(results, &r.cpu, &r.scheduler).unwrap_or(1.0);
        t.row(vec![
            r.cpu.clone(),
            r.scheduler.clone(),
            super::report::fmt_secs(r.latency.p50),
            format!("{:.1}", r.bandwidth_gbps),
            format!("{:.1}", r.mlc_gbps),
            format!("{:.1}%", r.bandwidth_utilization() * 100.0),
            format!("{sp:.2}x"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_dynamic_speedup_bands_match_paper() {
        let res = run_gemm(
            &["ultra_125h", "core_12900k"],
            &["static", "dynamic"],
            1024,
            4096,
            4096,
            10,
            10,
            false,
        );
        // paper: +65% on 125H, +85% on 12900K
        let s125 = speedup_vs_static(&res, "ultra_125h", "dynamic").unwrap();
        let s129 = speedup_vs_static(&res, "core_12900k", "dynamic").unwrap();
        assert!((1.55..1.80).contains(&s125), "125H speedup {s125}");
        assert!((1.70..1.95).contains(&s129), "12900K speedup {s129}");
    }

    #[test]
    fn gemv_dynamic_exceeds_90pct_of_mlc() {
        let res = run_gemv(&["ultra_125h"], &["static", "dynamic"], 4096, 4096, 12, 10, false);
        let d = res.iter().find(|r| r.scheduler == "dynamic").unwrap();
        assert!(d.bandwidth_utilization() > 0.90, "utilization {}", d.bandwidth_utilization());
        // paper: +19% bandwidth over static on 125H — accept a loose band
        let sp = speedup_vs_static(&res, "ultra_125h", "dynamic").unwrap();
        assert!((1.05..1.45).contains(&sp), "gemv speedup {sp}");
    }

    #[test]
    fn tables_render() {
        let res = run_gemm(&["ultra_125h"], &["static", "dynamic"], 128, 512, 512, 3, 3, false);
        let t = gemm_table(&res).render();
        assert!(t.contains("dynamic") && t.contains("speedup"));
        let res = run_gemv(&["ultra_125h"], &["static"], 512, 512, 2, 2, false);
        assert!(gemv_table(&res).render().contains("utilization"));
    }
}
