//! PR-3 perf trajectory: what folding accelerators into the coordinator
//! buys. Two scenarios on the same hardware (the 125H's four P-cores plus
//! its NPU) and the same scripted trace:
//!
//! * **serving** — the deterministic harness drives the micro model on two
//!   streams, once with the NPU unleased (`XpuAffinity::None`) and once
//!   floating; aggregate tok/s and mean TTFT come out. At micro-model
//!   kernel sizes the device's 20 µs launch overhead makes offload a wash —
//!   the class-keyed device table learns to keep decode on the cores,
//!   which is itself the result (the paper's reason to target prefill).
//! * **prefill GEMM** — the 7B-scale compute-bound kernel the paper's §4
//!   points at: per-stream sustained rates with and without the device.
//!
//! `dynpar bench pr3 [--out BENCH_pr3.json]` renders the JSON trajectory.

use crate::coordinator::{bus_share, AllocPolicy, Coordinator, XpuAffinity};
use crate::cpu::{presets, CpuSpec};
use crate::exec::{Executor, ParallelRuntime, PhantomWork};
use crate::kernels::cost;
use crate::model::ModelConfig;
use crate::perf::PerfConfig;
use crate::sched::DynamicScheduler;
use crate::server::fleet::DriftMonitor;
use crate::server::protocol::Request;
use crate::server::testing::TraceEvent;
use crate::server::BatcherOpts;
use crate::sim::xpu::AcceleratorSpec;
use crate::sim::{SimConfig, SimExecutor};
use crate::util::json::Json;

use super::common;

const WEIGHTS_SEED: u64 = 11;

fn machine() -> (CpuSpec, Vec<AcceleratorSpec>) {
    let ultra = presets::ultra_125h();
    let p_cores = [0usize, 1, 2, 3];
    (ultra.subset(&p_cores, bus_share(&ultra, &p_cores)), vec![AcceleratorSpec::npu()])
}

/// Frozen arrival script: 16 requests over two streams.
fn trace() -> Vec<TraceEvent> {
    let reqs = (0..16u64)
        .map(|i| Request {
            id: i,
            prompt: vec![1 + i as u32 * 5, 9, 4, 7, 2],
            max_new_tokens: 16,
        })
        .collect();
    common::streamed_trace(2, 2.0e-4, reqs)
}

/// (aggregate tok/s, mean TTFT µs) for one affinity choice.
fn serve_scenario(affinity: XpuAffinity) -> (f64, f64) {
    let (spec, accels) = machine();
    let coord = Coordinator::with_accelerators(
        spec.clone(),
        accels.clone(),
        AllocPolicy::Balanced,
        affinity,
    );
    let factory = common::xpu_factory(
        spec,
        accels,
        ModelConfig::micro(),
        WEIGHTS_SEED,
        SimConfig { execute_real: true, ..SimConfig::noiseless() },
        false,
    );
    let rep = common::serve_xpu(
        coord,
        &factory,
        BatcherOpts { max_batch: 4, prefill_chunk: 4 },
        DriftMonitor::disabled(),
        trace(),
    );
    (rep.throughput(), rep.mean_ttft() * 1e6)
}

/// Run `iters` of `probe` through a fresh dynamic `ParallelRuntime` over
/// `exec` and return the sustained rate (units/s of the last, converged
/// kernel) plus the executor for post-run inspection (e.g. the learned
/// device-ratio rows). Shared by this bench, `examples/multi_stream.rs`
/// part 4 and `coordinator_integration.rs` so the convergence protocol
/// cannot drift apart between them.
pub fn sustained_rate<E: Executor>(exec: E, probe: &PhantomWork, iters: usize) -> (f64, E) {
    let mut rt = ParallelRuntime::new(exec, Box::new(DynamicScheduler), PerfConfig::default());
    let mut wall = f64::INFINITY;
    for _ in 0..iters {
        wall = rt.run(probe).wall_secs;
    }
    (probe.cost.units as f64 / wall, rt.exec)
}

/// Per-stream sustained prefill-GEMM rates (units/s), summed over the two
/// leases: cores-only split vs cores + floating NPU.
fn prefill_scenario() -> (f64, f64) {
    let (spec, accels) = machine();
    let mut coord = Coordinator::with_accelerators(
        spec.clone(),
        accels.clone(),
        AllocPolicy::Balanced,
        XpuAffinity::Floating,
    );
    coord.admit(0);
    coord.admit(1);
    let probe = PhantomWork::new(cost::gemm_i8_cost(512, 2048, 2048));
    let mut hetero = 0.0;
    let mut cores = 0.0;
    for lease in coord.leases() {
        let exec = lease.xpu_executor(&spec, &accels, SimConfig::noiseless());
        hetero += sustained_rate(exec, &probe, 15).0;

        let sub = spec.subset(&lease.cores(), bus_share(&spec, &lease.cores()));
        cores += sustained_rate(SimExecutor::new(sub, SimConfig::noiseless()), &probe, 15).0;
    }
    (cores, hetero)
}

/// Full PR-3 trajectory as JSON.
pub fn run() -> Json {
    let (cores_tok_s, cores_ttft) = serve_scenario(XpuAffinity::None);
    let (npu_tok_s, npu_ttft) = serve_scenario(XpuAffinity::Floating);
    let (gemm_cores, gemm_npu) = prefill_scenario();
    let scenario = |tok_s: f64, ttft: f64| {
        Json::obj(vec![
            ("tok_s", Json::num(tok_s)),
            ("mean_ttft_us", Json::num(ttft)),
        ])
    };
    Json::obj(vec![
        ("bench", Json::str("pr3")),
        ("machine", Json::str("ultra_125h[4P] + npu")),
        ("model", Json::str("micro")),
        (
            "serving",
            Json::obj(vec![
                ("cores_only", scenario(cores_tok_s, cores_ttft)),
                ("cores_plus_npu", scenario(npu_tok_s, npu_ttft)),
            ]),
        ),
        (
            "prefill_gemm_7b_scale",
            Json::obj(vec![
                ("cores_only_units_s", Json::num(gemm_cores)),
                ("cores_plus_npu_units_s", Json::num(gemm_npu)),
                ("speedup", Json::num(gemm_npu / gemm_cores)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr3_trajectory_is_well_formed_and_sane() {
        let j = run();
        let serving = j.get("serving").unwrap();
        for s in ["cores_only", "cores_plus_npu"] {
            let row = serving.get(s).unwrap();
            assert!(row.get("tok_s").unwrap().as_f64().unwrap() > 0.0, "{s}");
            assert!(row.get("mean_ttft_us").unwrap().as_f64().unwrap() > 0.0, "{s}");
        }
        let gemm = j.get("prefill_gemm_7b_scale").unwrap();
        // the compute-bound prefill phase is where the device pays off
        assert!(gemm.get("speedup").unwrap().as_f64().unwrap() > 1.5);
        // micro-model serving must not regress under offload: the
        // class-keyed table learns within a few kernels to keep µs-scale
        // decode on the cores (only a short seeding transient remains)
        let a = serving.get("cores_only").unwrap().get("tok_s").unwrap().as_f64().unwrap();
        let b = serving.get("cores_plus_npu").unwrap().get("tok_s").unwrap().as_f64().unwrap();
        assert!(b > 0.9 * a, "offload regressed serving: {b} vs {a} tok/s");
    }
}
