//! Shared scaffolding for the PR-series perf trajectories: every
//! `pr*.rs` bench builds the same three things — a frozen arrival trace,
//! an engine factory over some simulated machine, and a JSON "side" of
//! tok/s + TTFT + makespan — and diverges only in the machine, the model
//! and the knob under test. The builders live here so the protocol (seeded
//! weights, `DynamicScheduler`, default `PerfConfig`, queue depth 64,
//! drain asserts) cannot drift apart between benches.

use std::sync::Arc;

use crate::coordinator::{Coordinator, Lease};
use crate::cpu::CpuSpec;
use crate::engine::Engine;
use crate::exec::Executor;
use crate::model::{ModelConfig, ModelWeights};
use crate::perf::PerfConfig;
use crate::sched::DynamicScheduler;
use crate::server::fleet::{DriftMonitor, EngineFactory};
use crate::server::protocol::Request;
use crate::server::testing::{run_fleet, HarnessReport, TraceEvent};
use crate::server::BatcherOpts;
use crate::sim::xpu::{AcceleratorSpec, XpuDispatch, XpuExecutor};
use crate::sim::{SimConfig, SimExecutor};
use crate::util::json::Json;

/// Admission-queue depth every PR bench serves with.
pub const QUEUE_DEPTH: usize = 64;

/// The PR benches' fixed model shape: 2 transformer layers, 128-position
/// KV, standard RoPE/rmsnorm constants. Only the dimensions under test
/// vary per bench.
pub fn bench_model(
    name: &str,
    vocab: usize,
    d_model: usize,
    n_heads: usize,
    d_ff: usize,
    prefill_len: usize,
) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        vocab,
        d_model,
        n_layers: 2,
        n_heads,
        d_ff,
        t_max: 128,
        prefill_len,
        rope_theta: 10000.0,
        rms_eps: 1e-5,
    }
}

/// One engine over `exec` with the bench protocol's fixed scheduler and
/// perf config.
pub fn bench_engine<E: Executor>(
    cfg: &ModelConfig,
    weights: &Arc<ModelWeights>,
    exec: E,
) -> Engine<E> {
    Engine::new(
        cfg.clone(),
        Arc::clone(weights),
        exec,
        Box::new(DynamicScheduler),
        PerfConfig::default(),
    )
}

/// Cores-only engine factory: every lease gets a sim engine over its core
/// subset of `machine`, with the fused-dispatch arena path on or off.
pub fn sim_factory(
    machine: CpuSpec,
    cfg: ModelConfig,
    seed: u64,
    sim: SimConfig,
    fused: bool,
) -> EngineFactory<SimExecutor> {
    let weights = Arc::new(ModelWeights::random_init(&cfg, seed));
    Box::new(move |lease: &Lease, _dispatch: XpuDispatch| {
        let mut e = bench_engine(&cfg, &weights, lease.sim_executor(&machine, sim.clone()));
        e.opts.fused = fused;
        e
    })
}

/// Heterogeneous engine factory: cores plus accelerators. With
/// `per_dispatch` the lease materializes the dispatch-specific executor
/// (`xpu_executor_mode`) so an async-batch pair gets its CpuOnly /
/// DeviceOnly halves; without it every engine sees the full split.
pub fn xpu_factory(
    machine: CpuSpec,
    accels: Vec<AcceleratorSpec>,
    cfg: ModelConfig,
    seed: u64,
    sim: SimConfig,
    per_dispatch: bool,
) -> EngineFactory<XpuExecutor> {
    let weights = Arc::new(ModelWeights::random_init(&cfg, seed));
    Box::new(move |lease: &Lease, dispatch: XpuDispatch| {
        let exec = if per_dispatch {
            lease.xpu_executor_mode(&machine, &accels, sim.clone(), dispatch)
        } else {
            lease.xpu_executor(&machine, &accels, sim.clone())
        };
        bench_engine(&cfg, &weights, exec)
    })
}

/// Frozen arrival script: `n_streams` stream connects at t = 0, then the
/// requests arrive round-robin across the streams at `1 µs + i * gap`.
pub fn streamed_trace(n_streams: u64, gap: f64, reqs: Vec<Request>) -> Vec<TraceEvent> {
    let mut t: Vec<TraceEvent> =
        (0..n_streams).map(|s| TraceEvent::Connect { at: 0.0, stream: s }).collect();
    for (i, req) in reqs.into_iter().enumerate() {
        t.push(TraceEvent::arrive(1.0e-6 + i as f64 * gap, i as u64 % n_streams, req));
    }
    t
}

/// Serve one frozen trace through the deterministic harness with the
/// bench protocol's queue depth, asserting the trace fully drained.
pub fn serve(
    coord: Coordinator,
    factory: &EngineFactory<SimExecutor>,
    opts: BatcherOpts,
    monitor: DriftMonitor,
    trace: Vec<TraceEvent>,
) -> HarnessReport {
    let rep = run_fleet(coord, factory, opts, QUEUE_DEPTH, monitor, trace);
    assert!(rep.all_finished(), "bench trace did not drain");
    rep
}

/// [`serve`] for heterogeneous (cores + accelerator) factories.
pub fn serve_xpu(
    coord: Coordinator,
    factory: &EngineFactory<XpuExecutor>,
    opts: BatcherOpts,
    monitor: DriftMonitor,
    trace: Vec<TraceEvent>,
) -> HarnessReport {
    let rep = run_fleet(coord, factory, opts, QUEUE_DEPTH, monitor, trace);
    assert!(rep.all_finished(), "bench trace did not drain");
    rep
}

/// The JSON fields every bench reports per scenario side. Callers extend
/// the vector with bench-specific fields before wrapping it in an object.
pub fn side_fields(rep: &HarnessReport) -> Vec<(&'static str, Json)> {
    vec![
        ("tok_s", Json::num(rep.throughput())),
        ("mean_ttft_us", Json::num(rep.mean_ttft() * 1e6)),
        ("makespan_s", Json::num(rep.makespan)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streamed_trace_connects_then_round_robins() {
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request { id: i, prompt: vec![1, 2], max_new_tokens: 1 })
            .collect();
        let t = streamed_trace(2, 1.0e-4, reqs);
        assert_eq!(t.len(), 6);
        assert!(matches!(t[0], TraceEvent::Connect { stream: 0, .. }));
        assert!(matches!(t[1], TraceEvent::Connect { stream: 1, .. }));
        match (&t[2], &t[5]) {
            (
                TraceEvent::Arrive { stream: s0, at: a0, .. },
                TraceEvent::Arrive { stream: s3, at: a3, .. },
            ) => {
                assert_eq!((*s0, *s3), (0, 1));
                assert!(a3 > a0, "arrivals must be spaced by the gap");
            }
            other => panic!("expected arrivals, got {other:?}"),
        }
    }

    #[test]
    fn bench_model_pins_the_shared_shape() {
        let m = bench_model("t", 512, 256, 4, 512, 24);
        assert_eq!((m.n_layers, m.t_max), (2, 128));
        assert_eq!(m.prefill_len, 24);
        assert_eq!(m.d_model, 256);
    }
}
