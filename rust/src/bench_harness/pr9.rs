//! PR-9 cluster tier: aggregate throughput vs machine count behind one
//! admission plane, plus what cross-machine re-placement buys back when a
//! whole machine degrades mid-trace.
//!
//! One Poisson arrival script (48 requests over 8 streams, 32-token
//! prompts, 48 decode rounds each) is served through the deterministic
//! cluster harness at three cluster sizes built from heterogeneous
//! machines — a stock 12900k, a 6P+6E cut of it, a 12-core homogeneous
//! box and a 125H — so the scaling curve reflects capability-proportional
//! placement, not N copies of one machine:
//!
//! * **scaling** — aggregate tok/s at k = 1, 2, 4 machines; the k = 4
//!   cluster must clear 3.5x the single 12900k (the capability-sum ratio
//!   is 271/68 ≈ 3.99, so near-linear placement has headroom to spare).
//! * **degrade-recovery** — the same 4-machine trace with machine 0
//!   collapsing to 1% compute mid-run, served once with the cluster drift
//!   monitor disabled (streams stay stuck on the dying machine) and once
//!   enabled (skew fires, streams migrate bit-identically over the
//!   interconnect). The ratio of the two aggregate throughputs is the
//!   recovery factor.
//!
//! Timing comes from the cost model alone (`execute_real: false`): the
//! trace moves ~1500 prompt and ~2300 decode tokens of a d_model-1024
//! model, and real matmuls would dominate bench wall-clock without
//! changing any virtual timestamp.
//!
//! `dynpar bench pr9 [--out BENCH_pr9.json]` renders the JSON report.

use crate::cluster::harness::{run_cluster, ClusterReport};
use crate::cluster::{ClusterCoordinator, InterconnectSpec, MachineSpec};
use crate::cpu::{presets, CpuSpec};
use crate::model::ModelConfig;
use crate::router::ServingPolicy;
use crate::server::fleet::DriftMonitor;
use crate::server::protocol::Request;
use crate::server::testing::TraceEvent;
use crate::sim::SimConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::common;

const WEIGHTS_SEED: u64 = 29;
const N_STREAMS: u64 = 8;
const N_REQ: u64 = 48;
const PROMPT_LEN: usize = 32;
const MAX_NEW: usize = 48;
const CHUNK: usize = 16;
/// mean Poisson inter-arrival gap (seconds)
const MEAN_GAP: f64 = 2.0e-4;
/// when machine 0 collapses in the degrade scenarios (virtual seconds,
/// just after the ~9.6 ms arrival burst, early in the ~170 ms healthy
/// 4-machine makespan so most decode work is still ahead of the failure)
const DEGRADE_AT: f64 = 0.01;
const DEGRADE_FRACTION: f64 = 0.99;

/// The four machines, most capable bus first: stock 12900k (68 GB/s), a
/// 6P+6E salvage cut of it (51 GB/s), a 12-core homogeneous box
/// (80 GB/s) and the 125H (72 GB/s).
fn machines() -> Vec<CpuSpec> {
    let k = presets::core_12900k();
    let cut: Vec<usize> = (0..6).chain(8..14).collect();
    vec![
        k.clone(),
        k.subset(&cut, 51.0),
        presets::homogeneous(12),
        presets::ultra_125h(),
    ]
}

/// d_model-1024 2-layer model: decode at this width is bus-bound on every
/// bench machine, so healthy per-machine rates track bus capability and
/// the scaling curve measures the placer, not kernel quirks.
fn model() -> ModelConfig {
    common::bench_model("pr9", 1024, 1024, 8, 2048, CHUNK)
}

/// Frozen Poisson script: 8 streams connect at t = 0, then 48 requests
/// arrive with seeded exponential gaps, round-robin across the streams.
fn trace() -> Vec<TraceEvent> {
    let mut rng = Rng::new(0x9E3779B97F4A7C15);
    let mut t: Vec<TraceEvent> =
        (0..N_STREAMS).map(|s| TraceEvent::Connect { at: 0.0, stream: s }).collect();
    let mut at = 1.0e-6;
    for i in 0..N_REQ {
        at += -(1.0 - rng.f64()).ln() * MEAN_GAP;
        let prompt: Vec<u32> =
            (0..PROMPT_LEN as u32).map(|k| 1 + (i as u32 * 11 + k * 13) % 1000).collect();
        let req = Request { id: i, prompt, max_new_tokens: MAX_NEW };
        t.push(TraceEvent::arrive(at, i % N_STREAMS, req));
    }
    t
}

/// Serve the frozen trace on the first `k` machines.
fn scenario(k: usize, monitor: DriftMonitor, degrade: bool) -> ClusterReport {
    let cpus: Vec<CpuSpec> = machines().into_iter().take(k).collect();
    let specs: Vec<MachineSpec> = cpus.iter().cloned().map(MachineSpec::cores_only).collect();
    let cluster = ClusterCoordinator::new(&specs, InterconnectSpec::default());
    let factories: Vec<_> = cpus
        .into_iter()
        .map(|cpu| common::sim_factory(cpu, model(), WEIGHTS_SEED, SimConfig::noiseless(), false))
        .collect();
    let mut t = trace();
    if degrade {
        t.push(TraceEvent::DegradeMachine {
            at: DEGRADE_AT,
            machine: 0,
            fraction: DEGRADE_FRACTION,
        });
    }
    let policy = ServingPolicy::builder()
        .max_batch(4)
        .prefill_chunk(CHUNK)
        .queue_depth(common::QUEUE_DEPTH)
        .drift(monitor.threshold, monitor.cooldown)
        .build()
        .expect("bench policy validates");
    let rep = run_cluster(cluster, &factories, &policy, t);
    assert!(rep.all_finished(), "bench trace did not drain");
    rep
}

/// The cluster drift monitor the recovery scenario serves with: skew 2.0
/// fires after 8 cluster-level observation folds of cooldown. The
/// threshold sits above the ~1.7 spread that pairwise strength folds can
/// open between healthy machines (observe() scales mass over whichever
/// subset has a full window, so healthy ratios wander) but well under the
/// ~2.3+ skew a machine pinned at 1% compute produces, so the dead
/// machine fires exactly one re-placement instead of churning.
fn recovery_monitor() -> DriftMonitor {
    DriftMonitor::new(2.0, 8)
}

/// Full PR-9 report as JSON.
pub fn run() -> Json {
    let k1 = scenario(1, DriftMonitor::disabled(), false);
    let k2 = scenario(2, DriftMonitor::disabled(), false);
    let k4 = scenario(4, DriftMonitor::disabled(), false);
    let scaling = k4.throughput() / k1.throughput();
    let stuck = scenario(4, DriftMonitor::disabled(), true);
    let replaced = scenario(4, recovery_monitor(), true);
    let recovery = replaced.throughput() / stuck.throughput();
    let side = |rep: &ClusterReport| Json::obj(common::side_fields(&rep.base));
    let trigger_skew = replaced.cluster_skew_at_trigger.first().copied().unwrap_or(f64::NAN);
    Json::obj(vec![
        ("bench", Json::str("pr9")),
        ("machines", Json::str("12900k | 12900k[6P+6E] | homogeneous(12) | ultra_125h")),
        ("model", Json::str("pr9 (d1024, 2L, cost-model timing)")),
        ("trace", Json::str("48 req x (32 prompt / chunk 16 + 48 decode), 8 streams, Poisson")),
        ("k1", side(&k1)),
        ("k2", side(&k2)),
        ("k4", side(&k4)),
        ("scaling", Json::num(scaling)),
        (
            "degrade",
            Json::obj(vec![
                ("no_replacement", side(&stuck)),
                ("with_replacement", side(&replaced)),
                ("recovery", Json::num(recovery)),
                ("replacements", Json::num(replaced.replacements as f64)),
                ("migrated_sessions", Json::num(replaced.migrated_sessions as f64)),
                ("interconnect_bytes", Json::num(replaced.interconnect_bytes)),
                ("skew_at_trigger", Json::num(trigger_skew)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr9_cluster_scales_and_recovers() {
        let j = run();
        // acceptance floor: 4 heterogeneous machines must clear 3.5x one
        // 12900k (the capability-sum ratio leaves ~0.5x of headroom)
        let scaling = j.get("scaling").unwrap().as_f64().unwrap();
        assert!(scaling >= 3.5, "cluster scaling {scaling:.3} below the 3.5x floor");
        let d = j.get("degrade").unwrap();
        // re-placement must actually fire and buy back 1.3x over riding
        // out the degrade on the dying machine
        let recovery = d.get("recovery").unwrap().as_f64().unwrap();
        assert!(recovery >= 1.3, "degrade recovery {recovery:.3} below the 1.3x floor");
        assert!(d.get("replacements").unwrap().as_f64().unwrap() >= 1.0);
        assert!(d.get("migrated_sessions").unwrap().as_f64().unwrap() >= 1.0);
        // cross-machine moves are never free
        assert!(d.get("interconnect_bytes").unwrap().as_f64().unwrap() > 0.0);
        let skew = d.get("skew_at_trigger").unwrap().as_f64().unwrap();
        assert!(skew > 1.5, "re-placement fired below the skew threshold: {skew}");
    }
}
