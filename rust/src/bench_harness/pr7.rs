//! PR-7 perf trajectory: what [`ExecMode::Disaggregated`] phase splitting
//! buys over a blended lease on the 12900k.
//!
//! One scripted long-prompt trace (24 requests, 96-token prompts chunked
//! by 24, 16 decode rounds each) is served twice through the
//! deterministic harness on the stock `core_12900k` preset:
//!
//! * **blended** — the baseline: one batcher owns all 16 cores and
//!   interleaves prefill chunks and decode rounds on a single virtual
//!   clock, so every request's first token queues behind whole prefill
//!   chunks of its batch neighbours.
//! * **disaggregated** — the tentpole: [`Coordinator::phase_leases`]
//!   splits the lease into a GEMM-steered prefill sub-lease (the P-cores)
//!   and a GEMV-steered decode sub-lease (the rest), each with its
//!   waterfill-derived share of the 68 GB/s bus. Finished prompts migrate
//!   decode-side by bit-identical session handoff
//!   ([`crate::server::fleet::route_handoff`]), so prefill of the next
//!   cohort overlaps decode of the previous one on two concurrent clocks.
//!
//! The model is deliberately small (d_model 256): per-kernel dispatch
//! overhead is then a significant minority of round time, which is
//! exactly the regime where phase overlap — not raw FLOPs — decides both
//! TTFT and aggregate throughput. (At d_model 2048 the same trace is
//! bus-bound and the static phase split buys nothing; see ROADMAP.)
//!
//! `dynpar bench pr7 [--out BENCH_pr7.json]` renders the JSON trajectory.

use crate::coordinator::{AllocPolicy, Coordinator, ExecMode};
use crate::cpu::presets;
use crate::model::ModelConfig;
use crate::server::fleet::DriftMonitor;
use crate::server::protocol::Request;
use crate::server::testing::{HarnessReport, TraceEvent};
use crate::server::BatcherOpts;
use crate::sim::SimConfig;
use crate::util::json::Json;

use super::common;

const WEIGHTS_SEED: u64 = 23;
const N_REQ: u64 = 24;
const PROMPT_LEN: usize = 96;
const MAX_NEW: usize = 16;
const CHUNK: usize = 24;

/// Small-vocab 2-layer model at d_model 256: small enough that the
/// 2 µs/kernel dispatch overhead is a real fraction of every round (the
/// phase-overlap regime), large enough that the partitioned kernels still
/// exercise the hybrid P/E split.
fn model() -> ModelConfig {
    common::bench_model("pr7", 512, 256, 4, 512, CHUNK)
}

/// Frozen arrival script: one stream, 24 near-simultaneous long-prompt
/// requests — 96 prompt tokens (4 prefill chunks) then 16 decode rounds
/// each, so prefill and decode carry comparable total work and the phase
/// pipeline stays full for ~6 cohorts.
fn trace() -> Vec<TraceEvent> {
    let reqs = (0..N_REQ)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..PROMPT_LEN as u32).map(|k| 1 + (i as u32 * 7 + k * 13) % 500).collect();
            Request { id: i, prompt, max_new_tokens: MAX_NEW }
        })
        .collect();
    common::streamed_trace(1, 1.0e-4, reqs)
}

/// Serve the frozen trace under one execution mode.
fn scenario(mode: ExecMode) -> HarnessReport {
    let spec = presets::core_12900k();
    let mut coord = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
    coord.set_exec_mode(mode);
    // cost-model timing only: the trace moves ~2700 prompt tokens and
    // 384 decode tokens; real matmuls would dominate bench wall-clock
    // without changing any virtual timestamp
    let factory =
        common::sim_factory(spec, model(), WEIGHTS_SEED, SimConfig::noiseless(), false);
    let rep = common::serve(
        coord,
        &factory,
        BatcherOpts { max_batch: 4, prefill_chunk: CHUNK },
        DriftMonitor::disabled(),
        trace(),
    );
    assert_eq!(rep.total_decoded, N_REQ as usize * MAX_NEW, "tokens went missing");
    rep
}

/// Full PR-7 trajectory as JSON.
pub fn run() -> Json {
    let blended = scenario(ExecMode::IntraKernel);
    let disagg = scenario(ExecMode::Disaggregated);
    let speedup = disagg.throughput() / blended.throughput();
    let ttft_ratio = blended.mean_ttft() / disagg.mean_ttft();
    let side = |rep: &HarnessReport| {
        let mut fields = common::side_fields(rep);
        fields.push(("handoffs", Json::num(rep.handoffs as f64)));
        Json::obj(fields)
    };
    Json::obj(vec![
        ("bench", Json::str("pr7")),
        ("machine", Json::str("core_12900k (8P+8E, bus 68 GB/s)")),
        ("model", Json::str("pr7 (d256, 2L, cost-model timing)")),
        ("trace", Json::str("24 req x (96 prompt / chunk 24 + 16 decode), 1 stream")),
        ("blended", side(&blended)),
        ("disaggregated", side(&disagg)),
        ("speedup", Json::num(speedup)),
        ("ttft_ratio", Json::num(ttft_ratio)),
        ("observations", Json::num(disagg.observations_accepted as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr7_disaggregation_beats_blended_on_ttft_and_throughput() {
        let j = run();
        // acceptance floor: disaggregated must win BOTH metrics — the
        // timing port places the wins near 1.35x/1.33x, so 1.10x leaves
        // headroom without accepting a regression to parity
        let speedup = j.get("speedup").unwrap().as_f64().unwrap();
        assert!(speedup >= 1.10, "disagg throughput speedup {speedup:.3} below the 1.10x floor");
        let ttft = j.get("ttft_ratio").unwrap().as_f64().unwrap();
        assert!(ttft >= 1.10, "disagg TTFT improvement {ttft:.3} below the 1.10x floor");
        // every request must actually flow prefill→decode across the pair
        let handoffs =
            j.get("disaggregated").unwrap().get("handoffs").unwrap().as_f64().unwrap();
        assert_eq!(handoffs as u64, N_REQ, "not every request was handed off");
        let blended_handoffs =
            j.get("blended").unwrap().get("handoffs").unwrap().as_f64().unwrap();
        assert_eq!(blended_handoffs as u64, 0, "blended mode must not hand off");
    }
}
