//! Fixed-width table / JSON rendering for benchmark results.

use crate::util::json::Json;

/// A simple column-aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// JSON form: array of objects keyed by header.
    pub fn to_json(&self) -> Json {
        Json::arr(self.rows.iter().map(|row| {
            Json::Object(
                self.headers
                    .iter()
                    .zip(row)
                    .map(|(h, c)| {
                        let v = c
                            .trim_end_matches('x')
                            .parse::<f64>()
                            .map(Json::Num)
                            .unwrap_or_else(|_| Json::Str(c.clone()));
                        (h.clone(), v)
                    })
                    .collect(),
            )
        }))
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new(&["cpu", "sched", "latency"]);
        t.row(vec!["ultra_125h".into(), "dynamic".into(), "1.2 ms".into()]);
        t.row(vec!["core_12900k".into(), "static".into(), "2.0 ms".into()]);
        let s = t.render();
        assert!(s.contains("ultra_125h"));
        assert_eq!(s.lines().count(), 4);
        // columns aligned: both data lines have 'static'/'dynamic' at same offset
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].find("dynamic"), lines[3].find("static"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn json_form_parses_numbers() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["x".into(), "2.5".into()]);
        let j = t.to_json();
        assert_eq!(j.idx(0).unwrap().get("value"), Some(&Json::Num(2.5)));
        assert_eq!(j.idx(0).unwrap().get("name"), Some(&Json::Str("x".into())));
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(5e-6).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains("s"));
    }
}
