//! PR-8 raw-speed tier: what the fused-dispatch arena path
//! ([`crate::engine::EngineOpts::fused`]) buys over the per-matrix
//! baseline, with bandwidth metering on both sides.
//!
//! The same frozen long-prompt trace as the PR-7 bench (24 requests,
//! 96-token prompts chunked by 24, 16 decode rounds each) is served twice
//! through the deterministic harness on the stock `core_12900k` preset
//! under a single blended lease:
//!
//! * **unfused** — the per-matrix baseline: every projection is its own
//!   dispatch (8 kernels per decode layer, 7 GEMMs + one attention call
//!   per position per prefill layer), each paying the 2 µs dispatch
//!   overhead and its own partition/observe round-trip.
//! * **fused** — the tentpole path: QKV and gate/up collapse into single
//!   stacked dispatches and prefill attention batches all chunk positions
//!   into one kernel (5 dispatches per layer in both phases), over the
//!   same per-engine scratch arena. Token streams are bit-identical to
//!   the baseline — the fusion only re-tiles the parallel dimension.
//!
//! Both sides meter kernel memory traffic ([`crate::perf::bandwidth`]):
//! the report carries achieved GB/s and utilization of the lease's
//! waterfill bus share, so the win decomposes into dispatch overhead
//! saved vs bandwidth actually drawn.
//!
//! `dynpar bench pr8 [--out BENCH_pr8.json]` renders the JSON report.

use crate::coordinator::{AllocPolicy, Coordinator, ExecMode};
use crate::cpu::presets;
use crate::model::ModelConfig;
use crate::server::fleet::DriftMonitor;
use crate::server::protocol::Request;
use crate::server::testing::{BandwidthUse, HarnessReport, TraceEvent};
use crate::server::BatcherOpts;
use crate::sim::SimConfig;
use crate::util::json::Json;

use super::common;

const WEIGHTS_SEED: u64 = 23;
const N_REQ: u64 = 24;
const PROMPT_LEN: usize = 96;
const MAX_NEW: usize = 16;
const CHUNK: usize = 24;

/// Same d_model-256 model as the PR-7 bench: small enough that the
/// 2 µs/kernel dispatch overhead is a real fraction of every round —
/// exactly the regime the fused path targets.
fn model() -> ModelConfig {
    common::bench_model("pr8", 512, 256, 4, 512, CHUNK)
}

/// Frozen arrival script — identical to the PR-7 trace so the two benches
/// stay comparable across PRs.
fn trace() -> Vec<TraceEvent> {
    let reqs = (0..N_REQ)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..PROMPT_LEN as u32).map(|k| 1 + (i as u32 * 7 + k * 13) % 500).collect();
            Request { id: i, prompt, max_new_tokens: MAX_NEW }
        })
        .collect();
    common::streamed_trace(1, 1.0e-4, reqs)
}

/// Serve the frozen trace with the fused path on or off.
fn scenario(fused: bool) -> HarnessReport {
    let spec = presets::core_12900k();
    let mut coord = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
    coord.set_exec_mode(ExecMode::IntraKernel);
    // cost-model timing only: real matmuls would dominate bench
    // wall-clock without changing any virtual timestamp
    let factory =
        common::sim_factory(spec, model(), WEIGHTS_SEED, SimConfig::noiseless(), fused);
    let rep = common::serve(
        coord,
        &factory,
        BatcherOpts { max_batch: 4, prefill_chunk: CHUNK },
        DriftMonitor::disabled(),
        trace(),
    );
    assert_eq!(rep.total_decoded, N_REQ as usize * MAX_NEW, "tokens went missing");
    rep
}

fn bandwidth_of(rep: &HarnessReport) -> BandwidthUse {
    rep.bandwidth.get(&0).cloned().unwrap_or_default()
}

/// Full PR-8 report as JSON.
pub fn run() -> Json {
    let unfused = scenario(false);
    let fused = scenario(true);
    let speedup = fused.throughput() / unfused.throughput();
    let side = |rep: &HarnessReport| {
        let bw = bandwidth_of(rep);
        let mut fields = common::side_fields(rep);
        fields.push(("bytes_moved", Json::num(bw.bytes)));
        fields.push(("kernel_secs", Json::num(bw.kernel_secs)));
        fields.push(("achieved_gbps", Json::num(bw.achieved_gbps())));
        fields.push(("bus_share_gbps", Json::num(bw.bus_share_gbps)));
        fields.push(("bandwidth_utilization", Json::num(bw.utilization())));
        Json::obj(fields)
    };
    Json::obj(vec![
        ("bench", Json::str("pr8")),
        ("machine", Json::str("core_12900k (8P+8E, bus 68 GB/s)")),
        ("model", Json::str("pr8 (d256, 2L, cost-model timing)")),
        ("trace", Json::str("24 req x (96 prompt / chunk 24 + 16 decode), 1 stream")),
        ("unfused", side(&unfused)),
        ("fused", side(&fused)),
        ("speedup", Json::num(speedup)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr8_fused_arena_path_beats_per_matrix_baseline() {
        let j = run();
        // acceptance floor: the timing port places the fused win near
        // 1.4x at d256 — 1.15 leaves headroom without accepting parity
        let speedup = j.get("speedup").unwrap().as_f64().unwrap();
        assert!(speedup >= 1.15, "fused speedup {speedup:.3} below the 1.15x floor");
        // bandwidth metering must be live on both sides; fused stacking
        // reads prefill activation rows once instead of per-matrix, so
        // traffic may drop a few percent but never diverge
        for key in ["unfused", "fused"] {
            let s = j.get(key).unwrap();
            let util = s.get("bandwidth_utilization").unwrap().as_f64().unwrap();
            assert!(util > 0.0, "{key}: no bandwidth utilization recorded");
            assert!(util <= 1.0, "{key}: utilization {util:.3} above the bus share");
        }
        let bu = j.get("unfused").unwrap().get("bytes_moved").unwrap().as_f64().unwrap();
        let bf = j.get("fused").unwrap().get("bytes_moved").unwrap().as_f64().unwrap();
        let rel = (bu - bf).abs() / bu.max(1.0);
        assert!(rel < 0.05, "fusion changed memory traffic by {:.1}%", rel * 100.0);
    }
}
