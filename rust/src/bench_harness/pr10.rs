//! PR-10 strategy-router trajectory: what live strategy switching buys
//! over every static configuration on a bursty, mixed, multi-tenant trace.
//!
//! One scripted three-phase trace is served three times on the stock
//! `core_12900k` preset through the deterministic harness
//! ([`crate::server::testing::run_trace`]):
//!
//! * **phase A** (decode-heavy chat) — interactive class-0 requests with a
//!   10 ms TTFT SLO plus sheddable class-2 background work, short prompts,
//!   long decodes. The blended intra-kernel split is the right strategy:
//!   all 16 cores decode.
//! * **phase B** (long-prompt burst) — a batch tenant (class 1) lands 16
//!   back-to-back 96-token prompts while background arrivals keep coming.
//!   Phase-disaggregated serving is the right strategy: prefill stops
//!   degrading decode (see the PR-7 bench: 1.35x on exactly this shape).
//! * **phase C** (chat again) — the burst drains and the mix returns to
//!   decode-heavy.
//!
//! The three runs:
//!
//! * **router** — [`crate::router::StrategyRouter`] watches the arrival
//!   window's prefill share and switches IntraKernel → Disaggregated when
//!   the burst lands, then back once the mix turns over (two switches,
//!   each a bit-identical session migration). The SLO gate sheds
//!   background arrivals while the burst backlog predicts a class-0 miss.
//! * **blended static** — IntraKernel for the whole trace: the burst
//!   queues behind chat decode and the TTFT tail blows up.
//! * **disaggregated static** — Disaggregated for the whole trace: the
//!   burst itself is fine, but its slower prefill drain leaves the
//!   backlog below the shed threshold, so the background stragglers are
//!   *served* — ten-plus milliseconds late — and land in the tail the
//!   router's SLO gate sheds away.
//!
//! The acceptance claim is the paper's, one level up: no static strategy
//! is right for the whole trace, and the router beats the *best* static
//! on p99 TTFT at equal (±2%) throughput with zero class-0 SLO violations.
//!
//! `dynpar bench pr10 [--out BENCH_pr10.json]` renders the JSON report.

use crate::coordinator::{AllocPolicy, Coordinator, ExecMode};
use crate::cpu::presets;
use crate::model::ModelConfig;
use crate::router::{RouterConfig, ServingPolicy};
use crate::server::protocol::Request;
use crate::server::testing::{run_trace, HarnessReport, TraceEvent};
use crate::sim::SimConfig;
use crate::util::json::Json;

use super::common;

const WEIGHTS_SEED: u64 = 31;
const CHUNK: usize = 24;

/// interactive chat shape: 8 prompt tokens, 32 decode rounds — prefill
/// share 0.2, well under the router's 0.35 exit threshold
const CHAT_PROMPT: usize = 8;
const CHAT_NEW: usize = 32;
/// batch-tenant burst shape: 96 prompt tokens (4 chunks), 8 decode rounds
/// — prefill share 0.92, well over the 0.6 enter threshold
const BURST_PROMPT: usize = 96;
const BURST_NEW: usize = 8;

/// class-0 TTFT target (seconds): comfortably above the router's chat-phase
/// tail (~0.3 ms), comfortably below the burst backlog's predicted drain
/// time (~16 ms at the backlog peak)
const TTFT_TARGET: f64 = 0.010;

const N_CHAT_A: u64 = 16;
const N_BURST: u64 = 16;
const N_CHAT_C: u64 = 14;
/// chat arrival gap: light enough that every config serves the
/// interactive class inside its SLO — the contest is decided on the
/// burst backlog, not on chat decode capacity
const GAP_CHAT: f64 = 3.0e-3;
/// burst arrival gap: just past the prefill service rate, so a real
/// backlog forms — under the blended config chat decode and burst prefill
/// degrade each other, and the SLO gate's predicted wait crosses the
/// class-0 target while the backlog peaks
const GAP_BURST: f64 = 1.15e-3;
/// when the burst lands / when the mix turns back over
const BURST_AT: f64 = 0.050;
const CHAT_C_AT: f64 = 0.075;

/// Priority classes: 0 = interactive (10 ms TTFT SLO, never shed),
/// 1 = batch burst (no SLO, never shed — it queues), 2 = background
/// (no SLO, sheddable first).
const CLASS_CHAT: usize = 0;
const CLASS_BURST: usize = 1;
const CLASS_BACKGROUND: usize = 2;

/// Same d256 phase-overlap regime as the PR-7 bench: small enough that
/// dispatch overhead is a real fraction of round time (where strategy
/// choice decides TTFT), large enough to exercise the hybrid P/E split.
fn model() -> ModelConfig {
    common::bench_model("pr10", 512, 256, 4, 512, CHUNK)
}

fn chat_req(id: u64) -> Request {
    let prompt: Vec<u32> =
        (0..CHAT_PROMPT as u32).map(|k| 1 + (id as u32 * 7 + k * 13) % 500).collect();
    Request { id, prompt, max_new_tokens: CHAT_NEW }
}

fn burst_req(id: u64) -> Request {
    let prompt: Vec<u32> =
        (0..BURST_PROMPT as u32).map(|k| 1 + (id as u32 * 11 + k * 17) % 500).collect();
    Request { id, prompt, max_new_tokens: BURST_NEW }
}

/// The frozen three-phase multi-tenant script (one stream; priority is an
/// admission property, not a connection property).
fn trace() -> Vec<TraceEvent> {
    let mut t = vec![TraceEvent::Connect { at: 0.0, stream: 0 }];
    let mut id = 0u64;
    let mut chat_wave = |t: &mut Vec<TraceEvent>, start: f64, n: u64| {
        for i in 0..n {
            let at = start + i as f64 * GAP_CHAT;
            t.push(TraceEvent::arrive_class(at, 0, chat_req(id), CLASS_CHAT));
            id += 1;
            // every third chat arrival drags a background request along
            if i % 3 == 2 {
                let at = at + 0.4 * GAP_CHAT;
                t.push(TraceEvent::arrive_class(at, 0, chat_req(id), CLASS_BACKGROUND));
                id += 1;
            }
        }
    };
    chat_wave(&mut t, 1.0e-6, N_CHAT_A);
    for i in 0..N_BURST {
        let at = BURST_AT + i as f64 * GAP_BURST;
        t.push(TraceEvent::arrive_class(at, 0, burst_req(id), CLASS_BURST));
        id += 1;
    }
    // background keeps arriving while the burst backlog drains — exactly
    // the load the SLO gate exists to shed
    for i in 0..8 {
        let at = BURST_AT + 2.0e-3 + i as f64 * 2.0e-3;
        t.push(TraceEvent::arrive_class(at, 0, chat_req(id), CLASS_BACKGROUND));
        id += 1;
    }
    chat_wave(&mut t, CHAT_C_AT, N_CHAT_C);
    t
}

/// The one policy of the bench, with the strategy router on or pinned to a
/// static mode. Classes and SLOs are identical across all three runs —
/// only the strategy decision differs.
fn policy(router: bool, mode: Option<ExecMode>) -> ServingPolicy {
    let mut b = ServingPolicy::builder()
        .max_batch(4)
        .prefill_chunk(CHUNK)
        .queue_depth(common::QUEUE_DEPTH)
        .drift(f64::INFINITY, 0)
        .slo(CLASS_CHAT, TTFT_TARGET)
        .class("burst", f64::INFINITY, false)
        .class("background", f64::INFINITY, true);
    if router {
        b = b.router(RouterConfig { cooldown_secs: 5.0e-3, ..RouterConfig::default() });
    }
    if let Some(m) = mode {
        b = b.mode(m);
    }
    b.build().expect("bench policy validates")
}

/// Serve the frozen trace under one policy.
fn scenario(policy: &ServingPolicy) -> HarnessReport {
    let spec = presets::core_12900k();
    let coord = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
    // cost-model timing only: the trace moves ~1900 prompt and ~1300
    // decode tokens; real matmuls would dominate bench wall-clock without
    // changing any virtual timestamp
    let factory = common::sim_factory(spec, model(), WEIGHTS_SEED, SimConfig::noiseless(), false);
    let rep = run_trace(coord, &factory, policy, trace());
    assert!(rep.all_finished(), "bench trace did not drain");
    rep
}

fn p99(rep: &HarnessReport) -> f64 {
    rep.ttft_summary().expect("bench run served requests").p99
}

fn side(rep: &HarnessReport) -> Json {
    let mut fields = common::side_fields(rep);
    fields.push(("p99_ttft_us", Json::num(p99(rep) * 1e6)));
    fields.push(("shed", Json::num(rep.shed.len() as f64)));
    let c0 = rep.ttft_summary_class(CLASS_CHAT).expect("class 0 was served");
    fields.push(("class0_p99_ttft_us", Json::num(c0.p99 * 1e6)));
    fields.push((
        "class0_slo_violations",
        Json::num(rep.slo_violations(CLASS_CHAT, TTFT_TARGET) as f64),
    ));
    Json::obj(fields)
}

/// Full PR-10 report as JSON.
pub fn run() -> Json {
    let routed = scenario(&policy(true, None));
    let blended = scenario(&policy(false, Some(ExecMode::IntraKernel)));
    let disagg = scenario(&policy(false, Some(ExecMode::Disaggregated)));
    let best_static_p99 = p99(&blended).min(p99(&disagg));
    let best_static_tput = blended.throughput().max(disagg.throughput());
    // > 1.0 ⇔ the router beats every static config on p99 TTFT (the
    // CI-gated headline number)
    let p99_ratio = best_static_p99 / p99(&routed);
    let tput_ratio = routed.throughput() / best_static_tput;
    let switches = Json::arr(routed.strategy_switches.iter().map(|(at, s)| {
        Json::obj(vec![
            ("at_ms", Json::num(at * 1e3)),
            ("to", Json::str(format!("{:?}", s.mode))),
        ])
    }));
    Json::obj(vec![
        ("bench", Json::str("pr10")),
        ("machine", Json::str("core_12900k (8P+8E, bus 68 GB/s)")),
        ("model", Json::str("pr10 (d256, 2L, cost-model timing)")),
        (
            "trace",
            Json::str(
                "3 phases: chat (8p/32d, SLO 10ms) | 16-req burst (96p/8d) + background | chat",
            ),
        ),
        ("router", side(&routed)),
        ("blended_static", side(&blended)),
        ("disaggregated_static", side(&disagg)),
        ("p99_vs_best_static", Json::num(p99_ratio)),
        ("throughput_vs_best_static", Json::num(tput_ratio)),
        ("switches", switches),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr10_router_beats_every_static_config() {
        let routed = scenario(&policy(true, None));
        let blended = scenario(&policy(false, Some(ExecMode::IntraKernel)));
        let disagg = scenario(&policy(false, Some(ExecMode::Disaggregated)));

        // the router took the phase transitions: into the disaggregated
        // pair when the burst landed, back to blended when the mix turned
        let modes: Vec<ExecMode> =
            routed.strategy_switches.iter().map(|(_, s)| s.mode).collect();
        assert_eq!(
            modes,
            vec![ExecMode::Disaggregated, ExecMode::IntraKernel],
            "switch sequence {modes:?} (at {:?})",
            routed.strategy_switches
        );

        // acceptance: beat the BEST static on p99 TTFT at equal throughput
        let best_p99 = p99(&blended).min(p99(&disagg));
        let ratio = best_p99 / p99(&routed);
        assert!(ratio >= 1.05, "router p99 only {ratio:.3}x the best static (need >= 1.05)");
        let tput = routed.throughput() / blended.throughput().max(disagg.throughput());
        assert!(tput >= 0.98, "router throughput ratio {tput:.3} below the 0.98 floor");

        // the SLO story: the protected class never misses its target under
        // the router, and everything shed was strictly lower-priority
        assert_eq!(
            routed.slo_violations(CLASS_CHAT, TTFT_TARGET),
            0,
            "class-0 p99 {:?}",
            routed.ttft_summary_class(CLASS_CHAT).map(|s| s.p99)
        );
        assert!(!routed.shed.is_empty(), "burst backlog shed no background work");
        assert!(
            routed.shed_classes().iter().all(|&c| c >= 1),
            "a protected class was shed: {:?}",
            routed.shed_classes()
        );
        // shedding answered clients immediately — nothing hangs
        assert!(routed.all_finished());
    }
}
