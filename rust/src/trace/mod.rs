//! Performance-ratio trace recording — the observable plotted in the
//! paper's Figure 4 (one P-core's AVX-VNNI ratio across prefill/decode).

use crate::cpu::Isa;
use crate::kernels::KernelClass;
use crate::perf::PerfTable;
use crate::util::json::Json;

/// One trace sample: the relative ratio of a core after a kernel update.
#[derive(Clone, Debug)]
pub struct TraceSample {
    /// running kernel-invocation index
    pub kernel_idx: u64,
    /// virtual (or wall) time of the sample
    pub time_secs: f64,
    /// phase label ("prefill" / "decode")
    pub phase: String,
    /// ratio of the traced core relative to the slowest core
    pub ratio: f64,
}

/// Records the relative ratio of one (core, kernel, ISA) over time.
#[derive(Clone, Debug)]
pub struct RatioTrace {
    pub core: usize,
    pub class: KernelClass,
    pub isa: Isa,
    pub samples: Vec<TraceSample>,
    next_idx: u64,
}

impl RatioTrace {
    pub fn new(core: usize, class: KernelClass, isa: Isa) -> RatioTrace {
        RatioTrace { core, class, isa, samples: Vec::new(), next_idx: 0 }
    }

    /// Sample the table after a kernel execution.
    pub fn record(&mut self, table: &PerfTable, time_secs: f64, phase: &str) {
        if let Some(rel) = table.relative_ratios(self.class, self.isa) {
            self.samples.push(TraceSample {
                kernel_idx: self.next_idx,
                time_secs,
                phase: phase.to_string(),
                ratio: rel[self.core],
            });
        }
        self.next_idx += 1;
    }

    /// CSV dump (kernel_idx,time_secs,phase,ratio).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("kernel_idx,time_secs,phase,ratio\n");
        for s in &self.samples {
            let line = format!("{},{:.9},{},{:.6}\n", s.kernel_idx, s.time_secs, s.phase, s.ratio);
            out.push_str(&line);
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("core", Json::num(self.core as f64)),
            ("kernel", Json::str(self.class.name())),
            ("isa", Json::str(self.isa.name())),
            (
                "samples",
                Json::arr(self.samples.iter().map(|s| {
                    Json::obj(vec![
                        ("kernel_idx", Json::num(s.kernel_idx as f64)),
                        ("time_secs", Json::num(s.time_secs)),
                        ("phase", Json::str(s.phase.clone())),
                        ("ratio", Json::num(s.ratio)),
                    ])
                })),
            ),
        ])
    }

    /// mean ratio over samples in a phase (Fig. 4 summary statistic)
    pub fn phase_mean(&self, phase: &str) -> Option<f64> {
        let vals: Vec<f64> =
            self.samples.iter().filter(|s| s.phase == phase).map(|s| s.ratio).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::PerfConfig;

    #[test]
    fn records_relative_ratio() {
        let mut table = PerfTable::new(2, PerfConfig { alpha: 0.0, init_ratio: 1.0 });
        let mut trace = RatioTrace::new(0, KernelClass::GemmI8, Isa::AvxVnni);
        table.update(KernelClass::GemmI8, Isa::AvxVnni, &[Some(1.0), Some(3.0)]);
        trace.record(&table, 0.5, "prefill");
        assert_eq!(trace.samples.len(), 1);
        assert!((trace.samples[0].ratio - 3.0).abs() < 1e-9);
        assert_eq!(trace.samples[0].phase, "prefill");
    }

    #[test]
    fn csv_and_json_shapes() {
        let mut table = PerfTable::new(2, PerfConfig::default());
        table.update(KernelClass::GemvQ4, Isa::AvxVnni, &[Some(1.0), Some(2.0)]);
        let mut trace = RatioTrace::new(0, KernelClass::GemvQ4, Isa::AvxVnni);
        trace.record(&table, 0.1, "decode");
        trace.record(&table, 0.2, "decode");
        let csv = trace.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("kernel_idx,"));
        let j = trace.to_json();
        assert_eq!(j.get("samples").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn phase_mean_filters() {
        let mut table = PerfTable::new(2, PerfConfig { alpha: 0.0, init_ratio: 1.0 });
        let mut trace = RatioTrace::new(0, KernelClass::GemmI8, Isa::AvxVnni);
        table.update(KernelClass::GemmI8, Isa::AvxVnni, &[Some(1.0), Some(2.0)]);
        trace.record(&table, 0.0, "prefill");
        table.update(KernelClass::GemmI8, Isa::AvxVnni, &[Some(1.0), Some(4.0)]);
        trace.record(&table, 1.0, "decode");
        assert!(trace.phase_mean("prefill").unwrap() < trace.phase_mean("decode").unwrap());
        assert!(trace.phase_mean("warmup").is_none());
    }

    #[test]
    fn unseen_table_row_records_nothing() {
        let table = PerfTable::new(2, PerfConfig::default());
        let mut trace = RatioTrace::new(0, KernelClass::Copy, Isa::Stream);
        trace.record(&table, 0.0, "x");
        assert!(trace.samples.is_empty());
    }
}
