//! The paper's **CPU runtime** (§2.1): per-core relative performance
//! ratios, keyed by (kernel class, ISA), updated after every kernel from
//! the measured per-core execution times and smoothed with an EWMA filter.
//!
//! Update rule (paper eq. 2):
//! ```text
//!   pr_i' = pr_i / Σ_j (t_i · pr_j / t_j)
//! ```
//! Eq. 2 as written normalizes Σ pr' = 1; to keep table entries on a
//! stable, interpretable scale across updates we rescale `pr'` so the
//! participating cores' total mass is preserved (this does not change the
//! *relative* ratios, which are all eq. 3 consumes). The filter is
//! `pr = α·pr + (1−α)·pr'` with constant gain α (paper uses α = 0.3).

pub mod bandwidth;

use crate::cpu::Isa;
use crate::kernels::KernelClass;

/// dense row index for the (class, isa) key — the table sits on the
/// per-kernel hot path, so the lookup is a pair of const jump tables
/// instead of linear scans over the `ALL` arrays
#[inline]
const fn slot(class: KernelClass, isa: Isa) -> usize {
    class.index() * Isa::ALL.len() + isa.index()
}

/// sized from the enums, so adding a kernel class or ISA grows the table
/// instead of silently corrupting the dense indexing
const N_SLOTS: usize = KernelClass::ALL.len() * Isa::ALL.len();

/// Configuration of the runtime's ratio table.
#[derive(Clone, Copy, Debug)]
pub struct PerfConfig {
    /// EWMA filter gain α ∈ [0, 1): weight of the *old* value.
    pub alpha: f64,
    /// initial ratio for every core (paper §2.1 initializes to 1; the
    /// Fig. 4 trace starts from a stale value of 5 — see `set_ratios`).
    pub init_ratio: f64,
}

impl Default for PerfConfig {
    fn default() -> Self {
        PerfConfig { alpha: 0.3, init_ratio: 1.0 }
    }
}

/// Per-(kernel, ISA) performance-ratio table.
#[derive(Clone, Debug)]
pub struct PerfTable {
    n_cores: usize,
    cfg: PerfConfig,
    /// dense (class × isa) rows, lazily initialized
    entries: Vec<Option<Vec<f64>>>,
    updates: u64,
}

impl PerfTable {
    pub fn new(n_cores: usize, cfg: PerfConfig) -> PerfTable {
        assert!(n_cores > 0);
        assert!((0.0..1.0).contains(&cfg.alpha), "alpha must be in [0,1)");
        assert!(cfg.init_ratio > 0.0);
        PerfTable { n_cores, cfg, entries: vec![None; N_SLOTS], updates: 0 }
    }

    pub fn n_cores(&self) -> usize {
        self.n_cores
    }

    pub fn update_count(&self) -> u64 {
        self.updates
    }

    /// Current ratios for a (kernel, ISA) pair, creating the row at the
    /// configured initial value on first use.
    pub fn ratios(&mut self, class: KernelClass, isa: Isa) -> &[f64] {
        let n = self.n_cores;
        let init = self.cfg.init_ratio;
        self.entries[slot(class, isa)].get_or_insert_with(|| vec![init; n])
    }

    /// Read-only view (None if the row was never touched).
    pub fn get(&self, class: KernelClass, isa: Isa) -> Option<&[f64]> {
        self.entries[slot(class, isa)].as_deref()
    }

    /// Seed a row explicitly (e.g. a stale persisted table, as in the
    /// paper's Fig. 4 where a P-core starts at ratio 5).
    pub fn set_ratios(&mut self, class: KernelClass, isa: Isa, ratios: Vec<f64>) {
        assert_eq!(ratios.len(), self.n_cores);
        assert!(ratios.iter().all(|&r| r > 0.0));
        self.entries[slot(class, isa)] = Some(ratios);
    }

    /// Apply eq. 2 + the EWMA filter from measured per-core times.
    /// `times[i] = None` means core i did not participate (zero work);
    /// its ratio is left unchanged.
    pub fn update(&mut self, class: KernelClass, isa: Isa, times: &[Option<f64>]) {
        assert_eq!(times.len(), self.n_cores);
        let alpha = self.cfg.alpha;
        let init = self.cfg.init_ratio;
        let n = self.n_cores;
        let row = self.entries[slot(class, isa)].get_or_insert_with(|| vec![init; n]);

        // single pass over participants (measured, positive time) —
        // allocation-free: this runs after *every* kernel on the hot path
        let mut mass = 0.0f64;
        let mut s = 0.0f64; // S = Σ_j pr_j / t_j
        let mut n_parts = 0usize;
        for (i, t) in times.iter().enumerate() {
            if let Some(t) = t {
                if *t > 0.0 {
                    mass += row[i];
                    s += row[i] / t;
                    n_parts += 1;
                }
            }
        }
        if n_parts < 2 {
            return; // a single participant carries no relative information
        }
        if !(s.is_finite() && s > 0.0 && mass > 0.0) {
            return;
        }
        let beta = (1.0 - alpha) * mass / s;
        for (i, t) in times.iter().enumerate() {
            if let Some(t) = t {
                if *t > 0.0 {
                    // eq. 2 (sum-normalized), rescaled to preserve mass
                    row[i] = alpha * row[i] + beta * row[i] / t;
                }
            }
        }
        self.updates += 1;
    }

    /// Ratios normalized so the slowest participating core is 1.0 —
    /// the representation plotted in the paper's Fig. 4.
    pub fn relative_ratios(&self, class: KernelClass, isa: Isa) -> Option<Vec<f64>> {
        let row = self.get(class, isa)?;
        let min = row.iter().cloned().fold(f64::INFINITY, f64::min);
        Some(row.iter().map(|r| r / min).collect())
    }

    /// All initialized rows (for trace snapshots).
    pub fn rows(&self) -> impl Iterator<Item = ((KernelClass, Isa), &Vec<f64>)> {
        self.entries.iter().enumerate().filter_map(|(idx, row)| {
            row.as_ref().map(|r| {
                let class = KernelClass::ALL[idx / Isa::ALL.len()];
                let isa = Isa::ALL[idx % Isa::ALL.len()];
                ((class, isa), r)
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const C: KernelClass = KernelClass::GemmI8;
    const I: Isa = Isa::AvxVnni;

    #[test]
    fn const_slot_matches_position_scan() {
        // the const jump tables must agree with the ALL-array ordering the
        // old linear scans used — and `rows()` still decodes by position
        for (c, class) in KernelClass::ALL.iter().enumerate() {
            assert_eq!(class.index(), c, "{class:?}");
            for (i, isa) in Isa::ALL.iter().enumerate() {
                assert_eq!(isa.index(), i, "{isa:?}");
                assert_eq!(slot(*class, *isa), c * Isa::ALL.len() + i);
            }
        }
        assert_eq!(N_SLOTS, KernelClass::ALL.len() * Isa::ALL.len());
    }

    fn table(n: usize, alpha: f64) -> PerfTable {
        PerfTable::new(n, PerfConfig { alpha, init_ratio: 1.0 })
    }

    #[test]
    fn init_is_flat() {
        let mut t = table(4, 0.3);
        assert_eq!(t.ratios(C, I), &[1.0; 4]);
    }

    #[test]
    fn equal_times_keep_ratios_flat() {
        let mut t = table(4, 0.3);
        for _ in 0..10 {
            t.update(C, I, &[Some(1.0); 4]);
        }
        for &r in t.ratios(C, I) {
            assert!((r - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn faster_core_gains_ratio() {
        let mut t = table(2, 0.0); // no smoothing: converge in one step
        // equal work, core 0 twice as fast
        t.update(C, I, &[Some(1.0), Some(2.0)]);
        let r = t.ratios(C, I);
        assert!((r[0] / r[1] - 2.0).abs() < 1e-9, "{r:?}");
        // mass preserved: 1 + 1 = 2
        assert!((r[0] + r[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn converges_to_true_rates_under_proportional_split() {
        // Simulate the closed loop: work split ∝ pr, times = share/rate.
        let rates = [3.0, 1.0, 1.0, 1.0];
        let mut t = table(4, 0.3);
        for _ in 0..50 {
            let pr: Vec<f64> = t.ratios(C, I).to_vec();
            let sum: f64 = pr.iter().sum();
            let times: Vec<Option<f64>> =
                (0..4).map(|i| Some((pr[i] / sum) / rates[i])).collect();
            t.update(C, I, &times);
        }
        let rel = t.relative_ratios(C, I).unwrap();
        assert!((rel[0] - 3.0).abs() < 0.05, "rel={rel:?}");
        assert!((rel[1] - 1.0).abs() < 0.05);
    }

    #[test]
    fn fixed_point_when_times_equalize() {
        // if all cores finish together, ratios must not move
        let mut t = table(3, 0.3);
        t.set_ratios(C, I, vec![3.0, 1.5, 1.0]);
        t.update(C, I, &[Some(0.7); 3]);
        let r = t.ratios(C, I);
        assert!((r[0] - 3.0).abs() < 1e-9 && (r[2] - 1.0).abs() < 1e-9, "{r:?}");
    }

    #[test]
    fn non_participants_unchanged() {
        let mut t = table(3, 0.0);
        t.set_ratios(C, I, vec![2.0, 1.0, 5.0]);
        t.update(C, I, &[Some(1.0), Some(1.0), None]);
        let r = t.ratios(C, I);
        assert!((r[2] - 5.0).abs() < 1e-12);
        // mass of participants preserved
        assert!((r[0] + r[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn single_participant_is_ignored() {
        let mut t = table(2, 0.0);
        t.update(C, I, &[Some(1.0), None]);
        assert_eq!(t.update_count(), 0);
        assert_eq!(t.ratios(C, I), &[1.0, 1.0]);
    }

    #[test]
    fn rows_are_independent_per_isa() {
        let mut t = table(2, 0.0);
        t.update(C, Isa::AvxVnni, &[Some(1.0), Some(2.0)]);
        assert_eq!(t.ratios(C, Isa::Avx2), &[1.0, 1.0]);
        assert_ne!(t.ratios(C, Isa::AvxVnni), &[1.0, 1.0]);
    }

    #[test]
    fn alpha_damps_convergence() {
        let mut fast = table(2, 0.0);
        let mut slow = table(2, 0.9);
        let times = [Some(1.0), Some(4.0)];
        fast.update(C, I, &times);
        slow.update(C, I, &times);
        let rf = fast.relative_ratios(C, I).unwrap()[0];
        let rs = slow.relative_ratios(C, I).unwrap()[0];
        assert!(rf > rs, "fast={rf} slow={rs}");
    }

    #[test]
    fn stale_high_init_decays_like_fig4() {
        // Fig. 4: table seeded at 5, true ratio ≈ 3 → trace decays to ~3.
        let mut t = table(2, 0.3);
        t.set_ratios(C, I, vec![5.0, 1.0]);
        let rates = [3.0, 1.0];
        let mut trace = Vec::new();
        for _ in 0..20 {
            let pr: Vec<f64> = t.ratios(C, I).to_vec();
            let sum: f64 = pr.iter().sum();
            let times: Vec<Option<f64>> =
                (0..2).map(|i| Some((pr[i] / sum) / rates[i])).collect();
            t.update(C, I, &times);
            trace.push(t.relative_ratios(C, I).unwrap()[0]);
        }
        assert!(trace[0] < 5.0 && trace[0] > 3.0, "first step {:?}", trace[0]);
        assert!((trace.last().unwrap() - 3.0).abs() < 0.05, "end {:?}", trace.last());
        // monotone-ish decay
        assert!(trace.windows(2).all(|w| w[1] <= w[0] + 1e-9));
    }

    #[test]
    fn prop_mass_preserved_and_positive() {
        prop::check("perf_mass_preserved", |rng| {
            let n = 2 + rng.below(6) as usize;
            let mut t =
                PerfTable::new(n, PerfConfig { alpha: rng.uniform(0.0, 0.9), init_ratio: 1.0 });
            let before: f64 = t.ratios(C, I).iter().sum();
            for _ in 0..5 {
                let times: Vec<Option<f64>> =
                    (0..n).map(|_| Some(rng.uniform(0.01, 10.0))).collect();
                t.update(C, I, &times);
            }
            let row = t.get(C, I).unwrap();
            if row.iter().any(|&r| !(r > 0.0 && r.is_finite())) {
                return Err(format!("non-positive ratio {row:?}"));
            }
            let after: f64 = row.iter().sum();
            prop::approx_eq(before, after, 1e-9)
        });
    }
}
