//! Effective-bandwidth accounting — how close the host path gets to the
//! paper's ">90% of memory bandwidth" headline.
//!
//! Every kernel's unique memory traffic is already priced by its
//! [`crate::kernels::WorkCost`] (`bytes_per_unit`); the runtime stamps the
//! total onto each [`crate::exec::RunResult`]. A [`BandwidthMeter`]
//! accumulates those bytes against busy kernel seconds and reports
//! achieved GB/s plus a utilization ratio against a reference bandwidth —
//! a lease's `bus_share_gbps`, or the machine's full bus.

/// Running bytes-over-busy-time accumulator. `GB` here is 1e9 bytes,
/// matching `CpuSpec::bus_bw_gbps` and `Lease::bus_share_gbps`.
#[derive(Clone, Copy, Debug, Default)]
pub struct BandwidthMeter {
    /// bytes of unique kernel memory traffic accumulated
    pub bytes: f64,
    /// busy kernel seconds the bytes were moved in
    pub secs: f64,
}

impl BandwidthMeter {
    /// Fold in one measurement (a kernel, a token round, a whole run).
    pub fn record(&mut self, bytes: f64, secs: f64) {
        self.bytes += bytes;
        self.secs += secs;
    }

    /// Achieved effective bandwidth in GB/s (0 while nothing is recorded).
    pub fn achieved_gbps(&self) -> f64 {
        bandwidth_gbps(self.bytes, self.secs)
    }

    /// Fraction of `reference_gbps` achieved, clamped to finite inputs.
    pub fn utilization(&self, reference_gbps: f64) -> f64 {
        bandwidth_utilization(self.achieved_gbps(), reference_gbps)
    }
}

/// bytes / secs in GB/s; 0 for empty or degenerate intervals.
pub fn bandwidth_gbps(bytes: f64, secs: f64) -> f64 {
    if secs > 0.0 && bytes >= 0.0 {
        bytes / secs / 1e9
    } else {
        0.0
    }
}

/// achieved / reference, 0 when the reference is degenerate.
pub fn bandwidth_utilization(achieved_gbps: f64, reference_gbps: f64) -> f64 {
    if reference_gbps > 0.0 && achieved_gbps.is_finite() {
        achieved_gbps / reference_gbps
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates_and_reports() {
        let mut m = BandwidthMeter::default();
        assert_eq!(m.achieved_gbps(), 0.0);
        assert_eq!(m.utilization(68.0), 0.0);
        m.record(34e9, 1.0);
        m.record(34e9, 1.0);
        assert!((m.achieved_gbps() - 34.0).abs() < 1e-9);
        assert!((m.utilization(68.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_are_zero_not_nan() {
        let m = BandwidthMeter { bytes: 1e9, secs: 0.0 };
        assert_eq!(m.achieved_gbps(), 0.0);
        assert_eq!(bandwidth_utilization(10.0, 0.0), 0.0);
        assert_eq!(bandwidth_utilization(f64::INFINITY, 68.0), 0.0);
        assert_eq!(bandwidth_gbps(-1.0, 1.0), 0.0);
    }
}
