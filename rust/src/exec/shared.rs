//! [`SharedSlice`]: disjoint-range mutable sharing of an output buffer
//! across worker threads.
//!
//! Kernels write disjoint slices of one output tensor from multiple
//! workers. Rust's borrow rules cannot express "disjoint at runtime", so
//! this wrapper provides an `unsafe` escape hatch with a crisp contract:
//! **callers must guarantee ranges handed to `slice_mut` never overlap
//! while any other such slice is alive.** All schedulers in this crate
//! produce disjoint ranges by construction (tested in `sched::partition`),
//! and chunk claiming uses a shared atomic counter, so the contract holds.

use std::marker::PhantomData;
use std::ops::Range;

/// A raw, Sync view over a mutable slice.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access discipline (disjoint ranges) is the caller's contract;
// the pointer itself is valid for 'a.
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Get a mutable sub-slice.
    ///
    /// # Safety
    /// The range must be in bounds and must not overlap any other slice
    /// obtained from this `SharedSlice` that is still alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn disjoint_writes_from_threads() {
        let mut data = vec![0usize; 1000];
        {
            let shared = SharedSlice::new(&mut data);
            let counter = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..4 {
                    scope.spawn(|| loop {
                        let start = counter.fetch_add(100, Ordering::Relaxed);
                        if start >= 1000 {
                            break;
                        }
                        let s = unsafe { shared.slice_mut(start..start + 100) };
                        for (i, v) in s.iter_mut().enumerate() {
                            *v = start + i;
                        }
                    });
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn len_tracks_source() {
        let mut data = vec![0u8; 17];
        let s = SharedSlice::new(&mut data);
        assert_eq!(s.len(), 17);
        assert!(!s.is_empty());
    }
}
