//! Execution abstraction: one [`Work`] + one [`DispatchPlan`] in, per-core
//! times out — whether the cores are real threads ([`crate::pool`]) or
//! simulated hybrid cores ([`crate::sim`]). The paper's closed loop
//! (Figure 1: partition → execute → measure → update table) lives in
//! [`ParallelRuntime`].

pub mod shared;
pub mod work;

use crate::cpu::{CoreKind, Isa};
use crate::kernels::{KernelClass, WorkCost};
use crate::perf::{PerfConfig, PerfTable};
use crate::sched::{DispatchPlan, Scheduler, SplitScratch};

pub use shared::SharedSlice;
pub use work::{FnWork, Work};

/// Result of one parallel kernel execution.
#[derive(Debug, Default)]
pub struct RunResult {
    /// per-core busy time in seconds; `None` = did not participate
    pub per_core_secs: Vec<Option<f64>>,
    /// wall-clock (or virtual) duration of the whole kernel
    pub wall_secs: f64,
    /// units each core processed (for balance diagnostics)
    pub units_done: Vec<usize>,
    /// bytes the kernel moved (from [`WorkCost`]) — the numerator of the
    /// effective-bandwidth metric (`perf::bandwidth`)
    pub bytes: f64,
}

// Manual Clone so `clone_from` reuses the destination's Vec capacities —
// the serving loop's `capture_last` copy must not allocate per kernel.
impl Clone for RunResult {
    fn clone(&self) -> Self {
        RunResult {
            per_core_secs: self.per_core_secs.clone(),
            wall_secs: self.wall_secs,
            units_done: self.units_done.clone(),
            bytes: self.bytes,
        }
    }

    fn clone_from(&mut self, src: &Self) {
        self.per_core_secs.clone_from(&src.per_core_secs);
        self.units_done.clone_from(&src.units_done);
        self.wall_secs = src.wall_secs;
        self.bytes = src.bytes;
    }
}

impl RunResult {
    /// Load imbalance: max busy time / mean busy time (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let times: Vec<f64> = self.per_core_secs.iter().flatten().copied().collect();
        if times.is_empty() {
            return 1.0;
        }
        let max = times.iter().cloned().fold(0.0, f64::max);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }
}

/// Something that can run a `Work` under a `DispatchPlan`.
pub trait Executor {
    fn n_workers(&self) -> usize;
    fn execute(&mut self, work: &dyn Work, plan: &DispatchPlan) -> RunResult;

    /// Allocation-free execution: write the measurement into `out`, reusing
    /// its buffers. The default delegates to [`Executor::execute`]
    /// (allocating); host-path executors override it so steady-state token
    /// rounds never touch the heap.
    fn execute_into(&mut self, work: &dyn Work, plan: &DispatchPlan, out: &mut RunResult) {
        *out = self.execute(work, plan);
    }

    /// Microarchitectural class of each worker, for core-class-tuned
    /// microkernels (P/E/LPE tile selection). Executors without topology
    /// knowledge report every worker as a P-core.
    fn core_kinds(&self) -> Vec<CoreKind> {
        vec![CoreKind::Performance; self.n_workers()]
    }

    /// Start a synthetic background load stealing `fraction` of the given
    /// workers' cycles from now on. Simulated executors model it
    /// (deterministic drift scenarios — see `server::testing`); real-thread
    /// executors cannot synthesize load and ignore it (the default).
    fn inject_background(&mut self, _workers: &[usize], _fraction: f64) {}
}

/// The paper's engine loop: query table → plan → execute → update table.
pub struct ParallelRuntime<E: Executor> {
    pub exec: E,
    pub table: PerfTable,
    pub sched: Box<dyn Scheduler>,
    /// when set, [`ParallelRuntime::run`] keeps a copy of each kernel's
    /// measurement in `last_result` for serving-level observers
    /// ([`crate::coordinator::Coordinator::observe`]). Off by default so
    /// the per-kernel hot path pays no clone when nothing reads it.
    pub capture_last: bool,
    pub last_result: Option<RunResult>,
    /// kernel class of the captured `last_result` — observers fold the
    /// timing into that class's strength row
    pub last_class: Option<KernelClass>,
    // persistent per-kernel buffers: after the first round at a given
    // shape, `run` plans and executes without heap allocations
    plan_buf: DispatchPlan,
    split_scratch: SplitScratch,
    result_buf: RunResult,
}

impl<E: Executor> ParallelRuntime<E> {
    pub fn new(exec: E, sched: Box<dyn Scheduler>, perf_cfg: PerfConfig) -> Self {
        let n = exec.n_workers();
        ParallelRuntime {
            exec,
            table: PerfTable::new(n, perf_cfg),
            sched,
            capture_last: false,
            last_result: None,
            last_class: None,
            plan_buf: DispatchPlan::Partitioned(Vec::new()),
            split_scratch: SplitScratch::default(),
            result_buf: RunResult::default(),
        }
    }

    /// Run one kernel through the full dynamic loop. The measurement is
    /// borrowed from the runtime's reusable buffer — clone it to keep it
    /// past the next kernel.
    pub fn run(&mut self, work: &dyn Work) -> &RunResult {
        let cost = work.cost();
        let ratios = self.table.ratios(cost.class, cost.isa);
        self.sched.plan_into(
            work.total_units(),
            work.grain(),
            ratios,
            &mut self.split_scratch,
            &mut self.plan_buf,
        );
        self.exec.execute_into(work, &self.plan_buf, &mut self.result_buf);
        self.result_buf.bytes = cost.total_bytes();
        // heterogeneous executors append per-device entries after the
        // per-core ones; the core table only consumes its own workers
        let n = self.table.n_cores().min(self.result_buf.per_core_secs.len());
        self.table.update(cost.class, cost.isa, &self.result_buf.per_core_secs[..n]);
        if self.capture_last {
            match &mut self.last_result {
                Some(r) => r.clone_from(&self.result_buf),
                None => self.last_result = Some(self.result_buf.clone()),
            }
            self.last_class = Some(cost.class);
        }
        &self.result_buf
    }

    /// Current relative ratios for a kernel (Fig. 4 observable).
    pub fn relative_ratios(&self, class: KernelClass, isa: Isa) -> Option<Vec<f64>> {
        self.table.relative_ratios(class, isa)
    }
}

/// Convenience: describe a phantom workload by cost only (no real compute)
/// — used by the simulator-driven figure benchmarks.
#[derive(Clone, Debug)]
pub struct PhantomWork {
    pub cost: WorkCost,
    pub grain: usize,
}

impl PhantomWork {
    pub fn new(cost: WorkCost) -> Self {
        PhantomWork { cost, grain: 1 }
    }

    pub fn with_grain(cost: WorkCost, grain: usize) -> Self {
        PhantomWork { cost, grain }
    }
}

impl Work for PhantomWork {
    fn total_units(&self) -> usize {
        self.cost.units
    }

    fn grain(&self) -> usize {
        self.grain
    }

    fn cost(&self) -> WorkCost {
        self.cost
    }

    fn run_range(&self, _worker: usize, _units: std::ops::Range<usize>) {
        // phantom: cost-only workload
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::cost;

    /// A deterministic fake executor: core i runs at rate `rates[i]`.
    struct FakeExec {
        rates: Vec<f64>,
    }

    impl Executor for FakeExec {
        fn n_workers(&self) -> usize {
            self.rates.len()
        }

        fn execute(&mut self, work: &dyn Work, plan: &DispatchPlan) -> RunResult {
            let units: Vec<usize> = match plan {
                DispatchPlan::Partitioned(rs) => rs.iter().map(|r| r.len()).collect(),
                // crude chunked model: proportional to rate (perfect
                // stealing); largest-remainder so no unit of work is lost
                // to truncation
                _ => crate::sched::largest_remainder_split(work.total_units(), &self.rates),
            };
            let times: Vec<Option<f64>> = units
                .iter()
                .zip(&self.rates)
                .map(|(&u, &r)| if u > 0 { Some(u as f64 / r) } else { None })
                .collect();
            let wall = times.iter().flatten().cloned().fold(0.0, f64::max);
            RunResult { per_core_secs: times, wall_secs: wall, units_done: units, bytes: 0.0 }
        }
    }

    #[test]
    fn runtime_converges_and_beats_static() {
        let rates = vec![3.0, 3.0, 1.0, 1.0];
        let work = PhantomWork::new(cost::gemm_i8_cost(1024, 64, 64));

        let mut dynamic = ParallelRuntime::new(
            FakeExec { rates: rates.clone() },
            Box::new(crate::sched::DynamicScheduler),
            PerfConfig::default(),
        );
        // warm up the table
        let mut last = f64::INFINITY;
        for _ in 0..30 {
            last = dynamic.run(&work).wall_secs;
        }

        let mut static_rt = ParallelRuntime::new(
            FakeExec { rates },
            Box::new(crate::sched::StaticEven),
            PerfConfig::default(),
        );
        let static_wall = static_rt.run(&work).wall_secs;

        // ideal speedup = Σrates / (N·min) = 8/4 = 2
        let speedup = static_wall / last;
        assert!(speedup > 1.9, "speedup={speedup}");
        // converged ratios ≈ 3:1
        let rel = dynamic.relative_ratios(KernelClass::GemmI8, Isa::AvxVnni).unwrap();
        assert!((rel[0] - 3.0).abs() < 0.1, "{rel:?}");
    }

    #[test]
    fn prop_chunked_fake_exec_conserves_units() {
        // the old `as usize` truncation could drop up to n_workers-1 tail
        // units; largest-remainder assignments must sum exactly
        crate::util::prop::check("fake-exec-unit-conservation", |rng| {
            let n_workers = 1 + rng.below(8) as usize;
            let rates: Vec<f64> = (0..n_workers).map(|_| rng.uniform(0.1, 8.0)).collect();
            let total = 1 + rng.below(5000) as usize;
            let mut exec = FakeExec { rates };
            let work = PhantomWork::new(cost::gemm_i8_cost(total, 64, 64));
            let res = exec.execute(&work, &DispatchPlan::Chunked { chunk: 1 });
            let done: usize = res.units_done.iter().sum();
            if done != total {
                return Err(format!("assigned {done} of {total} units"));
            }
            Ok(())
        });
    }

    #[test]
    fn run_captures_last_class_when_enabled() {
        let mut rt = ParallelRuntime::new(
            FakeExec { rates: vec![1.0, 1.0] },
            Box::new(crate::sched::DynamicScheduler),
            PerfConfig::default(),
        );
        rt.run(&PhantomWork::new(cost::gemv_q4_cost(256, 256)));
        assert!(rt.last_result.is_none() && rt.last_class.is_none());
        rt.capture_last = true;
        rt.run(&PhantomWork::new(cost::qmatmul_cost(8, 256, 256)));
        assert_eq!(rt.last_class, Some(KernelClass::GemmI8));
        assert!(rt.last_result.is_some());
    }

    #[test]
    fn imbalance_metric() {
        let r = RunResult {
            per_core_secs: vec![Some(1.0), Some(1.0), Some(2.0)],
            wall_secs: 2.0,
            units_done: vec![1, 1, 1],
            bytes: 0.0,
        };
        assert!((r.imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn phantom_work_reports_cost() {
        let w = PhantomWork::new(cost::gemv_q4_cost(4096, 4096));
        assert_eq!(w.total_units(), 4096);
        assert_eq!(w.cost().class, KernelClass::GemvQ4);
    }
}
