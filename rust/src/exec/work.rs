//! The [`Work`] trait: a kernel invocation over a splittable parallel
//! dimension, plus the closure-based adapter used to wrap native kernels.

use std::ops::Range;

use crate::kernels::WorkCost;

/// One parallel kernel invocation. Ranges handed to `run_range` by any
/// correct executor are disjoint and within `0..total_units()`; different
/// workers may call `run_range` concurrently.
pub trait Work: Sync {
    /// Length of the parallel dimension.
    fn total_units(&self) -> usize;

    /// Preferred alignment of partition boundaries (e.g. a row-block).
    fn grain(&self) -> usize {
        1
    }

    /// Analytic cost (for the simulator and for ISA/table keying).
    fn cost(&self) -> WorkCost;

    /// Execute units `units` as worker `worker`.
    fn run_range(&self, worker: usize, units: Range<usize>);
}

/// Closure-backed `Work` — wraps the range-based native kernels.
pub struct FnWork<F: Fn(usize, Range<usize>) + Sync> {
    cost: WorkCost,
    grain: usize,
    f: F,
}

impl<F: Fn(usize, Range<usize>) + Sync> FnWork<F> {
    pub fn new(cost: WorkCost, grain: usize, f: F) -> Self {
        FnWork { cost, grain, f }
    }
}

impl<F: Fn(usize, Range<usize>) + Sync> Work for FnWork<F> {
    fn total_units(&self) -> usize {
        self.cost.units
    }

    fn grain(&self) -> usize {
        self.grain
    }

    fn cost(&self) -> WorkCost {
        self.cost
    }

    fn run_range(&self, worker: usize, units: Range<usize>) {
        (self.f)(worker, units)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SharedSlice;
    use crate::kernels::cost;

    #[test]
    fn fn_work_executes_ranges() {
        let mut out = vec![0u32; 100];
        {
            let shared = SharedSlice::new(&mut out);
            let w = FnWork::new(cost::copy_cost(100 * 4096), 1, |_worker, range| {
                // SAFETY: test passes disjoint ranges
                let s = unsafe { shared.slice_mut(range.clone()) };
                for (i, v) in s.iter_mut().enumerate() {
                    *v = (range.start + i) as u32;
                }
            });
            w.run_range(0, 0..50);
            w.run_range(1, 50..100);
        }
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn grain_and_cost_passthrough() {
        let w = FnWork::new(cost::gemv_q4_cost(256, 512), 8, |_, _| {});
        assert_eq!(w.grain(), 8);
        assert_eq!(w.total_units(), 512);
    }
}
