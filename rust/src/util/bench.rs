//! Micro-benchmark harness (criterion is not available in this sandbox).
//!
//! Used by the `cargo bench` targets (`harness = false`): warmup, then
//! timed iterations, reporting mean / p50 / p95 like criterion's summary
//! line. Virtual-time simulator benches use [`BenchReport::record`]
//! directly with simulated latencies instead of wall-clock measurement.

use std::hint::black_box as bb;
use std::time::Instant;

use super::stats::Summary;

pub use std::hint::black_box;

/// Configuration for a wall-clock measurement.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 15 }
    }
}

/// One benchmark's collected samples (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_secs: Vec<f64>,
    /// optional work-rate annotations
    pub bytes_per_iter: Option<u64>,
    pub ops_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_secs)
    }

    /// criterion-style single line.
    pub fn line(&self) -> String {
        let s = self.summary();
        let mut out = format!(
            "{:<44} time: [{} {} {}]",
            self.name,
            fmt_time(s.min),
            fmt_time(s.p50),
            fmt_time(s.max),
        );
        if let Some(b) = self.bytes_per_iter {
            out.push_str(&format!("  bw: {:.2} GB/s", b as f64 / s.p50 / 1e9));
        }
        if let Some(o) = self.ops_per_iter {
            out.push_str(&format!("  rate: {:.2} Gops/s", o as f64 / s.p50 / 1e9));
        }
        out
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.2} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// A named group of benches that prints as it goes (like criterion).
pub struct BenchReport {
    pub group: String,
    pub results: Vec<BenchResult>,
}

impl BenchReport {
    pub fn new(group: &str) -> Self {
        println!("\n=== {group} ===");
        Self { group: group.to_string(), results: Vec::new() }
    }

    /// Measure a closure with wall-clock time.
    pub fn bench<F: FnMut()>(&mut self, name: &str, opts: &BenchOpts, mut f: F) -> &BenchResult {
        for _ in 0..opts.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(opts.iters);
        for _ in 0..opts.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.push(BenchResult {
            name: name.to_string(),
            samples_secs: samples,
            bytes_per_iter: None,
            ops_per_iter: None,
        })
    }

    /// Record externally-measured samples (e.g. simulator virtual time).
    pub fn record(
        &mut self,
        name: &str,
        samples_secs: Vec<f64>,
        bytes_per_iter: Option<u64>,
        ops_per_iter: Option<u64>,
    ) -> &BenchResult {
        self.push(BenchResult {
            name: name.to_string(),
            samples_secs,
            bytes_per_iter,
            ops_per_iter,
        })
    }

    fn push(&mut self, r: BenchResult) -> &BenchResult {
        println!("{}", r.line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Find a result by exact name.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Print a `a vs b: ×N.NN` comparison row based on p50.
    pub fn compare(&self, slow: &str, fast: &str) {
        if let (Some(a), Some(b)) = (self.get(slow), self.get(fast)) {
            let ratio = a.summary().p50 / b.summary().p50;
            println!("  speedup {fast} vs {slow}: ×{ratio:.2}");
        }
    }
}

/// Prevent the optimizer from removing a computation.
pub fn consume<T>(v: T) {
    bb(v);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_requested_samples() {
        let mut rep = BenchReport::new("test");
        let mut acc = 0u64;
        let r = rep.bench(
            "noop",
            &BenchOpts { warmup_iters: 1, iters: 5 },
            || {
                acc = acc.wrapping_add(1);
                consume(acc);
            },
        );
        assert_eq!(r.samples_secs.len(), 5);
        assert!(r.samples_secs.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn record_and_compare() {
        let mut rep = BenchReport::new("test2");
        rep.record("slow", vec![2.0, 2.0, 2.0], Some(1_000_000_000), None);
        rep.record("fast", vec![1.0, 1.0, 1.0], None, None);
        assert_eq!(rep.get("slow").unwrap().summary().p50, 2.0);
        rep.compare("slow", "fast"); // prints ×2.00
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with(" s"));
    }
}
