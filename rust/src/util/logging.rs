//! Tiny leveled logger controlled by `DYNPAR_LOG` (error|warn|info|debug|trace).

use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("DYNPAR_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

pub fn level() -> Level {
    *LEVEL.get_or_init(Level::from_env)
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {target}: {msg}", format!("{l:?}").to_uppercase());
    }
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_level_is_info() {
        // (cannot mutate env reliably in parallel tests; just exercise the path)
        assert!(enabled(Level::Error));
        assert!(level() >= Level::Error);
    }

    #[test]
    fn macros_compile_and_run() {
        log_info!("test", "hello {}", 42);
        log_debug!("test", "debug {}", 1);
        log_warn!("test", "warn");
    }
}
