//! Minimal property-testing runner (proptest is not available here).
//!
//! A property is a closure from a seeded [`Rng`](super::rng::Rng) to
//! `Result<(), String>`; the runner executes `iters` cases with derived
//! seeds and reports the failing seed so a case can be replayed exactly.
//! There is no shrinking — generators should draw *small* sizes directly.

use super::rng::Rng;

pub struct PropConfig {
    pub iters: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // DYNPAR_PROP_SEED / DYNPAR_PROP_ITERS allow replay & heavier runs.
        let seed = std::env::var("DYNPAR_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xD1A2);
        let iters =
            std::env::var("DYNPAR_PROP_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(64);
        Self { iters, seed }
    }
}

/// Run a property; panics with the failing case seed on violation.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_with(name, PropConfig::default(), &mut prop)
}

pub fn check_with<F>(name: &str, cfg: PropConfig, prop: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.iters {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} (replay: DYNPAR_PROP_SEED with case seed {case_seed:#x}):\n  {msg}",
                cfg.iters
            );
        }
    }
}

/// Helper: assert approximate equality inside a property.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !≈ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_with("always-true", PropConfig { iters: 10, seed: 1 }, &mut |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        check_with("always-false", PropConfig { iters: 3, seed: 2 }, &mut |_rng| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn approx_eq_tolerates_scale() {
        assert!(approx_eq(1000.0, 1000.5, 1e-3).is_ok());
        assert!(approx_eq(1.0, 2.0, 1e-3).is_err());
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut seq1 = Vec::new();
        check_with("collect1", PropConfig { iters: 5, seed: 7 }, &mut |rng| {
            seq1.push(rng.next_u64());
            Ok(())
        });
        let mut seq2 = Vec::new();
        check_with("collect2", PropConfig { iters: 5, seed: 7 }, &mut |rng| {
            seq2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seq1, seq2);
    }
}
