//! IEEE 754 binary16 conversions, matching numpy's round-to-nearest-even.
//!
//! Q4_0 scales are stored as f16; the Rust quantizer must produce *exactly*
//! the same scale bits as the Python reference (`compile/quant.py`, which
//! goes through `np.float16`) so that native kernels and PJRT artifacts see
//! identical weights.

/// Convert f32 → f16 bit pattern with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m | ((mant >> 13) as u16 & 0x03FF);
    }

    // unbiased exponent for f16
    let e16 = exp - 127 + 15;
    if e16 >= 0x1F {
        // overflow → inf
        return sign | 0x7C00;
    }
    if e16 <= 0 {
        // subnormal or zero in f16
        if e16 < -10 {
            return sign; // underflow to signed zero
        }
        // implicit leading 1 becomes explicit, then shift into subnormal place
        let m = mant | 0x0080_0000;
        let shift = (14 - e16) as u32; // 14..24
        let half = 1u32 << (shift - 1);
        let rounded = m + half - 1 + ((m >> shift) & 1); // round-half-to-even
        return sign | (rounded >> shift) as u16;
    }

    // normal case: round 23-bit mantissa to 10 bits, half-to-even
    let half = 0x0000_0FFF; // (1 << 13) - 1
    let rounded = mant + half + ((mant >> 13) & 1);
    let mut e = e16 as u32;
    let mut m = rounded >> 13;
    if m == 0x0400 {
        // mantissa overflowed into the exponent
        m = 0;
        e += 1;
        if e >= 0x1F {
            return sign | 0x7C00;
        }
    }
    sign | ((e as u16) << 10) | (m as u16 & 0x03FF)
}

/// Convert an f16 bit pattern → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// f32 → f16 storage → f32 (the precision of a stored Q4_0 scale).
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(f16_round(x), x, "i={i}");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00); // overflow → +inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(5.9604645e-8), 0x0001); // smallest subnormal
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly half-way between 1.0 and 1+2^-10 → ties to even (1.0)
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C00);
        // 1 + 3·2^-11 is half-way between 1+2^-10 and 1+2^-9 → ties to even (1+2^-9)
        let y = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(y), 0x3C02);
    }

    #[test]
    fn subnormal_roundtrip() {
        let x = 2f32.powi(-20);
        let r = f16_round(x);
        assert!((r - x).abs() / x < 0.05, "x={x} r={r}");
    }

    #[test]
    fn nan_stays_nan() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn monotone_on_samples() {
        // conversion must be monotone (weak) over increasing inputs
        let mut prev = f16_round(-1000.0);
        let mut x = -1000.0f32;
        while x < 1000.0 {
            let r = f16_round(x);
            assert!(r >= prev, "x={x}");
            prev = r;
            x += 0.37;
        }
    }

    #[test]
    fn matches_reference_grid() {
        // spot-check against values produced by numpy (precomputed)
        let cases: &[(f32, u16)] = &[
            (0.1, 0x2E66),
            (0.2, 0x3266),
            (0.3, 0x34CD),
            (3.14159, 0x4248),
            (-0.007812599, 0xA000),
            (1234.5678, 0x64D3),
        ];
        for &(x, bits) in cases {
            assert_eq!(f32_to_f16_bits(x), bits, "x={x}");
        }
    }
}
