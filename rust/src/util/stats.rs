//! Streaming and batch statistics used by the bench harness and metrics.

/// Welford's online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Batch summary over a sample vector.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize `samples`, ignoring non-finite values: one poisoned
    /// metric (NaN TTFT from a dead stream, an ∞ from a zero divide) must
    /// not take down a whole bench report. `n` counts the finite samples
    /// actually summarized; if every sample is non-finite the summary is
    /// explicitly empty (`n == 0`, all fields zero).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|s| s.is_finite()).collect();
        sorted.sort_by(|a, b| a.total_cmp(b));
        if sorted.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut w = Welford::new();
        for &s in &sorted {
            w.push(s);
        }
        Summary {
            n: sorted.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (used for cross-experiment speedup aggregation).
pub fn geomean(values: &[f64]) -> f64 {
    assert!(!values.is_empty());
    let s: f64 = values.iter().map(|v| v.ln()).sum();
    (s / values.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 3.5).abs() < 1e-12);
        assert!((w.variance() - 3.5).abs() < 1e-12); // sample var of 1..6
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 6.0);
        assert_eq!(w.count(), 6);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&s, 0.0), 10.0);
        assert_eq!(percentile_sorted(&s, 1.0), 40.0);
        assert!((percentile_sorted(&s, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p95 > 90.0 && s.p95 < 100.0);
        assert!(s.min == 1.0 && s.max == 100.0);
    }

    #[test]
    fn summary_survives_poisoned_samples() {
        // NaN/∞ entries are dropped, not propagated (and never panic the
        // old `partial_cmp().unwrap()` sort)
        let xs = [1.0, f64::NAN, 2.0, f64::INFINITY, 3.0, f64::NEG_INFINITY];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        // all-poisoned input yields an explicitly empty summary
        let e = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(e.n, 0);
        assert_eq!(e.mean, 0.0);
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
