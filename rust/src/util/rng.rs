//! Deterministic PRNGs (SplitMix64 seeding + Xoshiro256★★) — no external
//! crates are available in this sandbox, and determinism is a requirement
//! for reproducible figures, so we implement the standard generators.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256★★ — fast, high-quality, deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // avoid the all-zero state (astronomically unlikely, but cheap to fix)
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (unbiased via rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with N(0, sigma) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Fill a slice with uniform integers in `[lo, hi)` cast to the target.
    pub fn fill_i8(&mut self, out: &mut [i8], lo: i64, hi: i64) {
        for v in out.iter_mut() {
            *v = self.range(lo, hi) as i8;
        }
    }

    pub fn fill_u8(&mut self, out: &mut [u8], lo: i64, hi: i64) {
        for v in out.iter_mut() {
            *v = self.range(lo, hi) as u8;
        }
    }

    /// Random boolean with probability `p` of `true`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Split off an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn range_inclusive_exclusive() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.range(-3, 4);
            assert!((-3..4).contains(&v));
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut r = Rng::new(9);
        let mut a = r.split();
        let mut b = r.split();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
