//! Minimal JSON parser + writer (serde is not available in this sandbox).
//!
//! Used for the artifact manifest, CPU-spec configs, the serving protocol
//! and trace dumps. Supports the full JSON grammar; numbers are `f64`
//! (adequate: the manifest carries only small integers and floats).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Array(items.into_iter().collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Array(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs are rare in our configs; map
                            // lone surrogates to U+FFFD rather than erroring.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"name":"q4_0","nested":{"ok":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quote\"\ttab\\".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integer_formatting_is_integral() {
        assert_eq!(Json::num(42).dump(), "42");
        assert_eq!(Json::num(2.5).dump(), "2.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":"hlo-text","artifacts":{"qgemv":{"file":"qgemv.hlo.txt",
            "params":[{"name":"qs","shape":[256,256],"dtype":"i8"}]}}}"#;
        let v = Json::parse(src).unwrap();
        let p = v.get("artifacts").unwrap().get("qgemv").unwrap().get("params").unwrap();
        assert_eq!(p.idx(0).unwrap().get("shape").unwrap().idx(1).unwrap().as_usize(), Some(256));
    }
}
