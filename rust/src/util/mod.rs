//! Self-contained substrate utilities (no external crates available in the
//! sandbox beyond `xla`/`libc`/`anyhow`): PRNG, f16, JSON, CLI parsing,
//! statistics, a micro-bench harness and a property-test runner.

pub mod argparse;
pub mod bench;
pub mod f16;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
