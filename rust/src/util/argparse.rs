//! Tiny CLI argument parser (clap is not available in this sandbox).
//!
//! Supports `command [subcommand] --key value --flag positional...` with
//! typed getters and an automatic `--help` usage dump.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// program name (argv[0])
    pub program: String,
    /// first non-flag token, if any (the subcommand)
    pub command: Option<String>,
    /// remaining positional tokens (after the subcommand)
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()`.
    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse(&argv)
    }

    /// Parse a full argv (argv[0] is the program).
    pub fn parse(argv: &[String]) -> Args {
        let program = argv.first().cloned().unwrap_or_default();
        let mut command = None;
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(name.to_string());
                }
            } else if command.is_none() {
                command = Some(tok.clone());
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        Args { program, command, positional, options, flags }
    }

    /// String option `--key value` / `--key=value`.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Boolean flag `--key` (no value). A `--key value` form also counts
    /// as present.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.opt(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{key} {v:?}; using default");
                std::process::exit(2)
            }),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(tokens: &[&str]) -> Args {
        let argv: Vec<String> = std::iter::once("prog".to_string())
            .chain(tokens.iter().map(|s| s.to_string()))
            .collect();
        Args::parse(&argv)
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = args(&["bench", "gemm", "extra"]);
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["gemm", "extra"]);
    }

    #[test]
    fn options_both_forms() {
        let a = args(&["run", "--preset", "ultra_125h", "--alpha=0.3"]);
        assert_eq!(a.opt("preset"), Some("ultra_125h"));
        assert_eq!(a.f64_or("alpha", 0.0), 0.3);
    }

    #[test]
    fn flags() {
        let a = args(&["run", "--json", "--verbose"]);
        assert!(a.flag("json"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = args(&["x"]);
        assert_eq!(a.usize_or("iters", 10), 10);
        assert_eq!(a.f64_or("alpha", 0.3), 0.3);
        assert_eq!(a.opt_or("preset", "core_12900k"), "core_12900k");
    }

    #[test]
    fn flag_followed_by_flag_not_swallowed() {
        let a = args(&["run", "--json", "--alpha", "0.5"]);
        assert!(a.flag("json"));
        assert_eq!(a.f64_or("alpha", 0.0), 0.5);
    }
}
