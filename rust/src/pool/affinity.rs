//! Core pinning via `sched_setaffinity` — the paper's CPU runtime "binds
//! each thread to a physical core" so per-thread timing is per-core timing.
//!
//! No `libc` crate is available in this sandbox, so on x86-64 Linux the
//! affinity syscalls are issued directly. Everywhere else — and in
//! sandboxes that deny `sched_setaffinity` — the pin degrades to a
//! *virtual* pin: the worker↔core association is recorded per thread so
//! the pool's bookkeeping (and per-core timing labels) stay stable even
//! though the OS is free to migrate the thread.

use std::cell::Cell;

thread_local! {
    /// Set when the OS refused (or cannot express) the real pin.
    static VIRTUAL_PIN: Cell<Option<usize>> = Cell::new(None);
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod sys {
    use std::arch::asm;

    const SYS_SCHED_SETAFFINITY: i64 = 203;
    const SYS_GETCPU: i64 = 309;
    /// 1024-bit cpu mask, the kernel's default `CONFIG_NR_CPUS` ceiling.
    const MASK_WORDS: usize = 16;

    unsafe fn syscall3(n: i64, a1: i64, a2: i64, a3: i64) -> i64 {
        let ret: i64;
        unsafe {
            asm!(
                "syscall",
                inlateout("rax") n => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret
    }

    /// Pin the calling thread to `cpu`. Err(errno) if the kernel refused.
    pub fn set_affinity(cpu: usize) -> Result<(), i32> {
        if cpu >= MASK_WORDS * 64 {
            return Err(22); // EINVAL
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[cpu / 64] |= 1u64 << (cpu % 64);
        let rc = unsafe {
            syscall3(
                SYS_SCHED_SETAFFINITY,
                0, // pid 0 = calling thread
                std::mem::size_of_val(&mask) as i64,
                mask.as_ptr() as i64,
            )
        };
        if rc == 0 {
            Ok(())
        } else {
            Err((-rc) as i32)
        }
    }

    /// CPU the calling thread is executing on right now.
    pub fn getcpu() -> Option<usize> {
        let mut cpu: u32 = 0;
        let rc = unsafe { syscall3(SYS_GETCPU, &mut cpu as *mut u32 as i64, 0, 0) };
        if rc == 0 {
            Some(cpu as usize)
        } else {
            None
        }
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod sys {
    /// No affinity syscalls on this target: always fall back to the
    /// virtual pin.
    pub fn set_affinity(_cpu: usize) -> Result<(), i32> {
        Err(38) // ENOSYS
    }

    pub fn getcpu() -> Option<usize> {
        None
    }
}

/// Outcome of a pin request: the caller can tell whether per-thread
/// timings are truly per-core ([`Pin::Real`]) or whether the OS refused
/// the affinity call and the association is bookkeeping-only
/// ([`Pin::Virtual`] — the scheduler may migrate the thread).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pin {
    Real(usize),
    Virtual(usize),
}

impl Pin {
    /// The CPU the thread is associated with (pinned or virtual).
    pub fn cpu(&self) -> usize {
        match *self {
            Pin::Real(c) | Pin::Virtual(c) => c,
        }
    }

    /// True when the OS actually accepted the affinity mask.
    pub fn is_real(&self) -> bool {
        matches!(self, Pin::Real(_))
    }
}

/// Pin the calling thread to logical CPU `cpu` (modulo the host's CPU
/// count, so worker counts larger than the host degrade gracefully).
/// Always establishes at least a virtual association (see module docs);
/// the returned [`Pin`] says which kind the caller got.
pub fn pin_current_thread(cpu: usize) -> Pin {
    let ncpu = crate::cpu::topology::n_logical_cpus();
    let target = cpu % ncpu;
    match sys::set_affinity(target) {
        Ok(()) => {
            VIRTUAL_PIN.with(|p| p.set(None));
            Pin::Real(target)
        }
        Err(_errno) => {
            VIRTUAL_PIN.with(|p| p.set(Some(target)));
            Pin::Virtual(target)
        }
    }
}

/// The CPU the calling thread currently runs on (for diagnostics). Reports
/// the virtual pin when the real one was unavailable.
pub fn current_cpu() -> usize {
    if let Some(v) = VIRTUAL_PIN.with(|p| p.get()) {
        return v;
    }
    sys::getcpu().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_to_core_zero_succeeds() {
        // core 0 always exists; real or virtual, the association holds
        let pin = pin_current_thread(0);
        assert_eq!(pin.cpu(), 0);
        assert_eq!(current_cpu(), 0);
    }

    #[test]
    fn pin_wraps_modulo_host_cores() {
        let n = crate::cpu::topology::n_logical_cpus();
        assert_eq!(pin_current_thread(n + 1).cpu(), (n + 1) % n);
    }

    #[test]
    fn pinned_thread_reports_its_cpu() {
        let n = crate::cpu::topology::n_logical_cpus();
        let target = (n - 1).min(1);
        std::thread::spawn(move || {
            let pin = pin_current_thread(target);
            assert_eq!(pin.cpu(), target);
            assert_eq!(current_cpu(), target);
            // the kind is reported, not hidden
            let _ = pin.is_real();
        })
        .join()
        .unwrap();
    }
}
