//! Core pinning via `sched_setaffinity` — the paper's CPU runtime "binds
//! each thread to a physical core" so per-thread timing is per-core timing.

/// Pin the calling thread to logical CPU `cpu` (modulo the host's CPU
/// count, so worker counts larger than the host degrade gracefully).
/// Returns Ok(actual_cpu) or the errno on failure.
pub fn pin_current_thread(cpu: usize) -> Result<usize, i32> {
    let ncpu = crate::cpu::topology::n_logical_cpus();
    let target = cpu % ncpu;
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        libc::CPU_SET(target, &mut set);
        let rc = libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
        if rc == 0 {
            Ok(target)
        } else {
            Err(*libc::__errno_location())
        }
    }
}

/// The CPU the calling thread currently runs on (for diagnostics).
pub fn current_cpu() -> usize {
    let cpu = unsafe { libc::sched_getcpu() };
    cpu.max(0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_to_core_zero_succeeds() {
        // core 0 always exists
        let got = pin_current_thread(0).expect("pin failed");
        assert_eq!(got, 0);
        assert_eq!(current_cpu(), 0);
    }

    #[test]
    fn pin_wraps_modulo_host_cores() {
        let n = crate::cpu::topology::n_logical_cpus();
        let got = pin_current_thread(n + 1).expect("pin failed");
        assert_eq!(got, (n + 1) % n);
    }
}
