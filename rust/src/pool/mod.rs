//! Core-bound worker thread pool — the real-hardware executor.
//!
//! One worker per core, pinned with `sched_setaffinity` (paper §2: "its
//! thread pool binds each thread to a physical core and it tracks the
//! execution time of each thread during executing kernels"). Jobs are
//! published epoch-style: the leader installs a [`Work`] + plan, bumps the
//! epoch, and waits on a condvar until every worker has checked in; each
//! worker measures its own busy time with a monotonic clock.

pub mod affinity;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::exec::{Executor, RunResult, Work};
use crate::sched::DispatchPlan;

/// Fat-pointer smuggling for the scoped job. Soundness: `execute` blocks
/// until all workers have finished with the pointer, so the referent
/// outlives every dereference.
#[derive(Clone, Copy)]
struct WorkRef(*const (dyn Work + 'static));
unsafe impl Send for WorkRef {}
unsafe impl Sync for WorkRef {}

/// Same smuggling for the caller's [`DispatchPlan`]: workers read the plan
/// in place instead of cloning its range vector per job — publishing a job
/// is allocation-free. Soundness contract is identical to [`WorkRef`].
#[derive(Clone, Copy)]
struct PlanRef(*const DispatchPlan);
unsafe impl Send for PlanRef {}
unsafe impl Sync for PlanRef {}

#[derive(Clone, Copy)]
struct Job {
    work: WorkRef,
    plan: PlanRef,
    total: usize,
}

impl Job {
    /// SAFETY: the leader keeps the plan alive until all workers check in.
    fn plan(&self) -> &DispatchPlan {
        unsafe { &*self.plan.0 }
    }

    fn plan_workers(&self) -> usize {
        match self.plan() {
            DispatchPlan::Partitioned(rs) => rs.len(),
            _ => 0, // guided uses this only as a divisor hint; see claim_guided
        }
    }
}

struct PoolState {
    epoch: u64,
    shutdown: bool,
    job: Option<Job>,
    done: usize,
    times: Vec<Option<f64>>,
    units: Vec<usize>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    go: Condvar,
    finished: Condvar,
    /// shared claim cursor for chunked/guided plans, reset per job
    cursor: AtomicUsize,
}

/// The host thread-pool executor.
pub struct HostPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n: usize,
    /// logical CPU each worker was pinned to
    pub pinned_cpus: Vec<usize>,
}

impl HostPool {
    /// Spawn `n` workers pinned to cores `0..n` (mod host cores).
    pub fn new(n: usize) -> HostPool {
        assert!(n > 0);
        let cpus: Vec<usize> = (0..n).collect();
        HostPool::with_cores(&cpus)
    }

    /// Spawn one worker per entry of `cpus`, pinning worker `i` to logical
    /// CPU `cpus[i]` (mod host cores) — the executor for a
    /// [`crate::coordinator`] lease on real hardware, where the lease's
    /// *global* core ids must become the pinned CPUs.
    pub fn with_cores(cpus: &[usize]) -> HostPool {
        let n = cpus.len();
        assert!(n > 0, "empty core list");
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                shutdown: false,
                job: None,
                done: 0,
                times: vec![None; n],
                units: vec![0; n],
            }),
            go: Condvar::new(),
            finished: Condvar::new(),
            cursor: AtomicUsize::new(0),
        });
        let pin_results = Arc::new(Mutex::new(vec![0usize; n]));
        let mut handles = Vec::with_capacity(n);
        for (worker, &cpu_target) in cpus.iter().enumerate() {
            let shared = Arc::clone(&shared);
            let pin_results = Arc::clone(&pin_results);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dynpar-w{worker}"))
                    .spawn(move || {
                        // a virtual pin (OS refused the mask) still records
                        // the intended CPU so worker↔core labels stay stable
                        let pin = affinity::pin_current_thread(cpu_target);
                        pin_results.lock().unwrap()[worker] = pin.cpu();
                        if !pin.is_real() {
                            crate::log_warn!(
                                "pool",
                                "worker {worker}: OS refused pin to cpu {}; using virtual pin",
                                pin.cpu()
                            );
                        }
                        worker_loop(worker, &shared);
                    })
                    .expect("spawn worker"),
            );
        }
        let pinned_cpus = pin_results.lock().unwrap().clone();
        HostPool { shared, handles, n, pinned_cpus }
    }
}

fn worker_loop(worker: usize, shared: &PoolShared) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            while !st.shutdown && st.epoch == seen_epoch {
                st = shared.go.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen_epoch = st.epoch;
            st.job.expect("epoch bumped without a job")
        };

        let t0 = Instant::now();
        let mut units_done = 0usize;
        // SAFETY: leader keeps the Work alive until all workers check in.
        let work: &dyn Work = unsafe { &*job.work.0 };
        match job.plan() {
            DispatchPlan::Partitioned(ranges) => {
                let r = ranges.get(worker).cloned().unwrap_or(0..0);
                if !r.is_empty() {
                    units_done = r.len();
                    work.run_range(worker, r);
                }
            }
            DispatchPlan::Chunked { chunk } => {
                loop {
                    let start = shared.cursor.fetch_add(*chunk, Ordering::Relaxed);
                    if start >= job.total {
                        break;
                    }
                    let end = (start + chunk).min(job.total);
                    units_done += end - start;
                    work.run_range(worker, start..end);
                }
            }
            DispatchPlan::Guided { min_chunk } => loop {
                let claimed =
                    claim_guided(&shared.cursor, job.total, *min_chunk, job.plan_workers());
                match claimed {
                    None => break,
                    Some(r) => {
                        units_done += r.len();
                        work.run_range(worker, r);
                    }
                }
            },
        }
        let elapsed = t0.elapsed().as_secs_f64();

        let mut st = shared.state.lock().unwrap();
        st.times[worker] = if units_done > 0 { Some(elapsed) } else { None };
        st.units[worker] = units_done;
        st.done += 1;
        if st.done == st.times.len() {
            shared.finished.notify_one();
        }
    }
}

/// Claim the next guided chunk: `max(min_chunk, remaining / (2·n))`.
fn claim_guided(
    cursor: &AtomicUsize,
    total: usize,
    min_chunk: usize,
    n_workers_hint: usize,
) -> Option<Range<usize>> {
    let denom = 2 * n_workers_hint.max(4);
    loop {
        let cur = cursor.load(Ordering::Relaxed);
        if cur >= total {
            return None;
        }
        let remaining = total - cur;
        let chunk = (remaining / denom).max(min_chunk).min(remaining);
        match cursor.compare_exchange_weak(cur, cur + chunk, Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return Some(cur..cur + chunk),
            Err(_) => continue,
        }
    }
}

impl Executor for HostPool {
    fn n_workers(&self) -> usize {
        self.n
    }

    fn execute(&mut self, work: &dyn Work, plan: &DispatchPlan) -> RunResult {
        let mut out = RunResult::default();
        self.execute_into(work, plan, &mut out);
        out
    }

    /// Allocation-free dispatch: the job smuggles borrowed pointers to the
    /// caller's `Work` and `DispatchPlan`, and the result vectors in `out`
    /// are refilled in place once their capacity is warm.
    fn execute_into(&mut self, work: &dyn Work, plan: &DispatchPlan, out: &mut RunResult) {
        let total = work.total_units();
        // SAFETY: we erase the lifetimes; this function joins the epoch
        // before returning, so workers never outlive either borrow.
        let work_ref = WorkRef(unsafe {
            std::mem::transmute::<*const (dyn Work + '_), *const (dyn Work + 'static)>(
                work as *const dyn Work,
            )
        });
        let plan_ref = PlanRef(plan as *const DispatchPlan);
        let t0 = Instant::now();
        {
            let mut st = self.shared.state.lock().unwrap();
            self.shared.cursor.store(0, Ordering::Relaxed);
            st.job = Some(Job { work: work_ref, plan: plan_ref, total });
            st.done = 0;
            st.times.iter_mut().for_each(|t| *t = None);
            st.units.iter_mut().for_each(|u| *u = 0);
            st.epoch += 1;
            self.shared.go.notify_all();
        }
        let mut st = self.shared.state.lock().unwrap();
        while st.done < self.n {
            st = self.shared.finished.wait(st).unwrap();
        }
        st.job = None;
        out.per_core_secs.clone_from(&st.times);
        out.units_done.clone_from(&st.units);
        drop(st);
        out.wall_secs = t0.elapsed().as_secs_f64();
        out.bytes = 0.0;
    }
}

impl Drop for HostPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.go.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{FnWork, SharedSlice};
    use crate::kernels::cost;
    use crate::sched::{DynamicScheduler, Scheduler, StaticEven, WorkStealing};
    use std::sync::atomic::AtomicU64;

    fn counting_work(total: usize, counter: &AtomicU64) -> impl Work + '_ {
        FnWork::new(cost::elementwise_cost(total, 1.0, 1.0), 1, move |_w, r| {
            counter.fetch_add(r.len() as u64, Ordering::Relaxed);
        })
    }

    #[test]
    fn partitioned_executes_all_units() {
        let mut pool = HostPool::new(4);
        let counter = AtomicU64::new(0);
        let total = 1000;
        let work = FnWork::new(cost::copy_cost(total * 4096), 1, |_w, r| {
            counter.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        let plan = StaticEven.plan(total, 1, &[1.0; 4]);
        let res = pool.execute(&work, &plan);
        assert_eq!(counter.load(Ordering::Relaxed), total as u64);
        assert_eq!(res.units_done.iter().sum::<usize>(), total);
        assert!(res.wall_secs > 0.0);
    }

    #[test]
    fn chunked_executes_all_units_exactly_once() {
        let mut pool = HostPool::new(3);
        let total = 777;
        let mut hits = vec![0u8; total];
        {
            let shared = SharedSlice::new(&mut hits);
            let work = FnWork::new(cost::copy_cost(total * 4096), 1, |_w, r| {
                let s = unsafe { shared.slice_mut(r) };
                for v in s {
                    *v += 1;
                }
            });
            let plan = WorkStealing { chunk: 10 }.plan(total, 1, &[1.0; 3]);
            pool.execute(&work, &plan);
        }
        assert!(hits.iter().all(|&h| h == 1), "some units ran 0 or 2+ times");
    }

    #[test]
    fn guided_executes_all_units_exactly_once() {
        let mut pool = HostPool::new(4);
        let total = 1234;
        let mut hits = vec![0u8; total];
        {
            let shared = SharedSlice::new(&mut hits);
            let work = FnWork::new(cost::copy_cost(total * 4096), 1, |_w, r| {
                let s = unsafe { shared.slice_mut(r) };
                for v in s {
                    *v += 1;
                }
            });
            pool.execute(&work, &DispatchPlan::Guided { min_chunk: 4 });
        }
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn dynamic_partition_respects_ratios() {
        let mut pool = HostPool::new(2);
        let counter = AtomicU64::new(0);
        let work = counting_work(100, &counter);
        let plan = DynamicScheduler.plan(100, 1, &[3.0, 1.0]);
        let res = pool.execute(&work, &plan);
        assert_eq!(res.units_done, vec![75, 25]);
    }

    #[test]
    fn per_core_times_reported_for_participants() {
        let mut pool = HostPool::new(3);
        // only 2 units: worker 2 gets nothing under static split of 2
        let counter = AtomicU64::new(0);
        let work = counting_work(2, &counter);
        let plan = StaticEven.plan(2, 1, &[1.0; 3]);
        let res = pool.execute(&work, &plan);
        let participants = res.per_core_secs.iter().filter(|t| t.is_some()).count();
        assert_eq!(participants, 2);
    }

    #[test]
    fn reusable_across_many_jobs() {
        let mut pool = HostPool::new(4);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            let work = counting_work(64, &counter);
            let plan = StaticEven.plan(64, 1, &[1.0; 4]);
            pool.execute(&work, &plan);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 50 * 64);
    }

    #[test]
    fn with_cores_executes_on_a_leased_subset() {
        // lease-style core list (ids beyond the host wrap modulo its CPUs)
        let mut pool = HostPool::with_cores(&[0, 2, 5]);
        assert_eq!(pool.n_workers(), 3);
        let counter = AtomicU64::new(0);
        let work = counting_work(300, &counter);
        let plan = StaticEven.plan(300, 1, &[1.0; 3]);
        let res = pool.execute(&work, &plan);
        assert_eq!(counter.load(Ordering::Relaxed), 300);
        assert_eq!(res.units_done.iter().sum::<usize>(), 300);
    }

    #[test]
    fn real_kernel_through_pool_matches_serial() {
        use crate::kernels::gemv_q4::{gemv_q4_f32, gemv_q4_f32_range};
        use crate::quant::MatQ4;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let (n, k) = (128, 64);
        let mut wdata = vec![0.0f32; n * k];
        rng.fill_normal_f32(&mut wdata, 1.0);
        let w = MatQ4::quantize(&wdata, n, k);
        let mut x = vec![0.0f32; k];
        rng.fill_normal_f32(&mut x, 1.0);
        let serial = gemv_q4_f32(&w, &x);

        let mut y = vec![0.0f32; n];
        {
            let shared = SharedSlice::new(&mut y);
            let wref = &w;
            let xref = &x;
            let work = FnWork::new(cost::gemv_q4_cost(k, n), 1, move |_wk, r| {
                let out = unsafe { shared.slice_mut(0..n) };
                gemv_q4_f32_range(wref, xref, out, r);
            });
            let mut pool = HostPool::new(4);
            let plan = DynamicScheduler.plan(n, 1, &[2.0, 1.0, 1.0, 1.0]);
            pool.execute(&work, &plan);
        }
        assert_eq!(y, serial);
    }
}
