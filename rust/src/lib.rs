//! `dynpar` — a dynamic parallel runtime for hybrid CPUs.
//!
//! Reproduction of *"A dynamic parallel method for performance optimization
//! on hybrid CPUs"* (CS.DC 2024). The paper's contribution is implemented in
//! [`perf`] (the CPU runtime: per-core, per-ISA performance-ratio table with
//! EWMA filtering) and [`sched`] (the thread scheduler that splits each
//! kernel's parallel dimension proportionally to the dynamic ratios), driven
//! either by a real core-bound thread pool ([`pool`]) or by a discrete-event
//! hybrid-CPU simulator ([`sim`]) through the common [`exec`] abstraction.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.

pub mod util;
pub mod cpu;
pub mod perf;
pub mod sched;
pub mod pool;
pub mod exec;
pub mod sim;
pub mod quant;
pub mod tensor;
pub mod kernels;
pub mod model;
pub mod engine;
pub mod runtime;
pub mod server;
pub mod metrics;
pub mod bench_harness;
pub mod trace;
