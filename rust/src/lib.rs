//! `dynpar` — a dynamic parallel runtime for hybrid CPUs.
//!
//! Reproduction of *"A dynamic parallel method for performance optimization
//! on hybrid CPUs"* (CS.DC 2024). The paper's contribution is implemented in
//! [`perf`] (the CPU runtime: per-core, per-ISA performance-ratio table with
//! EWMA filtering) and [`sched`] (the thread scheduler that splits each
//! kernel's parallel dimension proportionally to the dynamic ratios), driven
//! either by a real core-bound thread pool ([`pool`]) or by a discrete-event
//! hybrid-CPU simulator ([`sim`]) through the common [`exec`] abstraction.
//!
//! Multi-stream serving is coordinated by [`coordinator`]: it owns the
//! machine's core set and leases disjoint, topology-aware core subsets to
//! concurrent engines, rebalancing as streams arrive/finish or as measured
//! per-core strength drifts (e.g. background load).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.

// Style lints the (large, pre-rustfmt) seed tree intentionally tolerates;
// correctness lints stay on.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::useless_vec
)]

pub mod util;
pub mod cpu;
pub mod perf;
pub mod sched;
pub mod pool;
pub mod exec;
pub mod coordinator;
pub mod cluster;
pub mod sim;
pub mod quant;
pub mod tensor;
pub mod kernels;
pub mod model;
pub mod engine;
pub mod router;
pub mod runtime;
pub mod server;
pub mod metrics;
pub mod bench_harness;
pub mod trace;
