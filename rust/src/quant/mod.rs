//! Block quantization formats (llama.cpp-compatible Q4_0, Q8 dynamic).
//!
//! The semantics here are the *same ops in the same order* as the Python
//! reference (`python/compile/quant.py`) so that the Rust native engine and
//! the AOT PJRT artifacts consume identical quantized tensors — the
//! native-vs-PJRT logits parity test depends on this.

pub mod q4_0;
pub mod q8;

pub use q4_0::{dequantize_row_q4_0, quantize_row_q4_0, BlockQ4_0, MatQ4, QK};
pub use q8::{quantize_q8_dynamic, QuantizedRow};
