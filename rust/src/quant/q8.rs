//! Per-row symmetric int8 dynamic activation quantization.
//!
//! Matches `compile/quant.py::quantize_q8_dynamic`: scale = absmax / 127
//! (or 1.0 for an all-zero row), codes = round-half-to-even(x / scale)
//! clamped to [−127, 127]. numpy's `np.round` is banker's rounding, so we
//! use `round_ties_even` for cross-language parity.

/// A dynamically-quantized activation row.
#[derive(Clone, Debug, Default)]
pub struct QuantizedRow {
    pub q: Vec<i8>,
    pub scale: f32,
}

/// Quantize one activation row.
pub fn quantize_q8_dynamic(x: &[f32]) -> QuantizedRow {
    let mut out = QuantizedRow::default();
    quantize_q8_dynamic_into(x, &mut out);
    out
}

/// Allocation-free quantization into a persistent row: identical codes and
/// scale to [`quantize_q8_dynamic`], but `out.q`'s capacity is reused so
/// the decode hot loop never touches the allocator after warm-up.
pub fn quantize_q8_dynamic_into(x: &[f32], out: &mut QuantizedRow) {
    let amax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    out.scale = scale;
    out.q.clear();
    out.q.extend(x.iter().map(|&v| (v * inv).round_ties_even().clamp(-127.0, 127.0) as i8));
}

impl QuantizedRow {
    /// Dequantize (tests only).
    pub fn dequantize(&self) -> Vec<f32> {
        self.q.iter().map(|&q| q as f32 * self.scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let mut x = vec![0.0f32; 256];
        rng.fill_normal_f32(&mut x, 3.0);
        let qr = quantize_q8_dynamic(&x);
        let deq = qr.dequantize();
        let amax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in x.iter().zip(&deq) {
            assert!((a - b).abs() <= amax / 127.0 * 0.51 + 1e-6);
        }
    }

    #[test]
    fn zero_row() {
        let qr = quantize_q8_dynamic(&[0.0; 16]);
        assert_eq!(qr.scale, 1.0);
        assert!(qr.q.iter().all(|&q| q == 0));
    }

    #[test]
    fn max_element_hits_127() {
        let x = [1.0f32, -0.5, 0.25, 0.0];
        let qr = quantize_q8_dynamic(&x);
        assert_eq!(qr.q[0], 127);
    }

    #[test]
    fn ties_round_to_even() {
        // scale = 1/127 · 127 = 1 → x = 0.5/127·127... construct directly:
        // amax = 127 → scale = 1.0; 0.5 rounds to 0, 1.5 rounds to 2
        let x = [127.0f32, 0.5, 1.5, -0.5];
        let qr = quantize_q8_dynamic(&x);
        assert_eq!(qr.scale, 1.0);
        assert_eq!(qr.q[1], 0);
        assert_eq!(qr.q[2], 2);
        assert_eq!(qr.q[3], 0);
    }

    #[test]
    fn into_variant_matches_and_reuses_capacity() {
        let mut rng = Rng::new(9);
        let mut x = vec![0.0f32; 192];
        rng.fill_normal_f32(&mut x, 2.0);
        let want = quantize_q8_dynamic(&x);
        let mut row = QuantizedRow::default();
        quantize_q8_dynamic_into(&x, &mut row);
        assert_eq!(row.q, want.q);
        assert_eq!(row.scale, want.scale);
        let cap = row.q.capacity();
        let ptr = row.q.as_ptr();
        quantize_q8_dynamic_into(&x, &mut row);
        assert_eq!(row.q, want.q);
        assert_eq!(row.q.capacity(), cap);
        assert_eq!(row.q.as_ptr(), ptr, "steady-state requantize must not reallocate");
    }

    #[test]
    fn prop_codes_bounded() {
        prop::check("q8_codes_bounded", |rng| {
            let n = 1 + rng.below(128) as usize;
            let mut x = vec![0.0f32; n];
            let scale = 10f32.powf(rng.uniform(-3.0, 3.0) as f32);
            rng.fill_normal_f32(&mut x, scale);
            let qr = quantize_q8_dynamic(&x);
            if qr.q.iter().all(|&q| (-127..=127).contains(&(q as i32))) {
                Ok(())
            } else {
                Err("code out of range".into())
            }
        });
    }
}

#[cfg(test)]
mod golden_tests {
    //! Cross-language golden values from `python/compile/quant.py` on
    //! `x[i] = sin(i+1)` — pins round-ties-even + scale semantics.

    use super::*;

    #[test]
    fn q8_codes_and_scale_match_python_exactly() {
        let x: Vec<f32> = (1..=32).map(|i| (i as f32).sin()).collect();
        let qr = quantize_q8_dynamic(&x);
        assert_eq!(&qr.q[..8], &[107i8, 115, 18, -96, -122, -35, 83, 126]);
        assert!((qr.scale - 0.007_873_938_4).abs() < 1e-9, "scale {}", qr.scale);
    }
}
