//! Q4_0: blocks of 32 weights, one f16 scale, 4-bit codes with offset 8.
//!
//! Reference semantics (must match `python/compile/quant.py` exactly):
//! ```text
//! max  = signed element with the largest |x| in the block (first on ties)
//! d    = max / -8                       (f32; stored as f16)
//! id   = 1/d if d != 0 else 0           (from the *unrounded* f32 d)
//! q    = clamp(floor(x * id + 8.5), 0, 15)
//! deq  = (q - 8) * f32(f16(d))
//! ```
//! Packing follows llama.cpp: byte `j` holds code `j` in the low nibble and
//! code `j + 16` in the high nibble (18 bytes per 32 weights).

use crate::util::f16;

/// Values per block.
pub const QK: usize = 32;

/// One packed Q4_0 block: 18 bytes for 32 weights (4.5 bits/weight).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockQ4_0 {
    /// f16 bit pattern of the scale
    pub d: u16,
    /// packed nibbles: qs[j] = code[j] | (code[j+16] << 4)
    pub qs: [u8; QK / 2],
}

impl BlockQ4_0 {
    pub const BYTES: usize = 2 + QK / 2;

    /// Scale as f32.
    #[inline]
    pub fn scale(&self) -> f32 {
        f16::f16_bits_to_f32(self.d)
    }

    /// Unpacked code (0..=15) at index `i`.
    #[inline]
    pub fn code(&self, i: usize) -> u8 {
        debug_assert!(i < QK);
        if i < QK / 2 {
            self.qs[i] & 0x0F
        } else {
            self.qs[i - QK / 2] >> 4
        }
    }

    /// Dequantized value at index `i`.
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        (self.code(i) as i32 - 8) as f32 * self.scale()
    }
}

/// Quantize one row (len divisible by QK) into packed blocks.
pub fn quantize_row_q4_0(x: &[f32]) -> Vec<BlockQ4_0> {
    assert!(x.len() % QK == 0, "row length {} not divisible by {QK}", x.len());
    x.chunks_exact(QK)
        .map(|chunk| {
            // signed max-|.| element, first on ties (matches np.argmax scan)
            let mut mx = 0.0f32;
            let mut amax = -1.0f32;
            for &v in chunk {
                if v.abs() > amax {
                    amax = v.abs();
                    mx = v;
                }
            }
            let d = mx / -8.0;
            let id = if d != 0.0 { 1.0 / d } else { 0.0 };
            let mut qs = [0u8; QK / 2];
            let mut code = [0u8; QK];
            for (i, &v) in chunk.iter().enumerate() {
                let q = (v * id + 8.5).floor().clamp(0.0, 15.0) as u8;
                code[i] = q;
            }
            for j in 0..QK / 2 {
                qs[j] = code[j] | (code[j + QK / 2] << 4);
            }
            BlockQ4_0 { d: f16::f32_to_f16_bits(d), qs }
        })
        .collect()
}

/// Dequantize packed blocks back to f32.
pub fn dequantize_row_q4_0(blocks: &[BlockQ4_0], out: &mut [f32]) {
    assert_eq!(out.len(), blocks.len() * QK);
    for (b, chunk) in blocks.iter().zip(out.chunks_exact_mut(QK)) {
        let d = b.scale();
        for j in 0..QK / 2 {
            let byte = b.qs[j];
            chunk[j] = ((byte & 0x0F) as i32 - 8) as f32 * d;
            chunk[j + QK / 2] = ((byte >> 4) as i32 - 8) as f32 * d;
        }
    }
}

/// A Q4_0-quantized row-major matrix `[rows, cols]`.
#[derive(Clone, Debug)]
pub struct MatQ4 {
    pub rows: usize,
    pub cols: usize,
    /// rows · (cols / QK) packed blocks, row-major
    pub blocks: Vec<BlockQ4_0>,
}

impl MatQ4 {
    pub fn blocks_per_row(&self) -> usize {
        self.cols / QK
    }

    /// Quantize a dense row-major f32 matrix.
    pub fn quantize(data: &[f32], rows: usize, cols: usize) -> MatQ4 {
        assert_eq!(data.len(), rows * cols);
        assert!(cols % QK == 0);
        let mut blocks = Vec::with_capacity(rows * cols / QK);
        for r in 0..rows {
            blocks.extend(quantize_row_q4_0(&data[r * cols..(r + 1) * cols]));
        }
        MatQ4 { rows, cols, blocks }
    }

    /// Blocks of one row.
    #[inline]
    pub fn row(&self, r: usize) -> &[BlockQ4_0] {
        let bpr = self.blocks_per_row();
        &self.blocks[r * bpr..(r + 1) * bpr]
    }

    /// Dequantize everything (tests / oracle paths).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            dequantize_row_q4_0(self.row(r), &mut out[r * self.cols..(r + 1) * self.cols]);
        }
        out
    }

    /// Unpack to `(codes 0..=15 as i8 [rows·cols], scales f32 [rows·cols/QK])`
    /// — the representation the PJRT artifacts take as parameters.
    pub fn unpack(&self) -> (Vec<i8>, Vec<f32>) {
        let mut codes = Vec::with_capacity(self.rows * self.cols);
        let mut scales = Vec::with_capacity(self.blocks.len());
        for b in &self.blocks {
            scales.push(b.scale());
            // NOTE: unpack order must be code index order (0..32), not byte order
        }
        for r in 0..self.rows {
            for b in self.row(r) {
                for i in 0..QK {
                    codes.push(b.code(i) as i8);
                }
            }
        }
        (codes, scales)
    }

    /// Total packed size in bytes (the number the decode phase streams).
    pub fn packed_bytes(&self) -> usize {
        self.blocks.len() * BlockQ4_0::BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal_f32(&mut v, scale);
        v
    }

    #[test]
    fn roundtrip_error_bounded_by_step() {
        let x = rand_row(256, 1, 1.0);
        let blocks = quantize_row_q4_0(&x);
        let mut out = vec![0.0; 256];
        dequantize_row_q4_0(&blocks, &mut out);
        for (chunk, ochunk) in x.chunks_exact(QK).zip(out.chunks_exact(QK)) {
            let amax = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let step = amax / 8.0;
            for (a, b) in chunk.iter().zip(ochunk) {
                assert!((a - b).abs() <= step + 1e-6, "{a} vs {b} (step {step})");
            }
        }
    }

    #[test]
    fn zero_block_is_exact() {
        let blocks = quantize_row_q4_0(&[0.0; QK]);
        assert_eq!(blocks[0].scale(), 0.0);
        let mut out = [1.0f32; QK];
        dequantize_row_q4_0(&blocks, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn codes_are_in_nibble_range() {
        let x = rand_row(QK * 8, 3, 10.0);
        for b in quantize_row_q4_0(&x) {
            for i in 0..QK {
                assert!(b.code(i) <= 15);
            }
        }
    }

    #[test]
    fn packing_layout_matches_llama_cpp() {
        // construct values that quantize to known distinct codes
        let mut x = [0.0f32; QK];
        x[0] = -8.0; // the max-|.| element → code 0
        x[16] = 7.0; // near the top → code 15
        let b = &quantize_row_q4_0(&x)[0];
        // byte 0 = code[0] | code[16] << 4
        assert_eq!(b.qs[0] & 0x0F, b.code(0));
        assert_eq!(b.qs[0] >> 4, b.code(16));
        assert_eq!(b.code(0), 0);
        assert_eq!(b.code(16), 15);
    }

    #[test]
    fn max_element_reconstructs_exactly() {
        let x = rand_row(QK * 4, 7, 2.0);
        let blocks = quantize_row_q4_0(&x);
        for (chunk, b) in x.chunks_exact(QK).zip(&blocks) {
            let (mut mx, mut amax) = (0.0f32, -1.0f32);
            for &v in chunk {
                if v.abs() > amax {
                    amax = v.abs();
                    mx = v;
                }
            }
            // max maps to code 0 → reconstructs to -8·d = max (up to f16)
            let idx = chunk.iter().position(|&v| v == mx).unwrap();
            let rel = (b.value(idx) - mx).abs() / mx.abs().max(1e-9);
            assert!(rel < 2e-3, "mx={mx} got={}", b.value(idx));
        }
    }

    #[test]
    fn mat_unpack_matches_dequant() {
        let data = rand_row(8 * 64, 9, 1.0);
        let m = MatQ4::quantize(&data, 8, 64);
        let (codes, scales) = m.unpack();
        assert_eq!(codes.len(), 8 * 64);
        assert_eq!(scales.len(), 8 * 2);
        let deq = m.dequantize();
        for r in 0..8 {
            for c in 0..64 {
                let code = codes[r * 64 + c] as f32 - 8.0;
                let sc = scales[r * 2 + c / QK];
                let want = code * sc;
                assert!((deq[r * 64 + c] - want).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn packed_bytes_is_4_5_bits_per_weight() {
        let data = rand_row(4 * 128, 11, 1.0);
        let m = MatQ4::quantize(&data, 4, 128);
        assert_eq!(m.packed_bytes(), 4 * 128 / QK * 18);
    }

    #[test]
    fn prop_roundtrip_bounded() {
        prop::check("q4_roundtrip", |rng| {
            let nblocks = 1 + rng.below(6) as usize;
            let scale = 10f32.powf(rng.uniform(-2.0, 2.0) as f32);
            let x = {
                let mut v = vec![0.0f32; nblocks * QK];
                rng.fill_normal_f32(&mut v, scale);
                v
            };
            let blocks = quantize_row_q4_0(&x);
            let mut out = vec![0.0; x.len()];
            dequantize_row_q4_0(&blocks, &mut out);
            let amax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let err = x.iter().zip(&out).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            if err <= amax / 8.0 * 1.01 + 1e-6 {
                Ok(())
            } else {
                Err(format!("err {err} > bound {}", amax / 8.0))
            }
        });
    }
}

#[cfg(test)]
mod golden_tests {
    //! Cross-language golden values: these constants were produced by
    //! `python/compile/quant.py` on the same deterministic input
    //! (`x[i] = 2·sin(i+1)`), pinning the Rust↔Python quantization ABI
    //! bit for bit (codes and f16 scale bit patterns).

    use super::*;

    #[test]
    fn q4_codes_and_scales_match_python_exactly() {
        let x: Vec<f32> = (1..=64).map(|i| (i as f32).sin() * 2.0).collect();
        let blocks = quantize_row_q4_0(&x);
        assert_eq!(blocks.len(), 2);
        #[rustfmt::skip]
        let want_codes: [u8; 64] = [
            15, 15, 9, 2, 0, 6, 13, 15, 11, 4, 0, 4, 11, 15, 13, 6,
            0, 2, 9, 15, 15, 8, 1, 1, 7, 14, 15, 10, 3, 0, 5, 12,
            0, 4, 11, 15, 13, 6, 0, 2, 9, 15, 15, 8, 1, 1, 7, 14,
            15, 10, 3, 0, 5, 12, 15, 12, 5, 0, 3, 10, 15, 14, 7, 1,
        ];
        for (i, &want) in want_codes.iter().enumerate() {
            let b = &blocks[i / QK];
            assert_eq!(b.code(i % QK), want, "code {i}");
        }
        // numpy f16 scale bit patterns
        assert_eq!(blocks[0].d, 0x3400, "scale 0");
        assert_eq!(blocks[1].d, 0xB400, "scale 1");
    }
}
