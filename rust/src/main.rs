//! `dynpar` — CLI launcher for the dynamic-parallel runtime.
//!
//! Subcommands:
//!   presets                         list simulated hybrid-CPU presets
//!   mlc        [--preset X]         MLC-like bandwidth reference
//!   bench gemm [--preset X|all] …   Figure 2-left (INT8 GEMM)
//!   bench gemv …                    Figure 2-right (INT4 GEMV bandwidth)
//!   bench e2e  …                    Figure 3 (llama2-7B end-to-end)
//!   bench all                       all of the above
//!   trace      [--alpha 0.3] …      Figure 4 ratio trace (CSV to stdout/file)
//!   infer      [--model tiny] …     tiny-model generation, native / PJRT
//!   serve      [--addr host:port] … TCP serving front-end
//!   ablate     alpha|chunk|noise    design-choice sweeps

use std::sync::Arc;

use dynpar::bench_harness::{fig2, fig3, fig4, report, sim_runtime, FIG2_SCHEDULERS, PAPER_CPUS};
use dynpar::cpu::{presets, Isa};
use dynpar::engine::Engine;
use dynpar::exec::PhantomWork;
use dynpar::kernels::cost;
use dynpar::model::{ModelConfig, ModelWeights};
use dynpar::perf::PerfConfig;
use dynpar::sched::{scheduler_by_name, SCHEDULER_NAMES};
use dynpar::sim::{HybridSim, SimConfig, SimExecutor};
use dynpar::util::argparse::Args;

const USAGE: &str = "usage: dynpar <presets|mlc|bench|trace|infer|serve|ablate> [options]
  dynpar bench <gemm|gemv|e2e|all> [--preset <name|all>] [--iters N] [--prompt N] [--decode N] [--noisy]
  dynpar bench pr3 [--out BENCH_pr3.json]     hetero-lease (cores+NPU) serving trajectory
  dynpar bench pr4 [--out BENCH_pr4.json]     async CPU/XPU batch split vs intra-kernel
  dynpar bench pr7 [--out BENCH_pr7.json]     disaggregated prefill/decode vs blended lease
  dynpar bench pr8 [--out BENCH_pr8.json]     fused-dispatch arena path vs per-matrix baseline
  dynpar bench pr9 [--out BENCH_pr9.json]     cluster tier: throughput vs machine count + recovery
  dynpar bench pr10 [--out BENCH_pr10.json]   SLO-aware strategy router vs every static config
  dynpar trace [--preset ultra_125h] [--alpha 0.3] [--init 5] [--prompt N] [--decode N] [--out file.csv]
  dynpar infer [--model tiny|micro] [--backend native|pjrt|both] [--preset X] [--sched dynamic] [--new N]
  dynpar serve [--addr 127.0.0.1:7878] [--model micro] [--preset X] [--max-batch 4]
  dynpar ablate <alpha|chunk|noise> [--preset X]
  dynpar mlc [--preset X]";

fn cpus_arg(args: &Args) -> Vec<String> {
    match args.opt("preset") {
        None | Some("all") => PAPER_CPUS.iter().map(|s| s.to_string()).collect(),
        Some(p) => vec![p.to_string()],
    }
}

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("presets") => cmd_presets(),
        Some("mlc") => cmd_mlc(&args),
        Some("bench") => cmd_bench(&args),
        Some("trace") => cmd_trace(&args),
        Some("infer") => cmd_infer(&args),
        Some("serve") => cmd_serve(&args),
        Some("ablate") => cmd_ablate(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_presets() {
    println!("available CPU presets:");
    for name in ["core_12900k", "ultra_125h", "homogeneous_16"] {
        let spec = presets::preset_by_name(name).unwrap();
        let p = spec.count_kind(dynpar::cpu::CoreKind::Performance);
        let e = spec.count_kind(dynpar::cpu::CoreKind::Efficiency);
        let lpe = spec.count_kind(dynpar::cpu::CoreKind::LowPower);
        let mlc = HybridSim::new(spec.clone(), SimConfig::noiseless()).mlc_bandwidth();
        println!(
            "  {name:<16} {p}P + {e}E + {lpe}LPE   bus {:>5.1} GB/s   mlc {mlc:>5.1} GB/s   VNNI P:E ratio {:.2}",
            spec.bus_bw_gbps,
            spec.ideal_ratios(Isa::AvxVnni)[0],
        );
    }
    println!("schedulers: {}", SCHEDULER_NAMES.join(", "));
}

fn cmd_mlc(args: &Args) {
    for cpu in cpus_arg(args) {
        let spec = presets::preset_by_name(&cpu).expect("unknown preset");
        let sim = HybridSim::new(spec.clone(), SimConfig::noiseless());
        println!(
            "{cpu}: mlc-like reference bandwidth = {:.1} GB/s (bus {:.1})",
            sim.mlc_bandwidth(),
            spec.bus_bw_gbps
        );
    }
}

fn cmd_bench(args: &Args) {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let cpus = cpus_arg(args);
    let cpu_refs: Vec<&str> = cpus.iter().map(|s| s.as_str()).collect();
    let iters = args.usize_or("iters", 20);
    let warmup = args.usize_or("warmup", 15);
    let noisy = args.flag("noisy");
    let json = args.flag("json");

    if which == "gemm" || which == "all" {
        let res =
            fig2::run_gemm(&cpu_refs, &FIG2_SCHEDULERS, 1024, 4096, 4096, warmup, iters, noisy);
        let t = fig2::gemm_table(&res);
        println!("\n== Figure 2-left: INT8 GEMM 1024x4096x4096 ==");
        print!("{}", if json { t.to_json().dump() } else { t.render() });
    }
    if which == "gemv" || which == "all" {
        let res = fig2::run_gemv(&cpu_refs, &FIG2_SCHEDULERS, 4096, 4096, warmup, iters, noisy);
        let t = fig2::gemv_table(&res);
        println!("\n== Figure 2-right: INT4 GEMV 1x4096x4096 (bandwidth) ==");
        print!("{}", if json { t.to_json().dump() } else { t.render() });
    }
    if which == "pr3" {
        let j = dynpar::bench_harness::pr3::run();
        match args.opt("out") {
            Some(path) => {
                std::fs::write(path, format!("{}\n", j.dump())).expect("write pr3 trajectory");
                eprintln!("wrote PR-3 trajectory to {path}");
            }
            None => println!("{}", j.dump()),
        }
        return;
    }
    if which == "pr4" {
        let j = dynpar::bench_harness::pr4::run();
        match args.opt("out") {
            Some(path) => {
                std::fs::write(path, format!("{}\n", j.dump())).expect("write pr4 trajectory");
                eprintln!("wrote PR-4 trajectory to {path}");
            }
            None => println!("{}", j.dump()),
        }
        return;
    }
    if which == "pr7" {
        let j = dynpar::bench_harness::pr7::run();
        match args.opt("out") {
            Some(path) => {
                std::fs::write(path, format!("{}\n", j.dump())).expect("write pr7 trajectory");
                eprintln!("wrote PR-7 trajectory to {path}");
            }
            None => println!("{}", j.dump()),
        }
        return;
    }
    if which == "pr8" {
        let j = dynpar::bench_harness::pr8::run();
        match args.opt("out") {
            Some(path) => {
                std::fs::write(path, format!("{}\n", j.dump())).expect("write pr8 report");
                eprintln!("wrote PR-8 report to {path}");
            }
            None => println!("{}", j.dump()),
        }
        return;
    }
    if which == "pr9" {
        let j = dynpar::bench_harness::pr9::run();
        match args.opt("out") {
            Some(path) => {
                std::fs::write(path, format!("{}\n", j.dump())).expect("write pr9 report");
                eprintln!("wrote PR-9 report to {path}");
            }
            None => println!("{}", j.dump()),
        }
        return;
    }
    if which == "pr10" {
        let j = dynpar::bench_harness::pr10::run();
        match args.opt("out") {
            Some(path) => {
                std::fs::write(path, format!("{}\n", j.dump())).expect("write pr10 report");
                eprintln!("wrote PR-10 report to {path}");
            }
            None => println!("{}", j.dump()),
        }
        return;
    }
    if which == "e2e" || which == "all" {
        let prompt = args.usize_or("prompt", 1024);
        let decode = args.usize_or("decode", 32);
        let res = fig3::run(&cpu_refs, prompt, decode, noisy);
        let t = fig3::table(&res);
        println!("\n== Figure 3: llama2-7B end-to-end (prompt {prompt}, decode {decode}) ==");
        print!("{}", if json { t.to_json().dump() } else { t.render() });
    }
}

fn cmd_trace(args: &Args) {
    let p = fig4::Fig4Params {
        cpu: args.opt_or("preset", "ultra_125h"),
        alpha: args.f64_or("alpha", 0.3),
        init_ratio: args.f64_or("init", 5.0),
        core: args.usize_or("core", 0),
        prompt_len: args.usize_or("prompt", 1024),
        n_decode: args.usize_or("decode", 64),
        prefill_chunk: args.usize_or("chunk", 64),
        noisy: !args.flag("noiseless"),
    };
    let trace = fig4::run(&p);
    let csv = trace.to_csv();
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &csv).expect("write trace");
            eprintln!(
                "wrote {} samples to {path} (prefill mean {:.2}, decode mean {:.2})",
                trace.samples.len(),
                trace.phase_mean("prefill").unwrap_or(0.0),
                trace.phase_mean("decode").unwrap_or(0.0)
            );
        }
        None => print!("{csv}"),
    }
}

fn cmd_infer(args: &Args) {
    let model = args.opt_or("model", "tiny");
    let cfg = ModelConfig::by_name(&model).expect("unknown model (tiny|micro)");
    let backend = args.opt_or("backend", "native");
    let preset = args.opt_or("preset", "ultra_125h");
    let sched = args.opt_or("sched", "dynamic");
    let n_new = args.usize_or("new", 16);
    let prompt: Vec<u32> =
        (1..=args.usize_or("prompt", 8) as u32).map(|t| t % cfg.vocab as u32).collect();
    let weights = Arc::new(ModelWeights::random_init(&cfg, args.u64_or("seed", 0)));

    let native_tokens = if backend == "native" || backend == "both" {
        let spec = presets::preset_by_name(&preset).expect("unknown preset");
        let exec =
            SimExecutor::new(spec, SimConfig { execute_real: true, ..SimConfig::noiseless() });
        let mut engine = Engine::new(
            cfg.clone(),
            Arc::clone(&weights),
            exec,
            scheduler_by_name(&sched).expect("unknown scheduler"),
            PerfConfig::default(),
        );
        let mut session = engine.new_session();
        let (tokens, m) = engine.generate(&mut session, &prompt, n_new);
        println!("[native/{preset}/{sched}] tokens: {tokens:?}");
        println!(
            "[native] prefill {:.3} ms ({} tok), decode {:.3} ms/tok, {:.1} tok/s (virtual time)",
            m.prefill_secs * 1e3,
            m.prompt_tokens,
            m.decode_latency() * 1e3,
            m.decode_tokens_per_sec()
        );
        Some(tokens)
    } else {
        None
    };

    if backend == "pjrt" || backend == "both" {
        let manifest =
            dynpar::runtime::Manifest::load(dynpar::runtime::artifacts::default_artifact_dir())
                .expect("artifacts missing — run `make artifacts`");
        let mut pjrt = dynpar::runtime::PjrtEngine::load(&manifest, &model, &weights)
            .expect("loading PJRT artifacts");
        let t0 = std::time::Instant::now();
        let tokens = pjrt.generate(&prompt, n_new).expect("pjrt generate");
        println!("[pjrt] tokens: {tokens:?}  ({:.2}s wall)", t0.elapsed().as_secs_f64());
        if let Some(nt) = native_tokens {
            assert_eq!(nt, tokens, "native and PJRT disagree!");
            println!("[parity] native and PJRT backends produced identical tokens ✓");
        }
    }
}

fn cmd_serve(args: &Args) {
    let model = args.opt_or("model", "micro");
    let cfg = ModelConfig::by_name(&model).expect("unknown model");
    let preset = args.opt_or("preset", "ultra_125h");
    let weights = Arc::new(ModelWeights::random_init(&cfg, args.u64_or("seed", 0)));
    let spec = presets::preset_by_name(&preset).expect("unknown preset");
    let exec = SimExecutor::new(spec, SimConfig { execute_real: true, ..SimConfig::noiseless() });
    let engine = Engine::new(
        cfg,
        weights,
        exec,
        scheduler_by_name(&args.opt_or("sched", "dynamic")).expect("unknown scheduler"),
        PerfConfig::default(),
    );
    let addr = args.opt_or("addr", "127.0.0.1:7878");
    let opts = dynpar::server::ServerOpts {
        max_batch: args.usize_or("max-batch", 4),
        prefill_chunk: args.usize_or("prefill-chunk", 16),
        queue_depth: args.usize_or("queue-depth", 256),
        ..Default::default()
    };
    let handle = dynpar::server::serve(&addr, engine, opts).expect("bind");
    println!("dynpar serving model '{model}' on {} (Ctrl-C to stop)", handle.addr);
    println!(r#"protocol: {{"id":1,"prompt":[1,2,3],"max_new_tokens":8}} per line"#);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_ablate(args: &Args) {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("alpha");
    let preset = args.opt_or("preset", "ultra_125h");
    let spec = presets::preset_by_name(&preset).expect("unknown preset");
    match which {
        "alpha" => {
            // filter-gain sweep: convergence speed vs steady-state latency
            println!("== ablation: EWMA filter gain α ({preset}, INT8 GEMM) ==");
            let mut t =
                report::Table::new(&["alpha", "first_iter", "converged_p50", "iters_to_1.05x"]);
            let c = cost::gemm_i8_cost(1024, 4096, 4096);
            for alpha in [0.0, 0.1, 0.3, 0.5, 0.7, 0.9] {
                let mut rt = sim_runtime(
                    spec.clone(),
                    "dynamic",
                    SimConfig::noiseless(),
                    PerfConfig { alpha, init_ratio: 1.0 },
                );
                let mut lat = Vec::new();
                for _ in 0..40 {
                    lat.push(rt.run(&PhantomWork::new(c)).wall_secs);
                }
                let best = lat.iter().cloned().fold(f64::INFINITY, f64::min);
                let conv = lat.iter().position(|&l| l < best * 1.05).unwrap_or(lat.len());
                t.row(vec![
                    format!("{alpha:.1}"),
                    report::fmt_secs(lat[0]),
                    report::fmt_secs(best),
                    format!("{conv}"),
                ]);
            }
            print!("{}", t.render());
        }
        "chunk" => {
            println!("== ablation: work-stealing chunk size ({preset}, INT8 GEMM) ==");
            let mut t = report::Table::new(&["chunk", "latency", "vs_dynamic"]);
            let c = cost::gemm_i8_cost(1024, 4096, 4096);
            let mut rtd =
                sim_runtime(spec.clone(), "dynamic", SimConfig::noiseless(), PerfConfig::default());
            for _ in 0..20 {
                rtd.run(&PhantomWork::new(c));
            }
            let dyn_p50 = rtd.run(&PhantomWork::new(c)).wall_secs;
            for chunk in [1usize, 4, 16, 64, 256] {
                let mut sim = HybridSim::new(spec.clone(), SimConfig::noiseless());
                let plan = dynpar::sched::DispatchPlan::Chunked { chunk };
                let wall = sim.execute_plan(None, &c, &plan).wall_secs;
                t.row(vec![
                    format!("{chunk}"),
                    report::fmt_secs(wall),
                    format!("{:.2}x", wall / dyn_p50),
                ]);
            }
            print!("{}", t.render());
        }
        "noise" => {
            println!("== ablation: background-load robustness ({preset}) ==");
            // a background task steals 50% of core 0 partway through; the
            // dynamic method re-balances, static cannot
            let c = cost::gemm_i8_cost(1024, 4096, 4096);
            let mut t = report::Table::new(&["scheduler", "clean", "with_load", "degradation"]);
            for sched in ["static", "dynamic"] {
                let run_with = |background: Vec<dynpar::sim::BackgroundLoad>| {
                    let noise = dynpar::sim::NoiseConfig {
                        sigma: 0.0,
                        background,
                        ..dynpar::sim::NoiseConfig::disabled()
                    };
                    let mut rt = sim_runtime(
                        spec.clone(),
                        sched,
                        SimConfig { noise, ..SimConfig::noiseless() },
                        PerfConfig::default(),
                    );
                    let mut last = 0.0;
                    for _ in 0..30 {
                        last = rt.run(&PhantomWork::new(c)).wall_secs;
                    }
                    last
                };
                let clean = run_with(vec![]);
                let loaded = run_with(vec![dynpar::sim::BackgroundLoad {
                    core: 0,
                    start: 0.0,
                    end: 1e9,
                    fraction: 0.5,
                }]);
                t.row(vec![
                    sched.to_string(),
                    report::fmt_secs(clean),
                    report::fmt_secs(loaded),
                    format!("{:.1}%", (loaded / clean - 1.0) * 100.0),
                ]);
            }
            print!("{}", t.render());
        }
        other => {
            eprintln!("unknown ablation '{other}' (alpha|chunk|noise)");
            std::process::exit(2);
        }
    }
}
