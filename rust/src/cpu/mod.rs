//! CPU descriptions: ISAs, core kinds, per-core capability specs and the
//! calibrated presets for the paper's two testbeds (Core i9-12900K and
//! Core Ultra 7 125H), plus host topology probing.

pub mod presets;
pub mod spec;
pub mod topology;

pub use presets::{core_12900k, homogeneous, preset_by_name, ultra_125h, PRESET_NAMES};
pub use spec::{CoreKind, CoreSpec, CpuSpec, Isa};
