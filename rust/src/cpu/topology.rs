//! Host topology probe: how many real cores we have to pin workers to.
//!
//! This sandbox exposes a single core, so the *figure* experiments run on
//! the simulator; the probe exists so the host thread pool binds correctly
//! on real hybrid machines (and degrades gracefully here).

use super::spec::{CoreKind, CoreSpec, CpuSpec, Isa};
use std::collections::BTreeMap;

/// Number of logical CPUs visible to this process.
pub fn n_logical_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A neutral spec describing the host (unknown microarchitecture):
/// used when running the real thread pool without a simulator preset.
pub fn host_spec() -> CpuSpec {
    let n = n_logical_cpus();
    let cores = (0..n)
        .map(|id| {
            let mut ops = BTreeMap::new();
            ops.insert(Isa::Scalar, 1.0);
            ops.insert(Isa::Avx2, 8.0);
            ops.insert(Isa::AvxVnni, 32.0);
            ops.insert(Isa::Stream, f64::INFINITY);
            CoreSpec {
                id,
                kind: CoreKind::Performance,
                freq_ghz: 2.7,
                ops_per_cycle: ops,
                mem_bw_gbps: 10.0,
                mem_weight: 1.0,
            }
        })
        .collect();
    CpuSpec { name: format!("host_{n}"), cores, bus_bw_gbps: 20.0 }
}

/// Model-name string from /proc/cpuinfo (informational only).
pub fn host_model_name() -> Option<String> {
    let text = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    text.lines()
        .find(|l| l.starts_with("model name"))
        .and_then(|l| l.split(':').nth(1))
        .map(|s| s.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_one_cpu() {
        assert!(n_logical_cpus() >= 1);
    }

    #[test]
    fn host_spec_validates() {
        host_spec().validate().unwrap();
        assert_eq!(host_spec().n_cores(), n_logical_cpus());
    }

    #[test]
    fn model_name_readable_on_linux() {
        // present on Linux; don't assert content
        let name = host_model_name();
        if cfg!(target_os = "linux") {
            assert!(name.is_some());
        }
    }
}
