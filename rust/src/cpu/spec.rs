//! Core and CPU capability specifications.
//!
//! A [`CoreSpec`] captures what the paper's CPU runtime ultimately observes
//! through timing: per-ISA instruction throughput × frequency (compute
//! rate) and achievable memory bandwidth (streaming rate + contention
//! weight). A [`CpuSpec`] is a set of cores sharing one memory bus.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Instruction-set families the runtime keys performance ratios by
/// (paper §2.1: "different ISAs should have varying performance ratios").
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Isa {
    /// scalar fallback
    Scalar,
    /// 256-bit f32 FMA (the f32 dequant/GEMV path)
    Avx2,
    /// 256-bit int8 dot-product (`vpdpbusd`) — the paper's GEMM/GEMV kernels
    AvxVnni,
    /// pure streaming (tensor copy, memset) — throughput set by the bus
    Stream,
}

impl Isa {
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Avx2, Isa::AvxVnni, Isa::Stream];

    /// Position in [`Isa::ALL`], as a const jump table — dense-table
    /// indexing without a linear scan (see `perf::slot`).
    #[inline]
    pub const fn index(&self) -> usize {
        match self {
            Isa::Scalar => 0,
            Isa::Avx2 => 1,
            Isa::AvxVnni => 2,
            Isa::Stream => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::AvxVnni => "avx_vnni",
            Isa::Stream => "stream",
        }
    }

    pub fn from_name(s: &str) -> Option<Isa> {
        Isa::ALL.iter().copied().find(|i| i.name() == s)
    }
}

/// Microarchitectural class of a core.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CoreKind {
    /// P-core (e.g. Golden Cove / Redwood Cove)
    Performance,
    /// E-core (e.g. Gracemont / Crestmont)
    Efficiency,
    /// low-power E-core on the SoC tile (Meteor Lake)
    LowPower,
}

impl CoreKind {
    pub fn name(&self) -> &'static str {
        match self {
            CoreKind::Performance => "P",
            CoreKind::Efficiency => "E",
            CoreKind::LowPower => "LPE",
        }
    }

    pub fn from_name(s: &str) -> Option<CoreKind> {
        match s {
            "P" => Some(CoreKind::Performance),
            "E" => Some(CoreKind::Efficiency),
            "LPE" => Some(CoreKind::LowPower),
            _ => None,
        }
    }
}

/// One physical core's capabilities (the paper binds one thread per core).
#[derive(Clone, Debug)]
pub struct CoreSpec {
    pub id: usize,
    pub kind: CoreKind,
    /// sustained all-core frequency (GHz) under vector load
    pub freq_ghz: f64,
    /// effective MAC-like ops per cycle, per ISA (calibrated, includes
    /// kernel efficiency — see DESIGN.md substitution table)
    pub ops_per_cycle: BTreeMap<Isa, f64>,
    /// max sustained per-core stream bandwidth (GB/s)
    pub mem_bw_gbps: f64,
    /// contention weight: relative share of the bus under full contention
    /// (proxy for memory-level parallelism / outstanding misses)
    pub mem_weight: f64,
}

impl CoreSpec {
    /// Compute rate in ops/second for an ISA.
    pub fn compute_rate(&self, isa: Isa) -> f64 {
        let opc = self.ops_per_cycle.get(&isa).copied().unwrap_or_else(|| {
            // fall back to the scalar column if the ISA is not listed
            self.ops_per_cycle.get(&Isa::Scalar).copied().unwrap_or(1.0)
        });
        self.freq_ghz * 1e9 * opc
    }
}

/// A hybrid CPU: cores plus the shared memory subsystem.
#[derive(Clone, Debug)]
pub struct CpuSpec {
    pub name: String,
    pub cores: Vec<CoreSpec>,
    /// effective total memory bandwidth (GB/s) — the realistic achievable
    /// number (what MLC would report), not the theoretical peak
    pub bus_bw_gbps: f64,
}

impl CpuSpec {
    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn count_kind(&self, kind: CoreKind) -> usize {
        self.cores.iter().filter(|c| c.kind == kind).count()
    }

    /// Ideal compute-rate ratios for an ISA (what a perfect perf table
    /// would converge to), normalized so the slowest core is 1.0.
    pub fn ideal_ratios(&self, isa: Isa) -> Vec<f64> {
        let rates: Vec<f64> = self.cores.iter().map(|c| c.compute_rate(isa)).collect();
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-30);
        rates.iter().map(|r| r / min).collect()
    }

    /// Total compute throughput for an ISA (ops/s) if perfectly balanced.
    pub fn total_compute_rate(&self, isa: Isa) -> f64 {
        self.cores.iter().map(|c| c.compute_rate(isa)).sum()
    }

    /// A new spec containing only `core_ids` (re-indexed to 0..k, original
    /// order preserved) with the given share of the memory bus — the
    /// executor-facing view of a [`crate::coordinator`] lease.
    ///
    /// Panics if `core_ids` is empty or contains an out-of-range id.
    pub fn subset(&self, core_ids: &[usize], bus_bw_gbps: f64) -> CpuSpec {
        assert!(!core_ids.is_empty(), "empty core subset");
        let cores: Vec<CoreSpec> = core_ids
            .iter()
            .enumerate()
            .map(|(new_id, &gid)| {
                let mut c = self.cores[gid].clone();
                c.id = new_id;
                c
            })
            .collect();
        CpuSpec { name: format!("{}_sub{}", self.name, core_ids.len()), cores, bus_bw_gbps }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.cores.is_empty() {
            return Err("no cores".into());
        }
        if self.bus_bw_gbps <= 0.0 {
            return Err("bus bandwidth must be positive".into());
        }
        for (i, c) in self.cores.iter().enumerate() {
            if c.id != i {
                return Err(format!("core {i} has id {}", c.id));
            }
            if c.freq_ghz <= 0.0 || c.mem_bw_gbps <= 0.0 || c.mem_weight <= 0.0 {
                return Err(format!("core {i} has non-positive rates"));
            }
        }
        Ok(())
    }

    // ---- JSON config round trip (custom CPUs via --cpu-config file) ----

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("bus_bw_gbps", Json::num(self.bus_bw_gbps)),
            (
                "cores",
                Json::arr(self.cores.iter().map(|c| {
                    Json::obj(vec![
                        ("id", Json::num(c.id as f64)),
                        ("kind", Json::str(c.kind.name())),
                        ("freq_ghz", Json::num(c.freq_ghz)),
                        ("mem_bw_gbps", Json::num(c.mem_bw_gbps)),
                        ("mem_weight", Json::num(c.mem_weight)),
                        (
                            "ops_per_cycle",
                            Json::Object(
                                c.ops_per_cycle
                                    .iter()
                                    .map(|(isa, v)| (isa.name().to_string(), Json::num(*v)))
                                    .collect(),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CpuSpec, String> {
        let name = v.get("name").and_then(Json::as_str).ok_or("missing name")?.to_string();
        let bus = v.get("bus_bw_gbps").and_then(Json::as_f64).ok_or("missing bus_bw_gbps")?;
        let cores_json = v.get("cores").and_then(Json::as_array).ok_or("missing cores")?;
        let mut cores = Vec::new();
        for (i, cj) in cores_json.iter().enumerate() {
            let kind_name = cj.get("kind").and_then(Json::as_str).ok_or("core missing kind")?;
            let kind =
                CoreKind::from_name(kind_name).ok_or_else(|| format!("bad kind {kind_name}"))?;
            let mut ops = BTreeMap::new();
            if let Some(m) = cj.get("ops_per_cycle").and_then(Json::as_object) {
                for (k, val) in m {
                    let isa = Isa::from_name(k).ok_or_else(|| format!("bad isa {k}"))?;
                    ops.insert(isa, val.as_f64().ok_or("bad ops value")?);
                }
            }
            cores.push(CoreSpec {
                id: cj.get("id").and_then(Json::as_usize).unwrap_or(i),
                kind,
                freq_ghz: cj.get("freq_ghz").and_then(Json::as_f64).ok_or("core missing freq_ghz")?,
                ops_per_cycle: ops,
                mem_bw_gbps: cj.get("mem_bw_gbps").and_then(Json::as_f64).unwrap_or(8.0),
                mem_weight: cj.get("mem_weight").and_then(Json::as_f64).unwrap_or(1.0),
            });
        }
        let spec = CpuSpec { name, cores, bus_bw_gbps: bus };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;

    #[test]
    fn isa_names_roundtrip() {
        for isa in Isa::ALL {
            assert_eq!(Isa::from_name(isa.name()), Some(isa));
        }
        assert_eq!(Isa::from_name("bogus"), None);
    }

    #[test]
    fn core_kind_names_roundtrip() {
        for k in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
            assert_eq!(CoreKind::from_name(k.name()), Some(k));
        }
    }

    #[test]
    fn compute_rate_scales_with_freq() {
        let spec = presets::core_12900k();
        let p = &spec.cores[0];
        let rate = p.compute_rate(Isa::AvxVnni);
        assert!((rate - p.freq_ghz * 1e9 * p.ops_per_cycle[&Isa::AvxVnni]).abs() < 1.0);
    }

    #[test]
    fn ideal_ratios_min_is_one() {
        let spec = presets::ultra_125h();
        let ratios = spec.ideal_ratios(Isa::AvxVnni);
        let min = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((min - 1.0).abs() < 1e-12);
        assert_eq!(ratios.len(), spec.n_cores());
    }

    #[test]
    fn json_roundtrip() {
        let spec = presets::core_12900k();
        let j = spec.to_json();
        let back = CpuSpec::from_json(&j).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.n_cores(), spec.n_cores());
        for (a, b) in back.cores.iter().zip(&spec.cores) {
            assert_eq!(a.kind, b.kind);
            assert!((a.freq_ghz - b.freq_ghz).abs() < 1e-12);
            assert_eq!(a.ops_per_cycle, b.ops_per_cycle);
        }
    }

    #[test]
    fn subset_reindexes_and_preserves_caps() {
        let spec = presets::core_12900k();
        let sub = spec.subset(&[0, 2, 8, 9], 34.0);
        sub.validate().unwrap();
        assert_eq!(sub.n_cores(), 4);
        assert_eq!(sub.bus_bw_gbps, 34.0);
        // ids re-indexed, capabilities carried over from the source cores
        for (i, &gid) in [0usize, 2, 8, 9].iter().enumerate() {
            assert_eq!(sub.cores[i].id, i);
            assert_eq!(sub.cores[i].kind, spec.cores[gid].kind);
            assert_eq!(sub.cores[i].freq_ghz, spec.cores[gid].freq_ghz);
            assert_eq!(sub.cores[i].ops_per_cycle, spec.cores[gid].ops_per_cycle);
        }
        assert_eq!(sub.count_kind(CoreKind::Performance), 2);
        assert_eq!(sub.count_kind(CoreKind::Efficiency), 2);
    }

    #[test]
    #[should_panic(expected = "empty core subset")]
    fn subset_rejects_empty() {
        presets::core_12900k().subset(&[], 10.0);
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let mut spec = presets::core_12900k();
        spec.bus_bw_gbps = 0.0;
        assert!(spec.validate().is_err());
        let mut spec2 = presets::core_12900k();
        spec2.cores[3].id = 99;
        assert!(spec2.validate().is_err());
        let spec3 = CpuSpec { name: "x".into(), cores: vec![], bus_bw_gbps: 10.0 };
        assert!(spec3.validate().is_err());
    }
}
