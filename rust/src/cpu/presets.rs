//! Calibrated CPU presets for the paper's two testbeds.
//!
//! Calibration targets (see DESIGN.md + EXPERIMENTS.md):
//! * 12900K VNNI P:E compute ratio ≈ 2.65 → static-split → dynamic GEMM
//!   speedup ≈ +8x% (paper: +85%).
//! * 125H VNNI P:E ≈ 2.5, P:LPE ≈ 2.9–3.0 (paper Fig. 4 trace stabilizes
//!   at 3–3.5 relative ratio) → GEMM speedup ≈ +6x% (paper: +65%).
//! * bus_bw is the *achievable* (MLC-like) number, not the DIMM peak.
//!
//! The ops/cycle entries are effective values: they fold in the micro-
//! kernel's efficiency on that core, which is what the paper's runtime
//! actually observes through timing. Sources: public spec sheets for
//! frequencies/counts; VNNI: 2×256-bit `vpdpbusd` pipes on P-cores
//! (64 int8 MAC/cycle), 1×256-bit equivalent on E-cores (32).

use std::collections::BTreeMap;

use super::spec::{CoreKind, CoreSpec, CpuSpec, Isa};

fn ops(scalar: f64, avx2: f64, vnni: f64) -> BTreeMap<Isa, f64> {
    let mut m = BTreeMap::new();
    m.insert(Isa::Scalar, scalar);
    m.insert(Isa::Avx2, avx2);
    m.insert(Isa::AvxVnni, vnni);
    // Stream has no compute component; keep a token entry so lookups succeed.
    m.insert(Isa::Stream, f64::INFINITY);
    m
}

/// Intel Core i9-12900K: 8 P (Golden Cove) + 8 E (Gracemont), DDR5-4800.
pub fn core_12900k() -> CpuSpec {
    let mut cores = Vec::new();
    for id in 0..8 {
        cores.push(CoreSpec {
            id,
            kind: CoreKind::Performance,
            freq_ghz: 4.9,
            ops_per_cycle: ops(2.0, 16.0, 64.0),
            mem_bw_gbps: 14.0,
            mem_weight: 1.3,
        });
    }
    for id in 8..16 {
        cores.push(CoreSpec {
            id,
            kind: CoreKind::Efficiency,
            freq_ghz: 3.7,
            ops_per_cycle: ops(1.2, 8.0, 32.0),
            mem_bw_gbps: 7.0,
            mem_weight: 0.8,
        });
    }
    CpuSpec { name: "core_12900k".into(), cores, bus_bw_gbps: 68.0 }
}

/// Intel Core Ultra 7 125H: 4 P (Redwood Cove) + 8 E (Crestmont) +
/// 2 LP-E (SoC tile), LPDDR5x.
pub fn ultra_125h() -> CpuSpec {
    let mut cores = Vec::new();
    for id in 0..4 {
        cores.push(CoreSpec {
            id,
            kind: CoreKind::Performance,
            freq_ghz: 4.5,
            ops_per_cycle: ops(2.0, 16.0, 64.0),
            mem_bw_gbps: 16.0,
            mem_weight: 1.3,
        });
    }
    for id in 4..12 {
        cores.push(CoreSpec {
            id,
            kind: CoreKind::Efficiency,
            freq_ghz: 3.6,
            ops_per_cycle: ops(1.2, 8.0, 32.0),
            mem_bw_gbps: 7.0,
            mem_weight: 0.8,
        });
    }
    for id in 12..14 {
        cores.push(CoreSpec {
            id,
            kind: CoreKind::LowPower,
            freq_ghz: 3.1,
            ops_per_cycle: ops(1.0, 8.0, 32.0),
            mem_bw_gbps: 5.0,
            mem_weight: 0.6,
        });
    }
    CpuSpec { name: "ultra_125h".into(), cores, bus_bw_gbps: 72.0 }
}

/// A homogeneous CPU (the degenerate case: dynamic ≡ static) — used for
/// ablations and as a server-CPU stand-in.
pub fn homogeneous(n: usize) -> CpuSpec {
    let cores = (0..n)
        .map(|id| CoreSpec {
            id,
            kind: CoreKind::Performance,
            freq_ghz: 3.0,
            ops_per_cycle: ops(2.0, 16.0, 64.0),
            mem_bw_gbps: 12.0,
            mem_weight: 1.0,
        })
        .collect();
    CpuSpec { name: format!("homogeneous_{n}"), cores, bus_bw_gbps: 80.0 }
}

pub const PRESET_NAMES: [&str; 3] = ["core_12900k", "ultra_125h", "homogeneous_16"];

/// Look up a preset by name (the CLI's `--preset`).
pub fn preset_by_name(name: &str) -> Option<CpuSpec> {
    match name {
        "core_12900k" => Some(core_12900k()),
        "ultra_125h" => Some(ultra_125h()),
        s if s.starts_with("homogeneous") => {
            let n = s.strip_prefix("homogeneous_").and_then(|t| t.parse().ok()).unwrap_or(16);
            Some(homogeneous(n))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in PRESET_NAMES {
            let spec = preset_by_name(name).unwrap();
            spec.validate().unwrap();
        }
    }

    #[test]
    fn core_counts_match_silicon() {
        let k = core_12900k();
        assert_eq!(k.n_cores(), 16);
        assert_eq!(k.count_kind(CoreKind::Performance), 8);
        assert_eq!(k.count_kind(CoreKind::Efficiency), 8);
        let h = ultra_125h();
        assert_eq!(h.n_cores(), 14);
        assert_eq!(h.count_kind(CoreKind::Performance), 4);
        assert_eq!(h.count_kind(CoreKind::Efficiency), 8);
        assert_eq!(h.count_kind(CoreKind::LowPower), 2);
    }

    #[test]
    fn calibration_12900k_static_speedup_band() {
        // Σpr / (N · pr_min) must land near the paper's +85% GEMM gain.
        let spec = core_12900k();
        let ratios = spec.ideal_ratios(Isa::AvxVnni);
        let sum: f64 = ratios.iter().sum();
        let speedup = sum / ratios.len() as f64; // pr_min = 1
        assert!((1.70..1.95).contains(&speedup), "speedup={speedup}");
    }

    #[test]
    fn calibration_125h_static_speedup_band() {
        // paper: +65% on Ultra-125H
        let spec = ultra_125h();
        let ratios = spec.ideal_ratios(Isa::AvxVnni);
        let sum: f64 = ratios.iter().sum();
        let speedup = sum / ratios.len() as f64;
        assert!((1.55..1.80).contains(&speedup), "speedup={speedup}");
    }

    #[test]
    fn calibration_125h_p_core_ratio_band() {
        // paper Fig. 4: P-core ratio stabilizes between 3 and 3.5
        let spec = ultra_125h();
        let ratios = spec.ideal_ratios(Isa::AvxVnni);
        let p_ratio = ratios[0];
        assert!((2.8..3.5).contains(&p_ratio), "p_ratio={p_ratio}");
    }

    #[test]
    fn homogeneous_ratios_are_flat() {
        let spec = homogeneous(8);
        let ratios = spec.ideal_ratios(Isa::AvxVnni);
        assert!(ratios.iter().all(|&r| (r - 1.0).abs() < 1e-12));
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(preset_by_name("threadripper").is_none());
    }
}
