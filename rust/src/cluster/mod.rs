//! Cluster tier: a hierarchical coordinator over many simulated machines
//! behind one admission plane.
//!
//! The per-machine [`Coordinator`] owns *compute units* (cores and
//! accelerators) and leases subsets to streams; this module adds the next
//! level of the same hierarchy — a [`ClusterCoordinator`] owns *machines*
//! (each wrapping its own `Coordinator`, possibly with different
//! [`CpuSpec`]s and accelerators) and places streams across them:
//!
//! * **Static placement** — [`ClusterCoordinator::admit`] runs the balanced
//!   k-way partitioner ([`partition`]) over per-machine capability scores
//!   with epsilon slack, so a new stream lands on the machine whose
//!   normalized fill stays lowest.
//! * **Strength learning** — [`ClusterCoordinator::observe`] folds served
//!   per-machine token rates into per-machine strengths with the same
//!   mass-preserving eq.-2 EWMA the coordinator uses per core: the total
//!   strength mass of the participating machines is conserved, so strengths
//!   stay mutually comparable while their *ratios* track live throughput.
//! * **Drift response** — [`ClusterCoordinator::skew`] measures how far
//!   machines' learned strengths have drifted from their capability seeds
//!   (a whole-machine degrade shows up here); past a threshold the serving
//!   loop calls [`ClusterCoordinator::replace`], which re-partitions and
//!   returns the net [`Migration`]s. Sessions migrate bit-identically
//!   through the existing fleet handoff machinery; *cross-machine* moves
//!   charge KV-transfer bytes over the [`InterconnectSpec`], while
//!   in-machine moves stay free — mirroring how leases already carry
//!   `bus_share_gbps` within a machine.
//!
//! The whole tier is simulation-only and deterministic: no sockets, no
//! threads — the virtual-time harness in [`harness`] drives N machines on
//! concurrent virtual clocks exactly like `server::testing::run_fleet`
//! drives N leases.

pub mod harness;
pub mod partition;

use std::collections::BTreeMap;

use crate::coordinator::{AllocPolicy, Coordinator, StreamId, XpuAffinity};
use crate::cpu::CpuSpec;
use crate::sim::bw::{full_contention_throughput, Contender};
use crate::sim::xpu::AcceleratorSpec;

use partition::{place_one, repartition};

/// Identifies one machine of the cluster — the coordinate *above*
/// [`crate::coordinator::ComputeUnit`] in the placement hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MachineId(pub usize);

impl std::fmt::Display for MachineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "m{}", self.0)
    }
}

/// Blueprint for one cluster machine: its CPU, its accelerators and the
/// lease policy its coordinator runs with. Machines in one cluster may
/// differ in all of these — the cluster is heterogeneous one level above
/// the CPUs already being hybrid.
#[derive(Clone, Debug)]
pub struct MachineSpec {
    pub cpu: CpuSpec,
    pub accels: Vec<AcceleratorSpec>,
    pub policy: AllocPolicy,
    pub affinity: XpuAffinity,
}

impl MachineSpec {
    /// A cores-only machine with the default balanced lease policy.
    pub fn cores_only(cpu: CpuSpec) -> MachineSpec {
        MachineSpec {
            cpu,
            accels: Vec::new(),
            policy: AllocPolicy::Balanced,
            affinity: XpuAffinity::None,
        }
    }

    pub fn with_accelerators(cpu: CpuSpec, accels: Vec<AcceleratorSpec>) -> MachineSpec {
        MachineSpec {
            cpu,
            accels,
            policy: AllocPolicy::Balanced,
            affinity: XpuAffinity::Floating,
        }
    }

    fn build(&self) -> Coordinator {
        if self.accels.is_empty() {
            Coordinator::new(self.cpu.clone(), self.policy)
        } else {
            Coordinator::with_accelerators(
                self.cpu.clone(),
                self.accels.clone(),
                self.policy,
                self.affinity,
            )
        }
    }
}

/// The inter-machine interconnect cost model. Within a machine, session
/// moves are free (KV stays in the same address space); across machines,
/// the session's KV cache must cross this link, so a migration charges
/// `bytes / (gbps · 1e9)` seconds of transfer delay before the destination
/// can serve the stream.
#[derive(Clone, Copy, Debug)]
pub struct InterconnectSpec {
    /// link bandwidth between any machine pair (GB/s); a flat fabric
    pub gbps: f64,
}

impl Default for InterconnectSpec {
    /// A 200 Gb/s-class datacenter fabric: 25 GB/s usable per link.
    fn default() -> InterconnectSpec {
        InterconnectSpec { gbps: 25.0 }
    }
}

impl InterconnectSpec {
    pub fn transfer_secs(&self, bytes: f64) -> f64 {
        if self.gbps > 0.0 && bytes > 0.0 {
            bytes / (self.gbps * 1e9)
        } else {
            0.0
        }
    }

    /// Cost of moving one session between machines: free within a machine,
    /// a KV transfer over the link otherwise.
    pub fn migration_cost_secs(&self, from: MachineId, to: MachineId, kv_bytes: f64) -> f64 {
        if from == to {
            0.0
        } else {
            self.transfer_secs(kv_bytes)
        }
    }
}

/// A machine's capability score: its full-contention memory throughput —
/// every core (and accelerator) waterfilled against the bus. Decode serving
/// is bandwidth-bound (the paper's regime), so the *bus* a machine can
/// actually sustain, not its peak compute, is what predicts its healthy
/// token rate; seeding cluster strengths from this keeps the learned
/// strength/seed ratios near 1.0 until something genuinely degrades.
pub fn machine_capability(coord: &Coordinator) -> f64 {
    let mut contenders: Vec<Contender> = coord
        .machine()
        .cores
        .iter()
        .map(|c| Contender { weight: c.mem_weight, cap: c.mem_bw_gbps })
        .collect();
    for a in coord.accelerators() {
        contenders.push(Contender { weight: a.mem_weight, cap: a.mem_bw_gbps });
    }
    full_contention_throughput(&contenders, coord.machine().bus_bw_gbps)
}

/// One corrective session move decided by [`ClusterCoordinator::replace`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Migration {
    pub stream: StreamId,
    pub from: MachineId,
    pub to: MachineId,
}

/// The cluster admission plane: owns N machine coordinators, places
/// streams across them, learns per-machine strengths from served traffic
/// and re-places when a machine drifts. The API mirrors the per-machine
/// [`Coordinator`] one level up: `admit`/`finish`/`observe`, an `epoch`
/// that bumps on every placement change, and a skew measure for the drift
/// monitor.
pub struct ClusterCoordinator {
    machines: Vec<Coordinator>,
    interconnect: InterconnectSpec,
    /// slack band of the balanced partitioner (placement stickiness)
    pub epsilon: f64,
    /// EWMA gain of the strength fold (same default as `PerfConfig`)
    pub alpha: f64,
    /// capability scores at construction — the strength seeds
    seed: Vec<f64>,
    /// learned per-machine strengths (starts at `seed`)
    strength: Vec<f64>,
    placements: BTreeMap<StreamId, usize>,
    epoch: u64,
    observations: u64,
    replacements: u64,
}

impl ClusterCoordinator {
    pub fn new(specs: &[MachineSpec], interconnect: InterconnectSpec) -> ClusterCoordinator {
        assert!(!specs.is_empty(), "a cluster needs at least one machine");
        let machines: Vec<Coordinator> = specs.iter().map(|s| s.build()).collect();
        let seed: Vec<f64> = machines.iter().map(machine_capability).collect();
        assert!(
            seed.iter().any(|&c| c > 0.0),
            "cluster has no machine with positive capability"
        );
        ClusterCoordinator {
            machines,
            interconnect,
            epsilon: 0.05,
            alpha: 0.3,
            strength: seed.clone(),
            seed,
            placements: BTreeMap::new(),
            epoch: 1,
            observations: 0,
            replacements: 0,
        }
    }

    pub fn n_machines(&self) -> usize {
        self.machines.len()
    }

    pub fn machine(&self, id: MachineId) -> &Coordinator {
        &self.machines[id.0]
    }

    pub fn machine_mut(&mut self, id: MachineId) -> &mut Coordinator {
        &mut self.machines[id.0]
    }

    pub fn interconnect(&self) -> &InterconnectSpec {
        &self.interconnect
    }

    /// Cluster placement epoch: bumps on every `admit`/`finish`/`replace`,
    /// so drift cooldowns and stale-observation fencing work exactly like
    /// the per-machine coordinator's epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Accepted cluster-level observations (rate folds) so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// How many times `replace()` actually moved streams.
    pub fn replacements(&self) -> u64 {
        self.replacements
    }

    /// Learned per-machine strengths (capability units, mass-preserved).
    pub fn strengths(&self) -> &[f64] {
        &self.strength
    }

    /// Capability seeds the strengths started from.
    pub fn seeds(&self) -> &[f64] {
        &self.seed
    }

    pub fn n_streams(&self) -> usize {
        self.placements.len()
    }

    pub fn placement_of(&self, stream: StreamId) -> Option<MachineId> {
        self.placements.get(&stream).map(|&m| MachineId(m))
    }

    /// Snapshot of the current stream → machine placement.
    pub fn placements(&self) -> impl Iterator<Item = (StreamId, MachineId)> + '_ {
        self.placements.iter().map(|(&s, &m)| (s, MachineId(m)))
    }

    /// Machines currently holding at least one stream.
    pub fn machines_in_use(&self) -> usize {
        let mut used = vec![false; self.machines.len()];
        for &m in self.placements.values() {
            used[m] = true;
        }
        used.iter().filter(|&&u| u).count()
    }

    /// Admit a stream: balanced k-way placement over learned strengths
    /// (epsilon-sticky), then the chosen machine's coordinator admits it
    /// and re-partitions its units. Returns where the stream landed.
    pub fn admit(&mut self, stream: StreamId) -> MachineId {
        assert!(
            !self.placements.contains_key(&stream),
            "stream {stream} already admitted to the cluster"
        );
        let mut load = vec![0.0; self.machines.len()];
        for &m in self.placements.values() {
            load[m] += 1.0;
        }
        // fill targets proportional to strength: only ratios matter to
        // `place_one`, so strengths serve directly as targets
        let m = place_one(&load, 1.0, &self.strength, self.epsilon);
        self.machines[m].admit(stream);
        self.placements.insert(stream, m);
        self.epoch += 1;
        MachineId(m)
    }

    /// A stream departed: release it on its machine.
    pub fn finish(&mut self, stream: StreamId) {
        if let Some(m) = self.placements.remove(&stream) {
            self.machines[m].finish(stream);
            self.epoch += 1;
        }
    }

    /// Fold one round of served per-machine token rates (tokens/s) into
    /// the strengths with the mass-preserving eq.-2 EWMA: the participating
    /// machines' strength mass is conserved, each machine's share moves
    /// toward its share of the observed rates. Needs ≥ 2 distinct
    /// machines with finite positive rates to be a *relative* signal;
    /// returns whether the observation was folded.
    pub fn observe(&mut self, rates: &[(MachineId, f64)]) -> bool {
        let mut seen = vec![false; self.machines.len()];
        let mut parts: Vec<(usize, f64)> = Vec::with_capacity(rates.len());
        for &(MachineId(m), r) in rates {
            if m >= self.machines.len() || !r.is_finite() || r <= 0.0 || seen[m] {
                return false;
            }
            seen[m] = true;
            parts.push((m, r));
        }
        if parts.len() < 2 {
            return false;
        }
        let mass: f64 = parts.iter().map(|&(m, _)| self.strength[m]).sum();
        let rate_sum: f64 = parts.iter().map(|&(_, r)| r).sum();
        if mass <= 0.0 || rate_sum <= 0.0 {
            return false;
        }
        let scale = mass / rate_sum;
        for &(m, r) in &parts {
            self.strength[m] = self.alpha * self.strength[m] + (1.0 - self.alpha) * r * scale;
        }
        self.observations += 1;
        true
    }

    /// Cluster-level skew: over machines that hold streams, how far the
    /// learned strength has drifted from the capability seed — the ratio of
    /// the largest to the smallest `strength/seed`. A healthy cluster sits
    /// near 1.0 whatever its heterogeneity (seeds absorb capability
    /// differences); a whole-machine degrade pushes it up. Returns 1.0
    /// with fewer than two machines in use.
    pub fn skew(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut n = 0;
        for m in 0..self.machines.len() {
            if self.seed[m] <= 0.0 || !self.placements.values().any(|&p| p == m) {
                continue;
            }
            let ratio = self.strength[m] / self.seed[m];
            lo = lo.min(ratio);
            hi = hi.max(ratio);
            n += 1;
        }
        if n < 2 || lo <= 0.0 {
            1.0
        } else {
            hi / lo
        }
    }

    /// Re-place streams under the current learned strengths. Always bumps
    /// the epoch (restarting drift cooldowns even when nothing moves); on
    /// actual moves the machines' coordinators transfer the streams and
    /// the returned [`Migration`]s tell the serving layer which sessions
    /// to carry — cross-machine ones paying the interconnect KV transfer.
    /// The partitioner's hysteresis keeps this conservative: near-balanced
    /// clusters yield no moves, so sessions prefer staying in-machine
    /// unless a machine's strength genuinely collapsed or recovered.
    pub fn replace(&mut self) -> Vec<Migration> {
        self.epoch += 1;
        let items: Vec<StreamId> = self.placements.keys().copied().collect();
        if items.is_empty() {
            return Vec::new();
        }
        let current: Vec<usize> = items.iter().map(|s| self.placements[s]).collect();
        let weights = vec![1.0; items.len()];
        let moves = repartition(&current, &weights, &self.strength, self.epsilon);
        let mut migrations = Vec::with_capacity(moves.len());
        for mv in moves {
            let stream = items[mv.item];
            self.machines[mv.from].finish(stream);
            self.machines[mv.to].admit(stream);
            self.placements.insert(stream, mv.to);
            migrations.push(Migration {
                stream,
                from: MachineId(mv.from),
                to: MachineId(mv.to),
            });
        }
        if !migrations.is_empty() {
            self.replacements += 1;
        }
        migrations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;

    fn two_identical() -> ClusterCoordinator {
        let spec = MachineSpec::cores_only(presets::core_12900k());
        ClusterCoordinator::new(&[spec.clone(), spec], InterconnectSpec::default())
    }

    #[test]
    fn admit_spreads_streams_by_capability() {
        let mut cluster = two_identical();
        let a = cluster.admit(0);
        let b = cluster.admit(1);
        assert_ne!(a, b, "identical machines must each take one stream");
        assert_eq!(cluster.n_streams(), 2);
        assert_eq!(cluster.machine(a).n_streams(), 1);
        assert_eq!(cluster.machine(b).n_streams(), 1);
        assert_eq!(cluster.machines_in_use(), 2);
    }

    #[test]
    fn capability_seeds_reflect_bus_not_core_count() {
        // homogeneous_16 has more cores but capability tracks sustainable
        // bus throughput, so seeds differ by bus, not by core count
        let specs = [
            MachineSpec::cores_only(presets::core_12900k()),
            MachineSpec::cores_only(presets::homogeneous(12)),
        ];
        let cluster = ClusterCoordinator::new(&specs, InterconnectSpec::default());
        let seeds = cluster.seeds();
        assert!((seeds[0] - 68.0).abs() < 1e-6, "12900k seed {}", seeds[0]);
        assert!((seeds[1] - 80.0).abs() < 1e-6, "homogeneous seed {}", seeds[1]);
    }

    #[test]
    fn observe_preserves_strength_mass_and_moves_ratios() {
        let mut cluster = two_identical();
        cluster.admit(0);
        cluster.admit(1);
        let before: f64 = cluster.strengths().iter().sum();
        // machine 1 serves twice the rate of machine 0
        assert!(cluster.observe(&[(MachineId(0), 1000.0), (MachineId(1), 2000.0)]));
        let after: f64 = cluster.strengths().iter().sum();
        assert!((before - after).abs() < 1e-9, "mass not preserved: {before} -> {after}");
        assert!(cluster.strengths()[1] > cluster.strengths()[0]);
        assert_eq!(cluster.observations(), 1);
        // invalid observations are refused
        assert!(!cluster.observe(&[(MachineId(0), 1000.0)]), "single participant");
        assert!(!cluster.observe(&[(MachineId(0), f64::NAN), (MachineId(1), 1.0)]));
        assert!(!cluster.observe(&[(MachineId(0), 1.0), (MachineId(0), 2.0)]), "dup machine");
    }

    #[test]
    fn skew_stays_flat_for_proportional_rates_and_rises_on_degrade() {
        let specs = [
            MachineSpec::cores_only(presets::core_12900k()), // seed 68
            MachineSpec::cores_only(presets::homogeneous(12)), // seed 80
        ];
        let mut cluster = ClusterCoordinator::new(&specs, InterconnectSpec::default());
        cluster.admit(0);
        cluster.admit(1);
        // healthy: rates proportional to capability → skew stays ~1
        for _ in 0..8 {
            assert!(cluster.observe(&[(MachineId(0), 6800.0), (MachineId(1), 8000.0)]));
        }
        assert!(cluster.skew() < 1.01, "healthy skew {}", cluster.skew());
        // machine 0 collapses to 1/8 its healthy rate → skew blows past 1.5
        for _ in 0..8 {
            assert!(cluster.observe(&[(MachineId(0), 850.0), (MachineId(1), 8000.0)]));
        }
        assert!(cluster.skew() > 1.5, "degraded skew {}", cluster.skew());
    }

    #[test]
    fn replace_prefers_in_machine_when_capabilities_are_close() {
        // the interconnect makes cross-machine moves expensive, so the
        // epsilon hysteresis must yield zero migrations while learned
        // strengths sit within the slack band of each other
        let mut cluster = two_identical();
        for s in 0..4u64 {
            cluster.admit(s);
        }
        // drift strengths ~3% apart — inside the 5% epsilon band
        for _ in 0..6 {
            assert!(cluster.observe(&[(MachineId(0), 1000.0), (MachineId(1), 1030.0)]));
        }
        let epoch = cluster.epoch();
        let moves = cluster.replace();
        assert!(moves.is_empty(), "near-tied machines churned sessions: {moves:?}");
        assert_eq!(cluster.replacements(), 0);
        // the epoch still bumps so drift cooldowns restart
        assert_eq!(cluster.epoch(), epoch + 1);
    }

    #[test]
    fn replace_drains_a_collapsed_machine_and_reports_migrations() {
        let mut cluster = two_identical();
        for s in 0..4u64 {
            cluster.admit(s);
        }
        // machine 0 collapses to ~6% of its healthy rate
        for _ in 0..12 {
            assert!(cluster.observe(&[(MachineId(0), 60.0), (MachineId(1), 1000.0)]));
        }
        let moves = cluster.replace();
        assert!(!moves.is_empty(), "collapsed machine kept its streams");
        assert_eq!(cluster.replacements(), 1);
        for mv in &moves {
            assert_eq!(mv.from, MachineId(0));
            assert_eq!(mv.to, MachineId(1));
            assert_eq!(cluster.placement_of(mv.stream), Some(MachineId(1)));
            // the machine coordinators transferred the stream
            assert!(cluster.machine(MachineId(1)).lease(mv.stream).is_some());
            assert!(cluster.machine(MachineId(0)).lease(mv.stream).is_none());
        }
    }

    #[test]
    fn interconnect_charges_cross_machine_only() {
        let net = InterconnectSpec { gbps: 25.0 };
        let kv = 12.5e9; // 12.5 GB of KV
        assert_eq!(net.migration_cost_secs(MachineId(0), MachineId(0), kv), 0.0);
        let cross = net.migration_cost_secs(MachineId(0), MachineId(1), kv);
        assert!((cross - 0.5).abs() < 1e-12, "cross-machine transfer {cross}");
        assert_eq!(net.transfer_secs(0.0), 0.0);
    }
}
