//! Virtual-time cluster harness: N simulated machines behind one admission
//! plane, no sockets, bit-for-bit deterministic.
//!
//! [`run_cluster`] is `server::testing::run_trace` one level up: every
//! machine runs its own batcher fleet on its own virtual clocks, the driver
//! always advances the globally smallest working clock, and one shared
//! priority-classed [`ClassedQueue`] feeds all machines — the cluster
//! admission plane, configured by the same [`ServingPolicy`] the
//! single-machine harness and the live server take. The trace vocabulary is
//! the shared [`crate::server::trace`] core, so one trace drives either
//! tier. `Connect` events place streams through [`ClusterCoordinator::admit`]
//! (balanced partition over learned machine strengths), served rounds fold
//! per-machine token rates into the cluster strength table, and the
//! [`crate::server::fleet::DriftMonitor`] watches cluster skew: a whole-machine degrade
//! ([`TraceEvent::DegradeMachine`]) triggers [`ClusterCoordinator::replace`]
//! mid-trace, with in-flight sessions migrating bit-identically through the
//! same `take_actives`/`distribute` machinery fleet rebuilds already use —
//! except that *cross-machine* moves charge their KV bytes against the
//! interconnect: the destination machine's clocks restart only after the
//! inbound transfer lands.
//!
//! Limits: machines run their leases blended or phase-disaggregated;
//! `ExecMode::AsyncBatch` pairs are not deficit-routed at cluster scope
//! (admission falls back to work-conserving first-fit), so benchmarks for
//! that mode should stay on the single-machine harness.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc;

use crate::coordinator::{Lease, StreamId};
use crate::exec::{Executor, RunResult};
use crate::kernels::KernelClass;
use crate::metrics::{MachineRollup, ServingMetrics};
use crate::router::ServingPolicy;
use crate::server::batcher::{ActiveRequest, BatcherOpts, LeaseBatcher, Pending, PhaseRole};
use crate::server::fleet::{self, EngineFactory};
use crate::server::queue::ClassedQueue;
use crate::server::testing::{self, HarnessReport};
use crate::server::trace::TraceEvent;

use super::{machine_capability, ClusterCoordinator, MachineId};

/// Served rounds a machine's rate window accumulates before it is folded
/// into the cluster strength table (smooths per-round jitter the same way
/// the coordinator's per-core EWMA smooths per-kernel jitter).
const OBS_ROUNDS: usize = 4;

/// One machine's accumulating tokens/kernel-seconds since the last cluster
/// observation fold.
#[derive(Clone, Copy, Default)]
struct RateWindow {
    tokens: usize,
    secs: f64,
    rounds: usize,
}

impl RateWindow {
    fn ready(&self) -> bool {
        self.rounds >= OBS_ROUNDS && self.secs > 0.0 && self.tokens > 0
    }

    fn rate(&self) -> f64 {
        self.tokens as f64 / self.secs
    }

    fn reset(&mut self) {
        *self = RateWindow::default();
    }
}

/// Everything the cluster harness observed about one machine.
#[derive(Clone, Debug, Default)]
pub struct MachineUse {
    /// decode tokens this machine served
    pub tokens: usize,
    /// busy kernel seconds across all its batchers and rebuilds
    pub kernel_secs: f64,
    /// scheduler rounds stepped on this machine
    pub rounds: usize,
    /// KV bytes that migrations *into* this machine moved over the fabric
    pub interconnect_bytes: f64,
    /// the machine's capability score (full-contention GB/s)
    pub capability_gbps: f64,
}

/// Aggregate outcome of a cluster run: the familiar per-request
/// [`HarnessReport`] plus the cluster-level picture.
pub struct ClusterReport {
    pub base: HarnessReport,
    pub machines: Vec<MachineUse>,
    /// `replace()` calls that actually moved streams
    pub replacements: u64,
    /// sessions carried across machines by those re-placements
    pub migrated_sessions: usize,
    /// total KV bytes charged against the interconnect
    pub interconnect_bytes: f64,
    /// cluster skew measured at each drift trigger that moved streams
    pub cluster_skew_at_trigger: Vec<f64>,
    /// cluster-level observations folded over the run
    pub cluster_observations: u64,
    pub final_skew: f64,
    pub final_strengths: Vec<f64>,
    /// where every still-connected stream lived when the run ended
    pub final_placements: BTreeMap<StreamId, MachineId>,
}

impl ClusterReport {
    pub fn throughput(&self) -> f64 {
        self.base.throughput()
    }

    pub fn mean_ttft(&self) -> f64 {
        self.base.mean_ttft()
    }

    pub fn all_finished(&self) -> bool {
        self.base.all_finished()
    }

    pub fn tokens_of(&self, id: u64) -> &[u32] {
        self.base.tokens_of(id)
    }

    /// The cluster-level [`ServingMetrics`] export: the classic serving
    /// counters plus per-machine rollups, cluster skew and interconnect
    /// traffic (the satellite the harness report shows the fleet through).
    pub fn serving_metrics(&self) -> ServingMetrics {
        let makespan = self.base.makespan;
        let mut bytes_moved = 0.0;
        let mut kernel_secs = 0.0;
        for bw in self.base.bandwidth.values() {
            bytes_moved += bw.bytes;
            kernel_secs += bw.kernel_secs;
        }
        let machines = self
            .machines
            .iter()
            .enumerate()
            .map(|(m, u)| MachineRollup {
                machine: m,
                tokens: u.tokens as u64,
                kernel_secs: u.kernel_secs,
                tok_s: if makespan > 0.0 { u.tokens as f64 / makespan } else { 0.0 },
                interconnect_bytes: u.interconnect_bytes,
            })
            .collect();
        let mut sm = ServingMetrics {
            requests: self.base.requests.len() as u64,
            tokens: self.base.total_decoded as u64,
            rejected: self.base.rejected.len() as u64,
            rebuilds: self.base.rebuilds as u64,
            drift_rebalances: self.base.drift_rebalances as u64,
            handoffs: self.base.handoffs as u64,
            bytes_moved,
            kernel_secs,
            bus_reference_gbps: self.machines.iter().map(|u| u.capability_gbps).sum(),
            machines,
            cluster_skew: self.final_skew,
            replacements: self.replacements,
            interconnect_bytes: self.interconnect_bytes,
            ..Default::default()
        };
        for r in self.base.requests.values() {
            if let Some(t) = r.ttft() {
                sm.ttft.record(t);
            }
        }
        for &d in &self.base.queue_depth_samples {
            sm.queue_depth.record(d as f64);
        }
        sm
    }
}

/// Drive a cluster end-to-end in virtual time. `factories` builds each
/// machine's engines (index-aligned with the cluster's machines — machines
/// may simulate entirely different CPUs); the shared `trace` scripts
/// arrivals, stream membership and degrades; the [`ServingPolicy`] carries
/// the batcher shape, the classed admission-queue bound and the drift
/// thresholds that gate cluster-level re-placement exactly like the
/// per-machine drift monitor gates `rebalance()`. Priority classes apply at
/// the admission plane (strict-priority dequeue, shed-lowest-first
/// eviction); the SLO predictor and the live strategy router stay
/// single-machine concerns (`run_trace` / `serve_dynamic`).
pub fn run_cluster<E: Executor>(
    mut cluster: ClusterCoordinator,
    factories: &[EngineFactory<E>],
    policy: &ServingPolicy,
    mut trace: Vec<TraceEvent>,
) -> ClusterReport {
    let n = cluster.n_machines();
    assert_eq!(factories.len(), n, "one engine factory per machine");
    let opts: BatcherOpts = policy.batcher_opts();
    let mut monitor = policy.drift_monitor();
    testing::validate_trace(&trace);
    trace.sort_by(|a, b| a.at().total_cmp(&b.at()));
    let mut report = HarnessReport::default();
    let mut batchers: Vec<Vec<LeaseBatcher<E>>> = (0..n).map(|_| Vec::new()).collect();
    let mut offsets: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut degraded: Vec<Vec<(Vec<usize>, f64)>> = vec![Vec::new(); n];
    let mut windows: Vec<RateWindow> = vec![RateWindow::default(); n];
    let mut usage: Vec<MachineUse> = vec![MachineUse::default(); n];
    for (m, u) in usage.iter_mut().enumerate() {
        u.capability_gbps = machine_capability(cluster.machine(MachineId(m)));
    }
    let mut queue: ClassedQueue<Pending> =
        ClassedQueue::new(policy.n_classes(), policy.queue_depth);
    let mut rxs: BTreeMap<u64, mpsc::Receiver<crate::server::protocol::Event>> = BTreeMap::new();
    let mut migrated_sessions = 0usize;
    let mut interconnect_bytes = 0.0f64;
    let mut skew_at_trigger: Vec<f64> = Vec::new();
    let mut cursor = 0usize;
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 5_000_000, "cluster harness runaway");
        for m in 0..n {
            testing::drain_handoffs(&mut batchers[m], &mut offsets[m], &mut report);
        }
        let next_at = if cursor < trace.len() { Some(trace[cursor].at()) } else { None };
        // working batcher with the globally smallest virtual clock
        let mut pick: Option<(usize, usize, f64)> = None;
        for m in 0..n {
            for i in 0..batchers[m].len() {
                let b = &batchers[m][i];
                let clock = offsets[m][i] + b.engine.kernel_secs;
                let parked = b.role() == PhaseRole::Prefill && b.n_prefilled() == b.n_active();
                let works = (!b.is_idle() && !parked)
                    || (!queue.is_empty() && b.role() != PhaseRole::Decode && b.has_capacity());
                if works && pick.is_none_or(|(_, _, c)| clock < c) {
                    pick = Some((m, i, clock));
                }
            }
        }
        let do_event = match (pick, next_at) {
            (None, None) => break,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some((_, _, clock)), Some(t)) => clock > t,
        };
        if do_event {
            let t = next_at.unwrap();
            // idle clocks across the whole cluster catch up to the event
            for m in 0..n {
                for i in 0..batchers[m].len() {
                    let clock = offsets[m][i] + batchers[m][i].engine.kernel_secs;
                    if clock < t {
                        offsets[m][i] = t - batchers[m][i].engine.kernel_secs;
                    }
                }
            }
            let mut touched: BTreeSet<usize> = BTreeSet::new();
            while cursor < trace.len() && trace[cursor].at() <= t + 1e-12 {
                let ev = trace[cursor].clone();
                cursor += 1;
                match ev {
                    TraceEvent::Arrive { at, req, class, .. } => {
                        testing::enqueue(&mut queue, &mut rxs, &mut report, at, req, class)
                    }
                    TraceEvent::Connect { stream, .. } => {
                        let MachineId(m) = cluster.admit(stream);
                        touched.insert(m);
                    }
                    TraceEvent::Disconnect { stream, .. } => {
                        if let Some(MachineId(m)) = cluster.placement_of(stream) {
                            cluster.finish(stream);
                            touched.insert(m);
                        }
                    }
                    // core-scoped degrade: by convention machine 0's cores
                    TraceEvent::Degrade { cores, fraction, .. } => {
                        testing::apply_degradation(&mut batchers[0], &cores, fraction);
                        degraded[0].push((cores, fraction));
                    }
                    TraceEvent::DegradeMachine { machine, fraction, .. } => {
                        let cores: Vec<usize> =
                            (0..cluster.machine(MachineId(machine)).machine().n_cores()).collect();
                        testing::apply_degradation(&mut batchers[machine], &cores, fraction);
                        degraded[machine].push((cores, fraction));
                    }
                }
            }
            // membership rebuilds stay machine-local: carried sessions
            // redistribute within their machine (no interconnect charge)
            for &m in &touched {
                let (stale, carried) = strip_machine(&mut batchers[m]);
                let carried_m: Vec<ActiveRequest> =
                    carried.into_iter().map(|(_, _, a)| a).collect();
                rebuild_machine(
                    &cluster,
                    m,
                    &factories[m],
                    opts,
                    &mut batchers[m],
                    &mut offsets[m],
                    carried_m,
                    &degraded[m],
                    t,
                    &mut report,
                );
                replay_stale(&mut cluster, m, &stale, &mut report);
            }
            continue;
        }

        let (m, i, mut clock) = pick.unwrap();
        report.queue_depth_samples.push(queue.len());
        let was_idle = batchers[m][i].is_idle();
        while batchers[m][i].role() != PhaseRole::Decode && batchers[m][i].has_capacity() {
            let Some((class, p)) = queue.pop() else { break };
            let id = p.req.id;
            let before = batchers[m][i].admitted();
            match batchers[m][i].admit(p) {
                Ok(()) => {
                    if batchers[m][i].admitted() > before {
                        report.admit_order.push((id, class));
                    }
                    // a batcher that sat idle starts this request at its
                    // arrival instant, not at the stale idle clock
                    if batchers[m][i].admitted() > before && was_idle {
                        if let Some(rec) = report.requests.get(&id) {
                            if clock < rec.arrived_at {
                                clock = rec.arrived_at;
                                offsets[m][i] = clock - batchers[m][i].engine.kernel_secs;
                            }
                        }
                    }
                    if let Some(rec) = report.requests.get_mut(&id) {
                        rec.admitted_at = Some(clock);
                    }
                }
                Err(p) => {
                    queue.push_front(class, p);
                    break;
                }
            }
        }
        let step = batchers[m][i].step();
        let (stream, bus) = testing::bandwidth_key(&batchers[m][i]);
        testing::absorb(&mut report, &step, offsets[m][i], stream, bus);
        usage[m].tokens += step.decoded_tokens;
        usage[m].kernel_secs += step.kernel_secs;
        usage[m].rounds += 1;
        // machine-local strength learning, exactly like run_fleet
        if let (Some(lease), Some(res), Some(class)) = (
            batchers[m][i].lease.clone(),
            batchers[m][i].engine.rt.last_result.clone(),
            batchers[m][i].engine.rt.last_class,
        ) {
            if cluster.machine_mut(MachineId(m)).observe(&lease, class, &res) {
                report.observations_accepted += 1;
            }
        }
        // cluster-level strength learning: fold windowed per-machine token
        // rates once ≥2 machines have a full window (a relative signal)
        if step.decoded_tokens > 0 && step.kernel_secs > 0.0 {
            let w = &mut windows[m];
            w.tokens += step.decoded_tokens;
            w.secs += step.kernel_secs;
            w.rounds += 1;
            let ready: Vec<usize> = (0..n).filter(|&k| windows[k].ready()).collect();
            if ready.len() >= 2 {
                let rates: Vec<(MachineId, f64)> =
                    ready.iter().map(|&k| (MachineId(k), windows[k].rate())).collect();
                if cluster.observe(&rates) {
                    for &k in &ready {
                        windows[k].reset();
                    }
                }
            }
        }
        // the cluster-drift check a fleet supervisor would run between
        // events: skew past threshold → re-place and migrate sessions
        let drift = monitor.check_drift_with(
            cluster.epoch(),
            cluster.observations(),
            cluster.machines_in_use(),
            || cluster.skew(),
        );
        if let Some(skew) = drift {
            let moves = cluster.replace();
            if moves.is_empty() {
                continue; // epoch bumped: the cooldown restarts
            }
            // rebuild at the cluster's latest clock — a machine running
            // ahead must not have its timeline rewound
            let mut now = clock;
            for k in 0..n {
                for j in 0..batchers[k].len() {
                    now = now.max(offsets[k][j] + batchers[k][j].engine.kernel_secs);
                }
            }
            let affected: BTreeSet<usize> =
                moves.iter().flat_map(|mv| [mv.from.0, mv.to.0]).collect();
            let mut stale: Vec<(usize, Lease, KernelClass, RunResult)> = Vec::new();
            let mut carried: Vec<(usize, Option<StreamId>, f64, ActiveRequest)> = Vec::new();
            for &k in &affected {
                let (s, c) = strip_machine(&mut batchers[k]);
                stale.extend(s.into_iter().map(|(l, cl, r)| (k, l, cl, r)));
                carried.extend(c.into_iter().map(|(st, kv, a)| (k, st, kv, a)));
            }
            // interconnect-cost-aware routing: each session follows its
            // stream's new placement; cross-machine moves charge KV bytes
            let mut inbound = vec![0.0f64; n];
            let mut groups: BTreeMap<usize, Vec<ActiveRequest>> = BTreeMap::new();
            for (src, stream, kv, a) in carried {
                let dest = stream
                    .and_then(|s| cluster.placement_of(s))
                    .map_or(src, |MachineId(d)| d);
                if dest != src {
                    migrated_sessions += 1;
                    interconnect_bytes += kv;
                    inbound[dest] += kv;
                    usage[dest].interconnect_bytes += kv;
                }
                groups.entry(dest).or_default().push(a);
            }
            for &k in &affected {
                let carried_k = groups.remove(&k).unwrap_or_default();
                // the destination resumes once its inbound KV landed
                let restart = now + cluster.interconnect().transfer_secs(inbound[k]);
                rebuild_machine(
                    &cluster,
                    k,
                    &factories[k],
                    opts,
                    &mut batchers[k],
                    &mut offsets[k],
                    carried_k,
                    &degraded[k],
                    restart,
                    &mut report,
                );
            }
            debug_assert!(groups.is_empty(), "session routed to an untouched machine");
            for (k, l, cl, r) in &stale {
                replay_stale_one(&mut cluster, *k, l, *cl, r, &mut report);
            }
            report.drift_rebalances += 1;
            skew_at_trigger.push(skew);
        }
    }
    for m in 0..n {
        let coord = cluster.machine(MachineId(m));
        for l in coord.leases() {
            if !l.accels().is_empty() {
                report.split_ratios.push(coord.split_ratio(l));
            }
        }
    }
    report.skew_at_trigger = skew_at_trigger.clone();
    testing::finalize(&mut report, &rxs);
    ClusterReport {
        base: report,
        machines: usage,
        replacements: cluster.replacements(),
        migrated_sessions,
        interconnect_bytes,
        cluster_skew_at_trigger: skew_at_trigger,
        cluster_observations: cluster.observations(),
        final_skew: cluster.skew(),
        final_strengths: cluster.strengths().to_vec(),
        final_placements: cluster.placements().collect(),
    }
}

/// Tear one machine's fleet down for a rebuild: collect the in-flight
/// measurements (for the stale-replay fence) and the active requests,
/// each tagged with its stream and KV footprint (the bytes a cross-machine
/// migration would move).
type StaleObs = (Lease, KernelClass, RunResult);
type CarriedSession = (Option<StreamId>, f64, ActiveRequest);

fn strip_machine<E: Executor>(
    batchers: &mut [LeaseBatcher<E>],
) -> (Vec<StaleObs>, Vec<CarriedSession>) {
    let mut stale = Vec::new();
    let mut carried = Vec::new();
    for b in batchers.iter_mut() {
        if let (Some(l), Some(c), Some(r)) =
            (b.lease.clone(), b.engine.rt.last_class, b.engine.rt.last_result.clone())
        {
            stale.push((l, c, r));
        }
        let stream = b.lease.as_ref().map(|l| l.stream);
        let cfg = b.engine.cfg.clone();
        for a in b.take_actives() {
            let kv = a.kv_bytes(&cfg);
            carried.push((stream, kv, a));
        }
    }
    (stale, carried)
}

#[allow(clippy::too_many_arguments)]
fn rebuild_machine<E: Executor>(
    cluster: &ClusterCoordinator,
    m: usize,
    factory: &EngineFactory<E>,
    opts: BatcherOpts,
    batchers: &mut Vec<LeaseBatcher<E>>,
    offsets: &mut Vec<f64>,
    carried: Vec<ActiveRequest>,
    degraded: &[(Vec<usize>, f64)],
    now: f64,
    report: &mut HarnessReport,
) {
    let coord = cluster.machine(MachineId(m));
    let mut fresh = fleet::build_batchers(coord, factory, opts);
    for a in fleet::distribute(carried, &mut fresh) {
        a.reject("no serving capacity, retry");
    }
    for (cores, fraction) in degraded {
        testing::apply_degradation(&mut fresh, cores, *fraction);
    }
    *offsets = fresh.iter().map(|b| now - b.engine.kernel_secs).collect();
    *batchers = fresh;
    report.rebuilds += 1;
    report.epochs_seen.push(cluster.epoch());
    report.lease_sets.push(coord.leases().cloned().collect());
}

fn replay_stale(
    cluster: &mut ClusterCoordinator,
    m: usize,
    stale: &[StaleObs],
    report: &mut HarnessReport,
) {
    for (l, c, r) in stale {
        replay_stale_one(cluster, m, l, *c, r, report);
    }
}

fn replay_stale_one(
    cluster: &mut ClusterCoordinator,
    m: usize,
    lease: &Lease,
    class: KernelClass,
    res: &RunResult,
    report: &mut HarnessReport,
) {
    if cluster.machine_mut(MachineId(m)).observe(lease, class, res) {
        report.stale_observations_accepted += 1;
    } else {
        report.stale_observations_dropped += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{InterconnectSpec, MachineSpec};
    use crate::cpu::presets;
    use crate::engine::Engine;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::server::protocol::Request;
    use crate::sim::{SimConfig, SimExecutor};
    use std::sync::Arc;

    fn factory(machine: crate::cpu::CpuSpec, seed: u64) -> EngineFactory<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, seed));
        Box::new(move |lease, _dispatch| {
            let sim = SimConfig { execute_real: true, ..SimConfig::noiseless() };
            let exec = lease.sim_executor(&machine, sim);
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        })
    }

    fn req(id: u64, prompt: &[u32], max_new: usize) -> Request {
        Request { id, prompt: prompt.to_vec(), max_new_tokens: max_new }
    }

    fn two_machine_cluster() -> (ClusterCoordinator, Vec<EngineFactory<SimExecutor>>) {
        let specs = [
            MachineSpec::cores_only(presets::core_12900k()),
            MachineSpec::cores_only(presets::homogeneous(12)),
        ];
        let cluster = ClusterCoordinator::new(&specs, InterconnectSpec::default());
        let factories =
            vec![factory(presets::core_12900k(), 5), factory(presets::homogeneous(12), 5)];
        (cluster, factories)
    }

    #[test]
    fn cluster_serves_across_machines_deterministically() {
        let run = || {
            let (cluster, factories) = two_machine_cluster();
            let mut trace = vec![
                TraceEvent::Connect { at: 0.0, stream: 0 },
                TraceEvent::Connect { at: 0.0, stream: 1 },
            ];
            for id in 0..6u64 {
                trace.push(TraceEvent::arrive(1e-6 + id as f64 * 1e-4, 0, req(id, &[1, 2, 3], 4)));
            }
            let policy = ServingPolicy::builder()
                .queue_depth(64)
                .drift(f64::INFINITY, 0)
                .build()
                .unwrap();
            run_cluster(cluster, &factories, &policy, trace)
        };
        let a = run();
        assert!(a.all_finished(), "unserved requests");
        assert_eq!(a.base.total_decoded, 24);
        // both machines held a stream and served tokens
        assert!(a.machines.iter().filter(|u| u.tokens > 0).count() >= 2, "one machine idle");
        // no drift monitor → no migrations, no interconnect traffic
        assert_eq!(a.migrated_sessions, 0);
        assert_eq!(a.interconnect_bytes, 0.0);
        let b = run();
        for id in 0..6u64 {
            assert_eq!(a.tokens_of(id), b.tokens_of(id), "non-deterministic stream {id}");
        }
        assert_eq!(a.base.makespan, b.base.makespan);
    }

    #[test]
    fn serving_metrics_rollup_exports_cluster_fields() {
        let (cluster, factories) = two_machine_cluster();
        let trace = vec![
            TraceEvent::Connect { at: 0.0, stream: 0 },
            TraceEvent::Connect { at: 0.0, stream: 1 },
            TraceEvent::arrive(1e-6, 0, req(1, &[1, 2], 3)),
            TraceEvent::arrive(2e-6, 0, req(2, &[3, 4], 3)),
        ];
        let policy = ServingPolicy::builder()
            .queue_depth(16)
            .drift(f64::INFINITY, 0)
            .build()
            .unwrap();
        let rep = run_cluster(cluster, &factories, &policy, trace);
        assert!(rep.all_finished());
        let sm = rep.serving_metrics();
        assert_eq!(sm.machines.len(), 2);
        assert_eq!(sm.tokens, 6);
        assert_eq!(sm.replacements, 0);
        let j = sm.to_json(2, 1);
        let machines = j.get("machines").expect("cluster export missing");
        assert_eq!(machines.as_array().map(|a| a.len()), Some(2));
        assert!(j.get("cluster_skew").is_some());
        assert!(j.get("interconnect_bytes").is_some());
    }
}
