//! Balanced k-way placement with epsilon slack — the cluster tier's static
//! partitioner.
//!
//! Machines are buckets with heterogeneous *capability* scores; streams are
//! items with weights. The partitioner fills buckets greedily in LPT order
//! (heaviest item first) by normalized fill `load / target`, where each
//! machine's target is its capability share of the total weight. An epsilon
//! slack band makes placement *sticky*: among destinations within `(1+ε)` of
//! the best fill-after, the lowest-indexed machine wins, so near-tied
//! capabilities don't cause churn between equivalent machines.
//!
//! [`repartition`] reuses the same fill criterion to move already-placed
//! items when capabilities change (a machine degrades or recovers). Moves are
//! accepted only under a strict-improvement hysteresis — the destination's
//! fill after the move must beat the source's fill before it by more than the
//! epsilon band — which both prevents oscillation between near-equal machines
//! and still fully drains a collapsed (zero- or near-zero-capability)
//! machine. Cross-machine moves are expensive (KV transfer over the
//! interconnect), so "no move" must always be the default for healthy
//! clusters; the property tests in `rust/tests/prop_invariants.rs` pin the
//! balance bound and the exactly-once guarantee.

/// One corrective move produced by [`repartition`]: item `item` relocates
/// from machine `from` to machine `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Move {
    pub item: usize,
    pub from: usize,
    pub to: usize,
}

/// Per-machine fill targets: each machine's capability share of the total
/// item weight. Zero-capability machines get target 0 and are never placed
/// onto. Panics if no machine has positive capability.
fn targets(total_weight: f64, capability: &[f64]) -> Vec<f64> {
    let cap_sum: f64 = capability.iter().filter(|c| **c > 0.0).sum();
    assert!(cap_sum > 0.0, "cluster has no machine with positive capability");
    capability
        .iter()
        .map(|&c| if c > 0.0 { total_weight * c / cap_sum } else { 0.0 })
        .collect()
}

/// Pick a destination for one item of weight `w` given current per-machine
/// `load`s and fill `target`s: the machine minimizing fill-after
/// `(load + w) / target`, with ties (within `(1 + epsilon)` of the best)
/// broken toward the lowest index. Zero-target machines are never eligible.
pub fn place_one(load: &[f64], w: f64, target: &[f64], epsilon: f64) -> usize {
    debug_assert_eq!(load.len(), target.len());
    let fill_after = |m: usize| -> f64 {
        if target[m] > 0.0 {
            (load[m] + w) / target[m]
        } else {
            f64::INFINITY
        }
    };
    let best = (0..load.len()).map(fill_after).fold(f64::INFINITY, f64::min);
    assert!(best.is_finite(), "no machine with positive capability to place onto");
    // lowest index within the slack band of the best fill-after
    (0..load.len())
        .find(|&m| fill_after(m) <= best * (1.0 + epsilon))
        .expect("slack band always contains the argmin")
}

/// Balanced k-way partition of `weights` over machines with `capability`
/// scores. Returns one machine index per item. Items are placed in LPT order
/// (heaviest first, stable by index) so large items land while buckets are
/// still empty; each lands on the machine with the least normalized fill
/// after placement, epsilon-sticky toward low indices.
///
/// Guarantees (property-tested):
/// * every item is assigned exactly once to a valid machine index;
/// * no item lands on a zero-capability machine;
/// * pairwise balance: for any machines `a`, `b` with positive targets,
///   `fill_a <= (1 + epsilon) * (fill_b + max_w / target_b)` — each bucket is
///   within one item (plus the slack band) of every other.
pub fn partition(weights: &[f64], capability: &[f64], epsilon: f64) -> Vec<usize> {
    let total: f64 = weights.iter().sum();
    let target = targets(total.max(f64::MIN_POSITIVE), capability);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        weights[b].partial_cmp(&weights[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut load = vec![0.0; capability.len()];
    let mut assignment = vec![usize::MAX; weights.len()];
    for &i in &order {
        let m = place_one(&load, weights[i], &target, epsilon);
        load[m] += weights[i];
        assignment[i] = m;
    }
    assignment
}

/// Corrective re-placement after capabilities changed. Takes the `current`
/// assignment and produces the net set of [`Move`]s that restore balance
/// under the *new* `capability` scores.
///
/// Two phases:
/// 1. **Forced evictions** — items on machines whose capability dropped to
///    zero (or below) must move; each goes to the current argmin fill-after.
/// 2. **Improvement loop** — repeatedly take the machine with the highest
///    normalized fill, try to move its lightest item to the machine with the
///    lowest fill-after, and accept only if the destination's fill after the
///    move is strictly below the source's fill before it divided by
///    `(1 + epsilon)`. The hysteresis means near-balanced clusters produce
///    *zero* moves (in-machine stability when capabilities are close), while
///    a collapsed machine — whose fill diverges — always drains.
///
/// Termination: every accepted move strictly lowers the maximum fill or, at
/// equal maxima, lexicographically lowers the sorted fill vector; an
/// iteration guard bounds pathological float cases. Moves are compressed to
/// net effect (an item bouncing `A -> B -> C` reports one `A -> C` move; a
/// round trip reports nothing).
pub fn repartition(
    current: &[usize],
    weights: &[f64],
    capability: &[f64],
    epsilon: f64,
) -> Vec<Move> {
    assert_eq!(current.len(), weights.len());
    let total: f64 = weights.iter().sum();
    let target = targets(total.max(f64::MIN_POSITIVE), capability);
    let mut placed = current.to_vec();
    let mut load = vec![0.0; capability.len()];
    for (i, &m) in placed.iter().enumerate() {
        assert!(m < capability.len(), "item {i} placed on unknown machine {m}");
        load[m] += weights[i];
    }

    let fill = |load: &[f64], m: usize| -> f64 {
        if target[m] > 0.0 {
            load[m] / target[m]
        } else if load[m] > 0.0 {
            f64::INFINITY
        } else {
            0.0
        }
    };

    // phase 1: forced evictions off zero-capability machines
    for i in 0..placed.len() {
        if target[placed[i]] <= 0.0 {
            let from = placed[i];
            let to = place_one(&load, weights[i], &target, epsilon);
            load[from] -= weights[i];
            load[to] += weights[i];
            placed[i] = to;
        }
    }

    // phase 2: hysteresis improvement loop
    let guard = 4 * placed.len().max(1) * capability.len().max(1);
    for _ in 0..guard {
        let src = match (0..capability.len())
            .filter(|&m| load[m] > 0.0)
            .max_by(|&a, &b| fill(&load, a).partial_cmp(&fill(&load, b)).unwrap())
        {
            Some(m) => m,
            None => break,
        };
        // lightest item on the most-loaded machine is the cheapest probe
        let item = match (0..placed.len()).filter(|&i| placed[i] == src).min_by(|&a, &b| {
            weights[a].partial_cmp(&weights[b]).unwrap_or(std::cmp::Ordering::Equal)
        }) {
            Some(i) => i,
            None => break,
        };
        let w = weights[item];
        let mut probe = load.clone();
        probe[src] -= w;
        let dst = place_one(&probe, w, &target, 0.0);
        if dst == src {
            break;
        }
        let fill_before = fill(&load, src);
        let dst_after = (probe[dst] + w) / target[dst];
        if dst_after >= fill_before / (1.0 + epsilon) {
            break; // no strict improvement — the cluster is balanced enough
        }
        load[src] -= w;
        load[dst] += w;
        placed[item] = dst;
    }

    // net moves only: compare final placement to the original
    (0..placed.len())
        .filter(|&i| placed[i] != current[i])
        .map(|i| Move { item: i, from: current[i], to: placed[i] })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(assign: &[usize], weights: &[f64], k: usize) -> Vec<f64> {
        let mut l = vec![0.0; k];
        for (i, &m) in assign.iter().enumerate() {
            l[m] += weights[i];
        }
        l
    }

    #[test]
    fn partition_balances_equal_machines() {
        let w = vec![1.0; 8];
        let cap = vec![10.0; 4];
        let a = partition(&w, &cap, 0.05);
        assert_eq!(loads(&a, &w, 4), vec![2.0; 4]);
    }

    #[test]
    fn partition_is_capability_proportional() {
        let w = vec![1.0; 12];
        let cap = vec![10.0, 20.0, 30.0];
        let a = partition(&w, &cap, 0.05);
        assert_eq!(loads(&a, &w, 3), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn partition_skips_zero_capability() {
        let w = vec![1.0, 1.0, 1.0];
        let cap = vec![0.0, 5.0, 0.0];
        let a = partition(&w, &cap, 0.05);
        assert!(a.iter().all(|&m| m == 1));
    }

    #[test]
    #[should_panic(expected = "no machine with positive capability")]
    fn partition_rejects_dead_cluster() {
        partition(&[1.0], &[0.0, 0.0], 0.05);
    }

    #[test]
    fn repartition_is_stable_when_capabilities_are_close() {
        // two machines 5% apart in capability, balanced placement: the
        // hysteresis must produce zero moves (in-machine preference).
        let w = vec![1.0; 4];
        let cap = vec![10.0, 10.5];
        let current = vec![0, 0, 1, 1];
        let moves = repartition(&current, &w, &cap, 0.05);
        assert!(moves.is_empty(), "near-tied capabilities must not churn: {moves:?}");
    }

    #[test]
    fn repartition_drains_collapsed_machine() {
        // machine 0 collapses to near-zero capability: its streams must
        // drain to the healthy machines, none may remain.
        let w = vec![1.0; 8];
        let cap = vec![0.08, 1.0, 1.0, 1.0];
        let current = vec![0, 0, 1, 1, 2, 2, 3, 3];
        let moves = repartition(&current, &w, &cap, 0.05);
        assert!(!moves.is_empty());
        let mut placed = current.clone();
        for mv in &moves {
            assert_eq!(placed[mv.item], mv.from);
            placed[mv.item] = mv.to;
        }
        assert!(placed.iter().all(|&m| m != 0), "collapsed machine kept streams: {placed:?}");
    }

    #[test]
    fn repartition_forces_eviction_off_zero_capability() {
        let w = vec![2.0, 1.0];
        let cap = vec![0.0, 1.0];
        let moves = repartition(&[0, 1], &w, &cap, 0.05);
        assert_eq!(moves, vec![Move { item: 0, from: 0, to: 1 }]);
    }

    #[test]
    fn repartition_reports_net_moves_only() {
        // already balanced — identical capabilities, equal loads — no moves.
        let w = vec![1.0; 6];
        let cap = vec![1.0, 1.0, 1.0];
        assert!(repartition(&[0, 0, 1, 1, 2, 2], &w, &cap, 0.05).is_empty());
    }
}
