//! Minimal dense tensor types used by the native kernels and the model.
//!
//! Deliberately small: row-major 2-D f32 matrices plus typed i8/u8 buffers.
//! (The heavy lifting lives in `kernels/` and `quant/`; this module only
//! owns layout and bounds logic so kernels stay readable.)

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> MatF32 {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> MatF32 {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        MatF32 { rows, cols, data }
    }

    /// Deterministic N(0, sigma) init.
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut crate::util::rng::Rng) -> MatF32 {
        let mut m = MatF32::zeros(rows, cols);
        rng.fill_normal_f32(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Transposed copy (used when marshalling PJRT literals).
    pub fn transposed(&self) -> MatF32 {
        let mut out = MatF32::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }
}

/// Typed 2-D i8 buffer (quant codes / int8 GEMM operands).
#[derive(Clone, Debug, PartialEq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn zeros(rows: usize, cols: usize) -> MatI8 {
        MatI8 { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Typed 2-D u8 buffer (unsigned int8 GEMM activations).
#[derive(Clone, Debug, PartialEq)]
pub struct MatU8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

impl MatU8 {
    pub fn zeros(rows: usize, cols: usize) -> MatU8 {
        MatU8 { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn row_indexing() {
        let m = MatF32::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.at(1, 2), 6.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let m = MatF32::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transposed().transposed(), m);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn from_vec_checks_shape() {
        MatF32::from_vec(vec![0.0; 5], 2, 3);
    }

    #[test]
    fn set_get() {
        let mut m = MatF32::zeros(3, 3);
        m.set(2, 1, 9.0);
        assert_eq!(m.at(2, 1), 9.0);
        assert_eq!(m.row(2), &[0.0, 9.0, 0.0]);
    }
}
