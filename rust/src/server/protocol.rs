//! JSON-lines wire protocol of the serving front-end.
//!
//! Client → server, one JSON object per line:
//!   {"id": 7, "prompt": [1,2,3], "max_new_tokens": 8, "class": 1}
//!   {"cmd": "metrics"}
//!
//! `class` is the optional admission priority class (0 = highest priority,
//! the default — see [`crate::router::ClassPolicy`]).
//! Server → client:
//!   {"id": 7, "token": 42}                              (streamed)
//!   {"id": 7, "done": true, "prefill_secs": …, "decode_secs": …,
//!    "tokens_per_sec": …, "n_tokens": …}
//!   {"id": 7, "error": "…"}
//!   {"metrics": {…}}

use crate::metrics::PhaseMetrics;
use crate::util::json::Json;

/// A parsed generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

/// Client line → request or control command.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientMessage {
    /// a generation request plus its admission priority class (0 =
    /// highest; absent on the wire → 0)
    Generate { req: Request, class: usize },
    Metrics,
}

pub fn parse_client_line(line: &str) -> Result<ClientMessage, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "metrics" => Ok(ClientMessage::Metrics),
            other => Err(format!("unknown cmd '{other}'")),
        };
    }
    let id = v.get("id").and_then(Json::as_i64).ok_or("missing id")? as u64;
    let prompt = v
        .get("prompt")
        .and_then(Json::as_array)
        .ok_or("missing prompt")?
        .iter()
        .map(|t| t.as_i64().map(|x| x as u32).ok_or("bad token"))
        .collect::<Result<Vec<u32>, _>>()?;
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    let max_new_tokens = v.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(16);
    let class = v.get("class").and_then(Json::as_usize).unwrap_or(0);
    Ok(ClientMessage::Generate { req: Request { id, prompt, max_new_tokens }, class })
}

/// A typed server→client message. The serving core (batcher/fleet)
/// produces these; the TCP front-end serializes them with [`Event::line`],
/// while the deterministic harness ([`crate::server::testing`]) consumes
/// them directly — same stream, no socket or JSON round-trip required.
#[derive(Clone, Debug)]
pub enum Event {
    Token { id: u64, token: u32 },
    Done { id: u64, metrics: PhaseMetrics },
    Error { id: u64, msg: String },
}

impl Event {
    pub fn id(&self) -> u64 {
        match self {
            Event::Token { id, .. } | Event::Done { id, .. } | Event::Error { id, .. } => *id,
        }
    }

    /// True for the message that terminates a request's stream.
    pub fn is_final(&self) -> bool {
        !matches!(self, Event::Token { .. })
    }

    /// The JSON-lines wire form.
    pub fn line(&self) -> String {
        match self {
            Event::Token { id, token } => token_line(*id, *token),
            Event::Done { id, metrics } => done_line(*id, metrics),
            Event::Error { id, msg } => error_line(*id, msg),
        }
    }
}

pub fn token_line(id: u64, token: u32) -> String {
    Json::obj(vec![("id", Json::num(id as f64)), ("token", Json::num(token as f64))]).dump()
}

pub fn done_line(id: u64, m: &PhaseMetrics) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("done", Json::Bool(true)),
        ("prefill_secs", Json::num(m.prefill_secs)),
        ("decode_secs", Json::num(m.decode_secs)),
        ("tokens_per_sec", Json::num(m.decode_tokens_per_sec())),
        ("n_tokens", Json::num(m.decoded_tokens as f64)),
    ])
    .dump()
}

pub fn error_line(id: u64, msg: &str) -> String {
    Json::obj(vec![("id", Json::num(id as f64)), ("error", Json::str(msg))]).dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generate() {
        let msg = parse_client_line(r#"{"id": 3, "prompt": [1, 2], "max_new_tokens": 4}"#).unwrap();
        assert_eq!(
            msg,
            ClientMessage::Generate {
                req: Request { id: 3, prompt: vec![1, 2], max_new_tokens: 4 },
                class: 0
            }
        );
    }

    #[test]
    fn parses_priority_class() {
        let msg =
            parse_client_line(r#"{"id": 3, "prompt": [1], "class": 2}"#).unwrap();
        let ClientMessage::Generate { class, .. } = msg else { panic!() };
        assert_eq!(class, 2);
    }

    #[test]
    fn default_max_tokens() {
        let ClientMessage::Generate { req: r, class } =
            parse_client_line(r#"{"id":1,"prompt":[5]}"#).unwrap()
        else {
            panic!()
        };
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(class, 0, "absent class defaults to highest priority");
    }

    #[test]
    fn parses_metrics_cmd() {
        assert_eq!(parse_client_line(r#"{"cmd":"metrics"}"#).unwrap(), ClientMessage::Metrics);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_client_line("not json").is_err());
        assert!(parse_client_line(r#"{"id":1}"#).is_err());
        assert!(parse_client_line(r#"{"id":1,"prompt":[]}"#).is_err());
        assert!(parse_client_line(r#"{"cmd":"explode"}"#).is_err());
    }

    #[test]
    fn response_lines_are_valid_json() {
        let m = PhaseMetrics {
            prefill_secs: 0.5,
            decode_secs: 1.0,
            prompt_tokens: 4,
            decoded_tokens: 16,
        };
        for line in [token_line(1, 42), done_line(1, &m), error_line(2, "boom")] {
            Json::parse(&line).unwrap();
        }
        let d = Json::parse(&done_line(9, &m)).unwrap();
        assert_eq!(d.get("tokens_per_sec").unwrap().as_f64(), Some(16.0));
    }

    #[test]
    fn typed_events_match_line_helpers() {
        let m = PhaseMetrics { decoded_tokens: 3, decode_secs: 1.5, ..Default::default() };
        let tok = Event::Token { id: 4, token: 9 };
        let done = Event::Done { id: 4, metrics: m.clone() };
        let err = Event::Error { id: 4, msg: "boom".into() };
        assert_eq!(tok.line(), token_line(4, 9));
        assert_eq!(done.line(), done_line(4, &m));
        assert_eq!(err.line(), error_line(4, "boom"));
        assert!(!tok.is_final() && done.is_final() && err.is_final());
        assert_eq!(tok.id(), 4);
    }
}
