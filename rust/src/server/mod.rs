//! Threaded TCP serving front-end with **continuous batching** and
//! **dynamic lease membership**.
//!
//! Architecture (one layer per module):
//!
//! * [`protocol`] — JSON-lines wire format and the typed [`protocol::Event`]
//!   stream the serving core produces.
//! * [`queue`] — the bounded admission queue. Client handlers parse
//!   requests into it; saturation answers with a protocol error or blocks
//!   the submitter ([`ServerOpts::on_full`]), so memory stays bounded under
//!   overload.
//! * [`batcher`] — continuous batching inside one lease: a persistent
//!   [`LeaseBatcher`] advances its live requests in token rounds (chunked
//!   prefill, one decoded token per round), admits new requests *between*
//!   rounds and retires finished ones immediately, reusing KV slots from a
//!   [`crate::model::SessionPool`]. This replaces the old run-to-completion
//!   `run_batch` loop — a request arriving mid-run now waits one round, not
//!   one whole batch.
//! * [`fleet`] — lease lifecycle: one batcher per non-empty coordinator
//!   lease, rebuilt on every epoch change with in-flight sessions migrating
//!   onto the new fleet (bit-identical streams; partitioning only changes
//!   timing). A lease in [`crate::coordinator::ExecMode::Disaggregated`]
//!   becomes a *pair* of batchers — a compute-steered prefill side and a
//!   bandwidth-steered decode side — linked here by a shared [`PhaseState`]:
//!   the prefill worker parks prefill-complete sessions and hands them
//!   through the buffer (bounded by the decode side's published free
//!   slots), the decode worker adopts and streams them. The handoff reuses
//!   the same `SessionPool` detach/adopt migration as a fleet rebuild, so
//!   token streams stay bit-identical to a blended lease.
//! * [`testing`] — a deterministic, virtual-time harness that drives the
//!   same batcher/fleet code with scripted arrival traces: the standard way
//!   to test serving features without sockets or wall-clock sleeps.
//!
//! Front-ends:
//!
//! * [`serve`] — one engine owning every core (the seed behavior).
//! * [`serve_multi`] — a fixed fleet, one engine per pre-built lease; all
//!   batchers drain the shared admission queue (first-idle-wins).
//! * [`serve_dynamic`] — the lease set follows the live connections: a
//!   connection's first generate request admits it to the
//!   [`crate::coordinator::Coordinator`] (epoch bump → fleet rebuild), its
//!   disconnect returns the units to the pool. Per-unit strength keeps
//!   being learned from served traffic via [`Coordinator::observe`];
//!   measurements racing a rebuild carry a stale lease epoch and are
//!   dropped, never mis-attributed. The supervisor also watches
//!   [`Coordinator::strength_skew`] through a [`fleet::DriftMonitor`]
//!   ([`ServerOpts::drift_threshold`]): when background load skews the
//!   learned strengths past the threshold, it calls `rebalance()` and
//!   rebuilds the fleet live — in-flight sessions migrate bit-identically,
//!   exactly as on a membership change. The caller supplies the
//!   coordinator, so heterogeneous machines (cores + accelerators, see
//!   [`crate::coordinator::XpuAffinity`]) serve through the same loop.
//!
//! All three front-ends take an `impl Into<`[`ServingPolicy`]`>` — the
//! unified serving config from [`crate::router`]. A legacy [`ServerOpts`]
//! converts losslessly (single class, router off); a policy built with
//! [`ServingPolicy::builder`] adds priority classes with per-class TTFT
//! targets (SLO-aware shedding: low-priority work is bounced first) and,
//! under [`serve_dynamic`], the live [`StrategyRouter`]
//! that re-plans the serving strategy from the offered load.

pub mod batcher;
pub mod fleet;
pub mod protocol;
pub mod queue;
pub mod testing;
pub mod trace;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, Lease, Strategy, StreamId};
use crate::engine::Engine;
use crate::exec::Executor;
use crate::kernels::KernelClass;
use crate::metrics::ServingMetrics;
use crate::router::{ServingPolicy, SloGate, StrategyRouter};
use crate::sim::xpu::XpuDispatch;
use crate::util::json::Json;

pub use batcher::{ActiveRequest, BatcherOpts, LeaseBatcher, Pending, PhaseRole};
pub use queue::{AdmissionPolicy, AdmissionQueue, ClassedQueue};

use protocol::{ClientMessage, Event};

/// Poison-recovering lock: the shared state guarded by the server's
/// mutexes (queue, metrics, coordinator, pair stats) is valid after any
/// panic — every critical section leaves it consistent — so a worker or
/// handler that panicked must not cascade into every other thread's
/// `lock().unwrap()`. Recover the guard and keep serving.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// concurrent requests (= KV slots) per engine
    pub max_batch: usize,
    /// prompt tokens prefilled per scheduler round (admission-latency bound)
    pub prefill_chunk: usize,
    /// admission-queue bound; a request finding it full hits `on_full`
    pub queue_depth: usize,
    pub on_full: AdmissionPolicy,
    /// learned-strength skew that triggers a live `rebalance()` + fleet
    /// rebuild in [`serve_dynamic`] (`f64::INFINITY` disables the monitor)
    pub drift_threshold: f64,
    /// accepted observations required after any epoch change before the
    /// drift monitor may fire again (keeps a fresh partition from being
    /// torn down on its own convergence transient)
    pub drift_cooldown: u64,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            max_batch: 4,
            prefill_chunk: 16,
            queue_depth: 256,
            on_full: AdmissionPolicy::Reject,
            drift_threshold: 1.25,
            drift_cooldown: 32,
        }
    }
}

impl ServerOpts {
    fn batcher(&self) -> BatcherOpts {
        BatcherOpts { max_batch: self.max_batch, prefill_chunk: self.prefill_chunk }
    }

    fn drift_monitor(&self) -> fleet::DriftMonitor {
        // no clamping: a threshold below 1.0 is a misconfiguration that
        // would rebuild the fleet on every cooldown — fail loudly instead
        // (DriftMonitor::new asserts)
        fleet::DriftMonitor::new(self.drift_threshold, self.drift_cooldown)
    }
}

/// Membership change of the live-connection set, routed to the supervisor.
enum ConnEvent {
    Connect(StreamId),
    Disconnect(StreamId),
}

/// What woke the supervisor. Every variant runs the same
/// retire → coordinator-update → rebuild → migrate sequence; only the
/// coordinator update differs.
enum Wake {
    /// live-connection membership changed (admit/finish streams)
    Membership(Vec<ConnEvent>),
    /// the drift monitor fired → `rebalance()`
    Drift,
    /// the strategy router decided a different serving strategy fits the
    /// offered load → `apply_strategy()`
    Switch(Strategy),
}

/// Shared state of one `ExecMode::AsyncBatch` batcher pair: lifetime
/// admission counters for the deficit routing, each side's free-slot flag
/// for the work-conserving override, and the latest round timings waiting
/// to be stitched into one [`Coordinator::observe_round`] call.
#[derive(Default)]
struct PairState {
    cpu_admitted: AtomicUsize,
    dev_admitted: AtomicUsize,
    cpu_free: AtomicBool,
    dev_free: AtomicBool,
    round: Mutex<PairRound>,
}

/// Most recent decode-round `(wall_secs, tokens)` per side of a pair.
#[derive(Default)]
struct PairRound {
    cpu: Option<(f64, usize)>,
    dev: Option<(f64, usize)>,
}

impl PairState {
    /// May `side_is_dev` admit the next request? The deficit rule keeps
    /// the running admission split on the coordinator's learned ratio; a
    /// side that is not owed may still admit when its twin has no free
    /// slot (work conservation — never idle capacity while requests wait).
    fn may_admit(&self, side_is_dev: bool, ratio: f64) -> bool {
        let c = self.cpu_admitted.load(Ordering::SeqCst);
        let d = self.dev_admitted.load(Ordering::SeqCst);
        let total = (c + d + 1) as f64;
        let (owed, twin_free) = if side_is_dev {
            ((d as f64) < ratio * total, self.cpu_free.load(Ordering::SeqCst))
        } else {
            ((c as f64) < (1.0 - ratio) * total, self.dev_free.load(Ordering::SeqCst))
        };
        owed || !twin_free
    }

    fn note_admitted(&self, side_is_dev: bool) {
        if side_is_dev {
            self.dev_admitted.fetch_add(1, Ordering::SeqCst);
        } else {
            self.cpu_admitted.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Shared state of one `ExecMode::Disaggregated` batcher pair: the
/// prefill→decode handoff buffer, the decode side's published free-slot
/// count (the capacity bound mirroring [`fleet::route_handoff`] — the
/// prefill worker never hands over more than the decode side can seat),
/// and the prefill side's liveness flag, which sequences shutdown so the
/// decode worker only exits once its twin can produce no more work.
struct PhaseState {
    handoff: Mutex<Vec<ActiveRequest>>,
    decode_free: AtomicUsize,
    prefill_live: AtomicBool,
}

impl PhaseState {
    fn new(max_batch: usize) -> PhaseState {
        PhaseState {
            handoff: Mutex::new(Vec::new()),
            // the decode batcher starts empty: every slot is free until
            // its first round publishes a measured count
            decode_free: AtomicUsize::new(max_batch),
            prefill_live: AtomicBool::new(true),
        }
    }
}

struct Shared {
    queue: Mutex<ClassedQueue<Pending>>,
    /// engine workers wait here for queued work
    work: Condvar,
    /// blocked submitters (AdmissionPolicy::Block) wait here for space
    space: Condvar,
    shutdown: AtomicBool,
    metrics: Mutex<ServingMetrics>,
    n_engines: AtomicUsize,
    /// coordinator epoch of the current fleet (0 for static fleets)
    epoch: AtomicU64,
    /// bumped by the supervisor to retire worker threads on fleet rebuild
    generation: AtomicU64,
    /// the full serving policy: overflow behavior, priority classes, SLO
    /// targets and (for `serve_dynamic`) the router knobs
    policy: ServingPolicy,
    /// learned decode capacity behind the SLO admission gate
    slo: Mutex<SloGate>,
    /// live strategy router; `Some` only under `serve_dynamic` with
    /// [`ServingPolicy::router`] set
    router: Mutex<Option<StrategyRouter>>,
    /// server start — the origin of the router's switch timeline
    started: Instant,
}

impl Shared {
    fn new(policy: ServingPolicy, n_engines: usize) -> Shared {
        Shared {
            queue: Mutex::new(ClassedQueue::new(policy.n_classes(), policy.queue_depth)),
            work: Condvar::new(),
            space: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Mutex::new(ServingMetrics::default()),
            n_engines: AtomicUsize::new(n_engines),
            epoch: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            slo: Mutex::new(SloGate::new()),
            router: Mutex::new(None),
            started: Instant::now(),
            policy,
        }
    }
}

/// A running server; call [`ServerHandle::shutdown`] to stop it. Every
/// thread the server ever spawned — batchers, supervisor, accept loop and
/// all connection handlers — is joined before `shutdown` returns, so no
/// handler can race the teardown.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving `engine` on `addr` (e.g. "127.0.0.1:0" for an ephemeral
/// port) — a single engine owning every core.
pub fn serve<E: Executor + Send + 'static>(
    addr: &str,
    engine: Engine<E>,
    opts: ServerOpts,
) -> std::io::Result<ServerHandle> {
    serve_multi(addr, vec![engine], opts)
}

/// Start serving a fixed fleet of engines — typically one per coordinator
/// lease, each restricted to a disjoint core subset. Every engine runs a
/// continuously-batching scheduler thread; all of them drain one shared
/// bounded admission queue, so the first batcher with a free slot claims
/// the next waiting request.
pub fn serve_multi<E: Executor + Send + 'static>(
    addr: &str,
    engines: Vec<Engine<E>>,
    opts: ServerOpts,
) -> std::io::Result<ServerHandle> {
    assert!(!engines.is_empty(), "need at least one engine");
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let bound = listener.local_addr()?;
    let shared = Arc::new(Shared::new(opts.into(), engines.len()));

    let mut threads = Vec::new();
    for engine in engines {
        let shared2 = Arc::clone(&shared);
        let b = LeaseBatcher::new(engine, None, opts.batcher());
        threads.push(std::thread::spawn(move || {
            let _ = run_batcher(b, shared2, 0, None, None, None);
        }));
    }
    threads.push(spawn_accept_loop(listener, Arc::clone(&shared), None));
    Ok(ServerHandle { addr: bound, shared, threads })
}

/// Start serving with **dynamic lease membership**: the engine fleet is not
/// fixed up front but follows the live connections. A connection's first
/// generate request admits it to the coordinator as a stream (epoch bump),
/// its disconnect finishes the stream; on every epoch change the fleet is
/// rebuilt from the new leases via `factory` and in-flight sessions migrate
/// onto the new engines (token streams stay bit-identical — only the unit
/// partitioning, and therefore timing, changes). The caller builds the
/// [`Coordinator`], so a heterogeneous machine (cores + accelerators) and
/// its placement affinity are its choice; between membership events the
/// supervisor watches learned-strength drift and rebalances live (see
/// [`ServingPolicy::drift_threshold`]).
///
/// Accepts anything convertible into a [`ServingPolicy`] — a legacy
/// [`ServerOpts`] keeps working unchanged, while a policy built with
/// [`ServingPolicy::builder`] additionally brings priority classes,
/// SLO-aware shedding and (with [`ServingPolicy::router`] set) the live
/// [`StrategyRouter`] that re-plans the fleet's serving strategy from the
/// offered load.
pub fn serve_dynamic<E, F, P>(
    addr: &str,
    mut coord: Coordinator,
    factory: F,
    policy: P,
) -> std::io::Result<ServerHandle>
where
    E: Executor + Send + 'static,
    F: Fn(&Lease, XpuDispatch) -> Engine<E> + Send + 'static,
    P: Into<ServingPolicy>,
{
    let policy: ServingPolicy = policy.into();
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let bound = listener.local_addr()?;
    if let Some(mode) = policy.mode {
        coord.set_exec_mode(mode);
    }
    let candidates = coord.strategy_candidates(policy.max_batch, policy.prefill_chunk);
    let shared = Arc::new(Shared::new(policy.clone(), 0));
    *lock(&shared.router) = StrategyRouter::from_policy(&policy, &candidates);
    let coord = Arc::new(Mutex::new(coord));
    let (ev_tx, ev_rx) = mpsc::channel::<ConnEvent>();

    let mut threads = Vec::new();
    {
        let shared2 = Arc::clone(&shared);
        let coord2 = Arc::clone(&coord);
        let factory: fleet::EngineFactory<E> = Box::new(factory);
        let batcher_opts = policy.batcher_opts();
        let monitor = policy.drift_monitor();
        threads.push(std::thread::spawn(move || {
            supervise(shared2, coord2, factory, batcher_opts, monitor, ev_rx);
        }));
    }
    threads.push(spawn_accept_loop(listener, Arc::clone(&shared), Some(ev_tx)));
    Ok(ServerHandle { addr: bound, shared, threads })
}

/// The supervisor owns the coordinator and the worker fleet. Each
/// membership event retires the running workers (generation bump),
/// collects their in-flight requests, applies admit/finish to the
/// coordinator, rebuilds one batcher per non-empty lease and migrates the
/// carried requests onto the new fleet. Idle ticks consult the
/// [`StrategyRouter`] (if the policy turned it on) and then the
/// [`fleet::DriftMonitor`]: a router switch or past-threshold strength
/// skew triggers the same retire→update→rebuild→migrate sequence with no
/// membership change, so a strategy flip migrates in-flight sessions
/// bit-identically — exactly as a membership rebuild does.
fn supervise<E: Executor + Send + 'static>(
    shared: Arc<Shared>,
    coord: Arc<Mutex<Coordinator>>,
    factory: fleet::EngineFactory<E>,
    mut opts: BatcherOpts,
    mut monitor: fleet::DriftMonitor,
    events: mpsc::Receiver<ConnEvent>,
) {
    let mut workers: Vec<std::thread::JoinHandle<Vec<ActiveRequest>>> = Vec::new();
    loop {
        let wake = match events.recv_timeout(Duration::from_millis(50)) {
            Ok(first) => {
                // coalesce a burst of membership changes into one rebuild
                let mut changes = vec![first];
                while let Ok(ev) = events.try_recv() {
                    changes.push(ev);
                }
                Wake::Membership(changes)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                // router first: a strategy decision is deliberate (window
                // full, outside the dead zone, past the cooldown) while a
                // drift rebalance is corrective — don't let the corrective
                // path pre-empt the deliberate one
                let switch = {
                    let mut r = lock(&shared.router);
                    r.as_mut().and_then(|router| {
                        let c = lock(&coord);
                        let share = c
                            .leases()
                            .find(|l| !l.accels().is_empty())
                            .map(|l| c.split_ratio(l));
                        router.decide(shared.started.elapsed().as_secs_f64(), share)
                    })
                };
                match switch {
                    Some(s) => Wake::Switch(s),
                    None if monitor.check_drift(&lock(&coord)).is_some() => Wake::Drift,
                    None => continue,
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // the accept loop (and every handler) is gone; treat it as
                // a shutdown so the workers drain and exit
                shared.shutdown.store(true, Ordering::SeqCst);
                break;
            }
        };
        let drift = matches!(wake, Wake::Drift);
        let switched = matches!(wake, Wake::Switch(_));

        // retire the current fleet; workers hand back their live requests
        shared.generation.fetch_add(1, Ordering::SeqCst);
        shared.work.notify_all();
        let mut carried: Vec<ActiveRequest> = Vec::new();
        for w in workers.drain(..) {
            carried.extend(w.join().unwrap_or_default());
        }

        // membership, learned drift or a strategy switch → coordinator:
        // every path bumps the epoch and re-issues every lease
        let (bus_reference, mut batchers) = {
            let mut c = lock(&coord);
            match wake {
                Wake::Drift => c.rebalance(),
                Wake::Switch(s) => {
                    opts = BatcherOpts { max_batch: s.max_batch, prefill_chunk: s.prefill_chunk };
                    c.apply_strategy(&s);
                }
                Wake::Membership(changes) => {
                    for ev in changes {
                        match ev {
                            ConnEvent::Connect(s) => {
                                let _ = c.admit(s);
                            }
                            ConnEvent::Disconnect(s) => c.finish(s),
                        }
                    }
                }
            }
            let batchers = fleet::build_batchers(&c, &factory, opts);
            shared.epoch.store(c.epoch(), Ordering::SeqCst);
            (c.bus_reference_gbps(), batchers)
        };
        for a in fleet::distribute(carried, &mut batchers) {
            // nobody left to serve the migrated stream: answer its client
            // instead of silently dropping it
            a.reject("no serving capacity, retry");
        }
        shared.n_engines.store(batchers.len(), Ordering::SeqCst);
        {
            let mut m = lock(&shared.metrics);
            m.rebuilds += 1;
            m.bus_reference_gbps = bus_reference;
            if drift {
                m.drift_rebalances += 1;
            }
            if switched {
                m.strategy_switches += 1;
            }
        }
        // one shared PairState per async-batch lease (its two batchers
        // carry the same stream id with CpuOnly/DeviceOnly dispatch)
        let mut pairs: std::collections::BTreeMap<StreamId, Arc<PairState>> =
            std::collections::BTreeMap::new();
        for b in &batchers {
            if b.dispatch() != XpuDispatch::Split {
                if let Some(l) = b.lease.as_ref() {
                    pairs.entry(l.stream).or_default();
                }
            }
        }
        // one shared PhaseState per disaggregated lease (its two batchers
        // carry the same stream id with Prefill/Decode roles)
        let mut phases: std::collections::BTreeMap<StreamId, Arc<PhaseState>> =
            std::collections::BTreeMap::new();
        for b in &batchers {
            if b.role() != PhaseRole::Mixed {
                if let Some(l) = b.lease.as_ref() {
                    phases
                        .entry(l.stream)
                        .or_insert_with(|| Arc::new(PhaseState::new(opts.max_batch)));
                }
            }
        }
        let gen = shared.generation.load(Ordering::SeqCst);
        for b in batchers {
            let shared2 = Arc::clone(&shared);
            let coord2 = Arc::clone(&coord);
            let pair = match b.dispatch() {
                XpuDispatch::Split => None,
                _ => b.lease.as_ref().and_then(|l| pairs.get(&l.stream)).map(Arc::clone),
            };
            let phase = match b.role() {
                PhaseRole::Mixed => None,
                _ => b.lease.as_ref().and_then(|l| phases.get(&l.stream)).map(Arc::clone),
            };
            workers.push(std::thread::spawn(move || {
                run_batcher(b, shared2, gen, Some(coord2), pair, phase)
            }));
        }
        shared.work.notify_all();
    }
    // shutdown: the workers drain the queue and exit on the flag
    shared.work.notify_all();
    for w in workers {
        let _ = w.join();
    }
    // with zero workers left, anything still queued would strand its
    // handler on a channel that never closes — drop it now
    let mut q = lock(&shared.queue);
    while q.pop().is_some() {}
    shared.space.notify_all();
}

/// One engine's scheduler thread: admit from the shared queue between
/// rounds, step the batcher, export metrics, feed measured per-core rates
/// to the coordinator. Returns the in-flight requests when its generation
/// is retired (fleet rebuild). A member of an async-batch pair routes its
/// admissions through the shared [`PairState`] and stitches its round
/// timings with its twin's into [`Coordinator::observe_round`].
fn run_batcher<E: Executor>(
    mut b: LeaseBatcher<E>,
    shared: Arc<Shared>,
    my_gen: u64,
    coord: Option<Arc<Mutex<Coordinator>>>,
    pair: Option<Arc<PairState>>,
    phase: Option<Arc<PhaseState>>,
) -> Vec<ActiveRequest> {
    let is_dev = b.dispatch() == XpuDispatch::DeviceOnly;
    let role = b.role();
    loop {
        // the learned device share steering this pair's admissions —
        // re-read every round so the split follows the online ratio
        let ratio = match (&pair, &coord, b.lease.as_ref()) {
            (Some(_), Some(c), Some(l)) => lock(c).split_ratio(l),
            _ => 0.0,
        };
        {
            let mut q = lock(&shared.queue);
            loop {
                if shared.generation.load(Ordering::SeqCst) != my_gen {
                    // a retiring phase batcher drains the shared handoff
                    // buffer too — sessions parked between the pair must
                    // migrate with the fleet, not vanish
                    let mut out = b.take_actives();
                    if let Some(ph) = &phase {
                        out.append(&mut lock(&ph.handoff));
                    }
                    return out;
                }
                // a decode-phase batcher is fed by its twin's handoff
                // buffer, never by the admission queue; on shutdown it
                // must outlive the prefill side (which can still be
                // producing work for it)
                let phase_done = match (&phase, role) {
                    (Some(ph), PhaseRole::Decode) => {
                        lock(&ph.handoff).is_empty()
                            && !ph.prefill_live.load(Ordering::SeqCst)
                    }
                    _ => true,
                };
                if shared.shutdown.load(Ordering::SeqCst)
                    && q.is_empty()
                    && b.is_idle()
                    && phase_done
                {
                    if let (Some(ph), PhaseRole::Prefill) = (&phase, role) {
                        ph.prefill_live.store(false, Ordering::SeqCst);
                        shared.work.notify_all();
                    }
                    return Vec::new();
                }
                let fed = match (&phase, role) {
                    (Some(ph), PhaseRole::Decode) => !lock(&ph.handoff).is_empty(),
                    _ => !q.is_empty(),
                };
                if !b.is_idle() || fed {
                    break;
                }
                let (qq, _) = shared
                    .work
                    .wait_timeout(q, Duration::from_millis(20))
                    .unwrap_or_else(PoisonError::into_inner);
                q = qq;
            }
            // per-round observables + admission between decode rounds
            // (the decode side of a phase pair never admits fresh
            // requests — everything it serves arrives via the handoff)
            lock(&shared.metrics).queue_depth.record(q.len() as f64);
            while role != PhaseRole::Decode && b.has_capacity() {
                if let Some(pair) = &pair {
                    if !pair.may_admit(is_dev, ratio) {
                        break; // the twin is owed this request
                    }
                }
                let Some((class, p)) = q.pop() else { break };
                shared.space.notify_all();
                let before = b.admitted();
                if let Err(p) = b.admit(p) {
                    q.push_front(class, p);
                    break;
                }
                if b.admitted() > before {
                    if let Some(pair) = &pair {
                        pair.note_admitted(is_dev);
                    }
                }
            }
            if let Some(pair) = &pair {
                let free = if is_dev { &pair.dev_free } else { &pair.cpu_free };
                free.store(b.has_capacity(), Ordering::SeqCst);
            }
        }

        // decode side: seat the sessions the prefill twin handed over,
        // then republish how many slots remain for the next handoff
        if let (Some(ph), PhaseRole::Decode) = (&phase, role) {
            let mut moved = 0u64;
            {
                let mut buf = lock(&ph.handoff);
                while b.has_capacity() && !buf.is_empty() {
                    b.adopt(buf.remove(0));
                    moved += 1;
                }
            }
            ph.decode_free.store(b.free_slots(), Ordering::SeqCst);
            if moved > 0 {
                lock(&shared.metrics).handoffs += moved;
            }
        }

        let report = b.step();

        // prefill side: hand prefill-complete sessions to the decode
        // twin, bounded by the free slots it last published (the same
        // capacity rule as fleet::route_handoff)
        if let (Some(ph), PhaseRole::Prefill) = (&phase, role) {
            let n = b.n_prefilled().min(ph.decode_free.load(Ordering::SeqCst));
            if n > 0 {
                let moved = b.take_prefilled(n);
                lock(&ph.handoff).extend(moved);
                shared.work.notify_all();
            }
        }

        // feed the SLO gate's capacity EWMA from every productive round
        if report.decoded_tokens > 0 && report.kernel_secs > 0.0 {
            lock(&shared.slo).observe(report.decoded_tokens, report.kernel_secs);
        }

        if !report.ttft_wall.is_empty() || !report.retired.is_empty() || report.kernel_secs > 0.0 {
            let mut m = lock(&shared.metrics);
            for d in &report.ttft_wall {
                m.ttft.record(d.as_secs_f64());
            }
            for r in &report.retired {
                m.record_request(&r.metrics);
            }
            // bandwidth accounting: every non-empty round contributes its
            // kernel traffic to the fleet-wide achieved-GB/s export
            m.bytes_moved += report.bytes;
            m.kernel_secs += report.kernel_secs;
        }

        // fold this round's measurement into the coordinator's strength
        // table; a result taken under a stale lease epoch is dropped
        // rather than mis-attributed
        if let Some(coord) = &coord {
            if let Some(pair) = &pair {
                // async pair: single-device rounds carry no relative
                // signal on their own — park this side's (wall, tokens)
                // and fold once the twin's round is in too
                if let Some(lease) = b.lease.as_ref() {
                    if report.decoded_tokens > 0 && report.kernel_secs > 0.0 {
                        let mut pr = lock(&pair.round);
                        let slot = if is_dev { &mut pr.dev } else { &mut pr.cpu };
                        *slot = Some((report.kernel_secs, report.decoded_tokens));
                        if let (Some(c), Some(d)) = (pr.cpu, pr.dev) {
                            *pr = PairRound::default();
                            drop(pr);
                            // paired rounds measure decode traffic: fold
                            // into the GEMV row
                            let _ =
                                lock(coord).observe_round(lease, KernelClass::GemvQ4, c, d);
                        }
                    }
                }
            } else if let (Some(lease), Some(res), Some(class)) = (
                b.lease.as_ref(),
                b.engine.rt.last_result.as_ref(),
                b.engine.rt.last_class,
            ) {
                let _ = lock(coord).observe(lease, class, res);
            }
        }
    }
}

/// Protocol error for an arrival bounced by the SLO admission gate.
const SHED_PREDICTED: &str = "shed: predicted SLO violation, low-priority load dropped";
/// Protocol error for a queued request evicted to seat a higher-priority
/// arrival at a saturated queue.
const SHED_PREEMPTED: &str = "shed: preempted by higher-priority arrival";

/// Submit a request to the bounded classed queue, honoring the SLO
/// admission gate and the overflow policy. `Err` hands the request back
/// with the protocol error message the client should see.
fn submit(shared: &Arc<Shared>, pending: Pending) -> Result<(), (Pending, &'static str)> {
    let mut pending = pending;
    let mut q = lock(&shared.queue);
    // SLO-aware shed: a sheddable class whose predicted queue-drain delay
    // already busts a higher-priority TTFT target is bounced up front,
    // before it can queue ahead of work with an SLO
    let backlog: f64 = q
        .iter()
        .map(|(_, p)| (p.req.prompt.len() + p.req.max_new_tokens) as f64)
        .sum();
    if lock(&shared.slo).should_shed(&shared.policy, pending.class, backlog) {
        return Err((pending, SHED_PREDICTED));
    }
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return Err((pending, "server shutting down"));
        }
        match q.try_push(pending.class, pending) {
            Ok(()) => {
                shared.work.notify_all();
                return Ok(());
            }
            Err(p) => {
                // a saturated queue makes room for a higher-priority
                // arrival by shedding the newest lowest-priority request
                if let Some((_, victim)) = q.evict_lower(p.class) {
                    let _ = victim.tx.send(Event::Error {
                        id: victim.req.id,
                        msg: SHED_PREEMPTED.into(),
                    });
                    lock(&shared.metrics).shed_requests += 1;
                    return match q.try_push(p.class, p) {
                        Ok(()) => {
                            shared.work.notify_all();
                            Ok(())
                        }
                        Err(p) => Err((p, "admission queue full")),
                    };
                }
                match shared.policy.on_full {
                    AdmissionPolicy::Reject => return Err((p, "admission queue full")),
                    AdmissionPolicy::Block => {
                        pending = p;
                        let (qq, _) = shared
                            .space
                            .wait_timeout(q, Duration::from_millis(50))
                            .unwrap_or_else(PoisonError::into_inner);
                        q = qq;
                    }
                }
            }
        }
    }
}

/// Accept loop. Handler threads are tracked and reaped as they finish, and
/// every live handler is joined before the loop thread exits — shutdown
/// can no longer race a handler still holding its stream.
fn spawn_accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    events: Option<mpsc::Sender<ConnEvent>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_conn: StreamId = 0;
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let shared2 = Arc::clone(&shared);
                    let ev = events.clone();
                    let conn = next_conn;
                    next_conn += 1;
                    handlers.push(std::thread::spawn(move || {
                        let _ = handle_client(stream, &shared2, conn, ev.as_ref());
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => break,
            }
            handlers.retain(|h| !h.is_finished());
        }
        for h in handlers {
            let _ = h.join();
        }
    })
}

fn handle_client(
    stream: TcpStream,
    shared: &Arc<Shared>,
    conn: StreamId,
    events: Option<&mpsc::Sender<ConnEvent>>,
) -> std::io::Result<()> {
    let mut connected = false;
    let res = client_loop(stream, shared, conn, events, &mut connected);
    if connected {
        if let Some(ev) = events {
            let _ = ev.send(ConnEvent::Disconnect(conn));
        }
    }
    res
}

fn client_loop(
    stream: TcpStream,
    shared: &Arc<Shared>,
    conn: StreamId,
    events: Option<&mpsc::Sender<ConnEvent>>,
    connected: &mut bool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_client_line(line.trim()) {
            Ok(ClientMessage::Metrics) => {
                let snap = lock(&shared.metrics).to_json(
                    shared.n_engines.load(Ordering::SeqCst),
                    shared.epoch.load(Ordering::SeqCst),
                );
                writeln!(writer, "{}", Json::obj(vec![("metrics", snap)]).dump())?;
            }
            Ok(ClientMessage::Generate { req, class }) => {
                // a connection becomes a coordinator stream on its first
                // request — metrics-only probes never grow the lease set
                if let Some(ev) = events {
                    if !*connected {
                        *connected = true;
                        let _ = ev.send(ConnEvent::Connect(conn));
                    }
                }
                let id = req.id;
                // every offered arrival feeds the router's decision window
                // — shed or admitted, the router reasons about offered load
                if let Some(r) = lock(&shared.router).as_mut() {
                    r.note_arrival(req.prompt.len(), req.max_new_tokens);
                }
                let (tx, rx) = mpsc::channel();
                let pending = Pending { req, tx, class, enqueued: Some(Instant::now()) };
                match submit(shared, pending) {
                    Ok(()) => {
                        // stream responses for this request until done/error
                        for msg in rx {
                            let fin = msg.is_final();
                            writeln!(writer, "{}", msg.line())?;
                            if fin {
                                break;
                            }
                        }
                    }
                    Err((_, reason)) => {
                        // distinguish backpressure from a shutdown race —
                        // only real saturation/shedding counts against the
                        // admission metrics
                        let msg = if shared.shutdown.load(Ordering::SeqCst) {
                            "server shutting down"
                        } else {
                            let mut m = lock(&shared.metrics);
                            if reason.starts_with("shed") {
                                m.shed_requests += 1;
                            } else {
                                m.rejected += 1;
                            }
                            reason
                        };
                        writeln!(writer, "{}", protocol::error_line(id, msg))?;
                    }
                }
            }
            Err(e) => {
                writeln!(writer, "{}", protocol::error_line(0, &e))?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::sim::{SimConfig, SimExecutor};

    fn test_engine() -> Engine<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 3));
        let exec = SimExecutor::new(
            presets::ultra_125h(),
            SimConfig { execute_real: true, ..SimConfig::noiseless() },
        );
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
    }

    fn send_request(addr: std::net::SocketAddr, line: &str) -> Vec<Json> {
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, "{line}").unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = Vec::new();
        for l in reader.lines() {
            let l = match l {
                Ok(l) => l,
                Err(_) => break,
            };
            let v = Json::parse(&l).unwrap();
            let fin =
                v.get("done").is_some() || v.get("error").is_some() || v.get("metrics").is_some();
            out.push(v);
            if fin {
                break;
            }
        }
        out
    }

    #[test]
    fn serves_generation_request() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let msgs =
            send_request(handle.addr, r#"{"id": 1, "prompt": [1,2,3], "max_new_tokens": 4}"#);
        let tokens: Vec<&Json> = msgs.iter().filter(|m| m.get("token").is_some()).collect();
        assert_eq!(tokens.len(), 4);
        let done = msgs.last().unwrap();
        assert_eq!(done.get("done"), Some(&Json::Bool(true)));
        assert!(done.get("prefill_secs").unwrap().as_f64().unwrap() > 0.0);
        handle.shutdown();
    }

    #[test]
    fn generation_is_deterministic_across_requests() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let get_tokens = |id: u64| {
            let msgs = send_request(
                handle.addr,
                &format!(r#"{{"id": {id}, "prompt": [5,6], "max_new_tokens": 5}}"#),
            );
            msgs.iter()
                .filter_map(|m| m.get("token").and_then(Json::as_i64))
                .collect::<Vec<i64>>()
        };
        assert_eq!(get_tokens(1), get_tokens(2));
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients_are_batched() {
        let opts = ServerOpts { max_batch: 4, ..Default::default() };
        let handle = serve("127.0.0.1:0", test_engine(), opts).unwrap();
        let addr = handle.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    send_request(
                        addr,
                        &format!(r#"{{"id": {i}, "prompt": [{i}, 2], "max_new_tokens": 3}}"#),
                    )
                })
            })
            .collect();
        for h in handles {
            let msgs = h.join().unwrap();
            assert!(msgs.iter().any(|m| m.get("done").is_some()));
            assert_eq!(msgs.iter().filter(|m| m.get("token").is_some()).count(), 3);
        }
        let metrics = send_request(addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        assert_eq!(m.get("requests").unwrap().as_i64(), Some(4));
        // continuous batching exports its two new observables
        assert!(m.get("ttft_p50_secs").unwrap().as_f64().unwrap() > 0.0);
        assert!(m.get("queue_depth_p50").is_some());
        handle.shutdown();
    }

    #[test]
    fn multi_engine_server_matches_single_engine_tokens() {
        use crate::coordinator::{AllocPolicy, Coordinator};
        // two lease-restricted engines over disjoint halves of the machine
        let machine = presets::core_12900k();
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 3));
        let mut coord = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let engines: Vec<Engine<SimExecutor>> = coord
            .leases()
            .map(|l| {
                let exec = l.sim_executor(
                    &machine,
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    Box::new(DynamicScheduler),
                    PerfConfig::default(),
                )
            })
            .collect();
        assert_eq!(engines.len(), 2);
        let multi_opts = ServerOpts { max_batch: 2, ..Default::default() };
        let multi = serve_multi("127.0.0.1:0", engines, multi_opts).unwrap();
        let single = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        // same weights + same prompt → identical tokens no matter which
        // lease's engine serves the request (partitioning never changes
        // the numbers, only the timing)
        let req = r#"{"id": 1, "prompt": [4, 2], "max_new_tokens": 5}"#;
        let toks = |msgs: &[Json]| {
            msgs.iter().filter_map(|m| m.get("token").and_then(Json::as_i64)).collect::<Vec<_>>()
        };
        let a = toks(&send_request(multi.addr, req));
        let b = toks(&send_request(single.addr, req));
        assert_eq!(a.len(), 5);
        assert_eq!(a, b);
        let metrics = send_request(multi.addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        assert_eq!(m.get("engines").unwrap().as_i64(), Some(2));
        multi.shutdown();
        single.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let msgs = send_request(handle.addr, r#"{"id": 1}"#);
        assert!(msgs[0].get("error").is_some());
        let msgs = send_request(handle.addr, r#"{"id": 2, "prompt": [1], "max_new_tokens": 1}"#);
        assert!(msgs.iter().any(|m| m.get("done").is_some() || m.get("error").is_some()));
        handle.shutdown();
    }

    #[test]
    fn too_long_prompt_rejected() {
        let engine = test_engine();
        let t_max = engine.cfg.t_max;
        let handle = serve("127.0.0.1:0", engine, ServerOpts::default()).unwrap();
        let prompt: Vec<String> = (0..t_max + 1).map(|i| i.to_string()).collect();
        let msgs = send_request(
            handle.addr,
            &format!(r#"{{"id": 9, "prompt": [{}], "max_new_tokens": 1}}"#, prompt.join(",")),
        );
        assert!(msgs[0].get("error").is_some());
        handle.shutdown();
    }

    #[test]
    fn saturated_queue_returns_protocol_error() {
        // depth 0: every generate request finds the queue full — the
        // deterministic worst case of saturation. The server answers with
        // a protocol error instead of growing memory.
        let opts = ServerOpts {
            queue_depth: 0,
            on_full: AdmissionPolicy::Reject,
            ..Default::default()
        };
        let handle = serve("127.0.0.1:0", test_engine(), opts).unwrap();
        let msgs = send_request(handle.addr, r#"{"id": 3, "prompt": [1], "max_new_tokens": 2}"#);
        assert_eq!(
            msgs[0].get("error").and_then(Json::as_str),
            Some("admission queue full")
        );
        let metrics = send_request(handle.addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        assert_eq!(m.get("rejected").unwrap().as_i64(), Some(1));
        assert_eq!(m.get("requests").unwrap().as_i64(), Some(0));
        handle.shutdown();
    }

    #[test]
    fn poisoned_shared_mutexes_do_not_cascade() {
        // regression: a panicking handler used to poison `queue`/`metrics`
        // and every other thread's `lock().unwrap()` then panicked in
        // cascade, deadlocking shutdown. The recover-guards keep serving.
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let shared = Arc::clone(&handle.shared);
        let panicker = std::thread::spawn(move || {
            let _q = shared.queue.lock().unwrap();
            let _m = shared.metrics.lock().unwrap();
            panic!("injected handler panic");
        });
        assert!(panicker.join().is_err());
        assert!(handle.shared.queue.lock().is_err(), "queue mutex should be poisoned");
        // the server must still serve a full request through the poisoned
        // locks and then shut down cleanly (joining every thread)
        let msgs =
            send_request(handle.addr, r#"{"id": 7, "prompt": [1,2], "max_new_tokens": 3}"#);
        assert_eq!(msgs.iter().filter(|m| m.get("token").is_some()).count(), 3);
        assert!(msgs.iter().any(|m| m.get("done").is_some()));
        handle.shutdown();
    }

    #[test]
    fn block_policy_serves_everyone_through_a_tiny_queue() {
        let opts = ServerOpts {
            max_batch: 1,
            queue_depth: 1,
            on_full: AdmissionPolicy::Block,
            ..Default::default()
        };
        let handle = serve("127.0.0.1:0", test_engine(), opts).unwrap();
        let addr = handle.addr;
        let joins: Vec<_> = (0..5)
            .map(|i| {
                std::thread::spawn(move || {
                    send_request(
                        addr,
                        &format!(r#"{{"id": {i}, "prompt": [{i}], "max_new_tokens": 2}}"#),
                    )
                })
            })
            .collect();
        for j in joins {
            let msgs = j.join().unwrap();
            assert!(msgs.iter().any(|m| m.get("done").is_some()), "{msgs:?}");
        }
        let metrics = send_request(addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        assert_eq!(m.get("requests").unwrap().as_i64(), Some(5));
        assert_eq!(m.get("rejected").unwrap().as_i64(), Some(0));
        handle.shutdown();
    }
}
