//! Threaded TCP serving front-end: request router + dynamic batcher over
//! one or more [`Engine`]s.
//!
//! Client handlers parse JSON-lines requests into a shared admission
//! queue; each engine runs on its own thread, draining the queue in
//! batches (up to `max_batch`), prefilling each request, then interleaving
//! decode steps round-robin across its batch, streaming tokens back as
//! they are produced. The perf-ratio table lives in each engine and keeps
//! adapting across requests — exactly the paper's "quickly adapt …
//! whether during program startup or when there are sudden changes"
//! property, surfaced as a service.
//!
//! With [`serve`] a single engine owns every core (the seed behavior).
//! With [`serve_multi`] the server runs one engine **per coordinator
//! lease** ([`crate::coordinator`]): each engine's executor is restricted
//! to its leased core subset, and admission is effectively round-robin —
//! whichever lease's engine goes idle first claims the next waiting
//! requests — so concurrent streams decode in parallel on disjoint cores
//! instead of serializing through one all-core engine.

pub mod protocol;

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::engine::Engine;
use crate::exec::Executor;
use crate::metrics::LatencyHistogram;
use crate::model::argmax;
use crate::util::json::Json;

use protocol::{ClientMessage, Request};

#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    pub max_batch: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts { max_batch: 4 }
    }
}

struct Pending {
    req: Request,
    tx: mpsc::Sender<String>,
}

#[derive(Default)]
struct ServerMetrics {
    requests: u64,
    tokens: u64,
    prefill: LatencyHistogram,
    decode_per_token: LatencyHistogram,
}

impl ServerMetrics {
    fn to_json(&self, n_engines: usize) -> Json {
        let mut fields = vec![
            ("requests", Json::num(self.requests as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("engines", Json::num(n_engines as f64)),
        ];
        if let Some(s) = self.prefill.summary() {
            fields.push(("prefill_p50_secs", Json::num(s.p50)));
        }
        if let Some(s) = self.decode_per_token.summary() {
            fields.push(("decode_p50_secs_per_token", Json::num(s.p50)));
        }
        Json::obj(fields)
    }
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Mutex<ServerMetrics>,
    /// engine threads draining the queue (1 = classic single-engine server)
    n_engines: usize,
}

/// A running server; dropping the handle shuts it down.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving `engine` on `addr` (e.g. "127.0.0.1:0" for an ephemeral
/// port). The engine runs on its own thread; handlers are per-connection.
pub fn serve<E: Executor + Send + 'static>(
    addr: &str,
    engine: Engine<E>,
    opts: ServerOpts,
) -> std::io::Result<ServerHandle> {
    serve_multi(addr, vec![engine], opts)
}

/// Start serving a fleet of engines — typically one per coordinator lease,
/// each restricted to a disjoint core subset — on `addr`. Every engine
/// gets its own batcher thread; all of them drain one shared admission
/// queue, so the first idle engine claims the next waiting requests
/// (round-robin admission under sustained load).
pub fn serve_multi<E: Executor + Send + 'static>(
    addr: &str,
    engines: Vec<Engine<E>>,
    opts: ServerOpts,
) -> std::io::Result<ServerHandle> {
    assert!(!engines.is_empty(), "need at least one engine");
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let bound = listener.local_addr()?;
    let shared = Arc::new(Shared {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        shutdown: AtomicBool::new(false),
        metrics: Mutex::new(ServerMetrics::default()),
        n_engines: engines.len(),
    });

    let mut threads = Vec::new();

    // ---- engine/batcher threads (one per lease) ----
    for mut engine in engines {
        let shared = Arc::clone(&shared);
        threads.push(std::thread::spawn(move || loop {
            let batch: Vec<Pending> = {
                let mut q = shared.queue.lock().unwrap();
                while q.is_empty() && !shared.shutdown.load(Ordering::SeqCst) {
                    let (qq, _) = shared.cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
                    q = qq;
                }
                if q.is_empty() && shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let take = opts.max_batch.min(q.len());
                q.drain(..take).collect()
            };
            run_batch(&mut engine, &shared, batch);
        }));
    }

    // ---- accept loop ----
    {
        let shared = Arc::clone(&shared);
        threads.push(std::thread::spawn(move || loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let shared = Arc::clone(&shared);
                    // handlers are detached; they exit when the client
                    // disconnects or shutdown flips
                    std::thread::spawn(move || {
                        let _ = handle_client(stream, &shared);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => return,
            }
        }));
    }

    Ok(ServerHandle { addr: bound, shared, threads })
}

/// Prefill every request, then interleave decode rounds across the batch.
fn run_batch<E: Executor>(engine: &mut Engine<E>, shared: &Arc<Shared>, batch: Vec<Pending>) {
    struct Active {
        pending: Pending,
        session: crate::model::Session,
        next: u32,
        produced: usize,
        metrics: crate::metrics::PhaseMetrics,
        dead: bool,
    }

    let vocab = engine.cfg.vocab as u32;
    let mut active: Vec<Active> = Vec::new();
    for pending in batch {
        let mut session = engine.new_session();
        let prompt: Vec<u32> = pending.req.prompt.iter().map(|&t| t % vocab).collect();
        let capacity = engine.cfg.t_max;
        if prompt.len() >= capacity {
            let _ = pending.tx.send(protocol::error_line(pending.req.id, "prompt too long"));
            continue;
        }
        let t0 = engine.kernel_secs;
        let logits = engine.prefill(&mut session, &prompt);
        let mut metrics = crate::metrics::PhaseMetrics {
            prompt_tokens: prompt.len(),
            ..Default::default()
        };
        metrics.prefill_secs = engine.kernel_secs - t0;
        let next = argmax(&logits);
        active.push(Active { pending, session, next, produced: 0, metrics, dead: false });
    }

    // round-robin decode
    loop {
        let mut progressed = false;
        for a in active.iter_mut() {
            if a.dead
                || a.produced >= a.pending.req.max_new_tokens
                || a.session.remaining_capacity(&engine.cfg) == 0
            {
                continue;
            }
            let token = a.next;
            if a.pending.tx.send(protocol::token_line(a.pending.req.id, token)).is_err() {
                a.dead = true; // client went away; stop decoding for it
                continue;
            }
            let t0 = engine.kernel_secs;
            let logits = engine.decode_step(&mut a.session, token);
            a.metrics.decode_secs += engine.kernel_secs - t0;
            a.next = argmax(&logits);
            a.produced += 1;
            a.metrics.decoded_tokens += 1;
            progressed = true;
        }
        if !progressed {
            break;
        }
    }

    let mut m = shared.metrics.lock().unwrap();
    for a in &active {
        if !a.dead {
            let _ = a.pending.tx.send(protocol::done_line(a.pending.req.id, &a.metrics));
        }
        m.requests += 1;
        m.tokens += a.produced as u64;
        m.prefill.record(a.metrics.prefill_secs);
        if a.metrics.decoded_tokens > 0 {
            m.decode_per_token.record(a.metrics.decode_latency());
        }
    }
}

fn handle_client(stream: TcpStream, shared: &Arc<Shared>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_client_line(line.trim()) {
            Ok(ClientMessage::Metrics) => {
                let snap = shared.metrics.lock().unwrap().to_json(shared.n_engines);
                writeln!(writer, "{}", Json::obj(vec![("metrics", snap)]).dump())?;
            }
            Ok(ClientMessage::Generate(req)) => {
                let (tx, rx) = mpsc::channel();
                {
                    let mut q = shared.queue.lock().unwrap();
                    q.push_back(Pending { req, tx });
                    shared.cv.notify_all();
                }
                // stream responses for this request until done/error
                for msg in rx {
                    let is_final = msg.contains("\"done\"") || msg.contains("\"error\"");
                    writeln!(writer, "{msg}")?;
                    if is_final {
                        break;
                    }
                }
            }
            Err(e) => {
                writeln!(writer, "{}", protocol::error_line(0, &e))?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::sim::{SimConfig, SimExecutor};

    fn test_engine() -> Engine<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 3));
        let exec = SimExecutor::new(
            presets::ultra_125h(),
            SimConfig { execute_real: true, ..SimConfig::noiseless() },
        );
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
    }

    fn send_request(addr: std::net::SocketAddr, line: &str) -> Vec<Json> {
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, "{line}").unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = Vec::new();
        for l in reader.lines() {
            let l = match l {
                Ok(l) => l,
                Err(_) => break,
            };
            let v = Json::parse(&l).unwrap();
            let fin = v.get("done").is_some() || v.get("error").is_some() || v.get("metrics").is_some();
            out.push(v);
            if fin {
                break;
            }
        }
        out
    }

    #[test]
    fn serves_generation_request() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let msgs =
            send_request(handle.addr, r#"{"id": 1, "prompt": [1,2,3], "max_new_tokens": 4}"#);
        let tokens: Vec<&Json> = msgs.iter().filter(|m| m.get("token").is_some()).collect();
        assert_eq!(tokens.len(), 4);
        let done = msgs.last().unwrap();
        assert_eq!(done.get("done"), Some(&Json::Bool(true)));
        assert!(done.get("prefill_secs").unwrap().as_f64().unwrap() > 0.0);
        handle.shutdown();
    }

    #[test]
    fn generation_is_deterministic_across_requests() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let get_tokens = |id: u64| {
            let msgs = send_request(
                handle.addr,
                &format!(r#"{{"id": {id}, "prompt": [5,6], "max_new_tokens": 5}}"#),
            );
            msgs.iter()
                .filter_map(|m| m.get("token").and_then(Json::as_i64))
                .collect::<Vec<i64>>()
        };
        assert_eq!(get_tokens(1), get_tokens(2));
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients_are_batched() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts { max_batch: 4 }).unwrap();
        let addr = handle.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    send_request(
                        addr,
                        &format!(r#"{{"id": {i}, "prompt": [{i}, 2], "max_new_tokens": 3}}"#),
                    )
                })
            })
            .collect();
        for h in handles {
            let msgs = h.join().unwrap();
            assert!(msgs.iter().any(|m| m.get("done").is_some()));
            assert_eq!(msgs.iter().filter(|m| m.get("token").is_some()).count(), 3);
        }
        let metrics = send_request(addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        assert_eq!(m.get("requests").unwrap().as_i64(), Some(4));
        handle.shutdown();
    }

    #[test]
    fn multi_engine_server_matches_single_engine_tokens() {
        use crate::coordinator::{AllocPolicy, Coordinator};
        // two lease-restricted engines over disjoint halves of the machine
        let machine = presets::core_12900k();
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 3));
        let mut coord = Coordinator::new(machine.clone(), AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let engines: Vec<Engine<SimExecutor>> = coord
            .leases()
            .map(|l| {
                let exec = l.sim_executor(
                    &machine,
                    SimConfig { execute_real: true, ..SimConfig::noiseless() },
                );
                Engine::new(
                    cfg.clone(),
                    Arc::clone(&weights),
                    exec,
                    Box::new(DynamicScheduler),
                    PerfConfig::default(),
                )
            })
            .collect();
        assert_eq!(engines.len(), 2);
        let multi = serve_multi("127.0.0.1:0", engines, ServerOpts { max_batch: 2 }).unwrap();
        let single = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        // same weights + same prompt → identical tokens no matter which
        // lease's engine serves the request (partitioning never changes
        // the numbers, only the timing)
        let req = r#"{"id": 1, "prompt": [4, 2], "max_new_tokens": 5}"#;
        let toks = |msgs: &[Json]| {
            msgs.iter().filter_map(|m| m.get("token").and_then(Json::as_i64)).collect::<Vec<_>>()
        };
        let a = toks(&send_request(multi.addr, req));
        let b = toks(&send_request(single.addr, req));
        assert_eq!(a.len(), 5);
        assert_eq!(a, b);
        let metrics = send_request(multi.addr, r#"{"cmd":"metrics"}"#);
        let m = metrics[0].get("metrics").unwrap();
        assert_eq!(m.get("engines").unwrap().as_i64(), Some(2));
        multi.shutdown();
        single.shutdown();
    }

    #[test]
    fn bad_requests_get_errors() {
        let handle = serve("127.0.0.1:0", test_engine(), ServerOpts::default()).unwrap();
        let msgs = send_request(handle.addr, r#"{"id": 1}"#);
        assert!(msgs[0].get("error").is_some());
        let msgs = send_request(handle.addr, r#"{"id": 2, "prompt": [1], "max_new_tokens": 1}"#);
        assert!(msgs.iter().any(|m| m.get("done").is_some() || m.get("error").is_some()));
        handle.shutdown();
    }

    #[test]
    fn too_long_prompt_rejected() {
        let engine = test_engine();
        let t_max = engine.cfg.t_max;
        let handle = serve("127.0.0.1:0", engine, ServerOpts::default()).unwrap();
        let prompt: Vec<String> = (0..t_max + 1).map(|i| i.to_string()).collect();
        let msgs = send_request(
            handle.addr,
            &format!(r#"{{"id": 9, "prompt": [{}], "max_new_tokens": 1}}"#, prompt.join(",")),
        );
        assert!(msgs[0].get("error").is_some());
        handle.shutdown();
    }
}
