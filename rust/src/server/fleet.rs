//! Fleet lifecycle: one [`LeaseBatcher`] per non-empty coordinator lease,
//! rebuilt on every epoch change (stream admit/finish or rebalance), with
//! in-flight requests migrating onto the new fleet.
//!
//! Sessions carry the KV state with the request, so a migrated stream
//! resumes decoding on its new lease's cores with bit-identical tokens —
//! partitioning only ever changes timing, never values. These helpers are
//! shared by the threaded TCP server ([`super::serve_dynamic`]) and the
//! deterministic harness ([`super::testing`]), so the lifecycle under test
//! is the lifecycle in production.

use crate::coordinator::{Coordinator, Lease};
use crate::engine::Engine;
use crate::exec::Executor;

use super::batcher::{ActiveRequest, BatcherOpts, LeaseBatcher};

/// Builds an engine for a lease. The serving layer owns *when* engines are
/// rebuilt (epoch changes); the factory owns *how* (executor choice,
/// shared weights, scheduler, perf config).
pub type EngineFactory<E> = Box<dyn Fn(&Lease) -> Engine<E> + Send>;

/// One batcher per non-empty lease of the coordinator's current epoch.
/// (Empty leases — more streams than cores — wait for capacity and get no
/// engine.)
pub fn build_batchers<E: Executor>(
    coord: &Coordinator,
    factory: &EngineFactory<E>,
    opts: BatcherOpts,
) -> Vec<LeaseBatcher<E>> {
    coord
        .leases()
        .filter(|l| !l.is_empty())
        .map(|l| LeaseBatcher::new(factory(l), Some(l.clone()), opts))
        .collect()
}

/// Spread carried-over in-flight requests across a fresh fleet, always
/// onto the least-loaded batcher. With an empty fleet (every stream gone)
/// the carried requests are dropped — their clients are gone too, so every
/// pending send would fail anyway.
pub fn distribute<E: Executor>(carried: Vec<ActiveRequest>, batchers: &mut [LeaseBatcher<E>]) {
    if batchers.is_empty() {
        return;
    }
    for a in carried {
        let target = batchers.iter_mut().min_by_key(|b| b.n_active()).unwrap();
        target.adopt(a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AllocPolicy;
    use crate::cpu::presets;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::server::batcher::Pending;
    use crate::server::protocol::Request;
    use crate::sim::{SimConfig, SimExecutor};
    use std::sync::Arc;

    fn factory() -> EngineFactory<SimExecutor> {
        let machine = presets::core_12900k();
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
        Box::new(move |lease: &Lease| {
            let exec = lease.sim_executor(
                &machine,
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        })
    }

    #[test]
    fn one_batcher_per_nonempty_lease() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let batchers = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(batchers.len(), 2);
        for b in &batchers {
            let lease = b.lease.as_ref().unwrap();
            assert_eq!(lease.epoch, coord.epoch());
            assert_eq!(b.engine.rt.exec.sim.spec.n_cores(), lease.n_cores());
        }
    }

    #[test]
    fn migration_preserves_in_flight_streams() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 1);

        // solo oracle for the full request
        let solo_lease = coord.lease(0).unwrap().clone();
        let mut oracle = f(&solo_lease);
        let mut s = oracle.new_session();
        let (expect, _) = oracle.generate(&mut s, &[4, 2, 7], 8);

        // start the request, run part of it, then rebuild mid-flight
        let (tx, rx) = std::sync::mpsc::channel();
        let req = Request { id: 1, prompt: vec![4, 2, 7], max_new_tokens: 8 };
        fleet[0].admit(Pending::new(req, tx)).map_err(|_| ()).unwrap();
        for _ in 0..4 {
            fleet[0].step();
        }
        let carried: Vec<ActiveRequest> =
            fleet.iter_mut().flat_map(|b| b.take_actives()).collect();
        assert_eq!(carried.len(), 1);
        coord.admit(1); // epoch change: fleet is rebuilt on halved leases
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 2);
        distribute(carried, &mut fleet);
        assert_eq!(fleet.iter().map(|b| b.n_active()).sum::<usize>(), 1);

        let mut guard = 0;
        while fleet.iter().any(|b| !b.is_idle()) {
            for b in fleet.iter_mut() {
                if !b.is_idle() {
                    b.step();
                }
            }
            guard += 1;
            assert!(guard < 10_000);
        }
        let tokens: Vec<u32> = rx
            .try_iter()
            .filter_map(|e| match e {
                crate::server::protocol::Event::Token { token, .. } => Some(token),
                _ => None,
            })
            .collect();
        assert_eq!(tokens, expect, "migrated stream diverged from solo run");
    }

    #[test]
    fn empty_fleet_drops_carried_requests() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        let (tx, _rx) = std::sync::mpsc::channel();
        let req = Request { id: 1, prompt: vec![1], max_new_tokens: 2 };
        fleet[0].admit(Pending::new(req, tx)).map_err(|_| ()).unwrap();
        fleet[0].step();
        let carried: Vec<ActiveRequest> =
            fleet.iter_mut().flat_map(|b| b.take_actives()).collect();
        coord.finish(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert!(fleet.is_empty());
        distribute(carried, &mut fleet); // no panic, requests dropped
    }
}
