//! Fleet lifecycle: one [`LeaseBatcher`] per non-empty coordinator lease,
//! rebuilt on every epoch change (stream admit/finish or rebalance), with
//! in-flight requests migrating onto the new fleet.
//!
//! Sessions carry the KV state with the request, so a migrated stream
//! resumes decoding on its new lease's cores with bit-identical tokens —
//! partitioning only ever changes timing, never values. These helpers —
//! including the [`DriftMonitor`] that closes the observe→rebalance loop —
//! are shared by the threaded TCP server ([`super::serve_dynamic`]) and
//! the deterministic harness ([`super::testing`]), so the lifecycle under
//! test is the lifecycle in production.

use crate::coordinator::{Coordinator, Lease};
use crate::engine::Engine;
use crate::exec::Executor;

use super::batcher::{ActiveRequest, BatcherOpts, LeaseBatcher};

/// Decides when learned strength drift warrants a live `rebalance()` +
/// fleet rebuild. The signal is [`Coordinator::strength_skew`] — how far
/// same-kind units have drifted apart *across* leases — gated by a
/// cooldown of accepted observations since the last epoch change, so a
/// fresh partition gets to learn before it can be torn down again.
#[derive(Clone, Debug)]
pub struct DriftMonitor {
    /// skew ratio that triggers a rebalance (`f64::INFINITY` disables)
    pub threshold: f64,
    /// accepted observations required since the last epoch change
    pub cooldown: u64,
    last_epoch: u64,
    obs_at_epoch: u64,
}

impl DriftMonitor {
    pub fn new(threshold: f64, cooldown: u64) -> DriftMonitor {
        assert!(threshold >= 1.0, "skew is a max/min ratio; threshold < 1 always fires");
        DriftMonitor { threshold, cooldown, last_epoch: 0, obs_at_epoch: 0 }
    }

    /// A monitor that never fires (cores-only static behavior).
    pub fn disabled() -> DriftMonitor {
        DriftMonitor::new(f64::INFINITY, 0)
    }

    /// When the coordinator's learned strengths have skewed past the
    /// threshold — with at least `cooldown` observations folded in since
    /// the last epoch change — returns the measured skew; `None`
    /// otherwise. Call from the serving loop; on `Some` the caller runs
    /// `rebalance()` and rebuilds the fleet (the epoch bump restarts the
    /// cooldown automatically), recording the returned skew if it keeps
    /// trigger observability (the skew is measured exactly once here).
    pub fn check_drift(&mut self, coord: &Coordinator) -> Option<f64> {
        if coord.epoch() != self.last_epoch {
            self.last_epoch = coord.epoch();
            self.obs_at_epoch = coord.observations();
        }
        if coord.n_streams() < 2 || coord.observations() - self.obs_at_epoch < self.cooldown {
            return None;
        }
        let skew = coord.strength_skew();
        (skew > self.threshold).then_some(skew)
    }
}

/// Builds an engine for a lease. The serving layer owns *when* engines are
/// rebuilt (epoch changes); the factory owns *how* (executor choice,
/// shared weights, scheduler, perf config).
pub type EngineFactory<E> = Box<dyn Fn(&Lease) -> Engine<E> + Send>;

/// One batcher per non-empty lease of the coordinator's current epoch.
/// (Empty leases — more streams than cores — wait for capacity and get no
/// engine.)
pub fn build_batchers<E: Executor>(
    coord: &Coordinator,
    factory: &EngineFactory<E>,
    opts: BatcherOpts,
) -> Vec<LeaseBatcher<E>> {
    coord
        .leases()
        .filter(|l| !l.is_empty())
        .map(|l| LeaseBatcher::new(factory(l), Some(l.clone()), opts))
        .collect()
}

/// Spread carried-over in-flight requests across a fresh fleet, always
/// onto the least-loaded batcher. With an empty fleet (every stream gone)
/// the carried requests are dropped — their clients are gone too, so every
/// pending send would fail anyway.
pub fn distribute<E: Executor>(carried: Vec<ActiveRequest>, batchers: &mut [LeaseBatcher<E>]) {
    if batchers.is_empty() {
        return;
    }
    for a in carried {
        let target = batchers.iter_mut().min_by_key(|b| b.n_active()).unwrap();
        target.adopt(a);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AllocPolicy;
    use crate::cpu::presets;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::server::batcher::Pending;
    use crate::server::protocol::Request;
    use crate::sim::{SimConfig, SimExecutor};
    use std::sync::Arc;

    fn factory() -> EngineFactory<SimExecutor> {
        let machine = presets::core_12900k();
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
        Box::new(move |lease: &Lease| {
            let exec = lease.sim_executor(
                &machine,
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        })
    }

    #[test]
    fn one_batcher_per_nonempty_lease() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let batchers = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(batchers.len(), 2);
        for b in &batchers {
            let lease = b.lease.as_ref().unwrap();
            assert_eq!(lease.epoch, coord.epoch());
            assert_eq!(b.engine.rt.exec.sim.spec.n_cores(), lease.n_cores());
        }
    }

    #[test]
    fn migration_preserves_in_flight_streams() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 1);

        // solo oracle for the full request
        let solo_lease = coord.lease(0).unwrap().clone();
        let mut oracle = f(&solo_lease);
        let mut s = oracle.new_session();
        let (expect, _) = oracle.generate(&mut s, &[4, 2, 7], 8);

        // start the request, run part of it, then rebuild mid-flight
        let (tx, rx) = std::sync::mpsc::channel();
        let req = Request { id: 1, prompt: vec![4, 2, 7], max_new_tokens: 8 };
        fleet[0].admit(Pending::new(req, tx)).map_err(|_| ()).unwrap();
        for _ in 0..4 {
            fleet[0].step();
        }
        let carried: Vec<ActiveRequest> =
            fleet.iter_mut().flat_map(|b| b.take_actives()).collect();
        assert_eq!(carried.len(), 1);
        coord.admit(1); // epoch change: fleet is rebuilt on halved leases
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 2);
        distribute(carried, &mut fleet);
        assert_eq!(fleet.iter().map(|b| b.n_active()).sum::<usize>(), 1);

        let mut guard = 0;
        while fleet.iter().any(|b| !b.is_idle()) {
            for b in fleet.iter_mut() {
                if !b.is_idle() {
                    b.step();
                }
            }
            guard += 1;
            assert!(guard < 10_000);
        }
        let tokens: Vec<u32> = rx
            .try_iter()
            .filter_map(|e| match e {
                crate::server::protocol::Event::Token { token, .. } => Some(token),
                _ => None,
            })
            .collect();
        assert_eq!(tokens, expect, "migrated stream diverged from solo run");
    }

    #[test]
    fn drift_monitor_gates_on_cooldown_and_skew() {
        use crate::cpu::CoreKind;
        use crate::exec::RunResult;
        let machine = presets::core_12900k();
        let mut coord = Coordinator::new(machine, AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let mut mon = DriftMonitor::new(1.25, 3);
        assert!(mon.check_drift(&coord).is_none(), "healthy partition fired");

        // stream 0's P-cores at half rate: skew grows with each observation
        let l0 = coord.lease(0).unwrap().clone();
        let res = RunResult {
            per_core_secs: (0..l0.n_cores())
                .map(|i| {
                    let kind = coord.machine().cores[l0.global_core(i)].kind;
                    let rate = if kind == CoreKind::Performance { 2.649 / 2.0 } else { 1.0 };
                    Some(100.0 / rate)
                })
                .collect(),
            wall_secs: 1.0,
            units_done: vec![100; l0.n_cores()],
        };
        for _ in 0..2 {
            assert!(coord.observe(&l0, &res));
            assert!(mon.check_drift(&coord).is_none(), "fired inside the cooldown");
        }
        assert!(coord.observe(&l0, &res));
        let skew = mon.check_drift(&coord).expect("drift past threshold not detected");
        assert!(skew > 1.25, "reported skew {skew}");

        // the rebalance epoch bump restarts the cooldown: no refire until
        // the fresh partition has folded in its own observations
        coord.rebalance();
        assert!(mon.check_drift(&coord).is_none(), "refired right after rebalance");
    }

    #[test]
    fn empty_fleet_drops_carried_requests() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        let (tx, _rx) = std::sync::mpsc::channel();
        let req = Request { id: 1, prompt: vec![1], max_new_tokens: 2 };
        fleet[0].admit(Pending::new(req, tx)).map_err(|_| ()).unwrap();
        fleet[0].step();
        let carried: Vec<ActiveRequest> =
            fleet.iter_mut().flat_map(|b| b.take_actives()).collect();
        coord.finish(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert!(fleet.is_empty());
        distribute(carried, &mut fleet); // no panic, requests dropped
    }
}
