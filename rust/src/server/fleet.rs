//! Fleet lifecycle: one [`LeaseBatcher`] per non-empty coordinator lease,
//! rebuilt on every epoch change (stream admit/finish or rebalance), with
//! in-flight requests migrating onto the new fleet.
//!
//! Sessions carry the KV state with the request, so a migrated stream
//! resumes decoding on its new lease's cores with bit-identical tokens —
//! partitioning only ever changes timing, never values. These helpers —
//! including the [`DriftMonitor`] that closes the observe→rebalance loop —
//! are shared by the threaded TCP server ([`super::serve_dynamic`]) and
//! the deterministic harness ([`super::testing`]), so the lifecycle under
//! test is the lifecycle in production.
//!
//! A hetero lease whose [`ExecMode`] is `AsyncBatch` materializes as
//! **two** batchers instead of one — a CPU-path engine
//! ([`XpuDispatch::CpuOnly`]) and a device-path engine
//! ([`XpuDispatch::DeviceOnly`]) — running their own batches concurrently
//! on the two halves of the lease. Admissions between the pair are routed
//! by [`route_admission`]: a deterministic deficit rule that tracks the
//! coordinator's live [`split_ratio`](Coordinator::split_ratio) (the
//! learned device share of the lease's strength) without randomness, plus
//! a work-conserving override so a side with free slots never idles while
//! requests queue. `AsyncBatch` wins over the default intra-kernel split
//! when single kernels are too small to amortize the device's launch
//! overhead — decode GEMVs on an NPU — because each side then amortizes
//! its overheads over whole token rounds of its own batch. Migration
//! across epoch rebuilds is unchanged: sessions carry the KV state, so
//! streams stay bit-identical whichever side (or mode) they land on.
//!
//! [`ExecMode::Disaggregated`] also builds a batcher pair per lease, but
//! split by **serving phase** instead of by device: the coordinator's
//! [`phase_leases`](Coordinator::phase_leases) carves the lease into a
//! prefill sub-lease over its GEMM-strong units and a decode sub-lease
//! over its bandwidth-rich remainder, and the fleet dedicates a
//! [`PhaseRole::Prefill`] batcher to the former and a
//! [`PhaseRole::Decode`] batcher to the latter. Admissions always enter
//! the prefill side; [`route_handoff`] moves prefill-complete requests to
//! the decode side through the same session-carrying migration machinery,
//! so the handed-off stream is bit-identical to one served by a single
//! blended batcher.

use crate::coordinator::{Coordinator, ExecMode, Lease};
use crate::engine::Engine;
use crate::exec::Executor;
use crate::sim::xpu::XpuDispatch;

use super::batcher::{ActiveRequest, BatcherOpts, LeaseBatcher, PhaseRole};

/// Decides when learned strength drift warrants a live `rebalance()` +
/// fleet rebuild. The signal is [`Coordinator::strength_skew`] — how far
/// same-kind units have drifted apart *across* leases — gated by a
/// cooldown of accepted observations since the last epoch change, so a
/// fresh partition gets to learn before it can be torn down again.
#[derive(Clone, Debug)]
pub struct DriftMonitor {
    /// skew ratio that triggers a rebalance (`f64::INFINITY` disables)
    pub threshold: f64,
    /// accepted observations required since the last epoch change
    pub cooldown: u64,
    last_epoch: u64,
    obs_at_epoch: u64,
}

impl DriftMonitor {
    pub fn new(threshold: f64, cooldown: u64) -> DriftMonitor {
        assert!(threshold >= 1.0, "skew is a max/min ratio; threshold < 1 always fires");
        DriftMonitor { threshold, cooldown, last_epoch: 0, obs_at_epoch: 0 }
    }

    /// A monitor that never fires (cores-only static behavior).
    pub fn disabled() -> DriftMonitor {
        DriftMonitor::new(f64::INFINITY, 0)
    }

    /// When the coordinator's learned strengths have skewed past the
    /// threshold — with at least `cooldown` observations folded in since
    /// the last epoch change — returns the measured skew; `None`
    /// otherwise. Call from the serving loop; on `Some` the caller runs
    /// `rebalance()` and rebuilds the fleet (the epoch bump restarts the
    /// cooldown automatically), recording the returned skew if it keeps
    /// trigger observability (the skew is measured exactly once here).
    pub fn check_drift(&mut self, coord: &Coordinator) -> Option<f64> {
        self.check_drift_with(coord.epoch(), coord.observations(), coord.n_streams(), || {
            coord.strength_skew()
        })
    }

    /// Generalized drift check for callers that aren't a single
    /// [`Coordinator`] — the cluster tier feeds its own epoch /
    /// observation counters, participant count, and skew measure here so
    /// machine-level drift reuses the exact same cooldown semantics as
    /// core-level drift. `skew` is only evaluated once the epoch and
    /// cooldown gates pass.
    pub fn check_drift_with(
        &mut self,
        epoch: u64,
        observations: u64,
        participants: usize,
        skew: impl FnOnce() -> f64,
    ) -> Option<f64> {
        if epoch != self.last_epoch {
            self.last_epoch = epoch;
            self.obs_at_epoch = observations;
        }
        if participants < 2 || observations - self.obs_at_epoch < self.cooldown {
            return None;
        }
        let skew = skew();
        (skew > self.threshold).then_some(skew)
    }
}

/// Builds an engine for one side of a lease. The serving layer owns *when*
/// engines are rebuilt (epoch changes); the factory owns *how* (executor
/// choice, shared weights, scheduler, perf config). The dispatch argument
/// is `Split` for ordinary leases and `CpuOnly` / `DeviceOnly` for the two
/// engines of an [`ExecMode::AsyncBatch`] pair — cores-only factories can
/// ignore it.
pub type EngineFactory<E> = Box<dyn Fn(&Lease, XpuDispatch) -> Engine<E> + Send>;

/// One batcher per non-empty lease of the coordinator's current epoch —
/// except [`ExecMode::AsyncBatch`] hetero leases, which get a
/// CPU-path/device-path batcher *pair*. (Empty leases — more streams than
/// cores — wait for capacity and get no engine.)
pub fn build_batchers<E: Executor>(
    coord: &Coordinator,
    factory: &EngineFactory<E>,
    opts: BatcherOpts,
) -> Vec<LeaseBatcher<E>> {
    let mut out = Vec::new();
    let d = XpuDispatch::Split;
    for l in coord.leases().filter(|l| !l.is_empty()) {
        if l.mode == ExecMode::AsyncBatch && !l.accels().is_empty() {
            for d in [XpuDispatch::CpuOnly, XpuDispatch::DeviceOnly] {
                out.push(LeaseBatcher::with_dispatch(factory(l, d), Some(l.clone()), opts, d));
            }
        } else if l.mode == ExecMode::Disaggregated {
            match coord.phase_leases(l) {
                Some((pf, dc)) => {
                    // phase pair: each side intra-kernel-splits across its
                    // own sub-lease's units
                    out.push(
                        LeaseBatcher::with_dispatch(factory(&pf, d), Some(pf.clone()), opts, d)
                            .with_role(PhaseRole::Prefill),
                    );
                    out.push(
                        LeaseBatcher::with_dispatch(factory(&dc, d), Some(dc.clone()), opts, d)
                            .with_role(PhaseRole::Decode),
                    );
                }
                // too few cores to disaggregate: serve the lease blended
                None => out.push(LeaseBatcher::with_dispatch(
                    factory(l, d),
                    Some(l.clone()),
                    opts,
                    d,
                )),
            }
        } else {
            out.push(LeaseBatcher::with_dispatch(factory(l, d), Some(l.clone()), opts, d));
        }
    }
    out
}

/// Which side of an async-batch pair should admit the next request, by
/// the deterministic deficit rule: the device side admits while its
/// admission count lags `ratio` of the pair total, the CPU side while it
/// lags `1 − ratio` — so the running split tracks the learned throughput
/// ratio with no randomness. When neither side is owed a request (or the
/// owed side is full), a work-conserving override lets any side with free
/// batch slots admit anyway; `None` means both sides are full.
pub fn route_admission<E: Executor>(
    cpu: &LeaseBatcher<E>,
    dev: &LeaseBatcher<E>,
    ratio: f64,
) -> Option<XpuDispatch> {
    let total = (cpu.admitted() + dev.admitted() + 1) as f64;
    let dev_owed = (dev.admitted() as f64) < ratio * total;
    let cpu_owed = (cpu.admitted() as f64) < (1.0 - ratio) * total;
    if dev_owed && dev.has_capacity() {
        return Some(XpuDispatch::DeviceOnly);
    }
    if cpu_owed && cpu.has_capacity() {
        return Some(XpuDispatch::CpuOnly);
    }
    // work-conserving override: never idle a side while requests queue
    if dev.has_capacity() {
        return Some(XpuDispatch::DeviceOnly);
    }
    if cpu.has_capacity() {
        return Some(XpuDispatch::CpuOnly);
    }
    None
}

/// How many prefill-complete requests a [`PhaseRole::Prefill`] batcher
/// should hand to its paired [`PhaseRole::Decode`] batcher this round —
/// the disaggregated analogue of [`route_admission`]'s deficit rule: the
/// decode side is owed every parked request its free slots can seat
/// (`min(ready, free)`), which keeps prefill slots turning over without
/// ever pushing the decode batch past `max_batch`. Returns 0 while
/// nothing is parked or the decode side is full.
pub fn route_handoff<E: Executor>(
    prefill: &LeaseBatcher<E>,
    decode: &LeaseBatcher<E>,
) -> usize {
    prefill.n_prefilled().min(decode.free_slots())
}

/// Spread carried-over in-flight requests across a fresh fleet, always
/// onto the least-loaded batcher. Requests that found no batcher to adopt
/// them (an empty fleet: every stream finished mid-rebuild, or a
/// degenerate machine) are handed back — the caller answers their clients
/// with a retryable error ([`ActiveRequest::reject`]) instead of dropping
/// the streams on the floor.
#[must_use = "leftover requests must be rejected, not dropped"]
pub fn distribute<E: Executor>(
    carried: Vec<ActiveRequest>,
    batchers: &mut [LeaseBatcher<E>],
) -> Vec<ActiveRequest> {
    let mut leftover = Vec::new();
    for a in carried {
        match batchers.iter_mut().min_by_key(|b| b.n_active()) {
            Some(target) => target.adopt(a),
            None => leftover.push(a),
        }
    }
    leftover
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AllocPolicy;
    use crate::cpu::presets;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::server::batcher::Pending;
    use crate::server::protocol::Request;
    use crate::sim::{SimConfig, SimExecutor};
    use std::sync::Arc;

    fn factory() -> EngineFactory<SimExecutor> {
        let machine = presets::core_12900k();
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, 5));
        Box::new(move |lease: &Lease, _dispatch: XpuDispatch| {
            let exec = lease.sim_executor(
                &machine,
                SimConfig { execute_real: true, ..SimConfig::noiseless() },
            );
            Engine::new(
                cfg.clone(),
                Arc::clone(&weights),
                exec,
                Box::new(DynamicScheduler),
                PerfConfig::default(),
            )
        })
    }

    #[test]
    fn one_batcher_per_nonempty_lease() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let batchers = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(batchers.len(), 2);
        for b in &batchers {
            let lease = b.lease.as_ref().unwrap();
            assert_eq!(lease.epoch, coord.epoch());
            assert_eq!(b.engine.rt.exec.sim.spec.n_cores(), lease.n_cores());
        }
    }

    #[test]
    fn migration_preserves_in_flight_streams() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 1);

        // solo oracle for the full request
        let solo_lease = coord.lease(0).unwrap().clone();
        let mut oracle = f(&solo_lease, XpuDispatch::Split);
        let mut s = oracle.new_session();
        let (expect, _) = oracle.generate(&mut s, &[4, 2, 7], 8);

        // start the request, run part of it, then rebuild mid-flight
        let (tx, rx) = std::sync::mpsc::channel();
        let req = Request { id: 1, prompt: vec![4, 2, 7], max_new_tokens: 8 };
        fleet[0].admit(Pending::new(req, tx)).map_err(|_| ()).unwrap();
        for _ in 0..4 {
            fleet[0].step();
        }
        let carried: Vec<ActiveRequest> =
            fleet.iter_mut().flat_map(|b| b.take_actives()).collect();
        assert_eq!(carried.len(), 1);
        coord.admit(1); // epoch change: fleet is rebuilt on halved leases
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 2);
        assert!(distribute(carried, &mut fleet).is_empty());
        assert_eq!(fleet.iter().map(|b| b.n_active()).sum::<usize>(), 1);

        let mut guard = 0;
        while fleet.iter().any(|b| !b.is_idle()) {
            for b in fleet.iter_mut() {
                if !b.is_idle() {
                    b.step();
                }
            }
            guard += 1;
            assert!(guard < 10_000);
        }
        let tokens: Vec<u32> = rx
            .try_iter()
            .filter_map(|e| match e {
                crate::server::protocol::Event::Token { token, .. } => Some(token),
                _ => None,
            })
            .collect();
        assert_eq!(tokens, expect, "migrated stream diverged from solo run");
    }

    #[test]
    fn drift_monitor_gates_on_cooldown_and_skew() {
        use crate::cpu::CoreKind;
        use crate::exec::RunResult;
        let machine = presets::core_12900k();
        let mut coord = Coordinator::new(machine, AllocPolicy::Balanced);
        coord.admit(0);
        coord.admit(1);
        let mut mon = DriftMonitor::new(1.25, 3);
        assert!(mon.check_drift(&coord).is_none(), "healthy partition fired");

        // stream 0's P-cores at half rate: skew grows with each observation
        let l0 = coord.lease(0).unwrap().clone();
        let res = RunResult {
            per_core_secs: (0..l0.n_cores())
                .map(|i| {
                    let kind = coord.machine().cores[l0.global_core(i)].kind;
                    let rate = if kind == CoreKind::Performance { 2.649 / 2.0 } else { 1.0 };
                    Some(100.0 / rate)
                })
                .collect(),
            wall_secs: 1.0,
            units_done: vec![100; l0.n_cores()],
            bytes: 0.0,
        };
        for _ in 0..2 {
            assert!(coord.observe(&l0, crate::kernels::KernelClass::GemvQ4, &res));
            assert!(mon.check_drift(&coord).is_none(), "fired inside the cooldown");
        }
        assert!(coord.observe(&l0, crate::kernels::KernelClass::GemvQ4, &res));
        let skew = mon.check_drift(&coord).expect("drift past threshold not detected");
        assert!(skew > 1.25, "reported skew {skew}");

        // the rebalance epoch bump restarts the cooldown: no refire until
        // the fresh partition has folded in its own observations
        coord.rebalance();
        assert!(mon.check_drift(&coord).is_none(), "refired right after rebalance");
    }

    #[test]
    fn empty_fleet_rejects_carried_requests_with_retry_error() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        let (tx, rx) = std::sync::mpsc::channel();
        let req = Request { id: 1, prompt: vec![1], max_new_tokens: 2 };
        fleet[0].admit(Pending::new(req, tx)).map_err(|_| ()).unwrap();
        fleet[0].step();
        let carried: Vec<ActiveRequest> =
            fleet.iter_mut().flat_map(|b| b.take_actives()).collect();
        coord.finish(0);
        let mut fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert!(fleet.is_empty());
        // no panic and no silent drop: the in-flight request comes back
        // and its client hears a retryable error
        let leftover = distribute(carried, &mut fleet);
        assert_eq!(leftover.len(), 1);
        assert_eq!(leftover[0].id(), 1);
        for a in leftover {
            a.reject("no serving capacity, retry");
        }
        match rx.try_recv().unwrap() {
            crate::server::protocol::Event::Error { id, msg } => {
                assert_eq!(id, 1);
                assert!(msg.contains("retry"), "unhelpful error: {msg}");
            }
            other => panic!("expected a retry error, got {other:?}"),
        }
    }

    #[test]
    fn async_batch_lease_builds_a_cpu_device_batcher_pair() {
        use crate::coordinator::{ExecMode, XpuAffinity};
        use crate::sim::xpu::AcceleratorSpec;
        let mut coord = Coordinator::with_accelerators(
            presets::ultra_125h(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        coord.set_exec_mode(ExecMode::AsyncBatch);
        coord.admit(0);
        coord.admit(1);
        let f = factory();
        let fleet = build_batchers(&coord, &f, BatcherOpts::default());
        // hetero lease → CpuOnly + DeviceOnly pair; cores-only lease → one
        assert_eq!(fleet.len(), 3);
        let hetero_stream =
            coord.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        let pair: Vec<XpuDispatch> = fleet
            .iter()
            .filter(|b| b.lease.as_ref().unwrap().stream == hetero_stream)
            .map(|b| b.dispatch())
            .collect();
        assert_eq!(pair, vec![XpuDispatch::CpuOnly, XpuDispatch::DeviceOnly]);
        let solo: Vec<XpuDispatch> = fleet
            .iter()
            .filter(|b| b.lease.as_ref().unwrap().stream != hetero_stream)
            .map(|b| b.dispatch())
            .collect();
        assert_eq!(solo, vec![XpuDispatch::Split]);
    }

    #[test]
    fn disaggregated_lease_builds_a_phase_batcher_pair() {
        use crate::coordinator::ExecMode;
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.set_exec_mode(ExecMode::Disaggregated);
        coord.admit(0);
        let f = factory();
        let fleet = build_batchers(&coord, &f, BatcherOpts::default());
        assert_eq!(fleet.len(), 2);
        let roles: Vec<PhaseRole> = fleet.iter().map(|b| b.role()).collect();
        assert_eq!(roles, vec![PhaseRole::Prefill, PhaseRole::Decode]);
        // each batcher's engine runs on exactly its phase sub-lease's cores
        let parent = coord.lease(0).unwrap();
        let mut covered = 0;
        for b in &fleet {
            let sub = b.lease.as_ref().unwrap();
            assert_eq!(sub.epoch, parent.epoch);
            assert_eq!(b.engine.rt.exec.sim.spec.n_cores(), sub.n_cores());
            covered += sub.n_cores();
        }
        assert_eq!(covered, parent.n_cores());
    }

    #[test]
    fn route_handoff_is_capacity_bounded() {
        use crate::coordinator::ExecMode;
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.set_exec_mode(ExecMode::Disaggregated);
        coord.admit(0);
        let f = factory();
        let opts = BatcherOpts { max_batch: 2, prefill_chunk: 64 };
        let mut fleet = build_batchers(&coord, &f, opts);
        let (mut pf, mut dc) = {
            let dc = fleet.pop().unwrap();
            let pf = fleet.pop().unwrap();
            (pf, dc)
        };
        assert_eq!(route_handoff(&pf, &dc), 0, "nothing parked yet");
        for id in 0..2u64 {
            let (tx, _rx) = std::sync::mpsc::channel();
            let p = Pending::new(Request { id, prompt: vec![1, 2], max_new_tokens: 4 }, tx);
            pf.admit(p).map_err(|_| ()).unwrap();
        }
        pf.step(); // one chunk fully prefills both prompts
        assert_eq!(pf.n_prefilled(), 2);
        assert_eq!(route_handoff(&pf, &dc), 2);
        // a busy decode side caps the handoff at its free slots
        let (tx, _rx) = std::sync::mpsc::channel();
        let p = Pending::new(Request { id: 9, prompt: vec![3], max_new_tokens: 4 }, tx);
        dc.admit(p).map_err(|_| ()).unwrap();
        assert_eq!(route_handoff(&pf, &dc), 1);
        for a in pf.take_prefilled(route_handoff(&pf, &dc)) {
            dc.adopt(a);
        }
        assert_eq!(route_handoff(&pf, &dc), 0, "decode side is full");
        assert_eq!(pf.n_prefilled(), 1);
    }

    #[test]
    fn route_admission_tracks_the_ratio_and_stays_work_conserving() {
        let f = factory();
        let mut coord = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        coord.admit(0);
        let lease = coord.lease(0).unwrap().clone();
        let opts = BatcherOpts { max_batch: 64, prefill_chunk: 4 };
        let mk = |d| {
            LeaseBatcher::with_dispatch(f(&lease, d), Some(lease.clone()), opts, d)
        };
        let mut cpu = mk(XpuDispatch::CpuOnly);
        let mut dev = mk(XpuDispatch::DeviceOnly);
        // a 0.75 device ratio: admissions settle at ~3:1 device:cpu
        for id in 0..40u64 {
            let side = route_admission(&cpu, &dev, 0.75).expect("capacity left");
            let (tx, _rx) = std::sync::mpsc::channel();
            let p = Pending::new(Request { id, prompt: vec![1], max_new_tokens: 1 }, tx);
            match side {
                XpuDispatch::DeviceOnly => dev.admit(p).map_err(|_| ()).unwrap(),
                _ => cpu.admit(p).map_err(|_| ()).unwrap(),
            }
        }
        assert_eq!(cpu.admitted() + dev.admitted(), 40);
        assert_eq!(dev.admitted(), 30, "deficit routing drifted: {}", dev.admitted());
        // work conservation: with the owed side full, the other admits
        let mut tiny_dev = LeaseBatcher::with_dispatch(
            f(&lease, XpuDispatch::DeviceOnly),
            Some(lease.clone()),
            BatcherOpts { max_batch: 0, prefill_chunk: 4 },
            XpuDispatch::DeviceOnly,
        );
        assert_eq!(
            route_admission(&cpu, &tiny_dev, 0.95),
            Some(XpuDispatch::CpuOnly),
            "full device side must not stall the queue"
        );
        tiny_dev.take_actives();
        assert_eq!(route_admission(&tiny_dev, &tiny_dev, 0.5), None);
    }
}
