//! Shared scripted-trace vocabulary for every serving harness tier.
//!
//! The single-machine harness (`server::testing`) and the cluster harness
//! (`cluster::harness`) grew separate trace dialects — `Degrade` spoke
//! lease-local core ids, `DegradeMachine` spoke whole machines, and the
//! arrival/connect events were re-declared per tier. This module is the one
//! event core both tiers consume, so a router scenario scripted once can be
//! replayed unchanged at either tier: single/fleet runs interpret
//! [`TraceEvent::DegradeMachine`] as machine 0 and ignore other machines,
//! the cluster harness interprets [`TraceEvent::Degrade`] as machine-global
//! core ids on machine 0.
//!
//! Arrivals carry a *priority class* (0 = highest). Legacy scripts built
//! through [`TraceEvent::arrive`] get class 0; multi-tenant scripts use
//! [`TraceEvent::arrive_class`] and the per-class admission queues of
//! [`crate::router::ServingPolicy`].

use crate::coordinator::StreamId;
use crate::util::rng::Rng;

use super::protocol::Request;

/// One scripted client action at a virtual-time instant (seconds).
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// a stream's connection opens (fleet mode: `Coordinator::admit`)
    Connect { at: f64, stream: StreamId },
    /// a request arrives (single mode: `stream` is ignored); `class` is the
    /// admission priority class, 0 = highest priority
    Arrive { at: f64, stream: StreamId, req: Request, class: usize },
    /// a stream's connection closes (fleet mode: `Coordinator::finish`)
    Disconnect { at: f64, stream: StreamId },
    /// a background process shows up and steals `fraction` of the given
    /// cores' cycles from `at` on. The load follows the *physical* core:
    /// in fleet mode `cores` are machine-global ids, re-applied to
    /// whichever lease holds each core after every rebuild; in single mode
    /// they are the engine's worker indices.
    Degrade { at: f64, cores: Vec<usize>, fraction: f64 },
    /// a *whole machine* degrades: every core of cluster machine `machine`
    /// loses `fraction` of its cycles from `at` on (the cluster harness's
    /// machine-scoped trace event — see `cluster::harness::run_cluster`).
    /// Single/fleet runs treat it as a whole-machine `Degrade` when
    /// `machine` is 0 (they drive exactly one machine) and ignore it
    /// otherwise.
    DegradeMachine { at: f64, machine: usize, fraction: f64 },
}

impl TraceEvent {
    pub fn at(&self) -> f64 {
        match self {
            TraceEvent::Connect { at, .. }
            | TraceEvent::Arrive { at, .. }
            | TraceEvent::Disconnect { at, .. }
            | TraceEvent::Degrade { at, .. }
            | TraceEvent::DegradeMachine { at, .. } => *at,
        }
    }

    /// Convenience constructor for arrival events (priority class 0).
    pub fn arrive(at: f64, stream: StreamId, req: Request) -> TraceEvent {
        TraceEvent::Arrive { at, stream, req, class: 0 }
    }

    /// Arrival with an explicit priority class (0 = highest priority).
    pub fn arrive_class(at: f64, stream: StreamId, req: Request, class: usize) -> TraceEvent {
        TraceEvent::Arrive { at, stream, req, class }
    }

    /// The arrival's priority class (0 for every non-arrival event).
    pub fn class(&self) -> usize {
        match self {
            TraceEvent::Arrive { class, .. } => *class,
            _ => 0,
        }
    }
}

/// Exponential inter-arrival instants (a Poisson process) from the repo's
/// deterministic RNG — seeded, replayable arrival scripts.
pub fn poisson_arrivals(seed: u64, n: usize, mean_gap: f64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += -(1.0 - rng.f64()).ln() * mean_gap;
        out.push(t);
    }
    out
}

/// A script with a NaN/∞ event time has no defined delivery order — fail
/// at trace construction with a pointed message instead of letting a sort
/// comparator panic (or worse, silently misorder) deep in the run.
pub(crate) fn validate_trace(trace: &[TraceEvent]) {
    for (i, ev) in trace.iter().enumerate() {
        assert!(
            ev.at().is_finite(),
            "trace event {i} has a non-finite time ({}): fix the script — \
             event times must be finite seconds",
            ev.at()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, prompt: vec![1], max_new_tokens: 1 }
    }

    #[test]
    fn arrive_defaults_to_class_zero() {
        let ev = TraceEvent::arrive(1.0, 3, req(7));
        assert_eq!(ev.class(), 0);
        assert_eq!(ev.at(), 1.0);
        let ev = TraceEvent::arrive_class(2.0, 3, req(8), 2);
        assert_eq!(ev.class(), 2);
        // non-arrival events have no class
        assert_eq!(TraceEvent::Connect { at: 0.0, stream: 1 }.class(), 0);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn validate_rejects_non_finite_times() {
        validate_trace(&[TraceEvent::arrive(f64::INFINITY, 0, req(1))]);
    }
}
