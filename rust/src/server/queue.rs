//! Bounded admission queue for the serving front-end.
//!
//! The queue never grows past its configured depth: [`AdmissionQueue::try_push`]
//! hands a request back to the caller when the queue is saturated, and the
//! server's [`super::ServerOpts::on_full`] policy decides whether the caller
//! answers with a protocol error ([`AdmissionPolicy::Reject`]) or blocks the
//! submitting connection until space frees up ([`AdmissionPolicy::Block`]).
//! Either way memory stays bounded under overload — the regression the
//! unbounded `VecDeque` of the run-to-completion server could not give.

use std::collections::VecDeque;

/// What the server does with a request that finds the queue full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// answer immediately with a protocol error (`"admission queue full"`)
    Reject,
    /// hold the submitting connection handler until space frees up
    Block,
}

/// FIFO queue with a hard depth bound.
#[derive(Debug)]
pub struct AdmissionQueue<T> {
    items: VecDeque<T>,
    depth: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(depth: usize) -> AdmissionQueue<T> {
        AdmissionQueue { items: VecDeque::new(), depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.depth
    }

    /// Enqueue at the tail; hands the item back instead of growing past
    /// the depth bound.
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            Err(item)
        } else {
            self.items.push_back(item);
            Ok(())
        }
    }

    /// Requeue at the head — used when a popped request could not be
    /// admitted after all (batch refilled first). Deliberately ignores the
    /// depth bound: the item was already accounted for when first pushed.
    pub fn push_front(&mut self, item: T) {
        self.items.push_front(item);
    }

    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }
}

/// Priority-classed admission queue: one FIFO lane per class, one shared
/// depth bound across all lanes.
///
/// Class 0 is the highest priority. [`ClassedQueue::pop`] always serves the
/// highest-priority non-empty lane; within a lane order is strictly FIFO
/// (the property `prop_classed_queue_is_fifo_per_class` tests). When the
/// shared bound is hit, [`ClassedQueue::evict_lower`] lets the server shed
/// the *newest lowest-priority* queued item to make room for a
/// higher-priority arrival — low-priority work is rejected first, exactly
/// the SLO-aware admission order `ServingPolicy` documents.
///
/// With a single class this reduces bit-for-bit to [`AdmissionQueue`]:
/// same bound, same FIFO order, no eviction possible.
#[derive(Debug)]
pub struct ClassedQueue<T> {
    lanes: Vec<VecDeque<T>>,
    depth: usize,
}

impl<T> ClassedQueue<T> {
    /// `n_classes` FIFO lanes sharing one `depth` bound. At least one lane
    /// always exists.
    pub fn new(n_classes: usize, depth: usize) -> ClassedQueue<T> {
        let n = n_classes.max(1);
        ClassedQueue { lanes: (0..n).map(|_| VecDeque::new()).collect(), depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn n_classes(&self) -> usize {
        self.lanes.len()
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn len_of(&self, class: usize) -> usize {
        self.lanes.get(class).map_or(0, |l| l.len())
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    pub fn is_full(&self) -> bool {
        self.len() >= self.depth
    }

    /// Enqueue at the tail of the class's lane; hands the item back instead
    /// of growing past the shared depth bound. An out-of-range class clamps
    /// to the lowest-priority lane.
    pub fn try_push(&mut self, class: usize, item: T) -> Result<(), T> {
        if self.is_full() {
            return Err(item);
        }
        let lane = class.min(self.lanes.len() - 1);
        self.lanes[lane].push_back(item);
        Ok(())
    }

    /// Requeue at the head of the class's lane — used when a popped request
    /// could not be admitted after all (batch refilled first). Deliberately
    /// ignores the depth bound: the item was already accounted for when
    /// first pushed.
    pub fn push_front(&mut self, class: usize, item: T) {
        let lane = class.min(self.lanes.len() - 1);
        self.lanes[lane].push_front(item);
    }

    /// Dequeue from the highest-priority non-empty lane (FIFO within it),
    /// returning the item with its class.
    pub fn pop(&mut self) -> Option<(usize, T)> {
        for (class, lane) in self.lanes.iter_mut().enumerate() {
            if let Some(item) = lane.pop_front() {
                return Some((class, item));
            }
        }
        None
    }

    /// Drop the *newest* item of the lowest-priority non-empty lane whose
    /// class is strictly lower priority (greater index) than `class`,
    /// returning it so the caller can answer its client. This is the
    /// shed-low-priority-first rule: a saturated queue makes room for a
    /// higher-priority arrival by bouncing the most recent low-priority
    /// request, never one of equal or higher priority.
    pub fn evict_lower(&mut self, class: usize) -> Option<(usize, T)> {
        for lane in (class + 1..self.lanes.len()).rev() {
            if let Some(item) = self.lanes[lane].pop_back() {
                return Some((lane, item));
            }
        }
        None
    }

    /// Queued items from highest to lowest priority, FIFO within a class —
    /// exactly the order [`ClassedQueue::pop`] would drain them.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &T)> {
        self.lanes.iter().enumerate().flat_map(|(c, lane)| lane.iter().map(move |i| (c, i)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturation_bounces_instead_of_growing() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert!(q.is_full());
        // the rejected item comes back to the caller, memory stays bounded
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn fifo_order_with_front_requeue() {
        let mut q = AdmissionQueue::new(4);
        for i in 0..3 {
            q.try_push(i).unwrap();
        }
        let head = q.pop().unwrap();
        assert_eq!(head, 0);
        q.push_front(head);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn zero_depth_rejects_everything() {
        let mut q: AdmissionQueue<u8> = AdmissionQueue::new(0);
        assert!(q.is_full() && q.is_empty());
        assert_eq!(q.try_push(7), Err(7));
    }

    #[test]
    fn classed_queue_single_class_reduces_to_fifo() {
        // one class must behave bit-for-bit like AdmissionQueue: same
        // bound, same order, nothing to evict
        let mut q: ClassedQueue<i32> = ClassedQueue::new(1, 2);
        assert!(q.try_push(0, 1).is_ok());
        assert!(q.try_push(0, 2).is_ok());
        assert!(q.is_full());
        assert_eq!(q.try_push(0, 3), Err(3));
        assert!(q.evict_lower(0).is_none());
        let head = q.pop().unwrap();
        assert_eq!(head, (0, 1));
        q.push_front(0, head.1);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, i)| i)).collect();
        assert_eq!(order, vec![1, 2]);
    }

    #[test]
    fn classed_queue_serves_strict_priority_fifo_within_class() {
        let mut q: ClassedQueue<i32> = ClassedQueue::new(3, 8);
        q.try_push(2, 20).unwrap();
        q.try_push(0, 1).unwrap();
        q.try_push(1, 10).unwrap();
        q.try_push(0, 2).unwrap();
        q.try_push(2, 21).unwrap();
        let order: Vec<(usize, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(0, 1), (0, 2), (1, 10), (2, 20), (2, 21)]);
    }

    #[test]
    fn classed_queue_evicts_newest_lowest_priority_first() {
        let mut q: ClassedQueue<i32> = ClassedQueue::new(3, 3);
        q.try_push(1, 10).unwrap();
        q.try_push(2, 20).unwrap();
        q.try_push(2, 21).unwrap();
        assert!(q.is_full());
        // a class-0 arrival evicts the newest class-2 item, not class 1
        assert_eq!(q.evict_lower(0), Some((2, 21)));
        q.try_push(0, 1).unwrap();
        // class-1 arrival may only evict class 2
        assert_eq!(q.evict_lower(1), Some((2, 20)));
        // nothing lower-priority than class 2 remains
        assert!(q.evict_lower(2).is_none());
        let order: Vec<(usize, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(0, 1), (1, 10)]);
    }

    #[test]
    fn classed_queue_out_of_range_class_clamps_to_lowest() {
        let mut q: ClassedQueue<i32> = ClassedQueue::new(2, 4);
        q.try_push(9, 99).unwrap();
        assert_eq!(q.len_of(1), 1);
        assert_eq!(q.pop(), Some((1, 99)));
    }
}
