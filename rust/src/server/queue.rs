//! Bounded admission queue for the serving front-end.
//!
//! The queue never grows past its configured depth: [`AdmissionQueue::try_push`]
//! hands a request back to the caller when the queue is saturated, and the
//! server's [`super::ServerOpts::on_full`] policy decides whether the caller
//! answers with a protocol error ([`AdmissionPolicy::Reject`]) or blocks the
//! submitting connection until space frees up ([`AdmissionPolicy::Block`]).
//! Either way memory stays bounded under overload — the regression the
//! unbounded `VecDeque` of the run-to-completion server could not give.

use std::collections::VecDeque;

/// What the server does with a request that finds the queue full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// answer immediately with a protocol error (`"admission queue full"`)
    Reject,
    /// hold the submitting connection handler until space frees up
    Block,
}

/// FIFO queue with a hard depth bound.
#[derive(Debug)]
pub struct AdmissionQueue<T> {
    items: VecDeque<T>,
    depth: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(depth: usize) -> AdmissionQueue<T> {
        AdmissionQueue { items: VecDeque::new(), depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.depth
    }

    /// Enqueue at the tail; hands the item back instead of growing past
    /// the depth bound.
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            Err(item)
        } else {
            self.items.push_back(item);
            Ok(())
        }
    }

    /// Requeue at the head — used when a popped request could not be
    /// admitted after all (batch refilled first). Deliberately ignores the
    /// depth bound: the item was already accounted for when first pushed.
    pub fn push_front(&mut self, item: T) {
        self.items.push_front(item);
    }

    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturation_bounces_instead_of_growing() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert!(q.is_full());
        // the rejected item comes back to the caller, memory stays bounded
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn fifo_order_with_front_requeue() {
        let mut q = AdmissionQueue::new(4);
        for i in 0..3 {
            q.try_push(i).unwrap();
        }
        let head = q.pop().unwrap();
        assert_eq!(head, 0);
        q.push_front(head);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn zero_depth_rejects_everything() {
        let mut q: AdmissionQueue<u8> = AdmissionQueue::new(0);
        assert!(q.is_full() && q.is_empty());
        assert_eq!(q.try_push(7), Err(7));
    }
}
