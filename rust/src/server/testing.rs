//! Deterministic serving harness: scripted clients over virtual time.
//!
//! Drives the *same* [`LeaseBatcher`]/[`fleet`] code the TCP server runs,
//! but single-threaded against simulator leases and a scripted trace —
//! requests are injected at exact virtual-time instants, streams connect
//! and disconnect on schedule, background loads degrade physical cores
//! mid-trace ([`TraceEvent::Degrade`]) with the production
//! [`DriftMonitor`] deciding live rebalances, and the report carries
//! per-request token streams, TTFT and aggregate throughput. No sockets,
//! no wall-clock sleeps, bit-for-bit reproducible: this is the standard
//! way to test serving features (see `rust/tests/serving_harness.rs`).
//!
//! The full-policy entry point is [`run_trace`]: it takes a
//! [`ServingPolicy`] and drives everything the live `serve_dynamic`
//! supervisor would — priority-classed admission with SLO shedding, the
//! live [`StrategyRouter`] switching [`crate::coordinator::Strategy`]
//! mid-trace (every switch a bit-identical session migration), and the
//! drift monitor. [`run_fleet`] is the legacy knob-level wrapper kept for
//! existing tests: a single-class, router-off policy behaves exactly like
//! the pre-policy harness.
//!
//! Virtual time: each lease's clock is its engine's accumulated kernel
//! seconds plus an idle offset (jumped forward when the lease sits waiting
//! for arrivals). Leases run concurrently — the driver always advances the
//! lease with the smallest clock, so cross-lease interleaving is exactly
//! what concurrent hardware would produce.

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::coordinator::{Coordinator, Lease, Strategy, StreamId};
use crate::exec::{Executor, RunResult};
use crate::kernels::KernelClass;
use crate::perf::bandwidth::{bandwidth_gbps, bandwidth_utilization};
use crate::router::{ServingPolicy, SloGate, StrategyRouter};
use crate::sim::xpu::XpuDispatch;
use crate::util::stats::Summary;

use super::batcher::{ActiveRequest, BatcherOpts, LeaseBatcher, Pending, PhaseRole, StepReport};
use super::fleet::{self, DriftMonitor, EngineFactory};
use super::protocol::{Event, Request};
use super::queue::ClassedQueue;

pub use super::trace::{poisson_arrivals, TraceEvent};
pub(crate) use super::trace::validate_trace;

/// When queued requests may enter a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitMode {
    /// continuous batching: admit whenever a slot is free (every round)
    Continuous,
    /// run-to-completion baseline (the pre-continuous-batching `serve_multi`
    /// behavior): admit only once the running batch has fully drained
    RunToCompletion,
}

/// Everything the harness observed about one request.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    /// admission priority class the request arrived with (0 = highest)
    pub class: usize,
    pub arrived_at: f64,
    pub admitted_at: Option<f64>,
    pub first_token_at: Option<f64>,
    pub finished_at: Option<f64>,
    pub tokens: Vec<u32>,
    pub error: Option<String>,
}

impl RequestRecord {
    fn new(id: u64, arrived_at: f64, class: usize) -> RequestRecord {
        RequestRecord {
            id,
            class,
            arrived_at,
            admitted_at: None,
            first_token_at: None,
            finished_at: None,
            tokens: Vec::new(),
            error: None,
        }
    }

    /// Time-to-first-token: arrival → first streamed token.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrived_at)
    }
}

/// Aggregate outcome of a harness run.
#[derive(Debug, Default)]
pub struct HarnessReport {
    pub requests: BTreeMap<u64, RequestRecord>,
    /// last retirement minus first arrival (virtual seconds)
    pub makespan: f64,
    pub total_decoded: usize,
    /// admission-queue depth sampled before every scheduler round
    pub queue_depth_samples: Vec<usize>,
    /// ids bounced by the bounded admission queue
    pub rejected: Vec<u64>,
    /// ids dropped by SLO-aware admission — predicted-overload sheds and
    /// queue-full preemptions of low-priority work (disjoint from
    /// `rejected`)
    pub shed: Vec<u64>,
    /// `(request id, class)` in successful admission order — the
    /// FIFO-per-class invariant's witness
    pub admit_order: Vec<(u64, usize)>,
    // ---- fleet mode ----
    /// coordinator epoch after each rebuild
    pub epochs_seen: Vec<u64>,
    /// lease set after each rebuild (disjoint/covering checks)
    pub lease_sets: Vec<Vec<Lease>>,
    pub rebuilds: usize,
    /// rebuilds triggered by the drift monitor (subset of `rebuilds`),
    /// with the strength skew observed at each trigger
    pub drift_rebalances: usize,
    pub skew_at_trigger: Vec<f64>,
    /// strategy switches the live router took: `(virtual seconds,
    /// strategy switched to)` — each one is also a rebuild
    pub strategy_switches: Vec<(f64, Strategy)>,
    /// live measurements folded into the coordinator's strength table
    pub observations_accepted: usize,
    /// pre-rebuild measurements replayed after the epoch change — dropped
    pub stale_observations_dropped: usize,
    /// ...and how many of those were wrongly accepted (must stay 0)
    pub stale_observations_accepted: usize,
    /// final learned device share (`Coordinator::split_ratio`) of every
    /// hetero lease still live when the run drained
    pub split_ratios: Vec<f64>,
    /// prefill→decode sessions moved between the batchers of an
    /// [`crate::coordinator::ExecMode::Disaggregated`] phase pair
    pub handoffs: usize,
    /// kernel memory traffic per stream (0 for unleased batchers),
    /// accumulated across every round and surviving fleet rebuilds
    pub bandwidth: BTreeMap<StreamId, BandwidthUse>,
}

/// Accumulated kernel bandwidth of one stream's batcher(s).
#[derive(Clone, Debug, Default)]
pub struct BandwidthUse {
    /// unique kernel memory traffic (bytes)
    pub bytes: f64,
    /// busy kernel seconds the bytes were moved in
    pub kernel_secs: f64,
    /// the stream's lease bus allocation when last observed (GB/s);
    /// 0 = unleased, no utilization defined
    pub bus_share_gbps: f64,
}

impl BandwidthUse {
    pub fn achieved_gbps(&self) -> f64 {
        bandwidth_gbps(self.bytes, self.kernel_secs)
    }

    /// Achieved bandwidth as a fraction of the lease's bus share (0 when
    /// the stream is unleased).
    pub fn utilization(&self) -> f64 {
        if self.bus_share_gbps > 0.0 {
            bandwidth_utilization(self.achieved_gbps(), self.bus_share_gbps)
        } else {
            0.0
        }
    }
}

impl HarnessReport {
    pub fn mean_ttft(&self) -> f64 {
        let ttfts: Vec<f64> = self.requests.values().filter_map(|r| r.ttft()).collect();
        if ttfts.is_empty() {
            0.0
        } else {
            ttfts.iter().sum::<f64>() / ttfts.len() as f64
        }
    }

    /// Aggregate decode throughput over the makespan (tokens/s).
    pub fn throughput(&self) -> f64 {
        if self.makespan > 0.0 {
            self.total_decoded as f64 / self.makespan
        } else {
            0.0
        }
    }

    pub fn tokens_of(&self, id: u64) -> &[u32] {
        self.requests.get(&id).map(|r| r.tokens.as_slice()).unwrap_or(&[])
    }

    pub fn all_finished(&self) -> bool {
        self.requests.values().all(|r| r.finished_at.is_some() || r.error.is_some())
    }

    /// TTFT distribution (p50/p95/p99…) over every served request; `None`
    /// when nothing streamed a first token.
    pub fn ttft_summary(&self) -> Option<Summary> {
        let t: Vec<f64> = self.requests.values().filter_map(|r| r.ttft()).collect();
        if t.is_empty() {
            None
        } else {
            Some(Summary::of(&t))
        }
    }

    /// TTFT distribution of one priority class.
    pub fn ttft_summary_class(&self, class: usize) -> Option<Summary> {
        let t: Vec<f64> =
            self.requests.values().filter(|r| r.class == class).filter_map(|r| r.ttft()).collect();
        if t.is_empty() {
            None
        } else {
            Some(Summary::of(&t))
        }
    }

    /// Served requests of `class` whose TTFT exceeded `target` seconds.
    /// Shed/rejected requests are not violations — they were answered
    /// immediately instead of silently blowing the target.
    pub fn slo_violations(&self, class: usize, target: f64) -> usize {
        self.requests
            .values()
            .filter(|r| r.class == class)
            .filter_map(|r| r.ttft())
            .filter(|&t| t > target)
            .count()
    }

    /// Priority classes of the shed requests (for "low-priority work is
    /// shed first" assertions).
    pub fn shed_classes(&self) -> Vec<usize> {
        self.shed.iter().filter_map(|id| self.requests.get(id).map(|r| r.class)).collect()
    }
}

pub(crate) fn enqueue(
    queue: &mut ClassedQueue<Pending>,
    rxs: &mut BTreeMap<u64, mpsc::Receiver<Event>>,
    report: &mut HarnessReport,
    at: f64,
    req: Request,
    class: usize,
) {
    let id = req.id;
    let (tx, rx) = mpsc::channel();
    rxs.insert(id, rx);
    report.requests.insert(id, RequestRecord::new(id, at, class));
    if let Err(p) = queue.try_push(class, Pending::with_class(req, tx, class)) {
        // a saturated queue makes room for a higher-priority arrival by
        // shedding the newest lowest-priority queued request
        if let Some((_, victim)) = queue.evict_lower(class) {
            let vid = victim.req.id;
            report.shed.push(vid);
            if let Some(rec) = report.requests.get_mut(&vid) {
                rec.error = Some("shed: preempted by higher-priority arrival".into());
            }
            queue
                .try_push(class, p)
                .unwrap_or_else(|_| unreachable!("eviction freed a slot"));
            return;
        }
        report.rejected.push(id);
        if let Some(rec) = report.requests.get_mut(&id) {
            rec.error = Some("admission queue full".into());
        }
    }
}

/// Record an arrival the SLO admission gate dropped on the floor: the
/// client is answered immediately (error record), nothing is queued.
fn shed_arrival(report: &mut HarnessReport, at: f64, req: Request, class: usize) {
    let id = req.id;
    let mut rec = RequestRecord::new(id, at, class);
    rec.error = Some("shed: predicted SLO violation, low-priority load dropped".into());
    report.requests.insert(id, rec);
    report.shed.push(id);
}

pub(crate) fn absorb(
    report: &mut HarnessReport,
    step: &StepReport,
    idle_offset: f64,
    stream: StreamId,
    bus_share_gbps: f64,
) {
    for (id, t) in &step.first_tokens {
        if let Some(rec) = report.requests.get_mut(id) {
            rec.first_token_at = Some(idle_offset + *t);
        }
    }
    for r in &step.retired {
        if let Some(rec) = report.requests.get_mut(&r.id) {
            rec.finished_at = Some(idle_offset + r.at);
        }
        report.total_decoded += r.metrics.decoded_tokens;
    }
    if step.kernel_secs > 0.0 || step.bytes > 0.0 {
        let bw = report.bandwidth.entry(stream).or_default();
        bw.bytes += step.bytes;
        bw.kernel_secs += step.kernel_secs;
        bw.bus_share_gbps = bus_share_gbps;
    }
}

/// `(stream, bus_share)` key a batcher's rounds are accounted under —
/// stream 0 with no bus reference for unleased batchers.
pub(crate) fn bandwidth_key<E: Executor>(b: &LeaseBatcher<E>) -> (StreamId, f64) {
    b.lease.as_ref().map_or((0, 0.0), |l| (l.stream, l.bus_share_gbps))
}

pub(crate) fn finalize(report: &mut HarnessReport, rxs: &BTreeMap<u64, mpsc::Receiver<Event>>) {
    for (id, rx) in rxs {
        let Some(rec) = report.requests.get_mut(id) else { continue };
        for ev in rx.try_iter() {
            match ev {
                Event::Token { token, .. } => rec.tokens.push(token),
                Event::Error { msg, .. } => rec.error = Some(msg),
                Event::Done { .. } => {}
            }
        }
    }
    let first = report.requests.values().map(|r| r.arrived_at).fold(f64::INFINITY, f64::min);
    let last = report
        .requests
        .values()
        .filter_map(|r| r.finished_at)
        .fold(f64::NEG_INFINITY, f64::max);
    report.makespan = if last > first { last - first } else { 0.0 };
}

/// Drive one batcher with a scripted arrival trace in virtual time.
/// `mode` selects continuous batching or the run-to-completion baseline —
/// same engine, same requests, directly comparable TTFT/throughput.
pub fn run_single<E: Executor>(
    mut batcher: LeaseBatcher<E>,
    mode: AdmitMode,
    queue_depth: usize,
    mut script: Vec<TraceEvent>,
) -> HarnessReport {
    validate_trace(&script);
    script.sort_by(|a, b| a.at().total_cmp(&b.at()));
    let mut report = HarnessReport::default();
    let mut queue: ClassedQueue<Pending> = ClassedQueue::new(1, queue_depth);
    let mut rxs: BTreeMap<u64, mpsc::Receiver<Event>> = BTreeMap::new();
    let mut idle_offset = 0.0f64;
    let mut cursor = 0usize;
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 5_000_000, "harness runaway");
        let now = idle_offset + batcher.engine.kernel_secs;
        // deliver every arrival due by now
        while cursor < script.len() && script[cursor].at() <= now + 1e-12 {
            let ev = script[cursor].clone();
            cursor += 1;
            match ev {
                TraceEvent::Arrive { at, req, class, .. } => {
                    enqueue(&mut queue, &mut rxs, &mut report, at, req, class);
                }
                TraceEvent::Degrade { cores, fraction, .. } => {
                    batcher.engine.rt.exec.inject_background(&cores, fraction);
                }
                TraceEvent::DegradeMachine { machine, fraction, .. } => {
                    if machine == 0 {
                        let all: Vec<usize> = (0..batcher.engine.rt.exec.n_workers()).collect();
                        batcher.engine.rt.exec.inject_background(&all, fraction);
                    }
                }
                TraceEvent::Connect { .. } | TraceEvent::Disconnect { .. } => {}
            }
        }
        if batcher.is_idle() && queue.is_empty() {
            if cursor >= script.len() {
                break;
            }
            // idle: jump the virtual clock to the next arrival
            idle_offset = script[cursor].at() - batcher.engine.kernel_secs;
            continue;
        }
        report.queue_depth_samples.push(queue.len());
        let may_admit = match mode {
            AdmitMode::Continuous => true,
            AdmitMode::RunToCompletion => batcher.is_idle(),
        };
        if may_admit {
            while batcher.has_capacity() {
                let Some((_, p)) = queue.pop() else { break };
                let id = p.req.id;
                let class = p.class;
                let before = batcher.admitted();
                match batcher.admit(p) {
                    Ok(()) => {
                        if batcher.admitted() > before {
                            report.admit_order.push((id, class));
                        }
                        if let Some(rec) = report.requests.get_mut(&id) {
                            rec.admitted_at = Some(now);
                        }
                    }
                    Err(p) => {
                        queue.push_front(class, p);
                        break;
                    }
                }
            }
        }
        let step = batcher.step();
        let (stream, bus) = bandwidth_key(&batcher);
        absorb(&mut report, &step, idle_offset, stream, bus);
    }
    finalize(&mut report, &rxs);
    report
}

/// Legacy knob-level fleet harness, kept so existing tests and benches
/// compile and measure unchanged: wraps the passed knobs into a
/// single-class, router-off [`ServingPolicy`] and runs [`run_trace`] —
/// which then behaves exactly like the pre-policy harness.
pub fn run_fleet<E: Executor>(
    coord: Coordinator,
    factory: &EngineFactory<E>,
    opts: BatcherOpts,
    queue_depth: usize,
    monitor: DriftMonitor,
    trace: Vec<TraceEvent>,
) -> HarnessReport {
    let policy = ServingPolicy::from_server_parts(
        opts.max_batch,
        opts.prefill_chunk,
        queue_depth,
        super::queue::AdmissionPolicy::Reject,
        monitor.threshold,
        monitor.cooldown,
    );
    run_trace(coord, factory, &policy, trace)
}

/// Drive a dynamic fleet end-to-end under one [`ServingPolicy`]:
/// `Connect`/`Disconnect` trace events admit/finish coordinator streams
/// (epoch bump → fleet rebuild, in-flight sessions migrating), `Arrive`
/// events feed the priority-classed admission queue — through the policy's
/// [`SloGate`], which sheds low-priority arrivals when the learned service
/// rate predicts a higher-priority SLO miss — and `Degrade` events start
/// background loads on physical cores (re-applied to whichever lease holds
/// each core after every rebuild). The caller builds the [`Coordinator`] —
/// cores-only or heterogeneous; the policy's drift thresholds are consulted
/// exactly like `serve_dynamic`'s idle tick, and a past-threshold skew
/// triggers the live `rebalance()` + rebuild + migration sequence.
///
/// With [`ServingPolicy::router`] set, a [`StrategyRouter`] watches the
/// arrival mix and switches the fleet's [`Strategy`] live: each switch is
/// an `apply_strategy` epoch bump riding the same rebuild path a
/// membership change takes, so every in-flight session migrates
/// bit-identically (property-tested against the static-config oracle).
/// After every rebuild, each batcher's pre-rebuild measurement is replayed
/// against the coordinator — exactly the in-flight-observation race a live
/// server has — and counted as dropped/accepted in the report.
pub fn run_trace<E: Executor>(
    mut coord: Coordinator,
    factory: &EngineFactory<E>,
    policy: &ServingPolicy,
    mut trace: Vec<TraceEvent>,
) -> HarnessReport {
    validate_trace(&trace);
    trace.sort_by(|a, b| a.at().total_cmp(&b.at()));
    if let Some(mode) = policy.mode {
        coord.set_exec_mode(mode);
    }
    let mut opts = policy.batcher_opts();
    let mut monitor = policy.drift_monitor();
    let candidates = coord.strategy_candidates(opts.max_batch, opts.prefill_chunk);
    let mut router = StrategyRouter::from_policy(policy, &candidates);
    let mut slo = SloGate::new();
    let mut report = HarnessReport::default();
    let mut batchers: Vec<LeaseBatcher<E>> = Vec::new();
    let mut offsets: Vec<f64> = Vec::new();
    let mut queue: ClassedQueue<Pending> = ClassedQueue::new(policy.n_classes(), policy.queue_depth);
    let mut rxs: BTreeMap<u64, mpsc::Receiver<Event>> = BTreeMap::new();
    // background loads by physical core — they outlive any one fleet
    let mut degraded: Vec<(Vec<usize>, f64)> = Vec::new();
    // admission counters + parked round timings per async-batch pair,
    // keyed by the lease's stream; reset whenever the fleet is rebuilt
    // (exactly like the live supervisor's per-generation `PairState`)
    let mut pairs: BTreeMap<StreamId, PairSlot> = BTreeMap::new();
    let mut cursor = 0usize;
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 5_000_000, "harness runaway");
        // phase pairs first: prefill-complete sessions move to the paired
        // decode batcher *before* the pick below, so parked work can never
        // strand the loop (a fully-parked prefill batcher does no work)
        drain_handoffs(&mut batchers, &mut offsets, &mut report);
        let next_at = if cursor < trace.len() { Some(trace[cursor].at()) } else { None };
        // working lease with the smallest virtual clock
        let mut pick: Option<(usize, f64)> = None;
        for i in 0..batchers.len() {
            let clock = offsets[i] + batchers[i].engine.kernel_secs;
            // an idle pair member the deficit router will not feed has
            // nothing to do — stepping it would spin the guard counter,
            // and so would a prefill batcher whose whole batch is parked
            // awaiting handoff (its step advances no kernel clock)
            let parked = batchers[i].role() == PhaseRole::Prefill
                && batchers[i].n_prefilled() == batchers[i].n_active();
            let works = (!batchers[i].is_idle() && !parked)
                || (!queue.is_empty()
                    && batchers[i].role() != PhaseRole::Decode
                    && batchers[i].has_capacity()
                    && pair_may_admit(&batchers, &pairs, &coord, i));
            if works && pick.is_none_or(|(_, c)| clock < c) {
                pick = Some((i, clock));
            }
        }
        let do_event = match (pick, next_at) {
            (None, None) => break,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some((_, clock)), Some(t)) => clock > t,
        };
        if do_event {
            let t = next_at.unwrap();
            // idle leases' clocks catch up to the event instant
            for i in 0..batchers.len() {
                let clock = offsets[i] + batchers[i].engine.kernel_secs;
                if clock < t {
                    offsets[i] = t - batchers[i].engine.kernel_secs;
                }
            }
            // coalesce everything scheduled for this instant
            let mut connects: Vec<StreamId> = Vec::new();
            let mut disconnects: Vec<StreamId> = Vec::new();
            while cursor < trace.len() && trace[cursor].at() <= t + 1e-12 {
                let ev = trace[cursor].clone();
                cursor += 1;
                match ev {
                    TraceEvent::Arrive { at, req, class, .. } => {
                        // the router reasons about *offered* load, so shed
                        // arrivals count toward its decision window too
                        if let Some(r) = router.as_mut() {
                            r.note_arrival(req.prompt.len(), req.max_new_tokens);
                        }
                        let backlog: f64 = queue
                            .iter()
                            .map(|(_, p)| (p.req.prompt.len() + p.req.max_new_tokens) as f64)
                            .sum();
                        if slo.should_shed(policy, class, backlog) {
                            shed_arrival(&mut report, at, req, class);
                        } else {
                            enqueue(&mut queue, &mut rxs, &mut report, at, req, class);
                        }
                    }
                    TraceEvent::Connect { stream, .. } => connects.push(stream),
                    TraceEvent::Disconnect { stream, .. } => disconnects.push(stream),
                    TraceEvent::Degrade { cores, fraction, .. } => {
                        apply_degradation(&mut batchers, &cores, fraction);
                        degraded.push((cores, fraction));
                    }
                    TraceEvent::DegradeMachine { machine, fraction, .. } => {
                        if machine == 0 {
                            let cores: Vec<usize> = (0..coord.machine().n_cores()).collect();
                            apply_degradation(&mut batchers, &cores, fraction);
                            degraded.push((cores, fraction));
                        }
                    }
                }
            }
            if !connects.is_empty() || !disconnects.is_empty() {
                rebuild(
                    &mut coord,
                    factory,
                    opts,
                    &mut batchers,
                    &mut offsets,
                    FleetChange::Membership { connects, disconnects },
                    &degraded,
                    t,
                    &mut report,
                );
                pairs.clear();
            }
            continue;
        }

        let (i, mut clock) = pick.unwrap();
        // the router's decision point — the same place the live supervisor
        // ticks: between rounds, before the next batch is admitted, so a
        // switch never runs fresh work under the outgoing strategy
        if let Some(r) = router.as_mut() {
            let device_share = coord
                .leases()
                .find(|l| !l.accels().is_empty())
                .map(|l| coord.split_ratio(l));
            if let Some(strat) = r.decide(clock, device_share) {
                opts = BatcherOpts { max_batch: strat.max_batch, prefill_chunk: strat.prefill_chunk };
                // rebuild at the fleet's latest clock: a lease running
                // ahead must not have its timeline rewound by the switch
                let now = (0..batchers.len())
                    .map(|j| offsets[j] + batchers[j].engine.kernel_secs)
                    .fold(clock, f64::max);
                rebuild(
                    &mut coord,
                    factory,
                    opts,
                    &mut batchers,
                    &mut offsets,
                    FleetChange::Strategy(strat),
                    &degraded,
                    now,
                    &mut report,
                );
                pairs.clear();
                report.strategy_switches.push((now, strat));
                continue;
            }
        }
        report.queue_depth_samples.push(queue.len());
        let was_idle = batchers[i].is_idle();
        while batchers[i].role() != PhaseRole::Decode
            && batchers[i].has_capacity()
            && pair_may_admit(&batchers, &pairs, &coord, i)
        {
            let Some((_, p)) = queue.pop() else { break };
            let id = p.req.id;
            let class = p.class;
            let before = batchers[i].admitted();
            match batchers[i].admit(p) {
                Ok(()) => {
                    if batchers[i].admitted() > before {
                        report.admit_order.push((id, class));
                        if let Some((stream, is_dev)) = pair_side(&batchers[i]) {
                            let slot = pairs.entry(stream).or_default();
                            if is_dev {
                                slot.dev_admitted += 1;
                            } else {
                                slot.cpu_admitted += 1;
                            }
                        }
                        // a lease that sat idle starts this request at its
                        // arrival instant, not at the stale idle clock
                        if was_idle {
                            if let Some(rec) = report.requests.get(&id) {
                                if clock < rec.arrived_at {
                                    clock = rec.arrived_at;
                                    offsets[i] = clock - batchers[i].engine.kernel_secs;
                                }
                            }
                        }
                    }
                    if let Some(rec) = report.requests.get_mut(&id) {
                        rec.admitted_at = Some(clock);
                    }
                }
                Err(p) => {
                    queue.push_front(class, p);
                    break;
                }
            }
        }
        let step = batchers[i].step();
        let (stream, bus) = bandwidth_key(&batchers[i]);
        absorb(&mut report, &step, offsets[i], stream, bus);
        slo.observe(step.decoded_tokens, step.kernel_secs);
        // live measurement → strength table (current lease, current epoch)
        if let Some((stream, is_dev)) = pair_side(&batchers[i]) {
            // async pair: park this side's round and fold both sides into
            // one relative observation once the twin's round lands too
            if step.decoded_tokens > 0 && step.kernel_secs > 0.0 {
                let slot = pairs.entry(stream).or_default();
                let cell = if is_dev { &mut slot.dev_round } else { &mut slot.cpu_round };
                *cell = Some((step.kernel_secs, step.decoded_tokens));
                if let (Some(c), Some(d)) = (slot.cpu_round, slot.dev_round) {
                    slot.cpu_round = None;
                    slot.dev_round = None;
                    let lease = batchers[i].lease.as_ref().unwrap().clone();
                    // paired token rounds are decode-dominated: fold into
                    // the GEMV row
                    if coord.observe_round(&lease, KernelClass::GemvQ4, c, d) {
                        report.observations_accepted += 1;
                    }
                }
            }
        } else if let (Some(lease), Some(res), Some(class)) = (
            batchers[i].lease.as_ref(),
            batchers[i].engine.rt.last_result.as_ref(),
            batchers[i].engine.rt.last_class,
        ) {
            if coord.observe(lease, class, res) {
                report.observations_accepted += 1;
            }
        }
        // the drift check a live supervisor runs between events: learned
        // skew past the threshold → rebalance() + rebuild, mid-trace
        if let Some(skew) = monitor.check_drift(&coord) {
            // rebuild at the fleet's *latest* clock: a lease running ahead
            // of the triggering one must not have its timeline rewound
            let now = (0..batchers.len())
                .map(|j| offsets[j] + batchers[j].engine.kernel_secs)
                .fold(f64::NEG_INFINITY, f64::max);
            rebuild(
                &mut coord,
                factory,
                opts,
                &mut batchers,
                &mut offsets,
                FleetChange::Rebalance,
                &degraded,
                now,
                &mut report,
            );
            pairs.clear();
            report.drift_rebalances += 1;
            report.skew_at_trigger.push(skew);
        }
    }
    for l in coord.leases() {
        if !l.accels().is_empty() {
            report.split_ratios.push(coord.split_ratio(l));
        }
    }
    finalize(&mut report, &rxs);
    report
}

/// Harness-side state of one `ExecMode::AsyncBatch` batcher pair: lifetime
/// admission counters driving the deficit router and the parked per-side
/// round timings waiting to be stitched into `Coordinator::observe_round`.
#[derive(Default)]
struct PairSlot {
    cpu_admitted: usize,
    dev_admitted: usize,
    cpu_round: Option<(f64, usize)>,
    dev_round: Option<(f64, usize)>,
}

/// `(stream, is_device_side)` when batcher is half of an async pair.
fn pair_side<E: Executor>(b: &LeaseBatcher<E>) -> Option<(StreamId, bool)> {
    if b.dispatch() == XpuDispatch::Split {
        return None;
    }
    b.lease.as_ref().map(|l| (l.stream, b.dispatch() == XpuDispatch::DeviceOnly))
}

/// The deficit-routing rule of an async pair, mirroring the live server:
/// a side may admit while its admission count trails its share of the
/// coordinator's learned split ratio; a side that is not owed may still
/// admit when its twin has no free slot (work conservation). Non-pair
/// batchers always may.
fn pair_may_admit<E: Executor>(
    batchers: &[LeaseBatcher<E>],
    pairs: &BTreeMap<StreamId, PairSlot>,
    coord: &Coordinator,
    i: usize,
) -> bool {
    let Some((stream, is_dev)) = pair_side(&batchers[i]) else { return true };
    let Some(lease) = batchers[i].lease.as_ref() else { return true };
    let ratio = coord.split_ratio(lease);
    let (c, d) = pairs.get(&stream).map_or((0, 0), |s| (s.cpu_admitted, s.dev_admitted));
    let total = (c + d + 1) as f64;
    let owed = if is_dev {
        (d as f64) < ratio * total
    } else {
        (c as f64) < (1.0 - ratio) * total
    };
    if owed {
        return true;
    }
    let twin_free = batchers.iter().enumerate().any(|(j, b)| {
        j != i
            && pair_side(b).is_some_and(|(s, dev)| s == stream && dev != is_dev)
            && b.has_capacity()
    });
    !twin_free
}

/// Move prefill-complete sessions from every [`PhaseRole::Prefill`]
/// batcher to its same-stream [`PhaseRole::Decode`] twin, bounded by the
/// decode side's free slots ([`fleet::route_handoff`]). The decode clock
/// is synced forward to the prefill clock first — a session cannot be
/// decoded before the instant its prefill finished — which is exactly the
/// queueing delay a physical handoff would incur.
pub(crate) fn drain_handoffs<E: Executor>(
    batchers: &mut [LeaseBatcher<E>],
    offsets: &mut [f64],
    report: &mut HarnessReport,
) {
    for i in 0..batchers.len() {
        if batchers[i].role() != PhaseRole::Prefill {
            continue;
        }
        let Some(stream) = batchers[i].lease.as_ref().map(|l| l.stream) else { continue };
        let Some(j) = (0..batchers.len()).find(|&j| {
            batchers[j].role() == PhaseRole::Decode
                && batchers[j].lease.as_ref().is_some_and(|l| l.stream == stream)
        }) else {
            continue;
        };
        let n = fleet::route_handoff(&batchers[i], &batchers[j]);
        if n == 0 {
            continue;
        }
        let pf_clock = offsets[i] + batchers[i].engine.kernel_secs;
        let dc_clock = offsets[j] + batchers[j].engine.kernel_secs;
        if dc_clock < pf_clock {
            offsets[j] = pf_clock - batchers[j].engine.kernel_secs;
        }
        let moved = batchers[i].take_prefilled(n);
        report.handoffs += moved.len();
        for a in moved {
            batchers[j].adopt(a);
        }
    }
}

/// What a rebuild applies to the coordinator.
enum FleetChange {
    Membership { connects: Vec<StreamId>, disconnects: Vec<StreamId> },
    Rebalance,
    /// a router switch: `Coordinator::apply_strategy` re-issues every
    /// live lease under the new mode (epoch bump)
    Strategy(Strategy),
}

/// Re-start the scripted background loads on a (possibly fresh) fleet:
/// each degraded physical core is mapped through its current lease to the
/// lease-local worker and injected into that engine's executor.
pub(crate) fn apply_degradation<E: Executor>(
    batchers: &mut [LeaseBatcher<E>],
    cores: &[usize],
    fraction: f64,
) {
    for b in batchers.iter_mut() {
        let Some(lease) = b.lease.as_ref() else { continue };
        let locals: Vec<usize> = cores.iter().filter_map(|&g| lease.local_index(g)).collect();
        if !locals.is_empty() {
            b.engine.rt.exec.inject_background(&locals, fraction);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn rebuild<E: Executor>(
    coord: &mut Coordinator,
    factory: &EngineFactory<E>,
    opts: BatcherOpts,
    batchers: &mut Vec<LeaseBatcher<E>>,
    offsets: &mut Vec<f64>,
    change: FleetChange,
    degraded: &[(Vec<usize>, f64)],
    now: f64,
    report: &mut HarnessReport,
) {
    // measurements still in flight from the epoch being torn down
    let stale: Vec<(Lease, KernelClass, RunResult)> = batchers
        .iter()
        .filter_map(|b| {
            match (b.lease.clone(), b.engine.rt.last_class, b.engine.rt.last_result.clone()) {
                (Some(l), Some(c), Some(r)) => Some((l, c, r)),
                _ => None,
            }
        })
        .collect();
    let mut carried: Vec<ActiveRequest> = Vec::new();
    for b in batchers.iter_mut() {
        carried.append(&mut b.take_actives());
    }
    match change {
        FleetChange::Membership { connects, disconnects } => {
            for s in connects {
                let _ = coord.admit(s);
            }
            for s in disconnects {
                coord.finish(s);
            }
        }
        FleetChange::Rebalance => coord.rebalance(),
        FleetChange::Strategy(s) => {
            coord.apply_strategy(&s);
        }
    }
    let mut fresh = fleet::build_batchers(coord, factory, opts);
    for a in fleet::distribute(carried, &mut fresh) {
        // the new fleet has nowhere to put this migrated stream — answer
        // its client instead of silently dropping it
        a.reject("no serving capacity, retry");
    }
    // the background load follows the physical core onto the new fleet
    for (cores, fraction) in degraded {
        apply_degradation(&mut fresh, cores, *fraction);
    }
    *offsets = fresh.iter().map(|b| now - b.engine.kernel_secs).collect();
    *batchers = fresh;
    report.rebuilds += 1;
    report.epochs_seen.push(coord.epoch());
    report.lease_sets.push(coord.leases().cloned().collect());
    // the replayed pre-epoch measurements must all be dropped
    for (lease, class, res) in &stale {
        if coord.observe(lease, *class, res) {
            report.stale_observations_accepted += 1;
        } else {
            report.stale_observations_dropped += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::engine::Engine;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::sim::{SimConfig, SimExecutor};
    use std::sync::Arc;

    fn engine(seed: u64) -> Engine<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, seed));
        let exec = SimExecutor::new(
            presets::core_12900k(),
            SimConfig { execute_real: true, ..SimConfig::noiseless() },
        );
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
    }

    fn req(id: u64, prompt: &[u32], max_new: usize) -> Request {
        Request { id, prompt: prompt.to_vec(), max_new_tokens: max_new }
    }

    #[test]
    fn scripted_arrivals_are_served_in_virtual_time() {
        let b = LeaseBatcher::new(engine(3), None, BatcherOpts::default());
        let script = vec![
            TraceEvent::arrive(0.0, 0, req(1, &[1, 2], 3)),
            TraceEvent::arrive(0.5, 0, req(2, &[3, 4], 3)),
        ];
        let rep = run_single(b, AdmitMode::Continuous, 16, script);
        assert!(rep.all_finished());
        assert_eq!(rep.tokens_of(1).len(), 3);
        assert_eq!(rep.tokens_of(2).len(), 3);
        // request 2 arrived half a virtual second in: the engine was long
        // idle (micro decode is µs-scale), so its TTFT stays µs-scale
        let r2 = &rep.requests[&2];
        assert!(r2.arrived_at == 0.5);
        assert!(r2.first_token_at.unwrap() > 0.5);
        assert!(r2.ttft().unwrap() < 0.01, "ttft {:?}", r2.ttft());
        assert_eq!(rep.total_decoded, 6);
        assert!(rep.makespan > 0.5);
        // both admissions are on record, in order, in the default class
        assert_eq!(rep.admit_order, vec![(1, 0), (2, 0)]);
    }

    #[test]
    fn run_is_deterministic() {
        let script = || {
            vec![
                TraceEvent::arrive(0.0, 0, req(1, &[5, 6, 7], 4)),
                TraceEvent::arrive(1e-4, 0, req(2, &[8], 4)),
            ]
        };
        let a = run_single(
            LeaseBatcher::new(engine(7), None, BatcherOpts::default()),
            AdmitMode::Continuous,
            16,
            script(),
        );
        let b = run_single(
            LeaseBatcher::new(engine(7), None, BatcherOpts::default()),
            AdmitMode::Continuous,
            16,
            script(),
        );
        assert_eq!(a.tokens_of(1), b.tokens_of(1));
        assert_eq!(a.tokens_of(2), b.tokens_of(2));
        assert_eq!(a.requests[&1].finished_at, b.requests[&1].finished_at);
        assert_eq!(a.mean_ttft(), b.mean_ttft());
    }

    #[test]
    fn bounded_queue_rejects_when_saturated() {
        let b = LeaseBatcher::new(
            engine(3),
            None,
            BatcherOpts { max_batch: 1, prefill_chunk: 16 },
        );
        // six simultaneous arrivals into a depth-2 queue: two fit, the
        // other four bounce with a protocol error instead of growing memory
        let script: Vec<TraceEvent> =
            (0..6).map(|i| TraceEvent::arrive(0.0, 0, req(i, &[1], 2))).collect();
        let rep = run_single(b, AdmitMode::Continuous, 2, script);
        assert_eq!(rep.rejected.len(), 4);
        for id in &rep.rejected {
            assert_eq!(rep.requests[id].error.as_deref(), Some("admission queue full"));
        }
        // the two that queued were fully served; memory never grew past depth
        let served: Vec<u64> = rep
            .requests
            .values()
            .filter(|r| r.finished_at.is_some())
            .map(|r| r.id)
            .collect();
        assert_eq!(served, vec![0, 1]);
        assert!(rep.queue_depth_samples.iter().all(|&d| d <= 2));
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn non_finite_event_time_is_rejected_at_construction() {
        // regression: a NaN arrival time used to reach the script sort's
        // `partial_cmp().unwrap()` and panic with no hint of the cause —
        // now the trace is validated up front with a pointed message
        let b = LeaseBatcher::new(engine(3), None, BatcherOpts::default());
        let script = vec![TraceEvent::arrive(f64::NAN, 0, req(1, &[1], 1))];
        let _ = run_single(b, AdmitMode::Continuous, 16, script);
    }

    #[test]
    fn harness_reports_per_stream_bandwidth() {
        let b = LeaseBatcher::new(engine(5), None, BatcherOpts::default());
        let script = vec![TraceEvent::arrive(0.0, 0, req(1, &[1, 2, 3], 4))];
        let rep = run_single(b, AdmitMode::Continuous, 16, script);
        assert!(rep.all_finished());
        let bw = rep.bandwidth.get(&0).expect("unleased batcher accounts under stream 0");
        assert!(bw.bytes > 0.0, "no kernel traffic recorded");
        assert!(bw.kernel_secs > 0.0);
        assert!(bw.achieved_gbps() > 0.0);
        // unleased: no bus reference, so utilization is undefined (0)
        assert_eq!(bw.bus_share_gbps, 0.0);
        assert_eq!(bw.utilization(), 0.0);
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_increasing() {
        let a = poisson_arrivals(42, 16, 1e-3);
        let b = poisson_arrivals(42, 16, 1e-3);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[1] > w[0]));
        let mean_gap = a.last().unwrap() / 16.0;
        assert!(mean_gap > 1e-4 && mean_gap < 1e-2, "mean gap {mean_gap}");
    }
}
