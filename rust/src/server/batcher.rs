//! Continuous batching inside one lease.
//!
//! A [`LeaseBatcher`] owns one [`Engine`] (typically built over a
//! coordinator lease's core subset) and a set of in-flight requests, and
//! advances them in **token rounds** instead of run-to-completion batches:
//!
//! * every round, each live request advances by one quantum — a bounded
//!   *prefill chunk* while its prompt is being consumed, then one decoded
//!   token per round;
//! * new requests are admitted **between rounds** (up to
//!   [`BatcherOpts::max_batch`]), so a stream arriving mid-run starts
//!   prefilling after at most one round plus one prefill chunk of delay
//!   rather than after the whole running batch has drained;
//! * finished requests are retired **immediately** at the end of their
//!   round and their KV slot returns to the [`SessionPool`] for reuse.
//!
//! Chunked prefill is bit-exact: every (position, row) dot product sees
//! exactly the inputs it would in a whole-prompt prefill, so token streams
//! are identical to solo execution under any admission interleaving
//! (property-tested in `rust/tests/prop_invariants.rs`).

use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::Lease;
use crate::engine::Engine;
use crate::exec::Executor;
use crate::metrics::PhaseMetrics;
use crate::model::{argmax, Session, SessionPool};
use crate::sim::xpu::XpuDispatch;

use super::protocol::{Event, Request};

/// Per-lease scheduling knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherOpts {
    /// concurrent requests (= KV slots) per engine
    pub max_batch: usize,
    /// prompt tokens prefilled per round — bounds how long one admission
    /// can starve the decode rounds of already-running requests
    pub prefill_chunk: usize,
}

impl Default for BatcherOpts {
    fn default() -> Self {
        BatcherOpts { max_batch: 4, prefill_chunk: 16 }
    }
}

/// Which serving phase a batcher is dedicated to under
/// [`crate::coordinator::ExecMode::Disaggregated`].
///
/// A `Prefill` batcher consumes prompts in chunks but never decodes: a
/// request that finishes its prompt parks (first token already computed by
/// the prefill argmax) until [`LeaseBatcher::take_prefilled`] hands it to
/// the paired `Decode` batcher, which streams tokens but admits nothing
/// directly. `Mixed` is the classic single-batcher behavior (both phases
/// interleaved in one token round) and the default everywhere else.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PhaseRole {
    #[default]
    Mixed,
    Prefill,
    Decode,
}

/// A queued request: parsed body, the channel its events stream back on,
/// its admission priority class (0 = highest; see
/// [`crate::router::ClassPolicy`]), and (for the TCP path) its wall-clock
/// enqueue instant for TTFT.
pub struct Pending {
    pub req: Request,
    pub tx: mpsc::Sender<Event>,
    pub class: usize,
    pub enqueued: Option<Instant>,
}

impl Pending {
    pub fn new(req: Request, tx: mpsc::Sender<Event>) -> Pending {
        Pending { req, tx, class: 0, enqueued: None }
    }

    /// Same as [`Pending::new`] with an explicit priority class.
    pub fn with_class(req: Request, tx: mpsc::Sender<Event>, class: usize) -> Pending {
        Pending { req, tx, class, enqueued: None }
    }
}

/// One in-flight request and its leased KV slot. Opaque outside the
/// serving layer: it can migrate between batchers across fleet rebuilds
/// (the session carries the KV state, so the stream stays bit-identical).
pub struct ActiveRequest {
    req: Request,
    tx: mpsc::Sender<Event>,
    enqueued: Option<Instant>,
    session: Session,
    /// prompt tokens consumed so far (prefill phase while < prompt.len())
    prefilled: usize,
    /// next token to emit/feed once prefill is complete
    next: u32,
    produced: usize,
    metrics: PhaseMetrics,
    dead: bool,
    emitted_first: bool,
}

impl ActiveRequest {
    pub fn id(&self) -> u64 {
        self.req.id
    }

    /// Refuse further service — the fleet it migrated off has no batcher
    /// left to adopt it. Answers the client with a retryable error instead
    /// of silently dropping the stream.
    pub fn reject(self, msg: &str) {
        let _ = self.tx.send(Event::Error { id: self.req.id, msg: msg.into() });
    }

    /// Bytes of KV state this session carries — what a cross-machine
    /// migration must ship over the interconnect: K and V, `n_layers`
    /// deep, `d_model` wide, f32, for every position written so far.
    pub fn kv_bytes(&self, cfg: &crate::model::ModelConfig) -> f64 {
        (2 * cfg.n_layers * cfg.d_model * 4 * self.session.pos) as f64
    }
}

/// A retired request, reported to the caller for metrics.
#[derive(Clone, Debug)]
pub struct Retired {
    pub id: u64,
    /// engine kernel clock at retirement (virtual seconds)
    pub at: f64,
    pub metrics: PhaseMetrics,
    /// true when the client went away before completion
    pub dead: bool,
}

/// Outcome of one scheduler round.
#[derive(Debug, Default)]
pub struct StepReport {
    /// requests that streamed their first token this round, with the
    /// engine kernel clock at emission (virtual-time TTFT for the harness)
    pub first_tokens: Vec<(u64, f64)>,
    /// wall-clock enqueue→first-token latencies (TCP path)
    pub ttft_wall: Vec<std::time::Duration>,
    pub retired: Vec<Retired>,
    pub decoded_tokens: usize,
    /// kernel seconds this round added to the engine clock
    pub kernel_secs: f64,
    /// kernel memory traffic this round added to the engine's byte meter
    pub bytes: f64,
}

/// Persistent per-lease scheduler: the continuous-batching replacement for
/// the old prefill-all-then-decode-all `run_batch`.
pub struct LeaseBatcher<E: Executor> {
    pub engine: Engine<E>,
    /// the coordinator lease this engine was built from (`None` for the
    /// static single-/multi-engine servers)
    pub lease: Option<Lease>,
    /// which side of the lease this batcher's engine runs on — `Split`
    /// for intra-kernel execution, `CpuOnly` / `DeviceOnly` for the two
    /// halves of an `ExecMode::AsyncBatch` pair
    dispatch: XpuDispatch,
    /// serving phase this batcher is dedicated to ([`PhaseRole::Mixed`]
    /// unless the fleet built a disaggregated prefill/decode pair)
    role: PhaseRole,
    pool: SessionPool,
    active: Vec<ActiveRequest>,
    /// lifetime count of requests admitted here (not adopted) — drives
    /// the deficit-based admission routing of an async-batch pair
    admitted: usize,
    opts: BatcherOpts,
}

impl<E: Executor> LeaseBatcher<E> {
    pub fn new(engine: Engine<E>, lease: Option<Lease>, opts: BatcherOpts) -> LeaseBatcher<E> {
        LeaseBatcher::with_dispatch(engine, lease, opts, XpuDispatch::Split)
    }

    /// A batcher tagged with the [`XpuDispatch`] its engine was built for
    /// — `server::fleet` uses this to pair the two halves of an
    /// async-batch lease.
    pub fn with_dispatch(
        mut engine: Engine<E>,
        lease: Option<Lease>,
        opts: BatcherOpts,
        dispatch: XpuDispatch,
    ) -> LeaseBatcher<E> {
        // the serving layer reads per-round measurements (coordinator
        // strength observations), so keep them on this engine
        engine.rt.capture_last = true;
        let cap = opts.max_batch.max(1);
        // leased batchers place KV slots bus-aware: each slot records its
        // stream and proportional share of the lease's bus allocation
        let pool = match &lease {
            Some(l) => SessionPool::with_lease(&engine.cfg, cap, l.stream, l.bus_share_gbps),
            None => SessionPool::new(&engine.cfg, cap),
        };
        LeaseBatcher {
            engine,
            lease,
            dispatch,
            role: PhaseRole::Mixed,
            pool,
            active: Vec::new(),
            admitted: 0,
            opts,
        }
    }

    /// Dedicate this batcher to one serving phase (builder-style; see
    /// [`PhaseRole`]).
    pub fn with_role(mut self, role: PhaseRole) -> LeaseBatcher<E> {
        self.role = role;
        self
    }

    /// Builder: this batcher's KV slots live across a NUMA/remote link of
    /// `gbps` bandwidth — every decode round charges its attention KV
    /// reads against that link on top of kernel time (leased batchers
    /// only; see [`SessionPool::set_remote_kv`]).
    pub fn with_remote_kv(mut self, gbps: f64) -> LeaseBatcher<E> {
        self.pool.set_remote_kv(gbps);
        self
    }

    pub fn role(&self) -> PhaseRole {
        self.role
    }

    pub fn dispatch(&self) -> XpuDispatch {
        self.dispatch
    }

    /// Requests admitted over this batcher's lifetime (adoptions from a
    /// previous epoch's fleet excluded).
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty()
    }

    /// Room to admit another request. Migrated-in sessions can push the
    /// batcher transiently over `max_batch`; it refuses admissions until
    /// retirements bring it back under.
    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.opts.max_batch
    }

    /// KV-slot ids of the live sessions (allocator-invariant checks).
    /// Sessions adopted from another batcher report `usize::MAX` until
    /// they retire into this pool.
    pub fn active_slots(&self) -> Vec<usize> {
        self.active.iter().map(|a| a.session.slot).collect()
    }

    pub fn pool(&self) -> &SessionPool {
        &self.pool
    }

    /// Admit one request into the batch. Invalid requests are answered
    /// with an error event and consumed (`Ok`); a full batch or exhausted
    /// slot pool hands the request back (`Err`) for requeueing.
    pub fn admit(&mut self, pending: Pending) -> Result<(), Pending> {
        if !self.has_capacity() {
            return Err(pending);
        }
        if pending.req.prompt.is_empty() {
            let _ = pending
                .tx
                .send(Event::Error { id: pending.req.id, msg: "empty prompt".into() });
            return Ok(());
        }
        if pending.req.prompt.len() >= self.engine.cfg.t_max {
            let _ = pending
                .tx
                .send(Event::Error { id: pending.req.id, msg: "prompt too long".into() });
            return Ok(());
        }
        let Some(session) = self.pool.acquire() else {
            return Err(pending);
        };
        let vocab = self.engine.cfg.vocab as u32;
        let mut req = pending.req;
        for t in req.prompt.iter_mut() {
            *t %= vocab;
        }
        let metrics = PhaseMetrics { prompt_tokens: req.prompt.len(), ..Default::default() };
        self.admitted += 1;
        self.active.push(ActiveRequest {
            req,
            tx: pending.tx,
            enqueued: pending.enqueued,
            session,
            prefilled: 0,
            next: 0,
            produced: 0,
            metrics,
            dead: false,
            emitted_first: false,
        });
        Ok(())
    }

    /// Take over an in-flight request from a previous epoch's batcher
    /// (fleet rebuild): the session travels with the request. Its slot id
    /// belonged to the old batcher's pool, so it is re-tagged as foreign
    /// (`usize::MAX`); [`SessionPool::release`] assigns it a fresh slot of
    /// this pool on retirement, keeping live slot ids unique per pool.
    pub fn adopt(&mut self, mut active: ActiveRequest) {
        active.session.slot = usize::MAX;
        self.active.push(active);
    }

    /// Drain every in-flight request (fleet rebuild), leaving the batcher
    /// empty.
    pub fn take_actives(&mut self) -> Vec<ActiveRequest> {
        std::mem::take(&mut self.active)
    }

    /// Live requests whose prompt is fully consumed — on a
    /// [`PhaseRole::Prefill`] batcher these are parked awaiting handoff.
    pub fn n_prefilled(&self) -> usize {
        self.active
            .iter()
            .filter(|a| !a.dead && a.prefilled == a.req.prompt.len())
            .count()
    }

    /// Admission slots currently unused (0 when a migration pushed the
    /// batcher transiently over `max_batch`).
    pub fn free_slots(&self) -> usize {
        self.opts.max_batch.saturating_sub(self.active.len())
    }

    /// Hand off up to `limit` prefill-complete requests for adoption by
    /// the paired decode batcher. Each departing session is
    /// [`SessionPool::detach`]ed so its KV (and the already-computed first
    /// token in `next`) travel with it while this pool's slot is reclaimed
    /// immediately — the handoff is bit-identical because the decode side
    /// replays exactly the `emit(next) → decode_step` sequence a
    /// [`PhaseRole::Mixed`] batcher would have run locally.
    pub fn take_prefilled(&mut self, limit: usize) -> Vec<ActiveRequest> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() && out.len() < limit {
            let ready = {
                let a = &self.active[i];
                !a.dead && a.prefilled == a.req.prompt.len()
            };
            if ready {
                let mut a = self.active.remove(i);
                self.pool.detach(&mut a.session);
                out.push(a);
            } else {
                i += 1;
            }
        }
        out
    }

    /// One scheduler round over the live batch; finished or abandoned
    /// requests are retired at the end of the round and their slots
    /// released for reuse.
    pub fn step(&mut self) -> StepReport {
        let mut report = StepReport::default();
        let chunk = self.opts.prefill_chunk.max(1);
        let round_start = self.engine.kernel_secs;
        let bytes_start = self.engine.bytes_moved;
        // remote-placed KV pools charge decode attention reads against the
        // far link (0.0 = local placement, reads are free)
        let remote_gbps = self.pool.placement_of(0).map_or(0.0, |p| p.remote_bw_gbps);

        {
            let LeaseBatcher { engine, active, role, .. } = self;
            let role = *role;
            for a in active.iter_mut() {
                if a.dead {
                    continue;
                }
                let prompt_len = a.req.prompt.len();
                if a.prefilled == prompt_len && role == PhaseRole::Prefill {
                    // prefill-complete on a dedicated prefill batcher:
                    // park for handoff instead of decoding here
                    continue;
                }
                if a.prefilled < prompt_len {
                    // ---- prefill quantum: one bounded chunk ----
                    let end = (a.prefilled + chunk).min(prompt_len);
                    let t0 = engine.kernel_secs;
                    // `prefill_in` lends the engine's scratch logits, so
                    // take the argmax before touching the clock again
                    let next =
                        argmax(engine.prefill_in(&mut a.session, &a.req.prompt[a.prefilled..end]));
                    a.metrics.prefill_secs += engine.kernel_secs - t0;
                    a.prefilled = end;
                    if a.prefilled == prompt_len {
                        a.next = next;
                    }
                } else if a.produced < a.req.max_new_tokens
                    && a.session.remaining_capacity(&engine.cfg) > 0
                {
                    // ---- decode quantum: stream one token ----
                    if a.tx.send(Event::Token { id: a.req.id, token: a.next }).is_err() {
                        a.dead = true; // client went away
                        continue;
                    }
                    if !a.emitted_first {
                        a.emitted_first = true;
                        report.first_tokens.push((a.req.id, engine.kernel_secs));
                        if let Some(t0) = a.enqueued {
                            report.ttft_wall.push(t0.elapsed());
                        }
                    }
                    let t0 = engine.kernel_secs;
                    let next = argmax(engine.decode_step_in(&mut a.session, a.next));
                    if remote_gbps > 0.0 {
                        // attention read K and V for every cached position
                        // over the remote link; the transfer rides on top
                        // of the kernel clock and lands in decode latency
                        let read = (2 * engine.cfg.n_layers * engine.cfg.d_model * 4
                            * a.session.pos) as f64;
                        engine.kernel_secs += read / (remote_gbps * 1e9);
                    }
                    a.metrics.decode_secs += engine.kernel_secs - t0;
                    a.next = next;
                    a.produced += 1;
                    a.metrics.decoded_tokens += 1;
                    report.decoded_tokens += 1;
                }
            }
        }

        // ---- immediate retirement: Done event + KV-slot reuse ----
        let mut i = 0;
        while i < self.active.len() {
            let finished = {
                let a = &self.active[i];
                a.dead
                    || (a.prefilled == a.req.prompt.len()
                        && (a.produced >= a.req.max_new_tokens
                            || a.session.remaining_capacity(&self.engine.cfg) == 0))
            };
            if finished {
                let a = self.active.remove(i);
                if !a.dead {
                    let _ = a.tx.send(Event::Done { id: a.req.id, metrics: a.metrics.clone() });
                }
                report.retired.push(Retired {
                    id: a.req.id,
                    at: self.engine.kernel_secs,
                    metrics: a.metrics,
                    dead: a.dead,
                });
                self.pool.release(a.session);
            } else {
                i += 1;
            }
        }

        report.kernel_secs = self.engine.kernel_secs - round_start;
        report.bytes = self.engine.bytes_moved - bytes_start;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::perf::PerfConfig;
    use crate::sched::DynamicScheduler;
    use crate::sim::{SimConfig, SimExecutor};
    use std::sync::Arc;

    fn test_engine(seed: u64) -> Engine<SimExecutor> {
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, seed));
        let exec = SimExecutor::new(
            presets::ultra_125h(),
            SimConfig { execute_real: true, ..SimConfig::noiseless() },
        );
        Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default())
    }

    /// A batcher over a real coordinator lease (the leased pool records
    /// bus-aware placement, which the remote-KV cost model hangs off).
    fn leased_batcher(seed: u64) -> LeaseBatcher<SimExecutor> {
        use crate::coordinator::{AllocPolicy, Coordinator};
        let spec = presets::ultra_125h();
        let mut coord = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let lease = coord.admit(0);
        let cfg = ModelConfig::micro();
        let weights = Arc::new(ModelWeights::random_init(&cfg, seed));
        let sim = SimConfig { execute_real: true, ..SimConfig::noiseless() };
        let exec = lease.sim_executor(&spec, sim);
        let engine =
            Engine::new(cfg, weights, exec, Box::new(DynamicScheduler), PerfConfig::default());
        LeaseBatcher::new(engine, Some(lease), BatcherOpts { max_batch: 2, prefill_chunk: 4 })
    }

    fn pending(id: u64, prompt: &[u32], max_new: usize) -> (Pending, mpsc::Receiver<Event>) {
        let (tx, rx) = mpsc::channel();
        let req = Request { id, prompt: prompt.to_vec(), max_new_tokens: max_new };
        (Pending::new(req, tx), rx)
    }

    fn drain_tokens(rx: &mpsc::Receiver<Event>) -> Vec<u32> {
        rx.try_iter()
            .filter_map(|e| match e {
                Event::Token { token, .. } => Some(token),
                _ => None,
            })
            .collect()
    }

    fn run_until_idle(b: &mut LeaseBatcher<SimExecutor>) {
        let mut guard = 0;
        while !b.is_idle() {
            b.step();
            guard += 1;
            assert!(guard < 10_000, "batcher did not drain");
        }
    }

    #[test]
    fn single_request_matches_generate_oracle() {
        let mut oracle = test_engine(3);
        let mut session = oracle.new_session();
        let (expect, _) = oracle.generate(&mut session, &[5, 6, 7], 6);

        let mut b = LeaseBatcher::new(
            test_engine(3),
            None,
            BatcherOpts { max_batch: 2, prefill_chunk: 2 },
        );
        let (p, rx) = pending(1, &[5, 6, 7], 6);
        b.admit(p).map_err(|_| ()).unwrap();
        run_until_idle(&mut b);
        assert_eq!(drain_tokens(&rx), expect);
        let done = rx.try_iter().count();
        assert_eq!(done, 0, "events fully drained");
    }

    #[test]
    fn mid_run_admission_keeps_streams_identical() {
        // request B joins while A is mid-decode; both must match solo runs
        let mut solo_a = test_engine(9);
        let mut sa = solo_a.new_session();
        let (expect_a, _) = solo_a.generate(&mut sa, &[1, 2, 3, 4, 5], 8);
        let mut solo_b = test_engine(9);
        let mut sb = solo_b.new_session();
        let (expect_b, _) = solo_b.generate(&mut sb, &[9, 8], 5);

        let mut b = LeaseBatcher::new(
            test_engine(9),
            None,
            BatcherOpts { max_batch: 4, prefill_chunk: 2 },
        );
        let (pa, rxa) = pending(1, &[1, 2, 3, 4, 5], 8);
        b.admit(pa).map_err(|_| ()).unwrap();
        for _ in 0..4 {
            b.step();
        }
        let (pb, rxb) = pending(2, &[9, 8], 5);
        b.admit(pb).map_err(|_| ()).unwrap();
        run_until_idle(&mut b);
        assert_eq!(drain_tokens(&rxa), expect_a);
        assert_eq!(drain_tokens(&rxb), expect_b);
    }

    #[test]
    fn retirement_frees_slots_for_reuse() {
        let mut b = LeaseBatcher::new(
            test_engine(1),
            None,
            BatcherOpts { max_batch: 2, prefill_chunk: 8 },
        );
        let (p, _rx1) = pending(1, &[3], 2);
        b.admit(p).map_err(|_| ()).unwrap();
        run_until_idle(&mut b);
        assert_eq!(b.pool().allocated(), 1);
        assert_eq!(b.pool().idle(), 1);
        // a second request reuses slot 0 instead of allocating slot 1
        let (p, _rx2) = pending(2, &[4], 2);
        b.admit(p).map_err(|_| ()).unwrap();
        assert_eq!(b.active_slots(), vec![0]);
        assert_eq!(b.pool().allocated(), 1);
    }

    #[test]
    fn full_batch_hands_the_request_back() {
        let mut b = LeaseBatcher::new(
            test_engine(1),
            None,
            BatcherOpts { max_batch: 1, prefill_chunk: 8 },
        );
        let (p1, _rx1) = pending(1, &[3], 4);
        b.admit(p1).map_err(|_| ()).unwrap();
        assert!(!b.has_capacity());
        let (p2, _rx2) = pending(2, &[4], 4);
        let back = b.admit(p2);
        assert!(back.is_err());
        assert_eq!(back.err().unwrap().req.id, 2);
    }

    #[test]
    fn too_long_prompt_errors_without_consuming_a_slot() {
        let mut b = LeaseBatcher::new(test_engine(1), None, BatcherOpts::default());
        let t_max = b.engine.cfg.t_max;
        let prompt: Vec<u32> = (0..t_max as u32).collect();
        let (p, rx) = pending(7, &prompt, 1);
        assert!(b.admit(p).is_ok());
        assert!(b.is_idle());
        assert_eq!(b.pool().allocated(), 0);
        match rx.try_recv().unwrap() {
            Event::Error { id, .. } => assert_eq!(id, 7),
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn empty_prompt_errors_instead_of_streaming_garbage() {
        // only the wire parser used to reject empty prompts; the library
        // path must too, or step() would stream an uncomputed token 0
        let mut b = LeaseBatcher::new(test_engine(1), None, BatcherOpts::default());
        let (p, rx) = pending(4, &[], 3);
        assert!(b.admit(p).is_ok());
        assert!(b.is_idle());
        match rx.try_recv().unwrap() {
            Event::Error { id, msg } => {
                assert_eq!(id, 4);
                assert_eq!(msg, "empty prompt");
            }
            other => panic!("expected error, got {other:?}"),
        }
    }

    #[test]
    fn prefill_role_parks_and_handoff_stream_is_bit_identical() {
        let mut oracle = test_engine(11);
        let mut session = oracle.new_session();
        let (expect, _) = oracle.generate(&mut session, &[4, 7, 1, 3], 6);

        let opts = BatcherOpts { max_batch: 2, prefill_chunk: 2 };
        let mut pf = LeaseBatcher::new(test_engine(11), None, opts).with_role(PhaseRole::Prefill);
        let mut dc = LeaseBatcher::new(test_engine(11), None, opts).with_role(PhaseRole::Decode);
        let (p, rx) = pending(1, &[4, 7, 1, 3], 6);
        pf.admit(p).map_err(|_| ()).unwrap();
        // the prefill batcher chews through the prompt but never decodes
        let mut guard = 0;
        while pf.n_prefilled() == 0 {
            pf.step();
            guard += 1;
            assert!(guard < 100, "prefill never completed");
        }
        assert!(drain_tokens(&rx).is_empty(), "prefill batcher decoded");
        // handoff reclaims the prefill slot immediately
        let ready = pf.take_prefilled(8);
        assert_eq!(ready.len(), 1);
        assert!(pf.is_idle());
        assert_eq!(pf.pool().idle(), 1);
        for a in ready {
            dc.adopt(a);
        }
        run_until_idle(&mut dc);
        assert_eq!(drain_tokens(&rx), expect, "handoff broke the token stream");
    }

    #[test]
    fn step_reports_round_bandwidth_bytes() {
        let mut b = LeaseBatcher::new(
            test_engine(5),
            None,
            BatcherOpts { max_batch: 2, prefill_chunk: 4 },
        );
        let (p, _rx) = pending(1, &[1, 2, 3], 3);
        b.admit(p).map_err(|_| ()).unwrap();
        let rep = b.step();
        assert!(rep.bytes > 0.0, "prefill round moved no bytes");
        assert!(rep.kernel_secs > 0.0);
        let mut total = rep.bytes;
        while !b.is_idle() {
            total += b.step().bytes;
        }
        // per-round deltas tile the engine's lifetime byte meter exactly
        assert_eq!(total, b.engine.bytes_moved);
    }

    #[test]
    fn dropped_client_retires_without_done() {
        let mut b = LeaseBatcher::new(test_engine(2), None, BatcherOpts::default());
        let (p, rx) = pending(1, &[2, 3], 6);
        b.admit(p).map_err(|_| ()).unwrap();
        b.step(); // prefill
        drop(rx); // client goes away
        let mut dead = false;
        for _ in 0..4 {
            let rep = b.step();
            if rep.retired.iter().any(|r| r.dead) {
                dead = true;
                break;
            }
        }
        assert!(dead, "abandoned request not retired as dead");
        assert!(b.is_idle());
        assert_eq!(b.pool().idle(), 1, "dead request's slot reclaimed");
    }

    #[test]
    fn local_kv_placement_beats_remote_on_decode() {
        let run = |remote: Option<f64>| {
            let mut b = leased_batcher(21);
            if let Some(gbps) = remote {
                b = b.with_remote_kv(gbps);
            }
            let (p, rx) = pending(1, &[5, 6, 7, 8], 6);
            b.admit(p).map_err(|_| ()).unwrap();
            run_until_idle(&mut b);
            (drain_tokens(&rx), b.engine.kernel_secs)
        };
        let (local_tokens, local_secs) = run(None);
        // the same request with its KV behind a 2 GB/s far link
        let (remote_tokens, remote_secs) = run(Some(2.0));
        // placement changes timing, never the generated stream
        assert_eq!(local_tokens, remote_tokens);
        assert!(
            remote_secs > local_secs,
            "remote KV reads must cost decode time: {remote_secs} vs {local_secs}"
        );
    }

    #[test]
    fn kv_bytes_grow_with_the_cursor() {
        let cfg = ModelConfig::micro();
        let mut b = LeaseBatcher::new(test_engine(4), None, BatcherOpts::default());
        let (p, _rx) = pending(1, &[1, 2], 4);
        b.admit(p).map_err(|_| ()).unwrap();
        assert_eq!(b.active[0].kv_bytes(&cfg), 0.0, "nothing cached before prefill");
        b.step();
        let after_prefill = b.active[0].kv_bytes(&cfg);
        assert!(after_prefill > 0.0);
        b.step();
        assert!(b.active[0].kv_bytes(&cfg) > after_prefill, "decode extends the KV footprint");
    }
}
