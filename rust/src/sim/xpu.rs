//! Extension (the paper's §4 future work): dispatch one kernel across
//! **hybrid compute units** — the CPU plus accelerators (NPU / iGPU) that
//! share the same system memory bus on an AIPC SoC.
//!
//! The mechanism is the paper's own, lifted one level: each *device* gets
//! a performance ratio learned from measured execution times with the
//! same eq. 2 + EWMA update, and each kernel is split proportionally
//! (eq. 3) — first across devices, then (on the CPU) across cores by the
//! inner dynamic scheduler. Bus contention between the CPU and the
//! accelerators is modelled with the same waterfill.

use super::bw::{waterfill, Contender};
use super::{HybridSim, SimConfig};
use crate::cpu::CpuSpec;
use crate::kernels::WorkCost;
use crate::sched::{DynamicScheduler, Scheduler};

/// An accelerator on the same SoC (NPU / iGPU class).
#[derive(Clone, Debug)]
pub struct AcceleratorSpec {
    pub name: String,
    /// effective int8 MAC/s (already folded: units × freq × utilization)
    pub ops_per_sec: f64,
    /// max share of the system bus it can pull (GB/s)
    pub mem_bw_gbps: f64,
    /// bus contention weight (DMA engines usually have high MLP)
    pub mem_weight: f64,
    /// per-kernel launch overhead (driver + fabric), seconds
    pub launch_overhead_secs: f64,
}

impl AcceleratorSpec {
    /// Intel AI Boost NPU class (Meteor Lake): ~10 int8 TOPS effective.
    pub fn npu() -> AcceleratorSpec {
        AcceleratorSpec {
            name: "npu".into(),
            ops_per_sec: 5.0e12, // MAC/s (10 TOPS ÷ 2 ops/MAC)
            mem_bw_gbps: 30.0,
            mem_weight: 1.5,
            launch_overhead_secs: 20e-6,
        }
    }

    /// Arc iGPU class: ~3 int8 TMAC/s effective.
    pub fn igpu() -> AcceleratorSpec {
        AcceleratorSpec {
            name: "igpu".into(),
            ops_per_sec: 3.0e12,
            mem_bw_gbps: 45.0,
            mem_weight: 1.8,
            launch_overhead_secs: 30e-6,
        }
    }
}

/// Result of one cross-device dispatch.
#[derive(Clone, Debug)]
pub struct XpuRunResult {
    pub wall_secs: f64,
    /// per-device busy time: index 0 = CPU, then accelerators in order
    pub device_secs: Vec<f64>,
    /// units processed per device
    pub device_units: Vec<usize>,
}

/// Two-level dynamic dispatcher: devices × (CPU cores).
pub struct XpuSim {
    pub cpu: HybridSim,
    pub accels: Vec<AcceleratorSpec>,
    /// learned per-device ratios (the device-level "performance table");
    /// index 0 = CPU
    pub device_ratios: Vec<f64>,
    pub alpha: f64,
    inner_sched: DynamicScheduler,
}

impl XpuSim {
    pub fn new(cpu_spec: CpuSpec, cfg: SimConfig, accels: Vec<AcceleratorSpec>) -> XpuSim {
        let n_dev = 1 + accels.len();
        XpuSim {
            cpu: HybridSim::new(cpu_spec, cfg),
            accels,
            device_ratios: vec![1.0; n_dev],
            alpha: 0.3,
            inner_sched: DynamicScheduler,
        }
    }

    /// Bus bandwidth each device sustains when all are active: the CPU
    /// aggregate competes with each accelerator's DMA engines.
    fn device_bandwidths(&self, active: &[bool]) -> Vec<f64> {
        // CPU cores aggregated into one contender
        let cpu_cap: f64 = self.cpu.spec.cores.iter().map(|c| c.mem_bw_gbps).sum();
        let cpu_weight: f64 = self.cpu.spec.cores.iter().map(|c| c.mem_weight).sum();
        let mut contenders = vec![Contender {
            weight: if active[0] { cpu_weight } else { 0.0 },
            cap: if active[0] { cpu_cap } else { 0.0 },
        }];
        for (i, a) in self.accels.iter().enumerate() {
            let on = active[i + 1];
            contenders.push(Contender {
                weight: if on { a.mem_weight } else { 0.0 },
                cap: if on { a.mem_bw_gbps } else { 0.0 },
            });
        }
        waterfill(&contenders, self.cpu.spec.bus_bw_gbps)
    }

    /// Execute one kernel split across all devices by the learned ratios.
    /// The CPU's share runs through the inner core-level dynamic loop.
    pub fn execute(&mut self, cost: &WorkCost, cpu_core_ratios: &[f64]) -> XpuRunResult {
        let n_dev = 1 + self.accels.len();
        let split =
            crate::sched::largest_remainder_split(cost.units, &self.device_ratios);
        let active: Vec<bool> = split.iter().map(|&u| u > 0).collect();
        let bws = self.device_bandwidths(&active);

        let mut device_secs = vec![0.0; n_dev];
        // CPU share: inner dynamic partition over the cores
        if split[0] > 0 {
            let mut sub = *cost;
            sub.units = split[0];
            let plan = self.inner_sched.plan(sub.units, 1, cpu_core_ratios);
            // the accelerators eat into the bus the CPU sees: scale the
            // simulated bus for the duration of this kernel
            let saved_bus = self.cpu.spec.bus_bw_gbps;
            self.cpu.spec.bus_bw_gbps = bws[0].max(1e-3);
            let res = self.cpu.execute_plan(None, &sub, &plan);
            self.cpu.spec.bus_bw_gbps = saved_bus;
            device_secs[0] = res.wall_secs;
        }
        // accelerators: roofline with their bus share + launch overhead
        for (i, a) in self.accels.iter().enumerate() {
            let units = split[i + 1];
            if units == 0 {
                continue;
            }
            let ops = units as f64 * cost.ops_per_unit;
            let bytes = units as f64 * cost.bytes_per_unit;
            let t_comp = ops / a.ops_per_sec;
            let t_mem = bytes / (bws[i + 1].max(1e-3) * 1e9);
            device_secs[i + 1] = a.launch_overhead_secs + t_comp.max(t_mem);
        }

        let wall = device_secs.iter().cloned().fold(0.0, f64::max);

        // device-level eq. 2 + EWMA update (same rule as the core table)
        let mut mass = 0.0;
        let mut s = 0.0;
        let mut n_parts = 0;
        for (i, &t) in device_secs.iter().enumerate() {
            if t > 0.0 {
                mass += self.device_ratios[i];
                s += self.device_ratios[i] / t;
                n_parts += 1;
            }
        }
        if n_parts >= 2 && s > 0.0 {
            let beta = (1.0 - self.alpha) * mass / s;
            for (i, &t) in device_secs.iter().enumerate() {
                if t > 0.0 {
                    self.device_ratios[i] =
                        self.alpha * self.device_ratios[i] + beta * self.device_ratios[i] / t;
                }
            }
        }

        XpuRunResult { wall_secs: wall, device_secs, device_units: split }
    }

    /// CPU-only reference latency for the same kernel (for speedup math).
    pub fn cpu_only(&mut self, cost: &WorkCost, cpu_core_ratios: &[f64]) -> f64 {
        let plan = self.inner_sched.plan(cost.units, 1, cpu_core_ratios);
        self.cpu.execute_plan(None, cost, &plan).wall_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::kernels::cost;

    fn xpu() -> XpuSim {
        XpuSim::new(
            presets::ultra_125h(),
            SimConfig::noiseless(),
            vec![AcceleratorSpec::npu()],
        )
    }

    fn converged_cpu_ratios() -> Vec<f64> {
        presets::ultra_125h().ideal_ratios(crate::cpu::Isa::AvxVnni)
    }

    #[test]
    fn device_ratios_converge_and_offload_helps_prefill() {
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let c = cost::gemm_i8_cost(1024, 4096, 4096); // compute-bound
        let cpu_only = x.cpu_only(&c, &ratios);
        let mut wall = f64::INFINITY;
        for _ in 0..15 {
            wall = x.execute(&c, &ratios).wall_secs;
        }
        // NPU ~5 TMAC/s vs CPU ~2.23 TMAC/s → combined ≈ 3.2× CPU-only
        let speedup = cpu_only / wall;
        assert!(speedup > 2.0, "speedup {speedup}");
        // learned device ratio favours the NPU
        assert!(
            x.device_ratios[1] > 1.5 * x.device_ratios[0],
            "ratios {:?}",
            x.device_ratios
        );
    }

    #[test]
    fn memory_bound_kernel_gains_little() {
        // decode GEMV is bus-bound: an accelerator on the same bus cannot
        // add bandwidth, so the gain must be small (the paper's reason to
        // target the *prefill* phase with hybrid units)
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let c = cost::gemv_q4_cost(4096, 4096);
        let cpu_only = x.cpu_only(&c, &ratios);
        let mut wall = f64::INFINITY;
        for _ in 0..15 {
            wall = x.execute(&c, &ratios).wall_secs;
        }
        let speedup = cpu_only / wall;
        assert!(speedup < 1.3, "memory-bound speedup should be small, got {speedup}");
    }

    #[test]
    fn all_units_processed_exactly_once() {
        let mut x = XpuSim::new(
            presets::core_12900k(),
            SimConfig::noiseless(),
            vec![AcceleratorSpec::npu(), AcceleratorSpec::igpu()],
        );
        let ratios = vec![1.0; 16];
        let c = cost::gemm_i8_cost(999, 2048, 2048);
        for _ in 0..5 {
            let res = x.execute(&c, &ratios);
            assert_eq!(res.device_units.iter().sum::<usize>(), 999);
        }
    }

    #[test]
    fn launch_overhead_disfavours_tiny_kernels() {
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let c = cost::gemm_i8_cost(8, 256, 256); // tiny kernel
        for _ in 0..25 {
            x.execute(&c, &ratios);
        }
        // the 20 µs launch overhead makes the NPU look slow on tiny work;
        // its learned ratio collapses below the CPU's
        assert!(
            x.device_ratios[1] < x.device_ratios[0],
            "ratios {:?}",
            x.device_ratios
        );
    }
}
