//! Cross-device dispatch (the paper's §4 future work): run one kernel
//! across **hybrid compute units** — the CPU plus accelerators (NPU /
//! iGPU) that share the same system memory bus on an AIPC SoC.
//!
//! The mechanism is the paper's own, lifted one level: each *device* gets
//! a performance ratio learned from measured execution times with the
//! same eq. 2 + EWMA update, and each kernel is split proportionally
//! (eq. 3) — first across devices, then (on the CPU) across cores by the
//! inner dynamic scheduler. Like the CPU runtime's `perf::PerfTable`, the
//! device table keeps **one row per kernel class**: a 20 µs launch
//! overhead makes the NPU a loser on µs-scale decode GEMVs while it wins
//! prefill GEMMs, and the two must not fight over one row. Bus contention
//! between the CPU and the accelerators is modelled with the same
//! waterfill.
//!
//! Two entry points share the model:
//! * [`XpuSim::execute`] — cost-only dispatch for benches and examples;
//! * [`XpuExecutor`] — the [`Executor`] the serving stack uses: a
//!   coordinator lease that owns accelerators materializes one
//!   ([`crate::coordinator::Lease::xpu_executor`]) and runs its engine on
//!   it unchanged. Its [`RunResult`] appends one entry per device after
//!   the per-core entries — the same canonical unit order a lease uses —
//!   so `Coordinator::observe` folds device timings into the strength
//!   table with no special casing.
//!
//! The intra-kernel range split above is one of two execution modes
//! (`coordinator::ExecMode`). Under **`AsyncBatch`** the lease is served
//! by *two* engines built from this module instead of one:
//! [`XpuDispatch::CpuOnly`] runs every kernel entirely on the cores and
//! [`XpuDispatch::DeviceOnly`] entirely on the accelerator(s), each at the
//! bus share it gets when both sides stream concurrently. The serving
//! layer (`server::fleet`) pairs one batcher on each and routes requests
//! between them, so the 20 µs device launch amortizes over a *whole token
//! round* of its own batch instead of gating every shared kernel — the
//! regime where `AsyncBatch` beats the intra-kernel split is exactly
//! µs-scale decode kernels on launch-heavy devices. Neither single-device
//! path can learn a device:CPU ratio from its own timings (one
//! participant, no relative signal); that learning moves up to
//! `Coordinator::observe_round`, which stitches the two batchers'
//! per-round walls back into the shared strength table.

use std::collections::BTreeMap;

use super::bw::{waterfill, Contender};
use super::{HybridSim, SimConfig};
use crate::cpu::CpuSpec;
use crate::exec::{Executor, RunResult, Work};
use crate::kernels::{KernelClass, WorkCost};
use crate::sched::{
    largest_remainder_split, proportional_split, DispatchPlan, DynamicScheduler, Scheduler,
};

/// An accelerator on the same SoC (NPU / iGPU class).
#[derive(Clone, Debug)]
pub struct AcceleratorSpec {
    pub name: String,
    /// effective int8 MAC/s (already folded: units × freq × utilization)
    pub ops_per_sec: f64,
    /// max share of the system bus it can pull (GB/s)
    pub mem_bw_gbps: f64,
    /// bus contention weight (DMA engines usually have high MLP)
    pub mem_weight: f64,
    /// per-kernel launch overhead (driver + fabric), seconds
    pub launch_overhead_secs: f64,
}

impl AcceleratorSpec {
    /// Intel AI Boost NPU class (Meteor Lake): ~10 int8 TOPS effective.
    pub fn npu() -> AcceleratorSpec {
        AcceleratorSpec {
            name: "npu".into(),
            ops_per_sec: 5.0e12, // MAC/s (10 TOPS ÷ 2 ops/MAC)
            mem_bw_gbps: 30.0,
            mem_weight: 1.5,
            launch_overhead_secs: 20e-6,
        }
    }

    /// Arc iGPU class: ~3 int8 TMAC/s effective.
    pub fn igpu() -> AcceleratorSpec {
        AcceleratorSpec {
            name: "igpu".into(),
            ops_per_sec: 3.0e12,
            mem_bw_gbps: 45.0,
            mem_weight: 1.8,
            launch_overhead_secs: 30e-6,
        }
    }
}

/// How an [`XpuExecutor`] maps one kernel onto the lease's devices.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum XpuDispatch {
    /// intra-kernel range split across CPU + accelerators by the learned
    /// class ratios (the paper's mechanism, default)
    #[default]
    Split,
    /// whole kernel on the CPU cores only, at the bus share the CPU side
    /// sustains while the paired device batcher streams concurrently —
    /// the core half of an `ExecMode::AsyncBatch` pair
    CpuOnly,
    /// whole kernel on the accelerator(s) only, the CPU side idle for
    /// this batch — the device half of an `ExecMode::AsyncBatch` pair
    DeviceOnly,
}

/// Result of one cross-device dispatch.
#[derive(Clone, Debug)]
pub struct XpuRunResult {
    pub wall_secs: f64,
    /// per-device busy time: index 0 = CPU, then accelerators in order
    pub device_secs: Vec<f64>,
    /// units processed per device
    pub device_units: Vec<usize>,
}

/// Two-level dynamic dispatcher: devices × (CPU cores).
pub struct XpuSim {
    pub cpu: HybridSim,
    pub accels: Vec<AcceleratorSpec>,
    pub alpha: f64,
    /// per-kernel-class learned device ratios (index 0 = CPU), lazily
    /// seeded from `class_seeds` (when that class has a dedicated seed
    /// row) or `seeds` on first use of a class
    tables: BTreeMap<KernelClass, Vec<f64>>,
    seeds: Vec<f64>,
    /// per-class seed overrides — a coordinator lease that has observed a
    /// class passes its learned row here so a fresh executor starts each
    /// class where the fleet's last epoch left it
    class_seeds: BTreeMap<KernelClass, Vec<f64>>,
    inner_sched: DynamicScheduler,
}

impl XpuSim {
    pub fn new(cpu_spec: CpuSpec, cfg: SimConfig, accels: Vec<AcceleratorSpec>) -> XpuSim {
        let n_dev = 1 + accels.len();
        XpuSim {
            cpu: HybridSim::new(cpu_spec, cfg),
            accels,
            alpha: 0.3,
            tables: BTreeMap::new(),
            seeds: vec![1.0; n_dev],
            class_seeds: BTreeMap::new(),
            inner_sched: DynamicScheduler,
        }
    }

    /// Seed the device-level ratios (index 0 = CPU, then accelerators) —
    /// e.g. from a coordinator lease's learned strengths. Applies to every
    /// kernel-class row created afterwards.
    pub fn with_device_seeds(mut self, seeds: Vec<f64>) -> XpuSim {
        assert_eq!(seeds.len(), 1 + self.accels.len(), "one seed per device");
        assert!(seeds.iter().all(|&s| s > 0.0), "seeds must be positive");
        self.seeds = seeds;
        self
    }

    /// Per-class seed rows (same `[cpu, dev...]` layout as
    /// [`XpuSim::with_device_seeds`]): a class listed here starts from its
    /// own row instead of the flat seeds, so e.g. a launch-collapsed GEMV
    /// verdict carries across executor rebuilds without writing the device
    /// off for GEMM work. Classes not listed still fall back to the flat
    /// seeds.
    pub fn with_class_seeds(mut self, class_seeds: BTreeMap<KernelClass, Vec<f64>>) -> XpuSim {
        for (class, row) in &class_seeds {
            assert_eq!(row.len(), 1 + self.accels.len(), "one {class:?} seed per device");
            assert!(row.iter().all(|&s| s > 0.0), "{class:?} seeds must be positive");
        }
        self.class_seeds = class_seeds;
        self
    }

    /// Current learned device ratios for a kernel class (index 0 = CPU).
    pub fn device_ratios(&mut self, class: KernelClass) -> &[f64] {
        let seeds = &self.seeds;
        let class_seeds = &self.class_seeds;
        self.tables
            .entry(class)
            .or_insert_with(|| class_seeds.get(&class).unwrap_or(seeds).clone())
    }

    /// Bus bandwidth each device sustains when all are active: the CPU
    /// aggregate competes with each accelerator's DMA engines.
    fn device_bandwidths(&self, active: &[bool]) -> Vec<f64> {
        // CPU cores aggregated into one contender
        let cpu_cap: f64 = self.cpu.spec.cores.iter().map(|c| c.mem_bw_gbps).sum();
        let cpu_weight: f64 = self.cpu.spec.cores.iter().map(|c| c.mem_weight).sum();
        let mut contenders = vec![Contender {
            weight: if active[0] { cpu_weight } else { 0.0 },
            cap: if active[0] { cpu_cap } else { 0.0 },
        }];
        for (i, a) in self.accels.iter().enumerate() {
            let on = active[i + 1];
            contenders.push(Contender {
                weight: if on { a.mem_weight } else { 0.0 },
                cap: if on { a.mem_bw_gbps } else { 0.0 },
            });
        }
        waterfill(&contenders, self.cpu.spec.bus_bw_gbps)
    }

    /// Split a kernel across devices by the class row. The CPU is the
    /// host/reference device: it always keeps at least one unit, so every
    /// dispatch measures it and a mis-seeded row can re-learn — a fully
    /// offloaded kernel would have a single participant, skip the eq. 2
    /// fold and freeze its ratios forever. The mirror case — a device
    /// whose ratio collapsed to a zero split — freezes *its* row for this
    /// executor's lifetime (an idle device produces no timing): that is
    /// the intended "don't offload this class" verdict within an epoch,
    /// and every fleet rebuild re-auditions the device through
    /// [`crate::coordinator::Lease::xpu_executor`]'s floored seeds.
    fn device_split(&mut self, cost: &WorkCost) -> Vec<usize> {
        let ratios = self.device_ratios(cost.class).to_vec();
        let mut split = largest_remainder_split(cost.units, &ratios);
        if split[0] == 0 && cost.units > 0 {
            if let Some(donor) = (1..split.len()).max_by_key(|&i| split[i]) {
                if split[donor] > 0 {
                    split[donor] -= 1;
                    split[0] += 1;
                }
            }
        }
        split
    }

    /// Roofline time of `units` units on accelerator `i` at bus share `bw`.
    fn accel_secs(&self, i: usize, units: usize, cost: &WorkCost, bw: f64) -> f64 {
        let a = &self.accels[i];
        let ops = units as f64 * cost.ops_per_unit;
        let bytes = units as f64 * cost.bytes_per_unit;
        let t_comp = ops / a.ops_per_sec;
        let t_mem = bytes / (bw.max(1e-3) * 1e9);
        a.launch_overhead_secs + t_comp.max(t_mem)
    }

    /// Device-level eq. 2 + EWMA update (same rule as the core table) on
    /// the class's row.
    fn fold(&mut self, class: KernelClass, device_secs: &[f64]) {
        let alpha = self.alpha;
        let seeds = &self.seeds;
        let class_seeds = &self.class_seeds;
        let row = self
            .tables
            .entry(class)
            .or_insert_with(|| class_seeds.get(&class).unwrap_or(seeds).clone());
        let mut mass = 0.0;
        let mut s = 0.0;
        let mut n_parts = 0;
        for (i, &t) in device_secs.iter().enumerate() {
            if t > 0.0 {
                mass += row[i];
                s += row[i] / t;
                n_parts += 1;
            }
        }
        if n_parts >= 2 && s > 0.0 {
            let beta = (1.0 - alpha) * mass / s;
            for (i, &t) in device_secs.iter().enumerate() {
                if t > 0.0 {
                    row[i] = alpha * row[i] + beta * row[i] / t;
                }
            }
        }
    }

    /// Execute one kernel split across all devices by the learned ratios.
    /// The CPU's share runs through the inner core-level dynamic loop.
    pub fn execute(&mut self, cost: &WorkCost, cpu_core_ratios: &[f64]) -> XpuRunResult {
        let n_dev = 1 + self.accels.len();
        let split = self.device_split(cost);
        let active: Vec<bool> = split.iter().map(|&u| u > 0).collect();
        let bws = self.device_bandwidths(&active);

        let mut device_secs = vec![0.0; n_dev];
        // CPU share: inner dynamic partition over the cores
        if split[0] > 0 {
            let mut sub = *cost;
            sub.units = split[0];
            let plan = self.inner_sched.plan(sub.units, 1, cpu_core_ratios);
            // the accelerators eat into the bus the CPU sees: scale the
            // simulated bus for the duration of this kernel
            let saved_bus = self.cpu.spec.bus_bw_gbps;
            self.cpu.spec.bus_bw_gbps = bws[0].max(1e-3);
            let res = self.cpu.execute_plan(None, &sub, &plan);
            self.cpu.spec.bus_bw_gbps = saved_bus;
            device_secs[0] = res.wall_secs;
        }
        // accelerators: roofline with their bus share + launch overhead
        for i in 0..self.accels.len() {
            let units = split[i + 1];
            if units > 0 {
                device_secs[i + 1] = self.accel_secs(i, units, cost, bws[i + 1]);
            }
        }

        let wall = device_secs.iter().cloned().fold(0.0, f64::max);
        self.fold(cost.class, &device_secs);
        XpuRunResult { wall_secs: wall, device_secs, device_units: split }
    }

    /// CPU-only reference latency for the same kernel (for speedup math).
    pub fn cpu_only(&mut self, cost: &WorkCost, cpu_core_ratios: &[f64]) -> f64 {
        let plan = self.inner_sched.plan(cost.units, 1, cpu_core_ratios);
        self.cpu.execute_plan(None, cost, &plan).wall_secs
    }
}

/// [`Executor`] over [`XpuSim`]: the serving stack's materialization of a
/// heterogeneous coordinator lease (cores + accelerators).
///
/// The engine's scheduler keeps planning over the **CPU cores only**
/// (`n_workers` = core count); `execute` re-splits the kernel across
/// devices by the class-keyed learned ratios, re-partitions the CPU share
/// proportionally to the engine's plan, rooflines each accelerator's share
/// and — under `execute_real` — runs the accelerator ranges' actual work,
/// so token streams stay bit-identical to any cores-only run. The returned
/// [`RunResult`] appends one per-device entry after the per-core entries;
/// `ParallelRuntime` slices them off for its core table while
/// `Coordinator::observe` folds them into the unit strength table.
pub struct XpuExecutor {
    pub xpu: XpuSim,
    /// device mapping for every kernel this executor runs
    pub dispatch: XpuDispatch,
}

impl XpuExecutor {
    pub fn new(xpu: XpuSim) -> XpuExecutor {
        XpuExecutor::with_dispatch(xpu, XpuDispatch::Split)
    }

    /// An executor locked to one [`XpuDispatch`] — `CpuOnly` /
    /// `DeviceOnly` build the two halves of an async-batch pair.
    pub fn with_dispatch(xpu: XpuSim, dispatch: XpuDispatch) -> XpuExecutor {
        XpuExecutor { xpu, dispatch }
    }

    pub fn spec(&self) -> &CpuSpec {
        &self.xpu.cpu.spec
    }

    /// Whole kernel on the CPU cores at the both-sides-active bus share.
    fn execute_cpu_only(&mut self, work: &dyn Work, plan: &DispatchPlan) -> RunResult {
        let cost = work.cost();
        let n_acc = self.xpu.accels.len();
        // the paired device batcher streams concurrently: waterfill with
        // every device active and keep only the CPU's share
        let bws = self.xpu.device_bandwidths(&vec![true; 1 + n_acc]);
        let saved_bus = self.xpu.cpu.spec.bus_bw_gbps;
        self.xpu.cpu.spec.bus_bw_gbps = bws[0].max(1e-3);
        let mut res = self.xpu.cpu.execute_plan(Some(work), &cost, plan);
        self.xpu.cpu.spec.bus_bw_gbps = saved_bus;
        // keep the canonical lease layout: one (idle) entry per device
        for _ in 0..n_acc {
            res.per_core_secs.push(None);
            res.units_done.push(0);
        }
        res
    }

    /// Whole kernel on the accelerator(s), CPU idle for this batch.
    fn execute_device_only(&mut self, work: &dyn Work) -> RunResult {
        let cost = work.cost();
        let n_cores = self.xpu.cpu.spec.n_cores();
        let n_acc = self.xpu.accels.len();
        let bws = self.xpu.device_bandwidths(&vec![true; 1 + n_acc]);
        // split across the devices by their class-row shares (all units
        // to the single accelerator in the common case)
        let ratios: Vec<f64> = self.xpu.device_ratios(cost.class)[1..].to_vec();
        let split = largest_remainder_split(cost.units, &ratios);
        let mut per_core_secs: Vec<Option<f64>> = vec![None; n_cores];
        let mut units_done = vec![0usize; n_cores];
        let mut wall = 0.0f64;
        let mut cursor = 0usize;
        for (i, &units) in split.iter().enumerate() {
            if units > 0 {
                if self.xpu.cpu.cfg.execute_real {
                    work.run_range(n_cores + i, cursor..cursor + units);
                }
                cursor += units;
                let t = self.xpu.accel_secs(i, units, &cost, bws[i + 1]);
                wall = wall.max(t);
                per_core_secs.push(Some(t));
            } else {
                per_core_secs.push(None);
            }
            units_done.push(units);
        }
        // the lease's virtual clock advances by the device wall
        self.xpu.cpu.now += wall;
        RunResult { per_core_secs, wall_secs: wall, units_done, bytes: 0.0 }
    }
}

impl Executor for XpuExecutor {
    fn n_workers(&self) -> usize {
        self.xpu.cpu.spec.n_cores()
    }

    fn execute(&mut self, work: &dyn Work, plan: &DispatchPlan) -> RunResult {
        let cost = work.cost();
        let n_cores = self.xpu.cpu.spec.n_cores();
        let n_acc = self.xpu.accels.len();
        if n_acc == 0 {
            // cores-only lease: exactly the plain simulator path
            return self.xpu.cpu.execute_plan(Some(work), &cost, plan);
        }
        match self.dispatch {
            XpuDispatch::Split => {}
            XpuDispatch::CpuOnly => return self.execute_cpu_only(work, plan),
            XpuDispatch::DeviceOnly => return self.execute_device_only(work),
        }

        let split = self.xpu.device_split(&cost);
        let active: Vec<bool> = split.iter().map(|&u| u > 0).collect();
        let bws = self.xpu.device_bandwidths(&active);

        // ---- CPU share: prefix units, re-partitioned to the engine
        // plan's per-core proportions (grain preserved) ----
        let weights: Vec<f64> = match plan {
            DispatchPlan::Partitioned(rs) => rs.iter().map(|r| r.len() as f64).collect(),
            _ => vec![1.0; n_cores],
        };
        let cpu_res = if split[0] > 0 {
            let mut sub = cost;
            sub.units = split[0];
            let cpu_plan =
                DispatchPlan::Partitioned(proportional_split(split[0], work.grain(), &weights));
            let saved_bus = self.xpu.cpu.spec.bus_bw_gbps;
            self.xpu.cpu.spec.bus_bw_gbps = bws[0].max(1e-3);
            let res = self.xpu.cpu.execute_plan(Some(work), &sub, &cpu_plan);
            self.xpu.cpu.spec.bus_bw_gbps = saved_bus;
            res
        } else {
            RunResult {
                per_core_secs: vec![None; n_cores],
                wall_secs: 0.0,
                units_done: vec![0; n_cores],
                bytes: 0.0,
            }
        };

        // ---- accelerator shares: suffix ranges, real work included ----
        let mut device_secs = vec![0.0; 1 + n_acc];
        device_secs[0] = cpu_res.wall_secs;
        let mut cursor = split[0];
        for i in 0..n_acc {
            let units = split[i + 1];
            if units == 0 {
                continue;
            }
            if self.xpu.cpu.cfg.execute_real {
                work.run_range(n_cores + i, cursor..cursor + units);
            }
            cursor += units;
            device_secs[i + 1] = self.xpu.accel_secs(i, units, &cost, bws[i + 1]);
        }

        let wall = device_secs.iter().cloned().fold(0.0, f64::max);
        // the lease's virtual clock is the kernel wall; keep the CPU sim's
        // clock in step when an accelerator is the straggler
        self.xpu.cpu.now += wall - device_secs[0];
        self.xpu.fold(cost.class, &device_secs);

        let mut per_core_secs = cpu_res.per_core_secs;
        let mut units_done = cpu_res.units_done;
        for i in 0..n_acc {
            let units = split[i + 1];
            per_core_secs.push(if units > 0 { Some(device_secs[i + 1]) } else { None });
            units_done.push(units);
        }
        RunResult { per_core_secs, wall_secs: wall, units_done, bytes: 0.0 }
    }

    fn inject_background(&mut self, workers: &[usize], fraction: f64) {
        let n_cores = self.xpu.cpu.spec.n_cores();
        for &w in workers.iter().filter(|&&w| w < n_cores) {
            self.xpu.cpu.inject_background(w, fraction);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::exec::{FnWork, PhantomWork};
    use crate::kernels::cost;

    fn xpu() -> XpuSim {
        XpuSim::new(
            presets::ultra_125h(),
            SimConfig::noiseless(),
            vec![AcceleratorSpec::npu()],
        )
    }

    fn converged_cpu_ratios() -> Vec<f64> {
        presets::ultra_125h().ideal_ratios(crate::cpu::Isa::AvxVnni)
    }

    #[test]
    fn device_ratios_converge_and_offload_helps_prefill() {
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let c = cost::gemm_i8_cost(1024, 4096, 4096); // compute-bound
        let cpu_only = x.cpu_only(&c, &ratios);
        let mut wall = f64::INFINITY;
        for _ in 0..15 {
            wall = x.execute(&c, &ratios).wall_secs;
        }
        // NPU ~5 TMAC/s vs CPU ~2.23 TMAC/s → combined ≈ 3.2× CPU-only
        let speedup = cpu_only / wall;
        assert!(speedup > 2.0, "speedup {speedup}");
        // learned device ratio favours the NPU
        let dr = x.device_ratios(KernelClass::GemmI8);
        assert!(dr[1] > 1.5 * dr[0], "ratios {dr:?}");
    }

    #[test]
    fn memory_bound_kernel_gains_little() {
        // decode GEMV is bus-bound: an accelerator on the same bus cannot
        // add bandwidth, so the gain must be small (the paper's reason to
        // target the *prefill* phase with hybrid units)
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let c = cost::gemv_q4_cost(4096, 4096);
        let cpu_only = x.cpu_only(&c, &ratios);
        let mut wall = f64::INFINITY;
        for _ in 0..15 {
            wall = x.execute(&c, &ratios).wall_secs;
        }
        let speedup = cpu_only / wall;
        assert!(speedup < 1.3, "memory-bound speedup should be small, got {speedup}");
    }

    #[test]
    fn all_units_processed_exactly_once() {
        let mut x = XpuSim::new(
            presets::core_12900k(),
            SimConfig::noiseless(),
            vec![AcceleratorSpec::npu(), AcceleratorSpec::igpu()],
        );
        let ratios = vec![1.0; 16];
        let c = cost::gemm_i8_cost(999, 2048, 2048);
        for _ in 0..5 {
            let res = x.execute(&c, &ratios);
            assert_eq!(res.device_units.iter().sum::<usize>(), 999);
        }
    }

    #[test]
    fn launch_overhead_disfavours_tiny_kernels() {
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let c = cost::gemm_i8_cost(8, 256, 256); // tiny kernel
        for _ in 0..25 {
            x.execute(&c, &ratios);
        }
        // the 20 µs launch overhead makes the NPU look slow on tiny work;
        // its learned ratio collapses below the CPU's
        let dr = x.device_ratios(KernelClass::GemmI8);
        assert!(dr[1] < dr[0], "ratios {dr:?}");
    }

    #[test]
    fn device_tables_are_independent_per_kernel_class() {
        // tiny decode GEMVs collapse the NPU's GemvQ4 row; the GemmI8 row
        // (prefill) must keep favouring the device
        let mut x = xpu();
        let ratios = converged_cpu_ratios();
        let gemm = cost::gemm_i8_cost(1024, 4096, 4096);
        let gemv = cost::gemv_q4_cost(64, 128); // µs-scale decode kernel
        for _ in 0..20 {
            x.execute(&gemm, &ratios);
            x.execute(&gemv, &ratios);
        }
        let gemm_row = x.device_ratios(KernelClass::GemmI8).to_vec();
        let gemv_row = x.device_ratios(KernelClass::GemvQ4).to_vec();
        assert!(gemm_row[1] > gemm_row[0], "prefill row lost the NPU: {gemm_row:?}");
        assert!(gemv_row[1] < gemv_row[0], "decode row kept the NPU: {gemv_row:?}");
    }

    #[test]
    fn seeded_ratios_steer_the_first_split() {
        let mut x = xpu().with_device_seeds(vec![1.0, 3.0]);
        let c = cost::gemm_i8_cost(400, 1024, 1024);
        let res = x.execute(&c, &converged_cpu_ratios());
        assert_eq!(res.device_units[1], 300, "seeded 3:1 split, got {:?}", res.device_units);
    }

    #[test]
    fn class_seeds_override_flat_seeds_per_class_only() {
        let mut class_seeds = BTreeMap::new();
        class_seeds.insert(KernelClass::GemvQ4, vec![3.0, 1.0]); // collapsed-GEMV verdict
        let mut x = xpu().with_device_seeds(vec![1.0, 3.0]).with_class_seeds(class_seeds);
        // the seeded GEMV row starts 3:1 toward the CPU...
        let gemv = x.device_ratios(KernelClass::GemvQ4).to_vec();
        assert_eq!(gemv, vec![3.0, 1.0]);
        // ...while an unlisted class still reads the flat seeds
        let gemm = x.device_ratios(KernelClass::GemmI8).to_vec();
        assert_eq!(gemm, vec![1.0, 3.0]);
    }

    // ---- XpuExecutor ----

    fn noiseless_exec(accels: Vec<AcceleratorSpec>) -> XpuExecutor {
        XpuExecutor::new(XpuSim::new(presets::ultra_125h(), SimConfig::noiseless(), accels))
    }

    #[test]
    fn executor_without_accels_matches_plain_simulator() {
        let c = cost::gemm_i8_cost(512, 1024, 1024);
        let work = PhantomWork::new(c);
        let plan = DynamicScheduler.plan(512, 1, &converged_cpu_ratios());
        let mut a = noiseless_exec(vec![]);
        let mut b = super::super::SimExecutor::new(presets::ultra_125h(), SimConfig::noiseless());
        let ra = a.execute(&work, &plan);
        let rb = b.execute(&work, &plan);
        assert_eq!(ra.per_core_secs.len(), rb.per_core_secs.len());
        assert!((ra.wall_secs - rb.wall_secs).abs() < 1e-15);
    }

    #[test]
    fn executor_appends_device_entries_and_conserves_units() {
        let mut x = noiseless_exec(vec![AcceleratorSpec::npu()]);
        let n_cores = x.n_workers();
        let c = cost::gemm_i8_cost(1024, 2048, 2048);
        let work = PhantomWork::new(c);
        let plan = DynamicScheduler.plan(1024, 1, &converged_cpu_ratios());
        let res = x.execute(&work, &plan);
        assert_eq!(res.per_core_secs.len(), n_cores + 1);
        assert_eq!(res.units_done.len(), n_cores + 1);
        assert_eq!(res.units_done.iter().sum::<usize>(), 1024);
        // the device participated and its busy time bounds the wall
        let dev = res.per_core_secs[n_cores].expect("device idle");
        assert!(dev > 0.0 && dev <= res.wall_secs + 1e-12);
    }

    #[test]
    fn executor_runs_accelerator_ranges_for_real() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cfg = SimConfig { execute_real: true, ..SimConfig::noiseless() };
        let mut x = XpuExecutor::new(XpuSim::new(
            presets::ultra_125h(),
            cfg,
            vec![AcceleratorSpec::npu()],
        ));
        let counter = AtomicUsize::new(0);
        let work = FnWork::new(cost::gemm_i8_cost(512, 1024, 1024), 1, |_w, r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        let plan = DynamicScheduler.plan(512, 1, &converged_cpu_ratios());
        x.execute(&work, &plan);
        assert_eq!(counter.load(Ordering::Relaxed), 512, "accelerator share skipped");
    }

    #[test]
    fn cpu_only_dispatch_keeps_layout_and_leaves_devices_idle() {
        let mut x = XpuExecutor::with_dispatch(
            XpuSim::new(
                presets::ultra_125h(),
                SimConfig::noiseless(),
                vec![AcceleratorSpec::npu()],
            ),
            XpuDispatch::CpuOnly,
        );
        let n_cores = x.n_workers();
        let c = cost::gemm_i8_cost(512, 1024, 1024);
        let work = PhantomWork::new(c);
        let plan = DynamicScheduler.plan(512, 1, &converged_cpu_ratios());
        let res = x.execute(&work, &plan);
        assert_eq!(res.per_core_secs.len(), n_cores + 1);
        assert_eq!(res.per_core_secs[n_cores], None, "device must stay idle");
        assert_eq!(res.units_done[n_cores], 0);
        assert_eq!(res.units_done.iter().sum::<usize>(), 512);
        // the concurrent device batcher eats bus: slower than a solo run
        // with the whole bus on a memory-bound kernel
        let mut solo =
            super::super::SimExecutor::new(presets::ultra_125h(), SimConfig::noiseless());
        let mem = cost::gemv_q4_cost(4096, 4096);
        let mwork = PhantomWork::new(mem);
        let mplan = DynamicScheduler.plan(4096, 1, &converged_cpu_ratios());
        let shared = x.execute(&mwork, &mplan).wall_secs;
        let alone = solo.execute(&mwork, &mplan).wall_secs;
        assert!(shared > alone, "bus contention missing: {shared} vs {alone}");
    }

    #[test]
    fn device_only_dispatch_runs_the_whole_kernel_on_the_accelerator() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cfg = SimConfig { execute_real: true, ..SimConfig::noiseless() };
        let mut x = XpuExecutor::with_dispatch(
            XpuSim::new(presets::ultra_125h(), cfg, vec![AcceleratorSpec::npu()]),
            XpuDispatch::DeviceOnly,
        );
        let n_cores = x.n_workers();
        let counter = AtomicUsize::new(0);
        let work = FnWork::new(cost::gemm_i8_cost(256, 1024, 1024), 1, |_w, r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        let plan = DynamicScheduler.plan(256, 1, &converged_cpu_ratios());
        let before = x.xpu.cpu.now;
        let res = x.execute(&work, &plan);
        assert_eq!(counter.load(Ordering::Relaxed), 256, "device range skipped");
        assert!(res.per_core_secs[..n_cores].iter().all(|t| t.is_none()), "cores must idle");
        assert_eq!(res.units_done[n_cores], 256);
        let dev = res.per_core_secs[n_cores].expect("device idle");
        assert!((dev - res.wall_secs).abs() < 1e-15);
        assert!(res.wall_secs >= AcceleratorSpec::npu().launch_overhead_secs);
        assert!(x.xpu.cpu.now > before, "virtual clock did not advance");
    }

    #[test]
    fn executor_background_injection_reaches_the_cpu_sim() {
        let mut x = noiseless_exec(vec![AcceleratorSpec::npu()]);
        let c = cost::gemm_i8_cost(512, 2048, 2048);
        let work = PhantomWork::new(c);
        let plan = DynamicScheduler.plan(512, 1, &converged_cpu_ratios());
        // compare per-core *rates* — the device split shifts between the
        // calls as the class table learns, so raw times are not comparable
        let rate = |res: &RunResult| {
            res.units_done[0] as f64 / res.per_core_secs[0].expect("core 0 idle")
        };
        let before = rate(&x.execute(&work, &plan));
        x.inject_background(&[0], 0.5);
        let after = rate(&x.execute(&work, &plan));
        assert!(
            (before / after - 2.0).abs() < 0.05,
            "background steal invisible: rate {before} → {after}"
        );
    }
}
