//! Discrete-event simulator of a hybrid CPU.
//!
//! This is the substitute substrate for the paper's silicon (see DESIGN.md):
//! it reproduces the *observable* the scheduler feeds on — per-core
//! execution times under heterogeneous compute rates and shared-bus
//! memory contention — in deterministic virtual time.
//!
//! Model per kernel invocation:
//! * compute rate of core i: `freq·ops_per_cycle[isa]·efficiency_i(t)`
//! * memory: weighted waterfill of the shared bus over *currently active*
//!   cores (weights = MLP proxies, caps = per-core link + actual demand),
//!   re-solved at every completion event — see [`bw::waterfill`]
//! * unit progress rate: `min(compute, memory)` roofline combine
//! * work-stealing plans pay a claim overhead per chunk; every plan pays a
//!   dispatch (fork/join) overhead per kernel
//! * optional OU noise + background-load steals ([`noise`])

pub mod bw;
pub mod noise;
pub mod xpu;

use std::ops::Range;

use crate::cpu::CpuSpec;
use crate::exec::{Executor, RunResult, Work};
use crate::kernels::WorkCost;
use crate::sched::DispatchPlan;
use crate::util::rng::Rng;

pub use noise::{BackgroundLoad, NoiseConfig};

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// per-kernel fork/join + partition computation overhead (seconds)
    pub dispatch_overhead_secs: f64,
    /// per-chunk claim overhead for chunked/guided plans (seconds)
    pub chunk_claim_overhead_secs: f64,
    pub noise: NoiseConfig,
    /// if true, `Work::run_range` is actually executed (serially, in
    /// simulated-claim order) so results are real while time is virtual
    pub execute_real: bool,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            dispatch_overhead_secs: 2.0e-6,
            chunk_claim_overhead_secs: 1.5e-7,
            noise: NoiseConfig::default(),
            execute_real: false,
            seed: 0xC0FE,
        }
    }
}

impl SimConfig {
    pub fn noiseless() -> Self {
        SimConfig { noise: NoiseConfig::disabled(), ..Default::default() }
    }
}

/// Aggregate statistics over a simulation's lifetime.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub kernels: u64,
    pub events: u64,
    pub total_bytes: f64,
    pub total_ops: f64,
}

pub struct HybridSim {
    pub spec: CpuSpec,
    pub cfg: SimConfig,
    noise: noise::NoiseState,
    rng: Rng,
    /// virtual time (seconds since simulator creation)
    pub now: f64,
    pub stats: SimStats,
}

struct CoreRun {
    /// units left in the current chunk (fractional during simulation)
    remaining: f64,
    /// absolute virtual time until which the core is paying claim overhead
    stall_until: f64,
    units_done: usize,
    claims: Vec<Range<usize>>,
    finished_at: Option<f64>,
    /// partitioned range still to claim (single chunk), if any
    fixed: Option<Range<usize>>,
    current: Range<usize>,
}

impl HybridSim {
    pub fn new(spec: CpuSpec, cfg: SimConfig) -> HybridSim {
        spec.validate().expect("invalid CpuSpec");
        let noise = noise::NoiseState::new(spec.n_cores(), cfg.noise.clone());
        let rng = Rng::new(cfg.seed);
        HybridSim { spec, cfg, noise, rng, now: 0.0, stats: SimStats::default() }
    }

    /// A background process shows up *now* and steals `fraction` of core
    /// `core`'s cycles for the rest of the run (the live-drift scenario of
    /// `server::testing`; the scripted counterpart is
    /// `NoiseConfig::background`).
    pub fn inject_background(&mut self, core: usize, fraction: f64) {
        assert!(core < self.spec.n_cores(), "core {core} out of range");
        self.noise.add_background(BackgroundLoad {
            core,
            start: self.now,
            end: 1e9,
            fraction,
        });
    }

    /// The MLC-like reference: total stream throughput with every core
    /// pulling flat-out (GB/s).
    pub fn mlc_bandwidth(&self) -> f64 {
        let contenders: Vec<bw::Contender> = self
            .spec
            .cores
            .iter()
            .map(|c| bw::Contender { weight: c.mem_weight, cap: c.mem_bw_gbps })
            .collect();
        bw::full_contention_throughput(&contenders, self.spec.bus_bw_gbps)
    }

    /// Simulate one kernel under `plan`. `work` enables real execution.
    pub fn execute_plan(
        &mut self,
        work: Option<&dyn Work>,
        cost: &WorkCost,
        plan: &DispatchPlan,
    ) -> RunResult {
        let n = self.spec.n_cores();
        let total = cost.units;
        let invocation_start = self.now;
        self.now += self.cfg.dispatch_overhead_secs;
        let kernel_start = self.now;

        // ---- initialize per-core chunk sources ----
        let mut cores: Vec<CoreRun> = (0..n)
            .map(|_| CoreRun {
                remaining: 0.0,
                stall_until: 0.0,
                units_done: 0,
                claims: Vec::new(),
                finished_at: None,
                fixed: None,
                current: 0..0,
            })
            .collect();
        let mut cursor = 0usize; // shared claim cursor (chunked/guided)
        match plan {
            DispatchPlan::Partitioned(ranges) => {
                assert!(ranges.len() <= n, "plan for more workers than cores");
                for (i, r) in ranges.iter().enumerate() {
                    if !r.is_empty() {
                        cores[i].fixed = Some(r.clone());
                    }
                }
            }
            DispatchPlan::Chunked { .. } | DispatchPlan::Guided { .. } => {}
        }
        let claim = |cursor: &mut usize, plan: &DispatchPlan, n: usize| -> Option<Range<usize>> {
            if *cursor >= total {
                return None;
            }
            let size = match plan {
                DispatchPlan::Chunked { chunk } => *chunk,
                DispatchPlan::Guided { min_chunk } => {
                    ((total - *cursor) / (2 * n)).max(*min_chunk)
                }
                DispatchPlan::Partitioned(_) => unreachable!(),
            };
            let start = *cursor;
            let end = (start + size).min(total);
            *cursor = end;
            Some(start..end)
        };

        // initial claims
        for i in 0..n {
            match plan {
                DispatchPlan::Partitioned(_) => {
                    if let Some(r) = cores[i].fixed.take() {
                        cores[i].remaining = r.len() as f64;
                        cores[i].current = r.clone();
                        cores[i].claims.push(r);
                        cores[i].stall_until = kernel_start;
                    } else {
                        cores[i].finished_at = Some(kernel_start);
                    }
                }
                _ => {
                    if let Some(r) = claim(&mut cursor, plan, n) {
                        cores[i].remaining = r.len() as f64;
                        cores[i].current = r.clone();
                        cores[i].claims.push(r);
                        cores[i].stall_until = kernel_start + self.cfg.chunk_claim_overhead_secs;
                    } else {
                        cores[i].finished_at = Some(kernel_start);
                    }
                }
            }
        }

        self.now = kernel_start;
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(guard < 50_000_000, "simulator event-loop runaway");
            let unfinished: Vec<usize> =
                (0..n).filter(|&i| cores[i].finished_at.is_none()).collect();
            if unfinished.is_empty() {
                break;
            }
            // rates for running (non-stalled) cores
            let running: Vec<usize> = unfinished
                .iter()
                .copied()
                .filter(|&i| self.now >= cores[i].stall_until && cores[i].remaining > 0.0)
                .collect();

            let mut dt = f64::INFINITY;
            // stalled cores bound dt by their wake-up
            for &i in &unfinished {
                if self.now < cores[i].stall_until {
                    dt = dt.min(cores[i].stall_until - self.now);
                }
            }

            let mut rates = vec![0.0f64; n];
            if !running.is_empty() {
                // compute rates (units/s) limited by the compute pipeline
                let comp: Vec<f64> = running
                    .iter()
                    .map(|&i| {
                        let eff = self.noise.efficiency(i, self.now);
                        if cost.ops_per_unit <= 0.0 {
                            f64::INFINITY
                        } else {
                            self.spec.cores[i].compute_rate(cost.isa) * eff / cost.ops_per_unit
                        }
                    })
                    .collect();
                if cost.bytes_per_unit > 0.0 {
                    let contenders: Vec<bw::Contender> = running
                        .iter()
                        .zip(&comp)
                        .map(|(&i, &cr)| {
                            let demand_gbps = if cr.is_finite() {
                                (cr * cost.bytes_per_unit / 1e9).min(self.spec.cores[i].mem_bw_gbps)
                            } else {
                                self.spec.cores[i].mem_bw_gbps
                            };
                            let weight = self.spec.cores[i].mem_weight;
                            bw::Contender { weight, cap: demand_gbps }
                        })
                        .collect();
                    let alloc = bw::waterfill(&contenders, self.spec.bus_bw_gbps);
                    for ((&i, &cr), &bwa) in running.iter().zip(&comp).zip(&alloc) {
                        let mem_rate = bwa * 1e9 / cost.bytes_per_unit;
                        rates[i] = cr.min(mem_rate);
                    }
                } else {
                    for (&i, &cr) in running.iter().zip(&comp) {
                        rates[i] = cr;
                    }
                }
                for &i in &running {
                    if rates[i] > 0.0 {
                        if rates[i].is_finite() {
                            dt = dt.min(cores[i].remaining / rates[i]);
                        } else {
                            dt = 0.0;
                        }
                    }
                }
            }
            assert!(dt.is_finite(), "no progress possible: all rates zero");
            self.stats.events += 1;

            // advance
            self.now += dt;
            for &i in &running {
                if rates[i].is_finite() {
                    cores[i].remaining -= rates[i] * dt;
                } else {
                    cores[i].remaining = 0.0;
                }
            }
            // completions + next claims
            for &i in &unfinished {
                if self.now >= cores[i].stall_until && cores[i].remaining <= 1e-9 {
                    cores[i].units_done += cores[i].current.len();
                    let next = match plan {
                        DispatchPlan::Partitioned(_) => None,
                        _ => claim(&mut cursor, plan, n),
                    };
                    match next {
                        Some(r) => {
                            cores[i].remaining = r.len() as f64;
                            cores[i].current = r.clone();
                            cores[i].claims.push(r);
                            cores[i].stall_until = self.now + self.cfg.chunk_claim_overhead_secs;
                        }
                        None => {
                            cores[i].finished_at = Some(self.now);
                        }
                    }
                }
            }
        }

        let wall_end = self.now;
        // advance the noise process by the kernel's duration
        let wall = wall_end - invocation_start;
        self.noise.step(wall.max(1e-9), &mut self.rng);

        self.stats.kernels += 1;
        self.stats.total_bytes += cost.total_bytes();
        self.stats.total_ops += cost.total_ops();

        // real execution (serial, in claim order) for correctness paths
        if self.cfg.execute_real {
            if let Some(w) = work {
                for (i, core) in cores.iter().enumerate() {
                    for r in &core.claims {
                        w.run_range(i, r.clone());
                    }
                }
            }
        }

        RunResult {
            per_core_secs: cores
                .iter()
                .map(|c| {
                    if c.units_done > 0 {
                        Some(c.finished_at.unwrap() - kernel_start)
                    } else {
                        None
                    }
                })
                .collect(),
            wall_secs: wall,
            units_done: cores.iter().map(|c| c.units_done).collect(),
            bytes: 0.0,
        }
    }
}

/// [`Executor`] adapter over the simulator.
pub struct SimExecutor {
    pub sim: HybridSim,
}

impl SimExecutor {
    pub fn new(spec: CpuSpec, cfg: SimConfig) -> SimExecutor {
        SimExecutor { sim: HybridSim::new(spec, cfg) }
    }
}

impl Executor for SimExecutor {
    fn n_workers(&self) -> usize {
        self.sim.spec.n_cores()
    }

    fn core_kinds(&self) -> Vec<crate::cpu::CoreKind> {
        self.sim.spec.cores.iter().map(|c| c.kind).collect()
    }

    fn execute(&mut self, work: &dyn Work, plan: &DispatchPlan) -> RunResult {
        let cost = work.cost();
        self.sim.execute_plan(Some(work), &cost, plan)
    }

    fn inject_background(&mut self, workers: &[usize], fraction: f64) {
        for &w in workers {
            self.sim.inject_background(w, fraction);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;
    use crate::kernels::cost;
    use crate::sched::{DispatchPlan, DynamicScheduler, Scheduler, StaticEven};

    fn sim(spec: CpuSpec) -> HybridSim {
        HybridSim::new(spec, SimConfig::noiseless())
    }

    #[test]
    fn homogeneous_equal_split_finishes_together() {
        let mut s = sim(presets::homogeneous(4));
        let c = cost::gemm_i8_cost(1024, 512, 512);
        let plan = StaticEven.plan(1024, 1, &[1.0; 4]);
        let res = s.execute_plan(None, &c, &plan);
        let times: Vec<f64> = res.per_core_secs.iter().flatten().copied().collect();
        assert_eq!(times.len(), 4);
        let (min, max) = times.iter().fold((f64::MAX, 0.0f64), |(a, b), &t| (a.min(t), b.max(t)));
        assert!((max - min) / max < 1e-9, "times={times:?}");
        assert!((res.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn compute_time_matches_hand_calculation() {
        // single P-core of the 12900K: rate = 4.9e9·64 ops/s
        let spec = presets::core_12900k();
        let mut s = sim(spec.clone());
        let c = cost::gemm_i8_cost(64, 256, 256); // compute-bound
        let plan = DispatchPlan::Partitioned(vec![0..64]); // only core 0
        let res = s.execute_plan(None, &c, &plan);
        let t = res.per_core_secs[0].unwrap();
        let expect = c.total_ops() / spec.cores[0].compute_rate(crate::cpu::Isa::AvxVnni);
        assert!((t - expect).abs() / expect < 1e-6, "t={t} expect={expect}");
    }

    #[test]
    fn static_split_on_hybrid_bound_by_e_core() {
        let spec = presets::core_12900k();
        let mut s = sim(spec.clone());
        let c = cost::gemm_i8_cost(1024, 4096, 4096);
        let plan = StaticEven.plan(1024, 1, &vec![1.0; 16]);
        let res = s.execute_plan(None, &c, &plan);
        // wall is set by the E-cores (last 8), which are ~2.65× slower
        let tp = res.per_core_secs[0].unwrap();
        let te = res.per_core_secs[15].unwrap();
        assert!((te / tp - 2.65).abs() < 0.05, "te/tp={}", te / tp);
        assert!((res.wall_secs - te).abs() / te < 0.01);
    }

    #[test]
    fn ideal_dynamic_split_beats_static_by_calibrated_factor() {
        let spec = presets::core_12900k();
        let ratios = spec.ideal_ratios(crate::cpu::Isa::AvxVnni);
        let c = cost::gemm_i8_cost(1024, 4096, 4096);

        let mut s1 = sim(spec.clone());
        let static_res = s1.execute_plan(None, &c, &StaticEven.plan(1024, 1, &ratios));
        let mut s2 = sim(spec.clone());
        let dyn_res = s2.execute_plan(None, &c, &DynamicScheduler.plan(1024, 1, &ratios));

        let speedup = static_res.wall_secs / dyn_res.wall_secs;
        // calibration target: paper reports +85% on 12900K
        assert!((1.70..1.95).contains(&speedup), "speedup={speedup}");
        // dynamic split is balanced
        assert!(dyn_res.imbalance() < 1.05, "imbalance={}", dyn_res.imbalance());
    }

    #[test]
    fn memory_bound_kernel_is_limited_by_bus() {
        let spec = presets::core_12900k();
        let mlc = sim(spec.clone()).mlc_bandwidth();
        assert!(mlc <= spec.bus_bw_gbps + 1e-9);
        let mut s = sim(spec.clone());
        let c = cost::gemv_q4_cost(4096, 4096);
        let ratios = vec![1.0; 16];
        let res = s.execute_plan(None, &c, &StaticEven.plan(4096, 1, &ratios));
        let achieved_gbps = c.total_bytes() / res.wall_secs / 1e9;
        assert!(achieved_gbps <= mlc + 1e-6, "achieved {achieved_gbps} > mlc {mlc}");
        // must still achieve a decent fraction (static loses the tail)
        assert!(achieved_gbps > 0.5 * mlc, "achieved {achieved_gbps} mlc {mlc}");
    }

    #[test]
    fn chunked_plan_executes_all_units_and_pays_overhead() {
        let spec = presets::homogeneous(4);
        let c = cost::gemm_i8_cost(512, 128, 128);
        let mut s1 = sim(spec.clone());
        let res_part = s1.execute_plan(None, &c, &StaticEven.plan(512, 1, &[1.0; 4]));
        let mut s2 = sim(spec.clone());
        let res_ws = s2.execute_plan(None, &c, &DispatchPlan::Chunked { chunk: 8 });
        assert_eq!(res_ws.units_done.iter().sum::<usize>(), 512);
        // stealing pays claim overheads → slower than a perfect static split
        // on a homogeneous machine
        assert!(res_ws.wall_secs > res_part.wall_secs);
    }

    #[test]
    fn work_stealing_adapts_on_hybrid_better_than_static() {
        let spec = presets::core_12900k();
        let c = cost::gemm_i8_cost(1024, 4096, 4096);
        let mut s1 = sim(spec.clone());
        let static_res = s1.execute_plan(None, &c, &StaticEven.plan(1024, 1, &vec![1.0; 16]));
        let mut s2 = sim(spec.clone());
        let ws_res = s2.execute_plan(None, &c, &DispatchPlan::Chunked { chunk: 8 });
        // chunked stealing self-balances (at some overhead): must beat static
        assert!(ws_res.wall_secs < static_res.wall_secs);
    }

    #[test]
    fn execute_real_runs_the_work() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let spec = presets::homogeneous(2);
        let cfg = SimConfig { execute_real: true, ..SimConfig::noiseless() };
        let mut ex = SimExecutor::new(spec, cfg);
        let counter = AtomicUsize::new(0);
        let work = crate::exec::FnWork::new(cost::copy_cost(100 * 4096), 1, |_w, r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        let plan = StaticEven.plan(100, 1, &[1.0; 2]);
        ex.execute(&work, &plan);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn virtual_time_accumulates() {
        let mut s = sim(presets::homogeneous(2));
        let c = cost::gemm_i8_cost(64, 64, 64);
        let plan = StaticEven.plan(64, 1, &[1.0; 2]);
        s.execute_plan(None, &c, &plan);
        let t1 = s.now;
        s.execute_plan(None, &c, &plan);
        assert!((s.now - 2.0 * t1).abs() / s.now < 0.5);
        assert_eq!(s.stats.kernels, 2);
    }

    #[test]
    fn injected_background_starts_now_not_retroactively() {
        let spec = presets::homogeneous(2);
        let mut ex = SimExecutor::new(spec, SimConfig::noiseless());
        let c = cost::gemm_i8_cost(128, 256, 256);
        let work = crate::exec::PhantomWork::new(c);
        let plan = StaticEven.plan(128, 1, &[1.0; 2]);
        let clean = ex.execute(&work, &plan);
        let (c0, c1) = (clean.per_core_secs[0].unwrap(), clean.per_core_secs[1].unwrap());
        assert!((c0 - c1).abs() / c0 < 1e-9);
        ex.inject_background(&[1], 0.5);
        let loaded = ex.execute(&work, &plan);
        let (t0, t1) = (loaded.per_core_secs[0].unwrap(), loaded.per_core_secs[1].unwrap());
        assert!((t1 / t0 - 2.0).abs() < 0.01, "t1/t0={}", t1 / t0);
        assert!((t0 - c0).abs() / c0 < 1e-9, "unloaded core changed");
    }

    #[test]
    fn background_load_slows_one_core() {
        let spec = presets::homogeneous(2);
        let noise = NoiseConfig {
            sigma: 0.0,
            background: vec![BackgroundLoad { core: 1, start: 0.0, end: 1e9, fraction: 0.5 }],
            ..NoiseConfig::disabled()
        };
        let cfg = SimConfig { noise, ..SimConfig::noiseless() };
        let mut s = HybridSim::new(spec, cfg);
        let c = cost::gemm_i8_cost(128, 256, 256);
        let res = s.execute_plan(None, &c, &StaticEven.plan(128, 1, &[1.0; 2]));
        let t0 = res.per_core_secs[0].unwrap();
        let t1 = res.per_core_secs[1].unwrap();
        assert!((t1 / t0 - 2.0).abs() < 0.01, "t1/t0={}", t1 / t0);
    }
}
