//! Core-efficiency noise: an Ornstein–Uhlenbeck process around 1.0 plus
//! optional step "background load" intervals (paper §2.2: the method must
//! adapt to "sudden changes in the system background").

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct NoiseConfig {
    /// stationary std-dev of the OU efficiency process (0 disables)
    pub sigma: f64,
    /// relaxation time constant (seconds of virtual time)
    pub tau: f64,
    /// hard floor/ceiling on efficiency
    pub min_eff: f64,
    pub max_eff: f64,
    /// background loads stealing a fraction of specific cores
    pub background: Vec<BackgroundLoad>,
}

impl Default for NoiseConfig {
    fn default() -> Self {
        NoiseConfig { sigma: 0.02, tau: 0.02, min_eff: 0.4, max_eff: 1.2, background: Vec::new() }
    }
}

impl NoiseConfig {
    pub fn disabled() -> Self {
        NoiseConfig { sigma: 0.0, tau: 0.02, min_eff: 0.0, max_eff: 2.0, background: Vec::new() }
    }
}

/// A background process stealing `fraction` of core `core`'s cycles
/// during `[start, end)` virtual seconds.
#[derive(Clone, Copy, Debug)]
pub struct BackgroundLoad {
    pub core: usize,
    pub start: f64,
    pub end: f64,
    pub fraction: f64,
}

/// Per-core OU efficiency state.
#[derive(Clone, Debug)]
pub struct NoiseState {
    cfg: NoiseConfig,
    eff: Vec<f64>,
}

impl NoiseState {
    pub fn new(n_cores: usize, cfg: NoiseConfig) -> NoiseState {
        NoiseState { eff: vec![1.0; n_cores], cfg }
    }

    /// Start an additional background load mid-run (a process showing up
    /// while the simulator is live — see `Executor::inject_background`).
    pub fn add_background(&mut self, load: BackgroundLoad) {
        self.cfg.background.push(load);
    }

    /// Advance the OU process by `dt` virtual seconds.
    pub fn step(&mut self, dt: f64, rng: &mut Rng) {
        if self.cfg.sigma == 0.0 {
            return;
        }
        let a = (-dt / self.cfg.tau).exp();
        let s = self.cfg.sigma * (1.0 - a * a).sqrt();
        for e in self.eff.iter_mut() {
            let z = rng.normal();
            *e = (1.0 + (*e - 1.0) * a + s * z).clamp(self.cfg.min_eff, self.cfg.max_eff);
        }
    }

    /// Effective multiplier of core `i` at virtual time `now`
    /// (OU noise × background-load steals). Each load is floored at 1%
    /// remaining so a core can collapse (the cluster tier's whole-machine
    /// degrade scenario is a 99% steal) but never fully stall.
    pub fn efficiency(&self, i: usize, now: f64) -> f64 {
        let mut e = self.eff[i];
        for b in &self.cfg.background {
            if b.core == i && now >= b.start && now < b.end {
                e *= (1.0 - b.fraction).max(0.01);
            }
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_noise_is_identity() {
        let mut n = NoiseState::new(4, NoiseConfig::disabled());
        let mut rng = Rng::new(1);
        n.step(1.0, &mut rng);
        for i in 0..4 {
            assert_eq!(n.efficiency(i, 0.0), 1.0);
        }
    }

    #[test]
    fn ou_stays_within_bounds_and_near_one() {
        let cfg = NoiseConfig { sigma: 0.05, ..Default::default() };
        let mut n = NoiseState::new(2, cfg.clone());
        let mut rng = Rng::new(2);
        let mut sum = 0.0;
        let steps = 10_000;
        for _ in 0..steps {
            n.step(0.001, &mut rng);
            let e = n.efficiency(0, 0.0);
            assert!(e >= cfg.min_eff && e <= cfg.max_eff);
            sum += e;
        }
        let mean = sum / steps as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn background_load_steals_fraction() {
        let cfg = NoiseConfig {
            sigma: 0.0,
            background: vec![BackgroundLoad { core: 1, start: 1.0, end: 2.0, fraction: 0.5 }],
            ..NoiseConfig::disabled()
        };
        let n = NoiseState::new(2, cfg);
        assert_eq!(n.efficiency(1, 0.5), 1.0); // before
        assert_eq!(n.efficiency(1, 1.5), 0.5); // during
        assert_eq!(n.efficiency(1, 2.5), 1.0); // after
        assert_eq!(n.efficiency(0, 1.5), 1.0); // other core unaffected
    }
}
