//! Weighted waterfill allocation of shared memory-bus bandwidth.
//!
//! Cores contending for the bus receive bandwidth proportional to their
//! contention weight (a proxy for memory-level parallelism: P-cores keep
//! more misses in flight), capped by (a) their per-core link limit and
//! (b) their actual demand (a compute-bound core doesn't consume its
//! share). Freed capacity is redistributed until exhausted — the standard
//! waterfilling fixed point.

/// One contender: (weight, cap_gbps) where cap already includes demand.
#[derive(Clone, Copy, Debug)]
pub struct Contender {
    pub weight: f64,
    pub cap: f64,
}

/// Allocate `bus` GB/s over the contenders. Returns per-contender GB/s.
pub fn waterfill(contenders: &[Contender], bus: f64) -> Vec<f64> {
    let n = contenders.len();
    let mut alloc = vec![0.0f64; n];
    if n == 0 || bus <= 0.0 {
        return alloc;
    }
    let mut open: Vec<usize> = (0..n).filter(|&i| contenders[i].cap > 0.0).collect();
    let mut remaining = bus;
    // each pass fixes at least one contender at its cap, so ≤ n passes
    loop {
        let wsum: f64 = open.iter().map(|&i| contenders[i].weight).sum();
        if open.is_empty() || wsum <= 0.0 || remaining <= 1e-12 {
            break;
        }
        let mut capped = Vec::new();
        let mut progressed = false;
        for &i in &open {
            let share = remaining * contenders[i].weight / wsum;
            if share >= contenders[i].cap - 1e-12 {
                alloc[i] = contenders[i].cap;
                capped.push(i);
                progressed = true;
            }
        }
        if !progressed {
            // nobody capped: final proportional split
            for &i in &open {
                alloc[i] = remaining * contenders[i].weight / wsum;
            }
            break;
        }
        remaining -= capped.iter().map(|&i| contenders[i].cap).sum::<f64>();
        remaining = remaining.max(0.0);
        open.retain(|i| !capped.contains(i));
    }
    alloc
}

/// Total bus throughput when every core streams flat-out (the MLC-like
/// reference measurement).
pub fn full_contention_throughput(contenders: &[Contender], bus: f64) -> f64 {
    waterfill(contenders, bus).iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn c(weight: f64, cap: f64) -> Contender {
        Contender { weight, cap }
    }

    #[test]
    fn uncapped_split_is_proportional() {
        let a = waterfill(&[c(2.0, 1e9), c(1.0, 1e9)], 30.0);
        assert!((a[0] - 20.0).abs() < 1e-9);
        assert!((a[1] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn caps_redistribute() {
        // core 0 capped at 5 → remaining 25 goes to core 1 (cap 100)
        let a = waterfill(&[c(1.0, 5.0), c(1.0, 100.0)], 30.0);
        assert!((a[0] - 5.0).abs() < 1e-9);
        assert!((a[1] - 25.0).abs() < 1e-9);
    }

    #[test]
    fn total_never_exceeds_bus_or_caps() {
        let cs = [c(1.3, 14.0), c(1.3, 14.0), c(0.8, 7.0), c(0.8, 7.0)];
        let a = waterfill(&cs, 30.0);
        let total: f64 = a.iter().sum();
        assert!(total <= 30.0 + 1e-9);
        for (x, cc) in a.iter().zip(&cs) {
            assert!(*x <= cc.cap + 1e-9);
        }
    }

    #[test]
    fn bus_smaller_than_caps_fully_used() {
        let cs = [c(1.0, 50.0), c(1.0, 50.0)];
        let a = waterfill(&cs, 40.0);
        assert!((a.iter().sum::<f64>() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn caps_smaller_than_bus_limit_throughput() {
        let cs = [c(1.0, 5.0), c(1.0, 5.0)];
        assert!((full_contention_throughput(&cs, 100.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn zero_demand_core_gets_nothing() {
        let a = waterfill(&[c(1.0, 0.0), c(1.0, 10.0)], 8.0);
        assert_eq!(a[0], 0.0);
        assert!((a[1] - 8.0).abs() < 1e-9);
    }

    #[test]
    fn prop_waterfill_feasible_and_work_conserving() {
        prop::check("waterfill_invariants", |rng| {
            let n = 1 + rng.below(12) as usize;
            let cs: Vec<Contender> =
                (0..n).map(|_| c(rng.uniform(0.1, 2.0), rng.uniform(0.0, 20.0))).collect();
            let bus = rng.uniform(1.0, 120.0);
            let a = waterfill(&cs, bus);
            let total: f64 = a.iter().sum();
            if total > bus + 1e-6 {
                return Err(format!("total {total} > bus {bus}"));
            }
            for (x, cc) in a.iter().zip(&cs) {
                if *x > cc.cap + 1e-6 {
                    return Err(format!("alloc {x} > cap {}", cc.cap));
                }
                if *x < -1e-12 {
                    return Err("negative alloc".into());
                }
            }
            // work conserving: either bus exhausted or every cap binding
            let cap_sum: f64 = cs.iter().map(|cc| cc.cap).sum();
            let expect = bus.min(cap_sum);
            prop::approx_eq(total, expect, 1e-6)
        });
    }
}
