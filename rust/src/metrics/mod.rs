//! Inference metrics: phase latencies, token rates, bandwidth accounting
//! and latency histograms for the serving front-end.

use crate::util::stats::Summary;

/// Timings of one generation request, split by the paper's two phases.
#[derive(Clone, Debug, Default)]
pub struct PhaseMetrics {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub prompt_tokens: usize,
    pub decoded_tokens: usize,
}

impl PhaseMetrics {
    /// decode throughput (the paper's "~16 tokens/s" observable)
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_secs > 0.0 {
            self.decoded_tokens as f64 / self.decode_secs
        } else {
            0.0
        }
    }

    /// mean decode latency per token (seconds)
    pub fn decode_latency(&self) -> f64 {
        if self.decoded_tokens > 0 {
            self.decode_secs / self.decoded_tokens as f64
        } else {
            0.0
        }
    }

    /// prefill throughput in prompt tokens/s
    pub fn prefill_tokens_per_sec(&self) -> f64 {
        if self.prefill_secs > 0.0 {
            self.prompt_tokens as f64 / self.prefill_secs
        } else {
            0.0
        }
    }

    pub fn merge(&mut self, other: &PhaseMetrics) {
        self.prefill_secs += other.prefill_secs;
        self.decode_secs += other.decode_secs;
        self.prompt_tokens += other.prompt_tokens;
        self.decoded_tokens += other.decoded_tokens;
    }
}

/// Achieved bandwidth (GB/s) given bytes moved in `secs`.
pub fn bandwidth_gbps(bytes: f64, secs: f64) -> f64 {
    if secs > 0.0 {
        bytes / secs / 1e9
    } else {
        0.0
    }
}

/// Utilization of a reference bandwidth (the paper's ">90% of MLC").
pub fn bandwidth_utilization(achieved_gbps: f64, reference_gbps: f64) -> f64 {
    if reference_gbps > 0.0 {
        achieved_gbps / reference_gbps
    } else {
        0.0
    }
}

/// Simple latency histogram with fixed log-spaced buckets (µs scale).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    samples: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { samples: Vec::new() }
    }

    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn summary(&self) -> Option<Summary> {
        if self.samples.is_empty() {
            None
        } else {
            Some(Summary::of(&self.samples))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_per_sec() {
        let m = PhaseMetrics {
            prefill_secs: 2.0,
            decode_secs: 4.0,
            prompt_tokens: 1024,
            decoded_tokens: 64,
        };
        assert!((m.decode_tokens_per_sec() - 16.0).abs() < 1e-12);
        assert!((m.prefill_tokens_per_sec() - 512.0).abs() < 1e-12);
        assert!((m.decode_latency() - 0.0625).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_dont_divide_by_zero() {
        let m = PhaseMetrics::default();
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        assert_eq!(m.decode_latency(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PhaseMetrics {
            prefill_secs: 1.0,
            decode_secs: 1.0,
            prompt_tokens: 10,
            decoded_tokens: 5,
        };
        a.merge(&a.clone());
        assert_eq!(a.prompt_tokens, 20);
        assert_eq!(a.decode_secs, 2.0);
    }

    #[test]
    fn bandwidth_math() {
        assert!((bandwidth_gbps(68e9, 1.0) - 68.0).abs() < 1e-9);
        assert!((bandwidth_utilization(61.2, 68.0) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn histogram_summary() {
        let mut h = LatencyHistogram::new();
        assert!(h.summary().is_none());
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let s = h.summary().unwrap();
        assert_eq!(h.count(), 100);
        assert!((s.p50 - 0.0505).abs() < 1e-3);
    }
}
