//! Inference metrics: phase latencies, token rates, bandwidth accounting
//! and latency histograms for the serving front-end, plus the aggregate
//! [`ServingMetrics`] the continuous-batching server exports (per-request
//! phase latencies, time-to-first-token, per-round admission-queue depth).

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Timings of one generation request, split by the paper's two phases.
#[derive(Clone, Debug, Default)]
pub struct PhaseMetrics {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub prompt_tokens: usize,
    pub decoded_tokens: usize,
}

impl PhaseMetrics {
    /// decode throughput (the paper's "~16 tokens/s" observable)
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_secs > 0.0 {
            self.decoded_tokens as f64 / self.decode_secs
        } else {
            0.0
        }
    }

    /// mean decode latency per token (seconds)
    pub fn decode_latency(&self) -> f64 {
        if self.decoded_tokens > 0 {
            self.decode_secs / self.decoded_tokens as f64
        } else {
            0.0
        }
    }

    /// prefill throughput in prompt tokens/s
    pub fn prefill_tokens_per_sec(&self) -> f64 {
        if self.prefill_secs > 0.0 {
            self.prompt_tokens as f64 / self.prefill_secs
        } else {
            0.0
        }
    }

    pub fn merge(&mut self, other: &PhaseMetrics) {
        self.prefill_secs += other.prefill_secs;
        self.decode_secs += other.decode_secs;
        self.prompt_tokens += other.prompt_tokens;
        self.decoded_tokens += other.decoded_tokens;
    }
}

// Bandwidth math lives with the meter in `perf::bandwidth`; re-exported
// here so serving-side callers keep their `metrics::` paths.
pub use crate::perf::bandwidth::{bandwidth_gbps, bandwidth_utilization};

/// How many samples a [`LatencyHistogram`] retains for its summary — a
/// sliding window, so a server recording one sample per scheduler round
/// for days never grows it without bound.
pub const LATENCY_SAMPLE_CAP: usize = 4096;

/// Bounded latency reservoir: a ring of the most recent
/// [`LATENCY_SAMPLE_CAP`] samples plus a lifetime count.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    samples: Vec<f64>,
    /// ring cursor, meaningful once `samples` reached capacity
    next: usize,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { samples: Vec::new(), next: 0, total: 0 }
    }

    pub fn record(&mut self, secs: f64) {
        self.total += 1;
        if self.samples.len() < LATENCY_SAMPLE_CAP {
            self.samples.push(secs);
        } else {
            self.samples[self.next] = secs;
            self.next = (self.next + 1) % LATENCY_SAMPLE_CAP;
        }
    }

    /// Lifetime number of recorded samples (the summary only covers the
    /// most recent [`LATENCY_SAMPLE_CAP`] of them).
    pub fn count(&self) -> usize {
        self.total as usize
    }

    pub fn summary(&self) -> Option<Summary> {
        if self.samples.is_empty() {
            None
        } else {
            Some(Summary::of(&self.samples))
        }
    }
}

/// Per-machine rollup for the cluster tier: one machine's share of the
/// served workload plus the interconnect bytes that migrated *into* it.
/// Exported inside [`ServingMetrics::to_json`] under `"machines"` when the
/// metrics came from a cluster run.
#[derive(Clone, Debug, Default)]
pub struct MachineRollup {
    pub machine: usize,
    pub tokens: u64,
    /// busy kernel seconds on this machine
    pub kernel_secs: f64,
    /// decode throughput over the run's makespan (tokens / wall seconds)
    pub tok_s: f64,
    /// KV bytes migrated into this machine over the interconnect
    pub interconnect_bytes: f64,
}

impl MachineRollup {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machine", Json::num(self.machine as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("kernel_secs", Json::num(self.kernel_secs)),
            ("tok_s", Json::num(self.tok_s)),
            ("interconnect_bytes", Json::num(self.interconnect_bytes)),
        ])
    }
}

/// Aggregate serving-side metrics, exported on the wire by the server's
/// `{"cmd":"metrics"}` command. Next to the classic request/token counters
/// it tracks the two observables continuous batching is judged by:
/// **time-to-first-token** (admission-queue entry → first streamed token)
/// and the **admission-queue depth sampled once per scheduler round**.
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub requests: u64,
    pub tokens: u64,
    /// requests refused by the bounded admission queue
    pub rejected: u64,
    /// requests shed by SLO-aware admission: bounced by the predicted-wait
    /// gate or evicted from a saturated queue to seat a higher-priority
    /// arrival (disjoint from `rejected`, which counts plain saturation)
    pub shed_requests: u64,
    /// live strategy switches taken by the router (each one is a fleet
    /// rebuild with bit-identical session migration), a subset of
    /// `rebuilds`
    pub strategy_switches: u64,
    /// engine-fleet rebuilds (dynamic lease membership epoch changes)
    pub rebuilds: u64,
    /// rebuilds triggered by the drift monitor (learned-strength skew →
    /// live `rebalance()`), a subset of `rebuilds`
    pub drift_rebalances: u64,
    /// prefill→decode session migrations between the two batchers of a
    /// phase-disaggregated lease (`ExecMode::Disaggregated`)
    pub handoffs: u64,
    /// unique kernel memory traffic across all engines (bytes)
    pub bytes_moved: f64,
    /// busy kernel seconds the bytes were moved in
    pub kernel_secs: f64,
    /// reference bus bandwidth for the utilization export (the machine's
    /// full bus, or the lease-share sum); 0 = unknown, no export
    pub bus_reference_gbps: f64,
    /// cluster tier only: per-machine rollups (empty for single-machine
    /// runs, which keeps the JSON export unchanged for them)
    pub machines: Vec<MachineRollup>,
    /// cluster tier only: final strength skew across machines
    pub cluster_skew: f64,
    /// cluster tier only: re-placements triggered by machine-level drift
    pub replacements: u64,
    /// cluster tier only: total KV bytes migrated across the interconnect
    pub interconnect_bytes: f64,
    pub prefill: LatencyHistogram,
    pub decode_per_token: LatencyHistogram,
    pub ttft: LatencyHistogram,
    pub queue_depth: LatencyHistogram,
}

impl ServingMetrics {
    /// Fold one retired request's phase timings into the aggregates.
    pub fn record_request(&mut self, m: &PhaseMetrics) {
        self.requests += 1;
        self.tokens += m.decoded_tokens as u64;
        self.prefill.record(m.prefill_secs);
        if m.decoded_tokens > 0 {
            self.decode_per_token.record(m.decode_latency());
        }
    }

    pub fn to_json(&self, n_engines: usize, epoch: u64) -> Json {
        let mut fields = vec![
            ("requests", Json::num(self.requests as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("engines", Json::num(n_engines as f64)),
            ("epoch", Json::num(epoch as f64)),
            ("rebuilds", Json::num(self.rebuilds as f64)),
            ("drift_rebalances", Json::num(self.drift_rebalances as f64)),
            ("handoffs", Json::num(self.handoffs as f64)),
        ];
        // SLO/router observables appear once the features are exercised,
        // keeping the export unchanged for single-class, router-off runs
        if self.shed_requests > 0 {
            fields.push(("shed_requests", Json::num(self.shed_requests as f64)));
        }
        if self.strategy_switches > 0 {
            fields.push(("strategy_switches", Json::num(self.strategy_switches as f64)));
        }
        if self.kernel_secs > 0.0 {
            let achieved = bandwidth_gbps(self.bytes_moved, self.kernel_secs);
            fields.push(("bytes_moved", Json::num(self.bytes_moved)));
            fields.push(("kernel_secs", Json::num(self.kernel_secs)));
            fields.push(("achieved_gbps", Json::num(achieved)));
            if self.bus_reference_gbps > 0.0 {
                fields.push((
                    "bandwidth_utilization",
                    Json::num(bandwidth_utilization(achieved, self.bus_reference_gbps)),
                ));
            }
        }
        if !self.machines.is_empty() {
            fields.push(("cluster_skew", Json::num(self.cluster_skew)));
            fields.push(("replacements", Json::num(self.replacements as f64)));
            fields.push(("interconnect_bytes", Json::num(self.interconnect_bytes)));
            fields.push(("machines", Json::arr(self.machines.iter().map(|r| r.to_json()))));
        }
        if let Some(s) = self.prefill.summary() {
            fields.push(("prefill_p50_secs", Json::num(s.p50)));
        }
        if let Some(s) = self.decode_per_token.summary() {
            fields.push(("decode_p50_secs_per_token", Json::num(s.p50)));
        }
        if let Some(s) = self.ttft.summary() {
            fields.push(("ttft_p50_secs", Json::num(s.p50)));
        }
        if let Some(s) = self.queue_depth.summary() {
            fields.push(("queue_depth_p50", Json::num(s.p50)));
            fields.push(("queue_depth_max", Json::num(s.max)));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_per_sec() {
        let m = PhaseMetrics {
            prefill_secs: 2.0,
            decode_secs: 4.0,
            prompt_tokens: 1024,
            decoded_tokens: 64,
        };
        assert!((m.decode_tokens_per_sec() - 16.0).abs() < 1e-12);
        assert!((m.prefill_tokens_per_sec() - 512.0).abs() < 1e-12);
        assert!((m.decode_latency() - 0.0625).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_dont_divide_by_zero() {
        let m = PhaseMetrics::default();
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        assert_eq!(m.decode_latency(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PhaseMetrics {
            prefill_secs: 1.0,
            decode_secs: 1.0,
            prompt_tokens: 10,
            decoded_tokens: 5,
        };
        a.merge(&a.clone());
        assert_eq!(a.prompt_tokens, 20);
        assert_eq!(a.decode_secs, 2.0);
    }

    #[test]
    fn bandwidth_math() {
        assert!((bandwidth_gbps(68e9, 1.0) - 68.0).abs() < 1e-9);
        assert!((bandwidth_utilization(61.2, 68.0) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn serving_metrics_aggregate_and_export() {
        let mut sm = ServingMetrics::default();
        let m = PhaseMetrics {
            prefill_secs: 0.2,
            decode_secs: 1.0,
            prompt_tokens: 8,
            decoded_tokens: 10,
        };
        sm.record_request(&m);
        sm.record_request(&m);
        sm.ttft.record(0.25);
        sm.queue_depth.record(3.0);
        sm.rejected = 1;
        sm.rebuilds = 2;
        sm.drift_rebalances = 1;
        sm.handoffs = 3;
        let j = sm.to_json(4, 7);
        assert_eq!(j.get("requests").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("tokens").unwrap().as_i64(), Some(20));
        assert_eq!(j.get("rejected").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("engines").unwrap().as_i64(), Some(4));
        assert_eq!(j.get("epoch").unwrap().as_i64(), Some(7));
        assert_eq!(j.get("rebuilds").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("drift_rebalances").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("handoffs").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("ttft_p50_secs").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("queue_depth_p50").unwrap().as_f64(), Some(3.0));
        let decode_p50 = j.get("decode_p50_secs_per_token").unwrap().as_f64().unwrap();
        assert!((decode_p50 - 0.1).abs() < 1e-12);
        // empty histograms stay out of the export
        let empty = ServingMetrics::default().to_json(1, 0);
        assert!(empty.get("ttft_p50_secs").is_none());
    }

    #[test]
    fn bandwidth_exports_when_kernel_time_recorded() {
        let mut sm = ServingMetrics::default();
        // nothing recorded → no bandwidth fields at all
        assert!(sm.to_json(1, 0).get("achieved_gbps").is_none());
        sm.bytes_moved = 34e9;
        sm.kernel_secs = 1.0;
        let j = sm.to_json(1, 0);
        assert_eq!(j.get("achieved_gbps").unwrap().as_f64(), Some(34.0));
        // utilization only with a known reference bus
        assert!(j.get("bandwidth_utilization").is_none());
        sm.bus_reference_gbps = 68.0;
        let j = sm.to_json(1, 0);
        assert_eq!(j.get("bandwidth_utilization").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn machine_rollups_export_only_for_cluster_runs() {
        let mut sm = ServingMetrics::default();
        // single-machine metrics: no cluster fields at all
        assert!(sm.to_json(1, 0).get("machines").is_none());
        assert!(sm.to_json(1, 0).get("cluster_skew").is_none());
        let m0 = MachineRollup {
            machine: 0,
            tokens: 12,
            kernel_secs: 0.5,
            tok_s: 24.0,
            ..Default::default()
        };
        let m1 = MachineRollup {
            machine: 1,
            tokens: 6,
            interconnect_bytes: 4096.0,
            ..Default::default()
        };
        sm.machines = vec![m0, m1];
        sm.cluster_skew = 1.25;
        sm.replacements = 1;
        sm.interconnect_bytes = 4096.0;
        let j = sm.to_json(2, 3);
        assert_eq!(j.get("cluster_skew").unwrap().as_f64(), Some(1.25));
        assert_eq!(j.get("replacements").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("interconnect_bytes").unwrap().as_f64(), Some(4096.0));
        let machines = j.get("machines").unwrap().as_array().unwrap();
        assert_eq!(machines.len(), 2);
        assert_eq!(machines[0].get("tok_s").unwrap().as_f64(), Some(24.0));
        assert_eq!(machines[1].get("interconnect_bytes").unwrap().as_f64(), Some(4096.0));
    }

    #[test]
    fn slo_and_router_counters_export_only_when_exercised() {
        let mut sm = ServingMetrics::default();
        // single-class, router-off runs keep the legacy export shape
        assert!(sm.to_json(1, 0).get("shed_requests").is_none());
        assert!(sm.to_json(1, 0).get("strategy_switches").is_none());
        sm.shed_requests = 4;
        sm.strategy_switches = 2;
        let j = sm.to_json(1, 0);
        assert_eq!(j.get("shed_requests").unwrap().as_i64(), Some(4));
        assert_eq!(j.get("strategy_switches").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn histogram_summary() {
        let mut h = LatencyHistogram::new();
        assert!(h.summary().is_none());
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let s = h.summary().unwrap();
        assert_eq!(h.count(), 100);
        assert!((s.p50 - 0.0505).abs() < 1e-3);
    }

    #[test]
    fn histogram_memory_is_bounded() {
        let mut h = LatencyHistogram::new();
        for i in 0..3 * LATENCY_SAMPLE_CAP {
            h.record(i as f64);
        }
        // lifetime count keeps growing; retained samples do not
        assert_eq!(h.count(), 3 * LATENCY_SAMPLE_CAP);
        let s = h.summary().unwrap();
        assert_eq!(s.n, LATENCY_SAMPLE_CAP);
        // the window slid: only the most recent samples remain
        assert!(s.min >= (2 * LATENCY_SAMPLE_CAP) as f64, "min {}", s.min);
    }
}
