//! PJRT CPU execution of the AOT artifacts (the `xla` crate bindings).
//!
//! `HloModuleProto::from_text_file → XlaComputation → client.compile →
//! execute` — adapted from /opt/xla-example/load_hlo. All jax functions are
//! lowered with `return_tuple=True`, so every execution returns one tuple
//! literal which is unpacked here.

use anyhow::{anyhow, bail, Context, Result};

use super::artifacts::{ArtifactMeta, Manifest};
use crate::model::weights::FlatParam;
use crate::model::{ModelConfig, ModelWeights};

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        bail!("literal_f32: {} values for shape {shape:?}", data.len());
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)?)
}

/// Build an i8 literal.
pub fn literal_i8(data: &[i8], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        bail!("literal_i8: {} values for shape {shape:?}", data.len());
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, shape, bytes)?)
}

/// Build a u8 literal.
pub fn literal_u8(data: &[u8], shape: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, shape, data)?)
}

/// Build an i32 literal (shape [] for scalars).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)?)
}

/// One compiled artifact.
pub struct PjrtModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtModel {
    /// Load + compile `meta` on `client`.
    pub fn load(client: &xla::PjRtClient, meta: &ArtifactMeta) -> Result<PjrtModel> {
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compiling {}", meta.name))?;
        Ok(PjrtModel { meta: meta.clone(), exe })
    }

    /// Execute with positional literals; unpacks the output tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.params.len() {
            bail!(
                "artifact {} expects {} params, got {}",
                self.meta.name,
                self.meta.params.len(),
                inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Convert a flat weight parameter to a PJRT literal.
fn flat_param_literal(p: &FlatParam) -> Result<xla::Literal> {
    match p {
        FlatParam::F32 { shape, data, .. } => literal_f32(data, shape),
        FlatParam::I8 { shape, data, .. } => literal_i8(data, shape),
    }
}

/// A generation engine backed entirely by PJRT artifacts: the L2/L1 path.
/// Holds the compiled decode/prefill executables, the weight literals (in
/// ABI order) and the KV-cache state threaded between steps.
pub struct PjrtEngine {
    pub cfg: ModelConfig,
    decode: PjrtModel,
    prefill: PjrtModel,
    weight_literals: Vec<xla::Literal>,
    kv_k: xla::Literal,
    kv_v: xla::Literal,
    pub pos: usize,
}

impl PjrtEngine {
    /// Load the `<model>_decode` / `<model>_prefill` artifacts and marshal
    /// `weights` into literals once.
    pub fn load(manifest: &Manifest, model: &str, weights: &ModelWeights) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()?;
        let decode_meta = manifest.get(&format!("{model}_decode"))?;
        let prefill_meta = manifest.get(&format!("{model}_prefill"))?;
        let cfg = decode_meta
            .model
            .clone()
            .ok_or_else(|| anyhow!("artifact has no model config"))?;
        let decode = PjrtModel::load(&client, decode_meta)?;
        let prefill = PjrtModel::load(&client, prefill_meta)?;

        // marshal weights in ABI order, checking against the manifest
        let flat = weights.to_flat_params(&cfg);
        let expected = &decode_meta.params[4..];
        if expected.len() != flat.len() {
            bail!("weight count mismatch: manifest {} vs flat {}", expected.len(), flat.len());
        }
        let mut weight_literals = Vec::with_capacity(flat.len());
        for (pm, fp) in expected.iter().zip(&flat) {
            if pm.name != fp.name() || pm.shape != fp.shape() {
                let (name, want, got) = (&pm.name, &pm.shape, fp.shape());
                bail!("ABI mismatch at {name}: manifest {want:?} vs rust {got:?}");
            }
            weight_literals.push(flat_param_literal(fp)?);
        }

        let kv_shape = [cfg.n_layers, cfg.n_heads, cfg.t_max, cfg.head_dim()];
        let zeros = vec![0.0f32; kv_shape.iter().product()];
        let kv_k = literal_f32(&zeros, &kv_shape)?;
        let kv_v = literal_f32(&zeros, &kv_shape)?;
        Ok(PjrtEngine { cfg, decode, prefill, weight_literals, kv_k, kv_v, pos: 0 })
    }

    /// Clear the KV cache and cursor.
    pub fn reset(&mut self) -> Result<()> {
        let kv_shape = [self.cfg.n_layers, self.cfg.n_heads, self.cfg.t_max, self.cfg.head_dim()];
        let zeros = vec![0.0f32; kv_shape.iter().product()];
        self.kv_k = literal_f32(&zeros, &kv_shape)?;
        self.kv_v = literal_f32(&zeros, &kv_shape)?;
        self.pos = 0;
        Ok(())
    }

    fn run(&mut self, model_is_decode: bool, lead: Vec<xla::Literal>) -> Result<Vec<f32>> {
        let model = if model_is_decode { &self.decode } else { &self.prefill };
        let mut inputs = lead;
        inputs.push(self.kv_k.clone());
        inputs.push(self.kv_v.clone());
        for w in &self.weight_literals {
            inputs.push(w.clone());
        }
        let mut outs = model.execute(&inputs)?;
        if outs.len() != 3 {
            bail!("expected 3 outputs, got {}", outs.len());
        }
        self.kv_v = outs.pop().unwrap();
        self.kv_k = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>()?;
        Ok(logits)
    }

    /// One decode step at the current position.
    pub fn decode_step(&mut self, token: u32) -> Result<Vec<f32>> {
        if self.pos >= self.cfg.t_max {
            bail!("KV cache exhausted");
        }
        let lead = vec![
            literal_i32(&[token as i32], &[])?,
            literal_i32(&[self.pos as i32], &[])?,
        ];
        let logits = self.run(true, lead)?;
        self.pos += 1;
        Ok(logits)
    }

    /// One fixed-size prefill chunk (exactly `cfg.prefill_len` tokens).
    pub fn prefill_chunk(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        let s = self.cfg.prefill_len;
        if tokens.len() != s {
            bail!("prefill chunk must be exactly {s} tokens (got {})", tokens.len());
        }
        if self.pos + s > self.cfg.t_max {
            bail!("prompt exceeds KV capacity");
        }
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let lead = vec![literal_i32(&toks, &[s])?, literal_i32(&[self.pos as i32], &[])?];
        let logits = self.run(false, lead)?;
        self.pos += s;
        Ok(logits)
    }

    /// Prefill an arbitrary prompt: full chunks through the prefill
    /// artifact, the tail through the decode artifact. Returns the last
    /// logits.
    pub fn prefill(&mut self, tokens: &[u32]) -> Result<Vec<f32>> {
        let s = self.cfg.prefill_len;
        let mut logits = None;
        let mut i = 0;
        while i + s <= tokens.len() {
            logits = Some(self.prefill_chunk(&tokens[i..i + s])?);
            i += s;
        }
        for &t in &tokens[i..] {
            logits = Some(self.decode_step(t)?);
        }
        logits.ok_or_else(|| anyhow!("empty prompt"))
    }

    /// Greedy generation; returns the produced tokens.
    pub fn generate(&mut self, prompt: &[u32], n_new: usize) -> Result<Vec<u32>> {
        let logits = self.prefill(prompt)?;
        let mut next = crate::model::argmax(&logits);
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.cfg.t_max {
                break;
            }
            out.push(next);
            let logits = self.decode_step(next)?;
            next = crate::model::argmax(&logits);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_artifact_dir;

    fn manifest() -> Option<Manifest> {
        let dir = default_artifact_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn qgemv_artifact_matches_native_kernel() {
        let Some(m) = manifest() else { return };
        let client = xla::PjRtClient::cpu().unwrap();
        let model = PjrtModel::load(&client, m.get("qgemv").unwrap()).unwrap();

        // build a quantized weight with the native quantizer
        let (n, k) = (256, 256);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut wdata = vec![0.0f32; n * k];
        rng.fill_normal_f32(&mut wdata, 1.0);
        let w = crate::quant::MatQ4::quantize(&wdata, n, k);
        let (codes, scales) = w.unpack();
        let mut x = vec![0.0f32; k];
        rng.fill_normal_f32(&mut x, 1.0);

        let outs = model
            .execute(&[
                literal_i8(&codes, &[n, k]).unwrap(),
                literal_f32(&scales, &[n, k / 32]).unwrap(),
                literal_f32(&x, &[k]).unwrap(),
            ])
            .unwrap();
        let y_pjrt = outs[0].to_vec::<f32>().unwrap();
        let y_native = crate::kernels::gemv_q4::gemv_q4_f32(&w, &x);
        assert_eq!(y_pjrt.len(), n);
        for (a, b) in y_pjrt.iter().zip(&y_native) {
            assert!((a - b).abs() < 1e-3, "pjrt {a} vs native {b}");
        }
    }

    #[test]
    fn qgemm_artifact_matches_native_kernel() {
        let Some(m) = manifest() else { return };
        let client = xla::PjRtClient::cpu().unwrap();
        let model = PjrtModel::load(&client, m.get("qgemm").unwrap()).unwrap();
        let (mm, kk, nn) = (64, 64, 64);
        let mut rng = crate::util::rng::Rng::new(9);
        let mut a = crate::tensor::MatU8::zeros(mm, kk);
        rng.fill_u8(&mut a.data, 0, 256);
        let mut b_kn = vec![0i8; kk * nn];
        rng.fill_i8(&mut b_kn, -127, 128);

        let outs = model
            .execute(&[
                literal_u8(&a.data, &[mm, kk]).unwrap(),
                literal_i8(&b_kn, &[kk, nn]).unwrap(),
            ])
            .unwrap();
        let c_pjrt = outs[0].to_vec::<i32>().unwrap();

        // native gemm takes B transposed [N, K]
        let mut bt = crate::tensor::MatI8::zeros(nn, kk);
        for r in 0..kk {
            for c in 0..nn {
                bt.data[c * kk + r] = b_kn[r * nn + c];
            }
        }
        let c_native = crate::kernels::gemm_i8::gemm_i8(&a, &bt);
        assert_eq!(c_pjrt, c_native);
    }

    #[test]
    fn wrong_arity_is_rejected() {
        let Some(m) = manifest() else { return };
        let client = xla::PjRtClient::cpu().unwrap();
        let model = PjrtModel::load(&client, m.get("qgemv").unwrap()).unwrap();
        assert!(model.execute(&[]).is_err());
    }
}
