//! API-compatible stand-in for the PJRT engine, used when the
//! `xla-bindings` feature is off (the default — the `xla` crate is not
//! available in this sandbox).
//!
//! Every constructor fails with a clear message instead of executing, so
//! code paths that *require* the artifacts (`dynpar infer --backend pjrt`,
//! the parity integration tests) degrade into explicit errors / skips
//! rather than compile failures. The real implementation lives in
//! `pjrt.rs` behind the feature gate.

use anyhow::{bail, Result};

use super::artifacts::{ArtifactMeta, Manifest};
use crate::model::{ModelConfig, ModelWeights};

const UNAVAILABLE: &str =
    "PJRT backend unavailable: dynpar was built without the `xla-bindings` feature";

/// One compiled artifact (stub: never constructible without XLA).
pub struct PjrtModel {
    pub meta: ArtifactMeta,
}

impl PjrtModel {
    /// Execute with positional literals — unavailable in the stub.
    pub fn execute_unavailable(&self) -> Result<()> {
        bail!(UNAVAILABLE)
    }
}

/// A generation engine backed entirely by PJRT artifacts: the L2/L1 path.
/// In the stub build, [`PjrtEngine::load`] always returns an error.
pub struct PjrtEngine {
    pub cfg: ModelConfig,
    pub pos: usize,
}

impl PjrtEngine {
    /// Load the `<model>_decode` / `<model>_prefill` artifacts — always an
    /// error without the `xla-bindings` feature.
    pub fn load(_manifest: &Manifest, _model: &str, _weights: &ModelWeights) -> Result<PjrtEngine> {
        bail!(UNAVAILABLE)
    }

    /// Clear the KV cache and cursor.
    pub fn reset(&mut self) -> Result<()> {
        bail!(UNAVAILABLE)
    }

    /// One decode step at the current position.
    pub fn decode_step(&mut self, _token: u32) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    /// One fixed-size prefill chunk (exactly `cfg.prefill_len` tokens).
    pub fn prefill_chunk(&mut self, _tokens: &[u32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    /// Prefill an arbitrary prompt.
    pub fn prefill(&mut self, _tokens: &[u32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    /// Greedy generation; returns the produced tokens.
    pub fn generate(&mut self, _prompt: &[u32], _n_new: usize) -> Result<Vec<u32>> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn stub_load_fails_with_clear_message() {
        let manifest = Manifest { dir: std::path::PathBuf::from("."), artifacts: Vec::new() };
        let cfg = ModelConfig::micro();
        let weights = ModelWeights::random_init(&cfg, 1);
        let err = PjrtEngine::load(&manifest, "micro", &weights).unwrap_err();
        assert!(err.to_string().contains("xla-bindings"), "{err}");
    }
}
