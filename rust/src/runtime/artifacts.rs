//! Artifact manifest: the parameter ABI between `aot.py` and the Rust
//! literal marshalling.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::ModelConfig;
use crate::util::json::Json;

/// dtype names used in the manifest
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
    U8,
    I32,
}

impl Dtype {
    pub fn from_name(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "i8" => Some(Dtype::I8),
            "u8" => Some(Dtype::U8),
            "i32" => Some(Dtype::I32),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl ParamMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<ParamMeta>,
    pub outputs: Vec<ParamMeta>,
    pub model: Option<ModelConfig>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

fn parse_params(v: &Json) -> Result<Vec<ParamMeta>> {
    let arr = v.as_array().ok_or_else(|| anyhow!("params is not an array"))?;
    arr.iter()
        .map(|p| {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?
                .to_string();
            let shape = p
                .get("shape")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("param {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                .collect::<Result<Vec<_>>>()?;
            let dt = p
                .get("dtype")
                .and_then(Json::as_str)
                .and_then(Dtype::from_name)
                .ok_or_else(|| anyhow!("param {name} has bad dtype"))?;
            Ok(ParamMeta { name, shape, dtype: dt })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        if v.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("unsupported manifest format");
        }
        let arts =
            v.get("artifacts").and_then(Json::as_object).ok_or_else(|| anyhow!("no artifacts"))?;
        let mut artifacts = Vec::new();
        for (name, a) in arts {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let model = match a.get("model") {
                Some(mj) => Some(
                    ModelConfig::from_manifest_json(name.split('_').next().unwrap_or(name), mj)
                        .map_err(|e| anyhow!("artifact {name}: {e}"))?,
                ),
                None => None,
            };
            artifacts.push(ArtifactMeta {
                name: name.clone(),
                file: dir.join(file),
                params: parse_params(a.get("params").ok_or_else(|| anyhow!("no params"))?)?,
                outputs: parse_params(a.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
                model,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }
}

/// Default artifact directory: `$DYNPAR_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("DYNPAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> Option<PathBuf> {
        let d = default_artifact_dir();
        if d.join("manifest.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let keys =
            ["tiny_decode", "tiny_prefill", "micro_decode", "micro_prefill", "qgemv", "qgemm"];
        for key in keys {
            let a = m.get(key).unwrap();
            assert!(a.file.exists(), "{key} file missing");
            assert!(!a.params.is_empty());
        }
    }

    #[test]
    fn model_abi_matches_rust_flat_params() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("micro_decode").unwrap();
        let cfg = a.model.clone().unwrap();
        assert_eq!(cfg.d_model, crate::model::ModelConfig::micro().d_model);
        let w = crate::model::ModelWeights::random_init(&cfg, 1);
        let flat = w.to_flat_params(&cfg);
        // manifest params = token, pos, kv_k, kv_v, then the flat weights
        assert_eq!(a.params.len(), 4 + flat.len());
        for (pm, fp) in a.params[4..].iter().zip(&flat) {
            assert_eq!(pm.name, fp.name(), "ABI name mismatch");
            assert_eq!(pm.shape, fp.shape(), "ABI shape mismatch for {}", pm.name);
        }
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("nonexistent").is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::from_name("f32"), Some(Dtype::F32));
        assert_eq!(Dtype::from_name("i8"), Some(Dtype::I8));
        assert_eq!(Dtype::from_name("f64"), None);
    }
}
