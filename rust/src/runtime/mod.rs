//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see /opt/xla-example/README.md for why not serialized
//! protos), compile them on the PJRT CPU client, and execute them from the
//! Rust request path. Python is never involved at runtime.
//!
//! The real implementation ([`pjrt`] with the `xla-bindings` feature) needs
//! the external `xla` crate, which this sandbox cannot fetch; the default
//! build substitutes an API-compatible stub whose `load` fails gracefully,
//! so every artifact-dependent test keeps its skip-when-absent behavior.

pub mod artifacts;

#[cfg(feature = "xla-bindings")]
pub mod pjrt;

#[cfg(not(feature = "xla-bindings"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArtifactMeta, Manifest, ParamMeta};
pub use pjrt::{PjrtEngine, PjrtModel};
