//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see /opt/xla-example/README.md for why not serialized
//! protos), compile them on the PJRT CPU client, and execute them from the
//! Rust request path. Python is never involved at runtime.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactMeta, Manifest, ParamMeta};
pub use pjrt::{PjrtEngine, PjrtModel};
