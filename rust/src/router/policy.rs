//! The unified serving configuration: [`ServingPolicy`] and its builder.

use crate::coordinator::ExecMode;
use crate::server::batcher::BatcherOpts;
use crate::server::fleet::DriftMonitor;
use crate::server::queue::AdmissionPolicy;
use crate::server::ServerOpts;

/// Admission policy of one priority class (0 = highest priority).
#[derive(Clone, Debug)]
pub struct ClassPolicy {
    /// human-readable label for reports and metrics exports
    pub name: String,
    /// time-to-first-token target in seconds; `f64::INFINITY` = no SLO
    pub ttft_target: f64,
    /// whether the SLO-aware admission gate may shed this class's arrivals
    /// under predicted overload (class 0 is conventionally not sheddable)
    pub sheddable: bool,
}

impl Default for ClassPolicy {
    fn default() -> ClassPolicy {
        ClassPolicy { name: "default".into(), ttft_target: f64::INFINITY, sheddable: false }
    }
}

/// Knobs of the live [`crate::router::StrategyRouter`]. All thresholds act
/// on the *arrival-window prefill share*: over the last `window` arrivals,
/// the fraction of offered tokens that are prompt (prefill) tokens rather
/// than requested decode tokens — near 1.0 for long-prompt bursts, near
/// 0.0 for decode-heavy chat.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// arrivals in the sliding decision window (also the minimum number of
    /// arrivals before the router makes its first decision)
    pub window: usize,
    /// prefill share at or above which the router enters the
    /// prefill-optimized strategy ([`ExecMode::Disaggregated`])
    pub enter_prefill_share: f64,
    /// prefill share at or below which the router leaves it again; the gap
    /// to `enter_prefill_share` is the Schmitt-trigger dead zone that
    /// keeps the router from flapping on a mixed tail
    pub exit_prefill_share: f64,
    /// minimum seconds between strategy switches (the hysteresis cooldown
    /// generalized from [`DriftMonitor`]'s observation cooldown)
    pub cooldown_secs: f64,
    /// learned device share band (`Coordinator::split_ratio`) inside which
    /// a decode-heavy mix runs [`ExecMode::AsyncBatch`] instead of the
    /// blended split — the XPU is pulling enough weight to deserve whole
    /// token rounds, but not so much that the cores are passengers
    pub async_share_band: (f64, f64),
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            window: 12,
            enter_prefill_share: 0.6,
            exit_prefill_share: 0.35,
            cooldown_secs: 0.0,
            async_share_band: (0.35, 0.65),
        }
    }
}

/// One config for the whole serving surface.
///
/// Everything `serve_dynamic`, `server::testing::run_trace` and
/// `cluster::harness::run_cluster` need to know rides in here: batcher
/// shape, admission queue depth and overflow policy, drift thresholds, an
/// optional static [`ExecMode`] override, the priority classes of the
/// admission plane, and the optional [`RouterConfig`] that turns the live
/// strategy router on.
///
/// Build it with [`ServingPolicy::builder`] — the builder validates — or
/// convert a legacy [`ServerOpts`] via `From` (kept so existing call sites
/// compile unchanged; that path deliberately bypasses
/// [`ServingPolicy::validate`], e.g. for intentionally closed zero-depth
/// queues in overload tests). Direct struct construction is deprecated in
/// favour of the builder and may lose field-by-field compatibility in a
/// future change.
#[derive(Clone, Debug)]
pub struct ServingPolicy {
    /// batch slots per batcher
    pub max_batch: usize,
    /// prompt tokens prefilled per scheduler round and request
    pub prefill_chunk: usize,
    /// shared admission queue bound across all priority classes
    pub queue_depth: usize,
    /// what to do with an arrival that finds the queue full
    pub on_full: AdmissionPolicy,
    /// learned-strength skew that triggers a live rebalance
    /// (`f64::INFINITY` disables the monitor)
    pub drift_threshold: f64,
    /// accepted observations required between drift rebalances
    pub drift_cooldown: u64,
    /// static execution mode the fleet starts on (`None` = coordinator
    /// default, or the router's choice once it has a window)
    pub mode: Option<ExecMode>,
    /// priority classes, index 0 = highest priority; never empty
    pub classes: Vec<ClassPolicy>,
    /// `Some` turns the live strategy router on
    pub router: Option<RouterConfig>,
}

impl ServingPolicy {
    pub fn builder() -> ServingPolicyBuilder {
        ServingPolicyBuilder { policy: ServingPolicy::base() }
    }

    fn base() -> ServingPolicy {
        let o = ServerOpts::default();
        ServingPolicy {
            max_batch: o.max_batch,
            prefill_chunk: o.prefill_chunk,
            queue_depth: o.queue_depth,
            on_full: o.on_full,
            drift_threshold: o.drift_threshold,
            drift_cooldown: o.drift_cooldown,
            mode: None,
            classes: vec![ClassPolicy::default()],
            router: None,
        }
    }

    /// The legacy knob set, unvalidated — the `From<ServerOpts>` /
    /// `run_fleet` compatibility path.
    pub(crate) fn from_server_parts(
        max_batch: usize,
        prefill_chunk: usize,
        queue_depth: usize,
        on_full: AdmissionPolicy,
        drift_threshold: f64,
        drift_cooldown: u64,
    ) -> ServingPolicy {
        ServingPolicy {
            max_batch,
            prefill_chunk,
            queue_depth,
            on_full,
            drift_threshold,
            drift_cooldown,
            ..ServingPolicy::base()
        }
    }

    /// The batcher shape this policy starts the fleet on.
    pub fn batcher_opts(&self) -> BatcherOpts {
        BatcherOpts { max_batch: self.max_batch, prefill_chunk: self.prefill_chunk }
    }

    /// A fresh drift monitor on this policy's thresholds.
    pub fn drift_monitor(&self) -> DriftMonitor {
        DriftMonitor::new(self.drift_threshold, self.drift_cooldown)
    }

    /// Number of priority classes (≥ 1 even on a default policy).
    pub fn n_classes(&self) -> usize {
        self.classes.len().max(1)
    }

    /// Reject every NaN / zero / negative knob with a descriptive error.
    /// The builder calls this on `build()`; policies converted from
    /// [`ServerOpts`] bypass it for backwards compatibility.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("max_batch must be >= 1 (a zero-slot batcher can never admit)".into());
        }
        if self.prefill_chunk == 0 {
            return Err("prefill_chunk must be >= 1 token per round".into());
        }
        if self.queue_depth == 0 {
            return Err(
                "queue_depth must be >= 1 (use ServerOpts directly for an \
                 intentionally closed queue)"
                    .into(),
            );
        }
        if self.drift_threshold.is_nan() || self.drift_threshold < 1.0 {
            return Err(format!(
                "drift_threshold {} invalid: skew is a max/min ratio, so the threshold \
                 must be >= 1.0 (f64::INFINITY disables the monitor)",
                self.drift_threshold
            ));
        }
        if self.classes.is_empty() {
            return Err("at least one priority class is required".into());
        }
        for (i, c) in self.classes.iter().enumerate() {
            if c.ttft_target.is_nan() || c.ttft_target <= 0.0 {
                return Err(format!(
                    "class {i} ({}) ttft_target {} invalid: must be positive seconds \
                     (f64::INFINITY = no SLO)",
                    c.name, c.ttft_target
                ));
            }
        }
        if let Some(r) = &self.router {
            if r.window == 0 {
                return Err("router window must be >= 1 arrival".into());
            }
            for (label, v) in
                [("enter_prefill_share", r.enter_prefill_share), ("exit_prefill_share", r.exit_prefill_share)]
            {
                if !v.is_finite() || v <= 0.0 || v >= 1.0 {
                    return Err(format!(
                        "router {label} {v} invalid: prefill shares are fractions in (0, 1)"
                    ));
                }
            }
            if r.exit_prefill_share >= r.enter_prefill_share {
                return Err(format!(
                    "router exit_prefill_share {} must sit strictly below \
                     enter_prefill_share {} — the gap is the anti-flap dead zone",
                    r.exit_prefill_share, r.enter_prefill_share
                ));
            }
            if r.cooldown_secs.is_nan() || r.cooldown_secs < 0.0 {
                return Err(format!(
                    "router cooldown_secs {} invalid: must be >= 0 seconds",
                    r.cooldown_secs
                ));
            }
            let (lo, hi) = r.async_share_band;
            if !(lo.is_finite() && hi.is_finite() && 0.0 <= lo && lo < hi && hi <= 1.0) {
                return Err(format!(
                    "router async_share_band ({lo}, {hi}) invalid: need 0 <= lo < hi <= 1"
                ));
            }
        }
        Ok(())
    }
}

impl Default for ServingPolicy {
    fn default() -> ServingPolicy {
        ServingPolicy::base()
    }
}

/// Legacy compatibility: the flat `ServerOpts` knob set maps onto a
/// single-class, router-off policy. Unvalidated by design — existing tests
/// (e.g. zero-depth queue saturation) rely on out-of-band values.
impl From<ServerOpts> for ServingPolicy {
    fn from(o: ServerOpts) -> ServingPolicy {
        ServingPolicy::from_server_parts(
            o.max_batch,
            o.prefill_chunk,
            o.queue_depth,
            o.on_full,
            o.drift_threshold,
            o.drift_cooldown,
        )
    }
}

/// Fluent constructor for [`ServingPolicy`]; `build()` validates.
#[derive(Clone, Debug)]
pub struct ServingPolicyBuilder {
    policy: ServingPolicy,
}

impl ServingPolicyBuilder {
    pub fn max_batch(mut self, n: usize) -> Self {
        self.policy.max_batch = n;
        self
    }

    pub fn prefill_chunk(mut self, tokens: usize) -> Self {
        self.policy.prefill_chunk = tokens;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.policy.queue_depth = depth;
        self
    }

    pub fn on_full(mut self, policy: AdmissionPolicy) -> Self {
        self.policy.on_full = policy;
        self
    }

    /// Drift-monitor thresholds (`f64::INFINITY` threshold disables).
    pub fn drift(mut self, threshold: f64, cooldown: u64) -> Self {
        self.policy.drift_threshold = threshold;
        self.policy.drift_cooldown = cooldown;
        self
    }

    /// Static execution mode the fleet starts on.
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.policy.mode = Some(mode);
        self
    }

    /// Append a priority class (classes are indexed in call order after
    /// the implicit class 0 default — use [`Self::slo`] to retarget it).
    pub fn class(mut self, name: &str, ttft_target: f64, sheddable: bool) -> Self {
        self.policy.classes.push(ClassPolicy { name: name.into(), ttft_target, sheddable });
        self
    }

    /// Set the TTFT target (seconds) of priority class `class`, growing
    /// the class table with sheddable defaults as needed.
    pub fn slo(mut self, class: usize, ttft_target: f64) -> Self {
        while self.policy.classes.len() <= class {
            let i = self.policy.classes.len();
            self.policy.classes.push(ClassPolicy {
                name: format!("class{i}"),
                ttft_target: f64::INFINITY,
                sheddable: i > 0,
            });
        }
        self.policy.classes[class].ttft_target = ttft_target;
        self
    }

    /// Turn the live strategy router on.
    pub fn router(mut self, cfg: RouterConfig) -> Self {
        self.policy.router = Some(cfg);
        self
    }

    pub fn build(self) -> Result<ServingPolicy, String> {
        self.policy.validate()?;
        Ok(self.policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_rejects(b: ServingPolicyBuilder, needle: &str) {
        let err = b.build().expect_err("policy must be rejected");
        assert!(err.contains(needle), "error {err:?} does not mention {needle:?}");
    }

    #[test]
    fn builder_defaults_validate() {
        let p = ServingPolicy::builder().build().unwrap();
        assert_eq!(p.max_batch, ServerOpts::default().max_batch);
        assert_eq!(p.n_classes(), 1);
        assert!(p.router.is_none());
    }

    #[test]
    fn zero_max_batch_is_rejected() {
        assert_rejects(ServingPolicy::builder().max_batch(0), "max_batch");
    }

    #[test]
    fn zero_prefill_chunk_is_rejected() {
        assert_rejects(ServingPolicy::builder().prefill_chunk(0), "prefill_chunk");
    }

    #[test]
    fn zero_queue_depth_is_rejected() {
        assert_rejects(ServingPolicy::builder().queue_depth(0), "queue_depth");
    }

    #[test]
    fn nan_and_sub_unity_drift_thresholds_are_rejected() {
        assert_rejects(ServingPolicy::builder().drift(f64::NAN, 4), "drift_threshold");
        assert_rejects(ServingPolicy::builder().drift(0.5, 4), "drift_threshold");
        // INFINITY is the documented disable sentinel, not an error
        assert!(ServingPolicy::builder().drift(f64::INFINITY, 0).build().is_ok());
    }

    #[test]
    fn non_positive_slo_targets_are_rejected() {
        assert_rejects(ServingPolicy::builder().slo(0, f64::NAN), "ttft_target");
        assert_rejects(ServingPolicy::builder().slo(1, 0.0), "ttft_target");
        assert_rejects(ServingPolicy::builder().slo(0, -2.0), "ttft_target");
    }

    #[test]
    fn router_threshold_shapes_are_rejected() {
        let cfg = |f: fn(&mut RouterConfig)| {
            let mut c = RouterConfig::default();
            f(&mut c);
            ServingPolicy::builder().router(c)
        };
        assert_rejects(cfg(|c| c.window = 0), "window");
        assert_rejects(cfg(|c| c.enter_prefill_share = f64::NAN), "enter_prefill_share");
        assert_rejects(cfg(|c| c.exit_prefill_share = 0.0), "exit_prefill_share");
        // inverted hysteresis gap: flap-prone, rejected
        assert_rejects(
            cfg(|c| {
                c.enter_prefill_share = 0.3;
                c.exit_prefill_share = 0.5;
            }),
            "dead zone",
        );
        assert_rejects(cfg(|c| c.cooldown_secs = -1.0), "cooldown_secs");
        assert_rejects(cfg(|c| c.async_share_band = (0.7, 0.2)), "async_share_band");
    }

    #[test]
    fn slo_builder_grows_class_table() {
        let p = ServingPolicy::builder().slo(2, 0.5).build().unwrap();
        assert_eq!(p.n_classes(), 3);
        assert!(p.classes[0].ttft_target.is_infinite());
        assert!(!p.classes[0].sheddable, "class 0 defaults to protected");
        assert!(p.classes[1].sheddable);
        assert_eq!(p.classes[2].ttft_target, 0.5);
    }

    #[test]
    fn server_opts_convert_without_validation() {
        // the saturation tests run a zero-depth queue on purpose — the
        // legacy conversion must keep working
        let p: ServingPolicy = ServerOpts { queue_depth: 0, ..ServerOpts::default() }.into();
        assert_eq!(p.queue_depth, 0);
        assert!(p.validate().is_err());
        assert_eq!(p.n_classes(), 1);
    }
}
