//! SLO-aware adaptive strategy routing behind one [`ServingPolicy`].
//!
//! The repo's serving strategies — the blended intra-kernel split, the
//! async CPU/XPU parallel-batch pair, phase-disaggregated prefill/decode —
//! were each frozen per run while the metrics that tell them apart (TTFT,
//! queue depth, learned skew, tok/s, bus utilization) streamed by unused.
//! This module is the layer that chooses between them live:
//!
//! * [`ServingPolicy`] — the one config every serving entry point accepts
//!   (`serve_dynamic`, `server::testing::run_trace`,
//!   `cluster::harness::run_cluster`), built with
//!   [`ServingPolicy::builder`], validated by [`ServingPolicy::validate`],
//!   convertible from the legacy `ServerOpts` for compatibility.
//! * [`StrategyRouter`] — watches the arrival mix and switches the fleet's
//!   [`crate::coordinator::Strategy`] with Schmitt-trigger hysteresis and a
//!   switch cooldown (the anti-flap gates generalized from
//!   `DriftMonitor`); every switch rides the epoch-bump rebuild path, so
//!   in-flight sessions migrate bit-identically.
//! * [`SloGate`] + [`ClassPolicy`] — priority-classed admission with
//!   per-class TTFT targets: a deterministic capacity predictor sheds
//!   low-priority work first when the backlog already spells an SLO miss.
//!
//! Decision table (signal → strategy) — see README "Strategy router":
//!
//! | window prefill share | learned device share | strategy |
//! |---|---|---|
//! | ≥ `enter_prefill_share` | any | `Disaggregated` phase pair |
//! | ≤ `exit_prefill_share` | inside `async_share_band` | `AsyncBatch` pair |
//! | ≤ `exit_prefill_share` | outside band / cores-only | `IntraKernel` blend |
//! | in between (dead zone) | any | hold current (no flap) |

mod policy;
mod strategy;

pub use policy::{ClassPolicy, RouterConfig, ServingPolicy, ServingPolicyBuilder};
pub use strategy::{SloGate, StrategyRouter};
