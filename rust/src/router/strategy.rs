//! The live strategy router and the SLO admission predictor.

use std::collections::VecDeque;

use crate::coordinator::{ExecMode, Strategy};

use super::policy::{RouterConfig, ServingPolicy};

/// Watches the offered load and switches the fleet between the serving
/// strategies the machine supports.
///
/// The only signal a decision needs is already in the arrival stream: the
/// *prefill share* of the last [`RouterConfig::window`] arrivals (prompt
/// tokens over prompt + requested decode tokens). Long-prompt bursts push
/// it toward 1 → phase-disaggregated serving, where prefill and decode
/// stop degrading each other. Decode-heavy chat pulls it toward 0 → the
/// blended intra-kernel split (or, on a hetero lease whose learned device
/// share sits inside [`RouterConfig::async_share_band`], the async
/// parallel-batch pair that gives the XPU whole token rounds).
///
/// Two gates generalized from `DriftMonitor` keep it from flapping: a
/// Schmitt-trigger dead zone between the enter/exit thresholds (inside it
/// the router holds its current strategy) and a cooldown of
/// [`RouterConfig::cooldown_secs`] between switches. Every switch is an
/// epoch bump — the fleet rebuild migrates in-flight sessions
/// bit-identically, so flipping strategy never perturbs a token stream.
#[derive(Clone, Debug)]
pub struct StrategyRouter {
    cfg: RouterConfig,
    /// decode-heavy strategy (blended intra-kernel split)
    chat: Strategy,
    /// prefill-burst strategy (phase-disaggregated pair)
    burst: Strategy,
    /// async parallel-batch strategy, when the machine has a leasable XPU
    hetero: Option<Strategy>,
    window: VecDeque<(usize, usize)>,
    current: Strategy,
    last_switch_at: f64,
    /// every switch taken: (virtual seconds, strategy switched to)
    pub switches: Vec<(f64, Strategy)>,
}

impl StrategyRouter {
    /// A router over the machine's strategy candidates (see
    /// `Coordinator::strategy_candidates`), or `None` when the policy has
    /// no [`RouterConfig`]. The fleet starts on the policy's static mode
    /// if set, else the decode-heavy chat strategy.
    pub fn from_policy(policy: &ServingPolicy, candidates: &[Strategy]) -> Option<StrategyRouter> {
        let cfg = policy.router?;
        let find = |m: ExecMode| candidates.iter().find(|s| s.mode == m).copied();
        let chat = find(ExecMode::IntraKernel)?;
        let burst = find(ExecMode::Disaggregated).unwrap_or(chat);
        let hetero = find(ExecMode::AsyncBatch);
        let current = policy
            .mode
            .and_then(find)
            .unwrap_or(if policy.mode == Some(ExecMode::Disaggregated) { burst } else { chat });
        Some(StrategyRouter {
            cfg,
            chat,
            burst,
            hetero,
            window: VecDeque::with_capacity(cfg.window + 1),
            current,
            last_switch_at: f64::NEG_INFINITY,
            switches: Vec::new(),
        })
    }

    /// Feed one arrival into the decision window (shed arrivals count too:
    /// the router reasons about *offered* load, not admitted load).
    pub fn note_arrival(&mut self, prompt_tokens: usize, decode_tokens: usize) {
        self.window.push_back((prompt_tokens, decode_tokens));
        while self.window.len() > self.cfg.window {
            self.window.pop_front();
        }
    }

    /// Prompt-token fraction of the offered tokens in the current window.
    pub fn prefill_share(&self) -> f64 {
        let (p, d) = self
            .window
            .iter()
            .fold((0usize, 0usize), |(p, d), &(pp, dd)| (p + pp, d + dd));
        if p + d == 0 {
            0.0
        } else {
            p as f64 / (p + d) as f64
        }
    }

    pub fn current(&self) -> Strategy {
        self.current
    }

    /// Decide at virtual time `now` whether to switch, given the learned
    /// device share of the fleet's hetero lease (if any). Returns the
    /// strategy to rebuild onto, or `None` to hold — because the window is
    /// not full yet, the cooldown has not elapsed, the share sits in the
    /// hysteresis dead zone, or the target equals the current strategy.
    pub fn decide(&mut self, now: f64, device_share: Option<f64>) -> Option<Strategy> {
        if self.window.len() < self.cfg.window {
            return None;
        }
        if now - self.last_switch_at < self.cfg.cooldown_secs {
            return None;
        }
        let share = self.prefill_share();
        let target = if share >= self.cfg.enter_prefill_share {
            self.burst
        } else if share <= self.cfg.exit_prefill_share {
            let (lo, hi) = self.cfg.async_share_band;
            match (self.hetero, device_share) {
                (Some(h), Some(r)) if r >= lo && r <= hi => h,
                _ => self.chat,
            }
        } else {
            return None; // dead zone: hold the current strategy
        };
        if target == self.current {
            return None;
        }
        self.current = target;
        self.last_switch_at = now;
        self.switches.push((now, target));
        Some(target)
    }
}

/// Deterministic capacity predictor behind SLO-aware admission.
///
/// Tracks serving capacity as an EWMA of decode tokens per kernel second
/// (the same mass-preserving α=0.3 blend the coordinator's strength table
/// uses) and predicts the queue-drain delay an arrival would see. A
/// sheddable arrival is bounced when the predicted delay already exceeds
/// the tightest TTFT target of any *higher-priority* class — low-priority
/// work is rejected first, before it can queue ahead of work with an SLO.
#[derive(Clone, Debug)]
pub struct SloGate {
    rate: f64,
    alpha: f64,
}

impl Default for SloGate {
    fn default() -> SloGate {
        SloGate::new()
    }
}

impl SloGate {
    pub fn new() -> SloGate {
        SloGate { rate: 0.0, alpha: 0.3 }
    }

    /// Fold one scheduler round into the learned service rate.
    pub fn observe(&mut self, decoded_tokens: usize, kernel_secs: f64) {
        if decoded_tokens == 0 || !(kernel_secs > 0.0) {
            return;
        }
        let inst = decoded_tokens as f64 / kernel_secs;
        self.rate = if self.rate > 0.0 { self.alpha * inst + (1.0 - self.alpha) * self.rate } else { inst };
    }

    /// Learned decode capacity (tokens/s); 0 until the first observation.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Seconds the given backlog (tokens queued ahead) takes to drain at
    /// the learned rate — 0 while the rate is unknown (never shed blind).
    pub fn predicted_wait(&self, backlog_tokens: f64) -> f64 {
        if self.rate > 0.0 {
            backlog_tokens / self.rate
        } else {
            0.0
        }
    }

    /// Whether an arrival of `class` should be shed given the queued
    /// backlog. Only sheddable classes are ever shed, and only to protect
    /// a finite TTFT target of a strictly higher-priority class.
    pub fn should_shed(&self, policy: &ServingPolicy, class: usize, backlog_tokens: f64) -> bool {
        if !policy.classes.get(class).is_some_and(|c| c.sheddable) {
            return false;
        }
        let protected = policy.classes[..class.min(policy.classes.len())]
            .iter()
            .map(|c| c.ttft_target)
            .fold(f64::INFINITY, f64::min);
        protected.is_finite() && self.predicted_wait(backlog_tokens) > protected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(cfg: RouterConfig) -> StrategyRouter {
        let policy = ServingPolicy::builder().router(cfg).build().unwrap();
        let b = |mode| Strategy { mode, max_batch: 4, prefill_chunk: 16 };
        StrategyRouter::from_policy(
            &policy,
            &[b(ExecMode::IntraKernel), b(ExecMode::AsyncBatch), b(ExecMode::Disaggregated)],
        )
        .unwrap()
    }

    fn small_cfg() -> RouterConfig {
        RouterConfig { window: 4, cooldown_secs: 1.0, ..RouterConfig::default() }
    }

    #[test]
    fn holds_until_window_fills_then_switches_on_burst() {
        let mut r = router(small_cfg());
        assert_eq!(r.current().mode, ExecMode::IntraKernel);
        for _ in 0..3 {
            r.note_arrival(512, 4);
            assert!(r.decide(0.0, None).is_none(), "window not full yet");
        }
        r.note_arrival(512, 4);
        let s = r.decide(0.0, None).expect("burst window must switch");
        assert_eq!(s.mode, ExecMode::Disaggregated);
        assert_eq!(r.switches.len(), 1);
    }

    #[test]
    fn dead_zone_and_cooldown_prevent_flapping() {
        let mut r = router(small_cfg());
        for _ in 0..4 {
            r.note_arrival(512, 4);
        }
        assert!(r.decide(0.0, None).is_some());
        // mixed tail lands in the dead zone: share ~0.5 → hold
        for _ in 0..4 {
            r.note_arrival(8, 8);
        }
        assert!(r.decide(10.0, None).is_none());
        assert_eq!(r.current().mode, ExecMode::Disaggregated);
        // clearly decode-heavy, but inside the cooldown → still held
        for _ in 0..4 {
            r.note_arrival(2, 64);
        }
        assert!(r.decide(10.5, None).is_none(), "cooldown must gate");
        // past the cooldown the exit threshold finally fires
        let s = r.decide(11.5, None).expect("decode-heavy window must exit");
        assert_eq!(s.mode, ExecMode::IntraKernel);
        // repeating the same window never re-switches
        assert!(r.decide(20.0, None).is_none());
    }

    #[test]
    fn decode_heavy_with_learned_device_share_picks_async_batch() {
        let mut r = router(small_cfg());
        for _ in 0..4 {
            r.note_arrival(2, 64);
        }
        // share outside the async band → stay on the blended split
        assert!(r.decide(0.0, Some(0.9)).is_none());
        for _ in 0..4 {
            r.note_arrival(512, 4);
        }
        assert_eq!(r.decide(2.0, Some(0.9)).unwrap().mode, ExecMode::Disaggregated);
        for _ in 0..4 {
            r.note_arrival(2, 64);
        }
        // XPU pulling its weight → the decode-heavy exit lands on AsyncBatch
        assert_eq!(r.decide(4.0, Some(0.5)).unwrap().mode, ExecMode::AsyncBatch);
    }

    #[test]
    fn slo_gate_sheds_only_sheddable_classes_under_predicted_overload() {
        let policy = ServingPolicy::builder()
            .slo(0, 0.5)
            .class("batch", f64::INFINITY, true)
            .build()
            .unwrap();
        let mut g = SloGate::new();
        // unknown rate: never shed blind
        assert!(!g.should_shed(&policy, 1, 1e9));
        g.observe(100, 1.0); // 100 tok/s
        assert!((g.rate() - 100.0).abs() < 1e-9);
        // 10 queued tokens → 0.1 s wait, under the 0.5 s target
        assert!(!g.should_shed(&policy, 1, 10.0));
        // 100 queued tokens → 1 s predicted wait: shed the batch class...
        assert!(g.should_shed(&policy, 1, 100.0));
        // ...but never the protected class 0
        assert!(!g.should_shed(&policy, 0, 100.0));
    }

    #[test]
    fn slo_gate_rate_is_an_ewma() {
        let mut g = SloGate::new();
        g.observe(100, 1.0);
        g.observe(200, 1.0);
        assert!((g.rate() - (0.3 * 200.0 + 0.7 * 100.0)).abs() < 1e-9);
        g.observe(0, 1.0); // empty rounds leave the estimate alone
        g.observe(10, 0.0);
        assert!((g.rate() - 130.0).abs() < 1e-9);
    }
}
