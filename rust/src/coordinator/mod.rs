//! Multi-stream **coordinator** — the serving-level half of the paper's
//! coordination story (its §2 runtime balances one kernel across all cores;
//! this module decides *which cores each concurrent stream gets* before that
//! per-kernel proportional split runs).
//!
//! The [`Coordinator`] owns the machine's core set ([`CpuSpec`]) and hands
//! each admitted stream a [`Lease`]: a disjoint, topology-aware subset of
//! physical cores plus a proportional share of the shared memory bus. The
//! lease materializes as an executor — [`Lease::sim_executor`] for the
//! deterministic hybrid-CPU simulator, [`Lease::host_pool`] for real
//! core-pinned threads — so one `Engine`/`ParallelRuntime` per stream runs
//! the paper's dynamic loop *inside* its lease while the coordinator
//! rebalances *between* leases.
//!
//! Rebalancing reuses the paper's own mechanism one level up: every
//! [`Coordinator::observe`] folds a kernel's measured per-core rates into a
//! per-core **strength** table with the same mass-preserving EWMA as
//! `perf::PerfTable` (eq. 2), and [`Coordinator::rebalance`] re-partitions
//! cores so each stream's total strength is as equal as the topology
//! allows. A background process stealing half of one lease's P-cores is
//! therefore detected from timing alone and answered by spreading the
//! degraded cores across streams (see `rust/tests/coordinator_integration.rs`).
//!
//! Allocation invariants (property-tested in `rust/tests/prop_invariants.rs`):
//! * leases are pairwise **disjoint**;
//! * their union **covers** every core of the machine (work-conserving);
//! * under [`AllocPolicy::Balanced`] with uniform strengths, each core
//!   *kind* (P / E / LPE) is split across streams to within one core
//!   (**topology-aware** — every stream gets its fair share of fast cores);
//! * no lease is empty while another holds two or more cores.
//!
//! Strength values are mass-preserving *within* a lease per observation
//! (only co-measured cores are comparable, exactly like the paper's ratio
//! table); cross-lease drift washes out over successive rebalances as core
//! membership mixes.

use std::collections::BTreeMap;

use crate::cpu::{CoreKind, CpuSpec, Isa};
use crate::exec::RunResult;
use crate::pool::HostPool;
use crate::sched::largest_remainder_split;
use crate::sim::bw::{waterfill, Contender};
use crate::sim::{BackgroundLoad, SimConfig, SimExecutor};

/// Caller-chosen identity of one serving stream.
pub type StreamId = u64;

/// The memory-bus bandwidth (GB/s) the given cores can claim for
/// themselves: proportional to their waterfilled allocation when every core
/// of the machine streams flat out. Leasing *all* cores returns the full
/// bus, so a single-stream lease behaves exactly like the raw machine.
pub fn bus_share(machine: &CpuSpec, cores: &[usize]) -> f64 {
    let contenders: Vec<Contender> = machine
        .cores
        .iter()
        .map(|c| Contender { weight: c.mem_weight, cap: c.mem_bw_gbps })
        .collect();
    let alloc = waterfill(&contenders, machine.bus_bw_gbps);
    let total: f64 = alloc.iter().sum();
    if total <= 0.0 {
        return machine.bus_bw_gbps;
    }
    let share: f64 = cores.iter().map(|&i| alloc[i]).sum();
    machine.bus_bw_gbps * share / total
}

/// A disjoint reservation of physical cores for one stream.
///
/// Leases are snapshots: any membership change or rebalance bumps the
/// coordinator [`Coordinator::epoch`] and re-issues every lease, so holders
/// compare `lease.epoch` against `coordinator.epoch()` and rebuild their
/// executor when it lags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    pub stream: StreamId,
    /// global core ids (indices into the machine spec), ascending
    pub cores: Vec<usize>,
    /// allocation epoch this lease was issued under
    pub epoch: u64,
}

impl Lease {
    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// True when the machine had fewer cores than streams and this stream
    /// is waiting for capacity. Empty leases must not build executors.
    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    /// Global core id of lease-local worker `local`.
    pub fn global_core(&self, local: usize) -> usize {
        self.cores[local]
    }

    /// Lease-local worker index of global core `global`, if leased here.
    pub fn local_index(&self, global: usize) -> Option<usize> {
        self.cores.iter().position(|&c| c == global)
    }

    /// The executor-facing sub-machine: leased cores re-indexed `0..n`
    /// with this lease's proportional share of the memory bus.
    pub fn spec(&self, machine: &CpuSpec) -> CpuSpec {
        machine.subset(&self.cores, bus_share(machine, &self.cores))
    }

    /// Simulator executor over exactly the leased cores.
    pub fn sim_executor(&self, machine: &CpuSpec, cfg: SimConfig) -> SimExecutor {
        SimExecutor::new(self.spec(machine), cfg)
    }

    /// Real-thread executor: one worker per leased core, pinned to the
    /// lease's *global* core ids.
    pub fn host_pool(&self) -> HostPool {
        HostPool::with_cores(&self.cores)
    }

    /// Background-load entries for this lease's simulator: one per leased
    /// core whose *global* id appears in `degraded_globals`, mapped to the
    /// lease-local index and stealing `fraction` of that core's cycles for
    /// the whole run. Cores of `degraded_globals` leased elsewhere are
    /// ignored — the load follows the physical core, not the lease.
    pub fn background_for(&self, degraded_globals: &[usize], fraction: f64) -> Vec<BackgroundLoad> {
        self.cores
            .iter()
            .enumerate()
            .filter(|(_, g)| degraded_globals.contains(g))
            .map(|(local, _)| BackgroundLoad { core: local, start: 0.0, end: 1e9, fraction })
            .collect()
    }
}

/// How the coordinator partitions cores across streams.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Split every core kind evenly across streams and balance measured
    /// strength — fair multi-tenant serving (default).
    #[default]
    Balanced,
    /// Give the strongest cores to the earliest-admitted streams in
    /// contiguous blocks — latency-tiered serving.
    Packed,
}

/// Owns the machine's cores and leases disjoint subsets to streams.
pub struct Coordinator {
    spec: CpuSpec,
    policy: AllocPolicy,
    /// EWMA gain α for strength updates (weight of the old value, like
    /// `PerfConfig::alpha`; paper uses 0.3).
    pub alpha: f64,
    /// per-core measured strength, seeded from the spec's ideal VNNI
    /// compute ratios (slowest core = 1.0)
    strength: Vec<f64>,
    /// admitted streams in admission order
    streams: Vec<StreamId>,
    leases: BTreeMap<StreamId, Lease>,
    epoch: u64,
}

impl Coordinator {
    pub fn new(spec: CpuSpec, policy: AllocPolicy) -> Coordinator {
        spec.validate().expect("invalid CpuSpec");
        let strength = spec.ideal_ratios(Isa::AvxVnni);
        Coordinator {
            spec,
            policy,
            alpha: 0.3,
            strength,
            streams: Vec::new(),
            leases: BTreeMap::new(),
            epoch: 0,
        }
    }

    pub fn machine(&self) -> &CpuSpec {
        &self.spec
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Bumped on every admit/finish/rebalance; stale leases carry an older
    /// value and must be refreshed via [`Coordinator::lease`].
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Current measured per-core strengths (global core order).
    pub fn strengths(&self) -> &[f64] {
        &self.strength
    }

    /// Admit a new stream and return its lease. Re-partitions every
    /// existing lease (epoch bump). Panics on a duplicate stream id.
    pub fn admit(&mut self, stream: StreamId) -> Lease {
        assert!(!self.streams.contains(&stream), "stream {stream} already admitted");
        self.streams.push(stream);
        self.assign();
        self.leases[&stream].clone()
    }

    /// Release a stream's cores back to the pool (no-op for unknown ids);
    /// remaining leases grow to cover the machine again.
    pub fn finish(&mut self, stream: StreamId) {
        let before = self.streams.len();
        self.streams.retain(|&s| s != stream);
        if self.streams.len() != before {
            self.assign();
        }
    }

    /// The current lease of `stream`, if admitted.
    pub fn lease(&self, stream: StreamId) -> Option<&Lease> {
        self.leases.get(&stream)
    }

    /// All current leases (stream-id order).
    pub fn leases(&self) -> impl Iterator<Item = &Lease> {
        self.leases.values()
    }

    /// Fold one kernel's measured per-core result back into the strength
    /// table. `lease` must be the exact lease the measuring executor was
    /// built from: the result's local→global core mapping is only valid
    /// for it, so results measured under a stale lease (the coordinator
    /// re-partitioned since — different epoch or cores) or an unknown
    /// stream are silently dropped rather than mis-attributed to cores
    /// the stream no longer owns. Mirrors the paper's eq. 2:
    /// participating cores' rates are rescaled so their strength mass is
    /// preserved, then EWMA-filtered with `alpha`. A single participant
    /// carries no relative information and is skipped.
    ///
    /// Returns `true` when the observation was folded into the strength
    /// table, `false` when it was dropped (stale epoch, foreign stream or
    /// degenerate measurement) — the serving layer uses this to count
    /// epoch-stale measurements racing a rebuild.
    pub fn observe(&mut self, lease: &Lease, res: &RunResult) -> bool {
        match self.leases.get(&lease.stream) {
            Some(current) if current == lease => {}
            _ => return false, // stale or foreign lease
        }
        let mut mass = 0.0f64;
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for (local, t) in res.per_core_secs.iter().enumerate() {
            let Some(t) = t else { continue };
            let units = res.units_done.get(local).copied().unwrap_or(0);
            if *t > 0.0 && units > 0 && local < lease.cores.len() {
                let g = lease.global_core(local);
                mass += self.strength[g];
                rates.push((g, units as f64 / t));
            }
        }
        if rates.len() < 2 {
            return false;
        }
        let rate_sum: f64 = rates.iter().map(|(_, r)| r).sum();
        if !(rate_sum.is_finite() && rate_sum > 0.0 && mass > 0.0) {
            return false;
        }
        let scale = mass / rate_sum;
        for (g, r) in rates {
            self.strength[g] = self.alpha * self.strength[g] + (1.0 - self.alpha) * r * scale;
        }
        true
    }

    /// Re-partition cores across the admitted streams using the current
    /// strengths (epoch bump). Call after enough [`Coordinator::observe`]s
    /// have shifted the table — e.g. when a background load is detected.
    pub fn rebalance(&mut self) {
        self.assign();
    }

    fn assign(&mut self) {
        self.epoch += 1;
        self.leases.clear();
        let k = self.streams.len();
        if k == 0 {
            return;
        }
        let mut cores_per_stream: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut strength_sum = vec![0.0f64; k];

        match self.policy {
            AllocPolicy::Packed => {
                let mut order: Vec<usize> = (0..self.spec.n_cores()).collect();
                order.sort_by(|&a, &b| {
                    self.strength[b].partial_cmp(&self.strength[a]).unwrap().then(a.cmp(&b))
                });
                let sizes = largest_remainder_split(order.len(), &vec![1.0; k]);
                let mut cursor = 0;
                for (s, &sz) in sizes.iter().enumerate() {
                    for &core in &order[cursor..cursor + sz] {
                        cores_per_stream[s].push(core);
                        strength_sum[s] += self.strength[core];
                    }
                    cursor += sz;
                }
            }
            AllocPolicy::Balanced => {
                for kind in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
                    let mut pool: Vec<usize> = self
                        .spec
                        .cores
                        .iter()
                        .filter(|c| c.kind == kind)
                        .map(|c| c.id)
                        .collect();
                    if pool.is_empty() {
                        continue;
                    }
                    // strongest first; ties toward the lower core id
                    pool.sort_by(|&a, &b| {
                        self.strength[b].partial_cmp(&self.strength[a]).unwrap().then(a.cmp(&b))
                    });
                    // every stream gets its fair share of this kind (±1)
                    let mut quota = largest_remainder_split(pool.len(), &vec![1.0; k]);
                    for &core in &pool {
                        // among streams with quota left, the weakest so far;
                        // ties toward admission order
                        let mut best: Option<usize> = None;
                        for s in 0..k {
                            if quota[s] == 0 {
                                continue;
                            }
                            best = match best {
                                None => Some(s),
                                Some(b) if strength_sum[s] < strength_sum[b] - 1e-12 => Some(s),
                                other => other,
                            };
                        }
                        let s = best.expect("kind quotas sum to the kind's core count");
                        quota[s] -= 1;
                        cores_per_stream[s].push(core);
                        strength_sum[s] += self.strength[core];
                    }
                }
            }
        }

        // repair: no stream may be empty while another holds ≥ 2 cores
        // (possible when a kind has fewer cores than there are streams)
        loop {
            let Some(empty) = (0..k).find(|&s| cores_per_stream[s].is_empty()) else { break };
            let rich = (0..k)
                .filter(|&s| cores_per_stream[s].len() >= 2)
                .max_by(|&a, &b| {
                    let by_strength =
                        strength_sum[a].partial_cmp(&strength_sum[b]).unwrap().then(b.cmp(&a));
                    cores_per_stream[a].len().cmp(&cores_per_stream[b].len()).then(by_strength)
                });
            let Some(rich) = rich else { break };
            let pos = (0..cores_per_stream[rich].len())
                .min_by(|&i, &j| {
                    let (a, b) = (cores_per_stream[rich][i], cores_per_stream[rich][j]);
                    self.strength[a].partial_cmp(&self.strength[b]).unwrap().then(a.cmp(&b))
                })
                .unwrap();
            let core = cores_per_stream[rich].remove(pos);
            strength_sum[rich] -= self.strength[core];
            strength_sum[empty] += self.strength[core];
            cores_per_stream[empty].push(core);
        }

        for (s, &stream) in self.streams.iter().enumerate() {
            let mut cores = std::mem::take(&mut cores_per_stream[s]);
            cores.sort_unstable();
            self.leases.insert(stream, Lease { stream, cores, epoch: self.epoch });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;

    fn kinds(spec: &CpuSpec, lease: &Lease, kind: CoreKind) -> usize {
        lease.cores.iter().filter(|&&c| spec.cores[c].kind == kind).count()
    }

    fn assert_disjoint_covering(c: &Coordinator) {
        let mut seen = vec![false; c.machine().n_cores()];
        for lease in c.leases() {
            for &core in &lease.cores {
                assert!(!seen[core], "core {core} leased twice");
                seen[core] = true;
            }
        }
        if c.n_streams() > 0 {
            assert!(seen.iter().all(|&s| s), "not covering: {seen:?}");
        }
    }

    #[test]
    fn single_stream_gets_the_whole_machine() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let lease = c.admit(7);
        assert_eq!(lease.cores, (0..16).collect::<Vec<_>>());
        // full machine → full bus: lease spec behaves like the raw machine
        let sub = lease.spec(&spec);
        assert_eq!(sub.n_cores(), 16);
        assert!((sub.bus_bw_gbps - spec.bus_bw_gbps).abs() < 1e-9);
    }

    #[test]
    fn two_streams_split_both_kinds_evenly() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let l1 = c.lease(1).cloned();
        assert!(l1.is_none());
        let l1 = c.admit(1);
        // l0 from admit(0) is stale (epoch moved); refresh
        assert!(l0.epoch < c.epoch());
        let l0 = c.lease(0).unwrap().clone();
        assert_disjoint_covering(&c);
        for l in [&l0, &l1] {
            assert_eq!(l.n_cores(), 8);
            assert_eq!(kinds(&spec, l, CoreKind::Performance), 4);
            assert_eq!(kinds(&spec, l, CoreKind::Efficiency), 4);
            // equal halves of an equal-weight machine → half the bus
            let sub = l.spec(&spec);
            assert!((sub.bus_bw_gbps - spec.bus_bw_gbps / 2.0).abs() < 1e-9, "{}", sub.bus_bw_gbps);
        }
    }

    #[test]
    fn three_streams_on_125h_are_topology_aware() {
        let spec = presets::ultra_125h();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert!(!lease.is_empty());
            // 4 P / 3 streams → 1–2 each; 8 E → 2–3 each; 2 LPE → 0–1
            let p = kinds(&spec, lease, CoreKind::Performance);
            let e = kinds(&spec, lease, CoreKind::Efficiency);
            assert!((1..=2).contains(&p), "P={p}");
            assert!((2..=3).contains(&e), "E={e}");
        }
    }

    #[test]
    fn finish_returns_cores_to_the_survivors() {
        let mut c = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        let epoch = c.epoch();
        c.finish(0);
        assert!(c.epoch() > epoch);
        assert!(c.lease(0).is_none());
        assert_eq!(c.lease(1).unwrap().n_cores(), 16);
        // unknown stream: quiet no-op, no epoch churn
        let epoch = c.epoch();
        c.finish(99);
        assert_eq!(c.epoch(), epoch);
    }

    #[test]
    fn packed_policy_tiers_the_fast_cores() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Packed);
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        let l0 = c.lease(0).unwrap();
        let l1 = c.lease(1).unwrap();
        // stream 0 holds all 8 P-cores, stream 1 all 8 E-cores
        assert_eq!(kinds(&spec, l0, CoreKind::Performance), 8);
        assert_eq!(kinds(&spec, l1, CoreKind::Efficiency), 8);
    }

    #[test]
    fn more_streams_than_a_kind_still_covers_without_empties() {
        // 2P + 2E sub-machine, 3 streams: per-kind quotas alone would leave
        // stream 2 empty; the repair pass must fill it
        let machine = presets::core_12900k().subset(&[0, 1, 8, 9], 17.0);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert!(!lease.is_empty(), "empty lease {:?}", lease);
        }
    }

    #[test]
    fn more_streams_than_cores_leaves_overflow_waiting() {
        let machine = presets::core_12900k().subset(&[0, 8], 8.0);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        let empties = c.leases().filter(|l| l.is_empty()).count();
        assert_eq!(empties, 1);
        let total: usize = c.leases().map(|l| l.n_cores()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn observe_learns_a_slow_core_and_rebalance_spreads_it() {
        // homogeneous 4-core machine, 2 streams → 2 cores each
        let machine = presets::homogeneous(4);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        let l0 = c.lease(0).unwrap().clone();
        // stream 0's first core runs at half rate: equal units, double time
        for _ in 0..20 {
            let res = RunResult {
                per_core_secs: vec![Some(2.0), Some(1.0)],
                wall_secs: 2.0,
                units_done: vec![100, 100],
            };
            c.observe(&l0, &res);
        }
        let slow = l0.global_core(0);
        let fast = l0.global_core(1);
        assert!(
            c.strengths()[slow] < 0.6 * c.strengths()[fast],
            "strengths {:?}",
            c.strengths()
        );
        c.rebalance();
        assert_disjoint_covering(&c);
        // the slow core's lease also holds the strongest remaining core —
        // strength sums are balanced, not left lopsided
        let sums: Vec<f64> = c
            .leases()
            .map(|l| l.cores.iter().map(|&g| c.strengths()[g]).sum::<f64>())
            .collect();
        let (a, b) = (sums[0], sums[1]);
        assert!((a - b).abs() / a.max(b) < 0.35, "sums {sums:?}");
    }

    #[test]
    fn observe_ignores_degenerate_and_stale_results() {
        let mut c = Coordinator::new(presets::homogeneous(4), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let before = c.strengths().to_vec();
        // single participant: no relative information
        let accepted = c.observe(
            &l0,
            &RunResult {
                per_core_secs: vec![Some(1.0), None, None, None],
                wall_secs: 1.0,
                units_done: vec![10, 0, 0, 0],
            },
        );
        assert!(!accepted);
        // lease for a stream the coordinator never admitted: ignored
        let foreign = Lease { stream: 9, cores: vec![0, 1], epoch: 0 };
        let skewed = RunResult {
            per_core_secs: vec![Some(1.0), Some(4.0)],
            wall_secs: 4.0,
            units_done: vec![100, 100],
        };
        assert!(!c.observe(&foreign, &skewed));
        assert_eq!(c.strengths(), &before[..]);
        // stale lease: admitting stream 1 re-partitions, so a result
        // measured under the old 4-core lease must not be mis-mapped onto
        // the new 2-core lease's globals
        c.admit(1);
        let before = c.strengths().to_vec();
        assert!(!c.observe(&l0, &skewed));
        assert_eq!(c.strengths(), &before[..]);
        // the refreshed lease is accepted
        let fresh = c.lease(0).unwrap().clone();
        assert!(c.observe(&fresh, &skewed));
        assert_ne!(c.strengths(), &before[..]);
    }

    #[test]
    fn background_for_maps_globals_to_lease_locals() {
        let lease = Lease { stream: 0, cores: vec![1, 4, 9, 12], epoch: 1 };
        // global 4 → local 1, global 12 → local 3; global 5 leased elsewhere
        let bg = lease.background_for(&[4, 12, 5], 0.5);
        let cores: Vec<usize> = bg.iter().map(|b| b.core).collect();
        assert_eq!(cores, vec![1, 3]);
        assert!(bg.iter().all(|b| b.fraction == 0.5 && b.start == 0.0 && b.end == 1e9));
        assert!(lease.background_for(&[], 0.5).is_empty());
    }

    #[test]
    fn lease_local_global_maps_roundtrip() {
        let mut c = Coordinator::new(presets::ultra_125h(), AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        for lease in c.leases() {
            for local in 0..lease.n_cores() {
                let g = lease.global_core(local);
                assert_eq!(lease.local_index(g), Some(local));
            }
            assert_eq!(lease.local_index(999), None);
        }
    }
}
