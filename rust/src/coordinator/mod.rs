//! Multi-stream **coordinator** — the serving-level half of the paper's
//! coordination story (its §2 runtime balances one kernel across all cores;
//! this module decides *which compute units each concurrent stream gets*
//! before that per-kernel proportional split runs).
//!
//! The [`Coordinator`] owns the machine's **compute units** — its CPU cores
//! ([`CpuSpec`]) *and* its accelerators ([`AcceleratorSpec`]: NPU / iGPU
//! class devices on the same bus) — and hands each admitted stream a
//! [`Lease`]: a disjoint subset of units ([`ComputeUnit`]) plus a
//! proportional share of the shared memory bus. A lease can therefore be
//! heterogeneous — "2 P-cores + the NPU" — and materializes as an executor:
//! [`Lease::sim_executor`] for a cores-only lease on the deterministic
//! hybrid-CPU simulator, [`Lease::xpu_executor`] for a lease that owns
//! accelerators (cross-device dispatch through [`crate::sim::xpu`]), or
//! [`Lease::host_pool`] for real core-pinned threads. One
//! `Engine`/`ParallelRuntime` per stream runs the paper's dynamic loop
//! *inside* its lease while the coordinator rebalances *between* leases.
//!
//! Rebalancing reuses the paper's own mechanism one level up: every
//! [`Coordinator::observe`] folds a kernel's measured per-unit rates —
//! cores and accelerator devices alike — into one per-unit **strength**
//! table with the same mass-preserving EWMA as `perf::PerfTable` (eq. 2),
//! and [`Coordinator::rebalance`] re-partitions units so each stream's
//! total strength is as equal as the topology allows. A background process
//! stealing half of one lease's P-cores is therefore detected from timing
//! alone and answered by spreading the degraded cores across streams (see
//! `rust/tests/coordinator_integration.rs`). [`Coordinator::strength_skew`]
//! condenses that drift into one observable — the serving layer's
//! `DriftMonitor` triggers a live rebalance when it crosses a threshold.
//!
//! Accelerator placement is a policy dimension of its own
//! ([`XpuAffinity`]): devices can be excluded from leasing (`None`), follow
//! the strength balance on every epoch (`Floating`, the default), or stick
//! with the stream that first received them (`Pinned`).
//!
//! Allocation invariants (property-tested in `rust/tests/prop_invariants.rs`):
//! * leases are pairwise **disjoint**;
//! * their union **covers** every core of the machine (work-conserving);
//! * each accelerator is owned by **at most one** lease, and never by a
//!   lease that holds no cores (an accelerator cannot run the model alone);
//! * under [`AllocPolicy::Balanced`] with uniform strengths, each core
//!   *kind* (P / E / LPE) is split across streams to within one core
//!   (**topology-aware** — every stream gets its fair share of fast cores);
//! * no lease is empty while another holds two or more cores.
//!
//! Strength values are mass-preserving *within* a lease per observation
//! (only co-measured units are comparable, exactly like the paper's ratio
//! table); cross-lease drift washes out over successive rebalances as unit
//! membership mixes.

use std::collections::BTreeMap;

use crate::cpu::{CoreKind, CpuSpec, Isa};
use crate::exec::RunResult;
use crate::pool::HostPool;
use crate::sched::largest_remainder_split;
use crate::sim::bw::{waterfill, Contender};
use crate::sim::xpu::{AcceleratorSpec, XpuDispatch, XpuExecutor, XpuSim};
use crate::sim::{BackgroundLoad, SimConfig, SimExecutor};

/// Caller-chosen identity of one serving stream.
pub type StreamId = u64;

/// One leasable compute resource of the machine.
///
/// The derived ordering — all cores (ascending id) before all accelerators
/// (ascending index) — is the canonical unit order inside a [`Lease`]:
/// lease-local worker `i` of an executor maps to `lease.units[i]`, for
/// cores *and* for the appended accelerator entries of an
/// [`XpuExecutor`]'s result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ComputeUnit {
    /// global core id (index into the machine [`CpuSpec`])
    Core(usize),
    /// accelerator index (into the coordinator's [`AcceleratorSpec`] list)
    Xpu(usize),
}

impl ComputeUnit {
    pub fn is_core(&self) -> bool {
        matches!(self, ComputeUnit::Core(_))
    }
}

/// How accelerators participate in leasing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum XpuAffinity {
    /// accelerators are never leased — cores-only serving
    None,
    /// an accelerator stays with the stream that first received it for as
    /// long as that stream lives (stable placement: no device-state
    /// migration across rebalances)
    Pinned,
    /// accelerators are re-placed on every epoch onto the stream with the
    /// least total strength — they follow the balance like cores do
    #[default]
    Floating,
}

/// How a heterogeneous lease (cores + accelerator) turns its units into
/// token throughput.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// The paper's §2 split: every kernel's range is partitioned across
    /// cores *and* devices so all units finish in lockstep. Best when a
    /// single kernel is large enough to amortize the device launch.
    #[default]
    IntraKernel,
    /// APEX-style parallel-batch execution: the accelerator runs one
    /// sub-batcher's whole token rounds while the cores run another's,
    /// concurrently. Admissions are routed by [`Coordinator::split_ratio`]
    /// and the ratio is re-learned online via
    /// [`Coordinator::observe_round`]. Wins when per-kernel device time is
    /// dominated by launch overhead (small models / short rows) — the
    /// intra-kernel split then serializes launches that `AsyncBatch`
    /// overlaps with CPU compute.
    AsyncBatch,
}

/// The memory-bus bandwidth (GB/s) the given cores can claim for
/// themselves: proportional to their waterfilled allocation when every core
/// of the machine streams flat out. Leasing *all* cores returns the full
/// bus, so a single-stream lease behaves exactly like the raw machine.
pub fn bus_share(machine: &CpuSpec, cores: &[usize]) -> f64 {
    let units: Vec<ComputeUnit> = cores.iter().map(|&c| ComputeUnit::Core(c)).collect();
    bus_share_units(machine, &[], &units)
}

/// Heterogeneous generalization of [`bus_share`]: cores *and* accelerators
/// contend for the machine bus (accelerator DMA engines carry their own
/// contention weight), and a lease's share is the waterfilled allocation of
/// exactly the units it owns.
pub fn bus_share_units(
    machine: &CpuSpec,
    accels: &[AcceleratorSpec],
    units: &[ComputeUnit],
) -> f64 {
    let mut contenders: Vec<Contender> = machine
        .cores
        .iter()
        .map(|c| Contender { weight: c.mem_weight, cap: c.mem_bw_gbps })
        .collect();
    for a in accels {
        contenders.push(Contender { weight: a.mem_weight, cap: a.mem_bw_gbps });
    }
    let alloc = waterfill(&contenders, machine.bus_bw_gbps);
    let total: f64 = alloc.iter().sum();
    if total <= 0.0 {
        return machine.bus_bw_gbps;
    }
    let n_cores = machine.n_cores();
    let share: f64 = units
        .iter()
        .map(|u| match u {
            ComputeUnit::Core(g) => alloc[*g],
            ComputeUnit::Xpu(a) => alloc[n_cores + *a],
        })
        .sum();
    machine.bus_bw_gbps * share / total
}

/// A disjoint reservation of compute units for one stream.
///
/// Leases are snapshots: any membership change or rebalance bumps the
/// coordinator [`Coordinator::epoch`] and re-issues every lease, so holders
/// compare `lease.epoch` against `coordinator.epoch()` and rebuild their
/// executor when it lags. Next to the unit set, a lease carries the
/// per-unit learned strengths at issue time (executor seeds) and its
/// proportional share of the memory bus.
#[derive(Clone, Debug, PartialEq)]
pub struct Lease {
    pub stream: StreamId,
    /// owned units in canonical order: cores ascending, then accelerators
    /// ascending — lease-local index `i` is executor worker `i`
    pub units: Vec<ComputeUnit>,
    /// learned strength of each unit when the lease was issued (parallel
    /// to `units`) — seeds the device-level split of [`Lease::xpu_executor`]
    pub strengths: Vec<f64>,
    /// this lease's proportional share of the machine bus (GB/s)
    pub bus_share_gbps: f64,
    /// allocation epoch this lease was issued under
    pub epoch: u64,
    /// how a hetero lease executes ([`ExecMode`]); cores-only leases
    /// ignore it
    pub mode: ExecMode,
}

impl Lease {
    /// A cores-only lease with flat strengths — for tests and for
    /// replaying foreign/stale observations; executors built from it fall
    /// back to recomputing the bus share from the machine.
    pub fn cores_only(stream: StreamId, cores: Vec<usize>, epoch: u64) -> Lease {
        let units: Vec<ComputeUnit> = cores.into_iter().map(ComputeUnit::Core).collect();
        let strengths = vec![1.0; units.len()];
        Lease {
            stream,
            units,
            strengths,
            bus_share_gbps: 0.0,
            epoch,
            mode: ExecMode::IntraKernel,
        }
    }

    pub fn n_units(&self) -> usize {
        self.units.len()
    }

    pub fn n_cores(&self) -> usize {
        self.units.iter().filter(|u| u.is_core()).count()
    }

    /// Global core ids (ascending) — the executor-facing CPU subset.
    pub fn cores(&self) -> Vec<usize> {
        self.units
            .iter()
            .filter_map(|u| match u {
                ComputeUnit::Core(g) => Some(*g),
                ComputeUnit::Xpu(_) => None,
            })
            .collect()
    }

    /// Owned accelerator indices (ascending).
    pub fn accels(&self) -> Vec<usize> {
        self.units
            .iter()
            .filter_map(|u| match u {
                ComputeUnit::Xpu(a) => Some(*a),
                ComputeUnit::Core(_) => None,
            })
            .collect()
    }

    /// True when the machine had fewer cores than streams and this stream
    /// is waiting for capacity. A lease without cores must not build
    /// executors (an accelerator alone cannot run the model — the
    /// coordinator never issues an accelerator to a core-less lease).
    pub fn is_empty(&self) -> bool {
        self.n_cores() == 0
    }

    /// Total learned strength of the owned units.
    pub fn strength_sum(&self) -> f64 {
        self.strengths.iter().sum()
    }

    /// Global core id of lease-local worker `local`. Panics if `local`
    /// addresses an accelerator entry — device workers have no core id.
    pub fn global_core(&self, local: usize) -> usize {
        match self.units[local] {
            ComputeUnit::Core(g) => g,
            ComputeUnit::Xpu(a) => panic!("local worker {local} is accelerator {a}, not a core"),
        }
    }

    /// Lease-local worker index of global core `global`, if leased here.
    pub fn local_index(&self, global: usize) -> Option<usize> {
        self.units.iter().position(|&u| u == ComputeUnit::Core(global))
    }

    /// The executor-facing sub-machine: leased cores re-indexed `0..n`
    /// with this lease's proportional share of the memory bus.
    pub fn spec(&self, machine: &CpuSpec) -> CpuSpec {
        let cores = self.cores();
        let bus = if self.bus_share_gbps > 0.0 {
            self.bus_share_gbps
        } else {
            bus_share(machine, &cores)
        };
        machine.subset(&cores, bus)
    }

    /// Simulator executor over exactly the leased cores — the cores-only
    /// fast path. A lease that owns accelerators should materialize
    /// [`Lease::xpu_executor`] instead (debug builds assert this).
    pub fn sim_executor(&self, machine: &CpuSpec, cfg: SimConfig) -> SimExecutor {
        debug_assert!(
            self.accels().is_empty(),
            "lease owns accelerators {:?}; materialize xpu_executor() or they idle",
            self.accels()
        );
        SimExecutor::new(self.spec(machine), cfg)
    }

    /// Heterogeneous executor: the leased cores plus every owned
    /// accelerator, dispatched cross-device by [`crate::sim::xpu::XpuSim`]
    /// with device-level ratio learning seeded from this lease's strengths
    /// (CPU seed = summed core strength). Device seeds are floored at 5%
    /// of the CPU seed: a device whose learned strength collapsed still
    /// gets a non-zero first split on every fresh executor, so each epoch
    /// re-auditions it per kernel class instead of inheriting a frozen
    /// write-off. With no owned accelerator this is exactly the cores-only
    /// simulator path.
    pub fn xpu_executor(
        &self,
        machine: &CpuSpec,
        accels: &[AcceleratorSpec],
        cfg: SimConfig,
    ) -> XpuExecutor {
        self.xpu_executor_mode(machine, accels, cfg, XpuDispatch::Split)
    }

    /// [`Lease::xpu_executor`] with an explicit [`XpuDispatch`]: `Split` is
    /// the intra-kernel default; `CpuOnly` / `DeviceOnly` build the two
    /// halves of an [`ExecMode::AsyncBatch`] batcher pair, where each
    /// executor runs whole kernels on one side of the lease while the other
    /// side runs its own batch concurrently.
    pub fn xpu_executor_mode(
        &self,
        machine: &CpuSpec,
        accels: &[AcceleratorSpec],
        cfg: SimConfig,
        dispatch: XpuDispatch,
    ) -> XpuExecutor {
        let owned: Vec<AcceleratorSpec> =
            self.accels().iter().map(|&a| accels[a].clone()).collect();
        let cpu_strength: f64 = self
            .units
            .iter()
            .zip(&self.strengths)
            .filter(|(u, _)| u.is_core())
            .map(|(_, s)| s)
            .sum();
        let cpu_seed = cpu_strength.max(1e-9);
        let mut seeds = vec![cpu_seed];
        for (u, s) in self.units.iter().zip(&self.strengths) {
            if !u.is_core() {
                seeds.push(s.max(cpu_seed * 0.05));
            }
        }
        let sim = XpuSim::new(self.spec(machine), cfg, owned).with_device_seeds(seeds);
        XpuExecutor::with_dispatch(sim, dispatch)
    }

    /// Real-thread executor: one worker per leased core, pinned to the
    /// lease's *global* core ids.
    pub fn host_pool(&self) -> HostPool {
        HostPool::with_cores(&self.cores())
    }

    /// Background-load entries for this lease's simulator: one per leased
    /// core whose *global* id appears in `degraded_globals`, mapped to the
    /// lease-local index and stealing `fraction` of that core's cycles for
    /// the whole run. Degraded globals not leased here are skipped — the
    /// load follows the physical core, not the lease — and every produced
    /// entry is guarded to address a core worker (never an accelerator).
    pub fn background_for(&self, degraded_globals: &[usize], fraction: f64) -> Vec<BackgroundLoad> {
        let n_cores = self.n_cores();
        degraded_globals
            .iter()
            .filter_map(|&g| self.local_index(g))
            .map(|local| {
                debug_assert!(
                    local < n_cores,
                    "degraded global mapped to non-core worker {local}"
                );
                BackgroundLoad { core: local, start: 0.0, end: 1e9, fraction }
            })
            .collect()
    }
}

/// How the coordinator partitions cores across streams. Accelerator
/// placement is the orthogonal [`XpuAffinity`] dimension.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Split every core kind evenly across streams and balance measured
    /// strength — fair multi-tenant serving (default).
    #[default]
    Balanced,
    /// Give the strongest cores to the earliest-admitted streams in
    /// contiguous blocks — latency-tiered serving.
    Packed,
}

/// Owns the machine's compute units and leases disjoint subsets to streams.
pub struct Coordinator {
    spec: CpuSpec,
    policy: AllocPolicy,
    affinity: XpuAffinity,
    exec_mode: ExecMode,
    accels: Vec<AcceleratorSpec>,
    /// EWMA gain α for strength updates (weight of the old value, like
    /// `PerfConfig::alpha`; paper uses 0.3).
    pub alpha: f64,
    /// per-unit measured strength: cores (global order) then accelerators,
    /// seeded from the spec's ideal VNNI compute ratios (slowest core = 1.0)
    strength: Vec<f64>,
    /// `Pinned` affinity: accelerator → owning stream while it lives
    pinned: Vec<Option<StreamId>>,
    /// admitted streams in admission order
    streams: Vec<StreamId>,
    leases: BTreeMap<StreamId, Lease>,
    epoch: u64,
    observations: u64,
}

impl Coordinator {
    /// Cores-only coordinator (no accelerators leased).
    pub fn new(spec: CpuSpec, policy: AllocPolicy) -> Coordinator {
        Coordinator::with_accelerators(spec, Vec::new(), policy, XpuAffinity::None)
    }

    /// Heterogeneous coordinator: cores plus accelerators, with the given
    /// placement affinity. Accelerator strengths are seeded from their
    /// spec'd int8 throughput on the same scale as the core ratios
    /// (slowest core = 1.0).
    pub fn with_accelerators(
        spec: CpuSpec,
        accels: Vec<AcceleratorSpec>,
        policy: AllocPolicy,
        affinity: XpuAffinity,
    ) -> Coordinator {
        spec.validate().expect("invalid CpuSpec");
        let mut strength = spec.ideal_ratios(Isa::AvxVnni);
        let slowest = spec
            .cores
            .iter()
            .map(|c| c.compute_rate(Isa::AvxVnni))
            .fold(f64::INFINITY, f64::min)
            .max(1e-30);
        for a in &accels {
            strength.push((a.ops_per_sec / slowest).max(1e-9));
        }
        let pinned = vec![None; accels.len()];
        Coordinator {
            spec,
            policy,
            affinity,
            exec_mode: ExecMode::IntraKernel,
            accels,
            alpha: 0.3,
            strength,
            pinned,
            streams: Vec::new(),
            leases: BTreeMap::new(),
            epoch: 0,
            observations: 0,
        }
    }

    pub fn machine(&self) -> &CpuSpec {
        &self.spec
    }

    pub fn accelerators(&self) -> &[AcceleratorSpec] {
        &self.accels
    }

    /// Execution mode stamped on every issued hetero lease.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// Switch the execution mode for all future leases. Live leases are
    /// re-issued (epoch bump) so holders pick up the new mode on their
    /// next refresh.
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        if self.exec_mode != mode {
            self.exec_mode = mode;
            if !self.streams.is_empty() {
                self.assign();
            }
        }
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Bumped on every admit/finish/rebalance; stale leases carry an older
    /// value and must be refreshed via [`Coordinator::lease`].
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Current measured per-unit strengths: cores in global order, then
    /// one entry per accelerator.
    pub fn strengths(&self) -> &[f64] {
        &self.strength
    }

    /// Lifetime count of accepted observations — the serving layer's
    /// drift monitor uses this as its cooldown clock.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    fn strength_index(&self, unit: ComputeUnit) -> usize {
        match unit {
            ComputeUnit::Core(g) => g,
            ComputeUnit::Xpu(a) => self.spec.n_cores() + a,
        }
    }

    /// Admit a new stream and return its lease. Re-partitions every
    /// existing lease (epoch bump). Panics on a duplicate stream id.
    pub fn admit(&mut self, stream: StreamId) -> Lease {
        assert!(!self.streams.contains(&stream), "stream {stream} already admitted");
        self.streams.push(stream);
        self.assign();
        self.leases[&stream].clone()
    }

    /// Release a stream's units back to the pool (no-op for unknown ids);
    /// remaining leases grow to cover the machine again. Accelerators
    /// pinned to the departing stream become assignable again.
    pub fn finish(&mut self, stream: StreamId) {
        let before = self.streams.len();
        self.streams.retain(|&s| s != stream);
        if self.streams.len() != before {
            self.assign();
        }
    }

    /// The current lease of `stream`, if admitted.
    pub fn lease(&self, stream: StreamId) -> Option<&Lease> {
        self.leases.get(&stream)
    }

    /// All current leases (stream-id order).
    pub fn leases(&self) -> impl Iterator<Item = &Lease> {
        self.leases.values()
    }

    /// Fold one kernel's measured per-unit result back into the strength
    /// table. `lease` must be the exact lease the measuring executor was
    /// built from: the result's local→unit mapping is only valid for it,
    /// so results measured under a stale lease (the coordinator
    /// re-partitioned since — different epoch or units) or an unknown
    /// stream are silently dropped rather than mis-attributed to units
    /// the stream no longer owns. Entries past the lease's core count map
    /// onto its accelerators (the [`XpuExecutor`] result layout), so
    /// device timings feed the same table as core timings. Mirrors the
    /// paper's eq. 2: participating units' rates are rescaled so their
    /// strength mass is preserved, then EWMA-filtered with `alpha`. A
    /// single participant carries no relative information and is skipped.
    ///
    /// Returns `true` when the observation was folded into the strength
    /// table, `false` when it was dropped (stale epoch, foreign stream or
    /// degenerate measurement) — the serving layer uses this to count
    /// epoch-stale measurements racing a rebuild.
    pub fn observe(&mut self, lease: &Lease, res: &RunResult) -> bool {
        match self.leases.get(&lease.stream) {
            Some(current) if current == lease => {}
            _ => return false, // stale or foreign lease
        }
        let mut mass = 0.0f64;
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for (local, t) in res.per_core_secs.iter().enumerate() {
            let Some(t) = t else { continue };
            let units = res.units_done.get(local).copied().unwrap_or(0);
            if *t > 0.0 && units > 0 && local < lease.units.len() {
                let idx = self.strength_index(lease.units[local]);
                mass += self.strength[idx];
                rates.push((idx, units as f64 / t));
            }
        }
        if rates.len() < 2 {
            return false;
        }
        let rate_sum: f64 = rates.iter().map(|(_, r)| r).sum();
        if !(rate_sum.is_finite() && rate_sum > 0.0 && mass > 0.0) {
            return false;
        }
        let scale = mass / rate_sum;
        for (idx, r) in rates {
            self.strength[idx] = self.alpha * self.strength[idx] + (1.0 - self.alpha) * r * scale;
        }
        self.observations += 1;
        true
    }

    /// The fraction of a hetero lease's admissions that should be routed
    /// to its accelerator-path sub-batcher under
    /// [`ExecMode::AsyncBatch`]: the *live* accelerator share of the
    /// lease's total learned strength, clamped to `[0.05, 0.95]` so
    /// neither side is ever starved of the traffic it needs to keep its
    /// timings observable. Cores-only leases route everything to the CPU
    /// path (0.0).
    pub fn split_ratio(&self, lease: &Lease) -> f64 {
        let mut cpu = 0.0f64;
        let mut dev = 0.0f64;
        for &u in &lease.units {
            let s = self.strength[self.strength_index(u)];
            if u.is_core() {
                cpu += s;
            } else {
                dev += s;
            }
        }
        if dev <= 0.0 {
            return 0.0;
        }
        (dev / (cpu + dev).max(1e-30)).clamp(0.05, 0.95)
    }

    /// Fold one [`ExecMode::AsyncBatch`] round — the CPU sub-batcher's and
    /// the device sub-batcher's most recent `(wall_secs, tokens)` — into
    /// the same strength table that [`Coordinator::observe`] feeds. The
    /// two batchers never co-measure inside one kernel, so their raw round
    /// walls carry no relative signal once both run saturated; instead the
    /// per-path *token rates* `R = tokens / wall` are distributed over the
    /// path's units in proportion to their current strengths and folded
    /// through the usual mass-preserving EWMA. Algebraically the learned
    /// device share then converges geometrically (its residual shrinking
    /// by the old-value weight `α` each round) to
    /// `R_dev / (R_cpu + R_dev)` — the true device throughput
    /// share — independent of batch occupancy, which is exactly what
    /// [`Coordinator::split_ratio`] reads back. Stale or foreign leases
    /// are dropped like in `observe`; returns whether the round was
    /// folded.
    pub fn observe_round(
        &mut self,
        lease: &Lease,
        cpu: (f64, usize),
        dev: (f64, usize),
    ) -> bool {
        match self.leases.get(&lease.stream) {
            Some(current) if current == lease => {}
            _ => return false, // stale or foreign lease
        }
        let (cpu_wall, cpu_tokens) = cpu;
        let (dev_wall, dev_tokens) = dev;
        if !(cpu_wall.is_finite() && cpu_wall > 0.0 && dev_wall.is_finite() && dev_wall > 0.0) {
            return false;
        }
        if cpu_tokens == 0 || dev_tokens == 0 {
            return false;
        }
        let r_cpu = cpu_tokens as f64 / cpu_wall;
        let r_dev = dev_tokens as f64 / dev_wall;
        let cores: Vec<usize> = lease
            .units
            .iter()
            .filter(|u| u.is_core())
            .map(|&u| self.strength_index(u))
            .collect();
        let devs: Vec<usize> = lease
            .units
            .iter()
            .filter(|u| !u.is_core())
            .map(|&u| self.strength_index(u))
            .collect();
        if cores.is_empty() || devs.is_empty() {
            return false;
        }
        let cpu_mass: f64 = cores.iter().map(|&i| self.strength[i]).sum();
        let dev_mass: f64 = devs.iter().map(|&i| self.strength[i]).sum();
        if !(cpu_mass > 0.0 && dev_mass > 0.0) {
            return false;
        }
        // per-unit rates: each path's token rate split strength-
        // proportionally over its units, then the standard fold
        let mut mass = 0.0f64;
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for &i in &cores {
            mass += self.strength[i];
            rates.push((i, r_cpu * self.strength[i] / cpu_mass));
        }
        for &i in &devs {
            mass += self.strength[i];
            rates.push((i, r_dev * self.strength[i] / dev_mass));
        }
        let rate_sum: f64 = rates.iter().map(|(_, r)| r).sum();
        if !(rate_sum.is_finite() && rate_sum > 0.0) {
            return false;
        }
        let scale = mass / rate_sum;
        for (idx, r) in rates {
            self.strength[idx] = self.alpha * self.strength[idx] + (1.0 - self.alpha) * r * scale;
        }
        self.observations += 1;
        true
    }

    /// Cross-lease strength drift, condensed to one ratio: for every core
    /// kind held by two or more leases, compare the leases' *mean* learned
    /// strength of that kind and take the worst max/min ratio over kinds.
    /// A freshly balanced (or healthy converged) partition sits near 1.0;
    /// a background load degrading part of one lease pushes the ratio up
    /// because mass-preserving per-lease updates re-scale that lease's
    /// kinds against everyone else's. Accelerators are machine singletons
    /// (never co-held), so they cannot contribute a cross-lease ratio.
    ///
    /// The signal needs co-held kinds: under [`AllocPolicy::Packed`] a
    /// partition can tier each kind entirely into one lease (8P / 8E),
    /// leaving no cross-lease comparison — the skew then stays 1.0 and
    /// the drift monitor is blind. Use `Balanced` (the default) when live
    /// drift rebalancing matters.
    pub fn strength_skew(&self) -> f64 {
        let mut skew = 1.0f64;
        for kind in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
            let mut means: Vec<f64> = Vec::new();
            for lease in self.leases.values() {
                let vals: Vec<f64> = lease
                    .units
                    .iter()
                    .filter_map(|u| match u {
                        ComputeUnit::Core(g) if self.spec.cores[*g].kind == kind => {
                            Some(self.strength[*g])
                        }
                        _ => None,
                    })
                    .collect();
                if !vals.is_empty() {
                    means.push(vals.iter().sum::<f64>() / vals.len() as f64);
                }
            }
            if means.len() >= 2 {
                let mx = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mn = means.iter().cloned().fold(f64::INFINITY, f64::min);
                if mn > 0.0 {
                    skew = skew.max(mx / mn);
                }
            }
        }
        skew
    }

    /// Re-partition units across the admitted streams using the current
    /// strengths (epoch bump). Call after enough [`Coordinator::observe`]s
    /// have shifted the table — e.g. when [`Coordinator::strength_skew`]
    /// crosses the serving layer's drift threshold.
    pub fn rebalance(&mut self) {
        self.assign();
    }

    fn assign(&mut self) {
        self.epoch += 1;
        self.leases.clear();
        // release pins held by departed streams
        for p in self.pinned.iter_mut() {
            if let Some(owner) = p {
                if !self.streams.contains(owner) {
                    *p = None;
                }
            }
        }
        let k = self.streams.len();
        if k == 0 {
            return;
        }
        let n_cores = self.spec.n_cores();
        let mut cores_per_stream: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut accels_per_stream: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut strength_sum = vec![0.0f64; k];

        // ---- accelerators first: their strength steers the core picks ----
        if self.affinity != XpuAffinity::None {
            // strongest device first; ties toward the lower index
            let mut order: Vec<usize> = (0..self.accels.len()).collect();
            order.sort_by(|&a, &b| {
                let (sa, sb) = (self.strength[n_cores + a], self.strength[n_cores + b]);
                sb.partial_cmp(&sa).unwrap().then(a.cmp(&b))
            });
            for a in order {
                let pinned_slot = match self.affinity {
                    XpuAffinity::Pinned => self.pinned[a]
                        .and_then(|owner| self.streams.iter().position(|&s| s == owner)),
                    _ => None,
                };
                let s = pinned_slot.unwrap_or_else(|| {
                    // weakest strength sum so far; ties toward admission order
                    (0..k)
                        .min_by(|&x, &y| {
                            strength_sum[x].partial_cmp(&strength_sum[y]).unwrap().then(x.cmp(&y))
                        })
                        .unwrap()
                });
                if self.affinity == XpuAffinity::Pinned {
                    self.pinned[a] = Some(self.streams[s]);
                }
                accels_per_stream[s].push(a);
                strength_sum[s] += self.strength[n_cores + a];
            }
        }

        match self.policy {
            AllocPolicy::Packed => {
                let mut order: Vec<usize> = (0..n_cores).collect();
                order.sort_by(|&a, &b| {
                    self.strength[b].partial_cmp(&self.strength[a]).unwrap().then(a.cmp(&b))
                });
                let sizes = largest_remainder_split(order.len(), &vec![1.0; k]);
                let mut cursor = 0;
                for (s, &sz) in sizes.iter().enumerate() {
                    for &core in &order[cursor..cursor + sz] {
                        cores_per_stream[s].push(core);
                        strength_sum[s] += self.strength[core];
                    }
                    cursor += sz;
                }
            }
            AllocPolicy::Balanced => {
                for kind in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
                    let mut pool: Vec<usize> = self
                        .spec
                        .cores
                        .iter()
                        .filter(|c| c.kind == kind)
                        .map(|c| c.id)
                        .collect();
                    if pool.is_empty() {
                        continue;
                    }
                    // strongest first; ties toward the lower core id
                    pool.sort_by(|&a, &b| {
                        self.strength[b].partial_cmp(&self.strength[a]).unwrap().then(a.cmp(&b))
                    });
                    // every stream gets its fair share of this kind (±1)
                    let mut quota = largest_remainder_split(pool.len(), &vec![1.0; k]);
                    for &core in &pool {
                        // among streams with quota left, the weakest so far;
                        // ties toward admission order
                        let mut best: Option<usize> = None;
                        for s in 0..k {
                            if quota[s] == 0 {
                                continue;
                            }
                            best = match best {
                                None => Some(s),
                                Some(b) if strength_sum[s] < strength_sum[b] - 1e-12 => Some(s),
                                other => other,
                            };
                        }
                        let s = best.expect("kind quotas sum to the kind's core count");
                        quota[s] -= 1;
                        cores_per_stream[s].push(core);
                        strength_sum[s] += self.strength[core];
                    }
                }
            }
        }

        // repair: no stream may be core-less while another holds ≥ 2 cores
        // (possible when a kind has fewer cores than there are streams)
        loop {
            let Some(empty) = (0..k).find(|&s| cores_per_stream[s].is_empty()) else { break };
            let rich = (0..k)
                .filter(|&s| cores_per_stream[s].len() >= 2)
                .max_by(|&a, &b| {
                    let by_strength =
                        strength_sum[a].partial_cmp(&strength_sum[b]).unwrap().then(b.cmp(&a));
                    cores_per_stream[a].len().cmp(&cores_per_stream[b].len()).then(by_strength)
                });
            let Some(rich) = rich else { break };
            let pos = (0..cores_per_stream[rich].len())
                .min_by(|&i, &j| {
                    let (a, b) = (cores_per_stream[rich][i], cores_per_stream[rich][j]);
                    self.strength[a].partial_cmp(&self.strength[b]).unwrap().then(a.cmp(&b))
                })
                .unwrap();
            let core = cores_per_stream[rich].remove(pos);
            strength_sum[rich] -= self.strength[core];
            strength_sum[empty] += self.strength[core];
            cores_per_stream[empty].push(core);
        }

        // an accelerator must not strand on a core-less lease (it cannot
        // run the model alone): move it to the weakest lease that has cores
        for s in 0..k {
            if !cores_per_stream[s].is_empty() || accels_per_stream[s].is_empty() {
                continue;
            }
            let accels = std::mem::take(&mut accels_per_stream[s]);
            for a in accels {
                strength_sum[s] -= self.strength[n_cores + a];
                let target = (0..k)
                    .filter(|&t| !cores_per_stream[t].is_empty())
                    .min_by(|&x, &y| {
                        strength_sum[x].partial_cmp(&strength_sum[y]).unwrap().then(x.cmp(&y))
                    });
                let Some(t) = target else { break };
                if self.affinity == XpuAffinity::Pinned {
                    self.pinned[a] = Some(self.streams[t]);
                }
                accels_per_stream[t].push(a);
                strength_sum[t] += self.strength[n_cores + a];
            }
        }

        // accelerators kept off the lease pool by policy are guaranteed
        // idle: they must not contend for bus in anyone's share (a
        // single-stream cores-only lease still gets the whole bus)
        let contending: &[AcceleratorSpec] = match self.affinity {
            XpuAffinity::None => &[],
            _ => &self.accels,
        };
        for (s, &stream) in self.streams.iter().enumerate() {
            let mut units: Vec<ComputeUnit> = std::mem::take(&mut cores_per_stream[s])
                .into_iter()
                .map(ComputeUnit::Core)
                .collect();
            let mut accels = std::mem::take(&mut accels_per_stream[s]);
            accels.sort_unstable();
            units.extend(accels.into_iter().map(ComputeUnit::Xpu));
            units.sort();
            let strengths: Vec<f64> =
                units.iter().map(|&u| self.strength[self.strength_index(u)]).collect();
            let bus = if units.iter().any(ComputeUnit::is_core) {
                bus_share_units(&self.spec, contending, &units)
            } else {
                0.0
            };
            self.leases.insert(
                stream,
                Lease {
                    stream,
                    units,
                    strengths,
                    bus_share_gbps: bus,
                    epoch: self.epoch,
                    mode: self.exec_mode,
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;

    fn kinds(spec: &CpuSpec, lease: &Lease, kind: CoreKind) -> usize {
        lease.cores().iter().filter(|&&c| spec.cores[c].kind == kind).count()
    }

    fn assert_disjoint_covering(c: &Coordinator) {
        let mut seen = vec![false; c.machine().n_cores()];
        let mut accel_owner = vec![0usize; c.accelerators().len()];
        for lease in c.leases() {
            for &core in &lease.cores() {
                assert!(!seen[core], "core {core} leased twice");
                seen[core] = true;
            }
            for &a in &lease.accels() {
                accel_owner[a] += 1;
            }
        }
        if c.n_streams() > 0 {
            assert!(seen.iter().all(|&s| s), "not covering: {seen:?}");
        }
        assert!(accel_owner.iter().all(|&n| n <= 1), "accelerator leased twice");
    }

    #[test]
    fn single_stream_gets_the_whole_machine() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let lease = c.admit(7);
        assert_eq!(lease.cores(), (0..16).collect::<Vec<_>>());
        // full machine → full bus: lease spec behaves like the raw machine
        let sub = lease.spec(&spec);
        assert_eq!(sub.n_cores(), 16);
        assert!((sub.bus_bw_gbps - spec.bus_bw_gbps).abs() < 1e-9);
    }

    #[test]
    fn two_streams_split_both_kinds_evenly() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let l1 = c.lease(1).cloned();
        assert!(l1.is_none());
        let l1 = c.admit(1);
        // l0 from admit(0) is stale (epoch moved); refresh
        assert!(l0.epoch < c.epoch());
        let l0 = c.lease(0).unwrap().clone();
        assert_disjoint_covering(&c);
        for l in [&l0, &l1] {
            assert_eq!(l.n_cores(), 8);
            assert_eq!(kinds(&spec, l, CoreKind::Performance), 4);
            assert_eq!(kinds(&spec, l, CoreKind::Efficiency), 4);
            // equal halves of an equal-weight machine → half the bus
            let sub = l.spec(&spec);
            assert!((sub.bus_bw_gbps - spec.bus_bw_gbps / 2.0).abs() < 1e-9, "{}", sub.bus_bw_gbps);
        }
    }

    #[test]
    fn three_streams_on_125h_are_topology_aware() {
        let spec = presets::ultra_125h();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert!(!lease.is_empty());
            // 4 P / 3 streams → 1–2 each; 8 E → 2–3 each; 2 LPE → 0–1
            let p = kinds(&spec, lease, CoreKind::Performance);
            let e = kinds(&spec, lease, CoreKind::Efficiency);
            assert!((1..=2).contains(&p), "P={p}");
            assert!((2..=3).contains(&e), "E={e}");
        }
    }

    #[test]
    fn finish_returns_cores_to_the_survivors() {
        let mut c = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        let epoch = c.epoch();
        c.finish(0);
        assert!(c.epoch() > epoch);
        assert!(c.lease(0).is_none());
        assert_eq!(c.lease(1).unwrap().n_cores(), 16);
        // unknown stream: quiet no-op, no epoch churn
        let epoch = c.epoch();
        c.finish(99);
        assert_eq!(c.epoch(), epoch);
    }

    #[test]
    fn packed_policy_tiers_the_fast_cores() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Packed);
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        let l0 = c.lease(0).unwrap();
        let l1 = c.lease(1).unwrap();
        // stream 0 holds all 8 P-cores, stream 1 all 8 E-cores
        assert_eq!(kinds(&spec, l0, CoreKind::Performance), 8);
        assert_eq!(kinds(&spec, l1, CoreKind::Efficiency), 8);
    }

    #[test]
    fn more_streams_than_a_kind_still_covers_without_empties() {
        // 2P + 2E sub-machine, 3 streams: per-kind quotas alone would leave
        // stream 2 empty; the repair pass must fill it
        let machine = presets::core_12900k().subset(&[0, 1, 8, 9], 17.0);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert!(!lease.is_empty(), "empty lease {:?}", lease);
        }
    }

    #[test]
    fn more_streams_than_cores_leaves_overflow_waiting() {
        let machine = presets::core_12900k().subset(&[0, 8], 8.0);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        let empties = c.leases().filter(|l| l.is_empty()).count();
        assert_eq!(empties, 1);
        let total: usize = c.leases().map(|l| l.n_cores()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn observe_learns_a_slow_core_and_rebalance_spreads_it() {
        // homogeneous 4-core machine, 2 streams → 2 cores each
        let machine = presets::homogeneous(4);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        let l0 = c.lease(0).unwrap().clone();
        // stream 0's first core runs at half rate: equal units, double time
        for _ in 0..20 {
            let res = RunResult {
                per_core_secs: vec![Some(2.0), Some(1.0)],
                wall_secs: 2.0,
                units_done: vec![100, 100],
            };
            c.observe(&l0, &res);
        }
        assert_eq!(c.observations(), 20);
        let slow = l0.global_core(0);
        let fast = l0.global_core(1);
        assert!(
            c.strengths()[slow] < 0.6 * c.strengths()[fast],
            "strengths {:?}",
            c.strengths()
        );
        c.rebalance();
        assert_disjoint_covering(&c);
        // the slow core's lease also holds the strongest remaining core —
        // strength sums are balanced, not left lopsided
        let sums: Vec<f64> = c
            .leases()
            .map(|l| l.cores().iter().map(|&g| c.strengths()[g]).sum::<f64>())
            .collect();
        let (a, b) = (sums[0], sums[1]);
        assert!((a - b).abs() / a.max(b) < 0.35, "sums {sums:?}");
    }

    #[test]
    fn observe_ignores_degenerate_and_stale_results() {
        let mut c = Coordinator::new(presets::homogeneous(4), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let before = c.strengths().to_vec();
        // single participant: no relative information
        let accepted = c.observe(
            &l0,
            &RunResult {
                per_core_secs: vec![Some(1.0), None, None, None],
                wall_secs: 1.0,
                units_done: vec![10, 0, 0, 0],
            },
        );
        assert!(!accepted);
        // lease for a stream the coordinator never admitted: ignored
        let foreign = Lease::cores_only(9, vec![0, 1], 0);
        let skewed = RunResult {
            per_core_secs: vec![Some(1.0), Some(4.0)],
            wall_secs: 4.0,
            units_done: vec![100, 100],
        };
        assert!(!c.observe(&foreign, &skewed));
        assert_eq!(c.strengths(), &before[..]);
        assert_eq!(c.observations(), 0);
        // stale lease: admitting stream 1 re-partitions, so a result
        // measured under the old 4-core lease must not be mis-mapped onto
        // the new 2-core lease's globals
        c.admit(1);
        let before = c.strengths().to_vec();
        assert!(!c.observe(&l0, &skewed));
        assert_eq!(c.strengths(), &before[..]);
        // the refreshed lease is accepted
        let fresh = c.lease(0).unwrap().clone();
        assert!(c.observe(&fresh, &skewed));
        assert_ne!(c.strengths(), &before[..]);
        assert_eq!(c.observations(), 1);
    }

    #[test]
    fn background_for_maps_globals_to_lease_locals() {
        let lease = Lease::cores_only(0, vec![1, 4, 9, 12], 1);
        // global 4 → local 1, global 12 → local 3; global 5 leased elsewhere
        let bg = lease.background_for(&[4, 12, 5], 0.5);
        let cores: Vec<usize> = bg.iter().map(|b| b.core).collect();
        assert_eq!(cores, vec![1, 3]);
        assert!(bg.iter().all(|b| b.fraction == 0.5 && b.start == 0.0 && b.end == 1e9));
        assert!(lease.background_for(&[], 0.5).is_empty());
    }

    #[test]
    fn background_for_skips_globals_on_a_hetero_lease() {
        // a lease owning an accelerator maps background loads exactly like
        // a cores-only lease: only its own cores, always to core workers
        let mut c = Coordinator::with_accelerators(
            presets::core_12900k(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        c.admit(0);
        c.admit(1);
        let with_npu = c.leases().find(|l| !l.accels().is_empty()).unwrap().clone();
        let other = c.leases().find(|l| l.accels().is_empty()).unwrap().clone();
        let foreign: Vec<usize> = other.cores();
        // degraded cores leased to the *other* stream: all skipped
        assert!(with_npu.background_for(&foreign, 0.5).is_empty());
        // its own first two cores map to locals 0 and 1
        let own: Vec<usize> = with_npu.cores().into_iter().take(2).collect();
        let bg = with_npu.background_for(&own, 0.25);
        assert_eq!(bg.iter().map(|b| b.core).collect::<Vec<_>>(), vec![0, 1]);
        assert!(bg.iter().all(|b| b.core < with_npu.n_cores()));
    }

    #[test]
    fn lease_local_global_maps_roundtrip() {
        let mut c = Coordinator::new(presets::ultra_125h(), AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        for lease in c.leases() {
            for local in 0..lease.n_cores() {
                let g = lease.global_core(local);
                assert_eq!(lease.local_index(g), Some(local));
            }
            assert_eq!(lease.local_index(999), None);
        }
    }

    // ---- heterogeneous (accelerator) leasing ----

    #[test]
    fn floating_accelerator_lands_on_one_lease_and_steers_cores() {
        let spec = presets::ultra_125h();
        let mut c = Coordinator::with_accelerators(
            spec.clone(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        let owners: Vec<StreamId> =
            c.leases().filter(|l| !l.accels().is_empty()).map(|l| l.stream).collect();
        assert_eq!(owners.len(), 1, "exactly one lease owns the NPU");
        // per-kind core quotas still hold on both leases
        for l in c.leases() {
            assert_eq!(kinds(&spec, l, CoreKind::Performance), 2);
            assert_eq!(kinds(&spec, l, CoreKind::Efficiency), 4);
        }
        // the lease snapshot carries the device strength and a bus share
        let with_npu = c.leases().find(|l| !l.accels().is_empty()).unwrap();
        assert_eq!(with_npu.units.len(), with_npu.strengths.len());
        assert!(with_npu.strength_sum() > 10.0, "NPU strength missing");
        assert!(with_npu.bus_share_gbps > 0.0);
    }

    #[test]
    fn two_accelerators_float_to_different_leases() {
        let mut c = Coordinator::with_accelerators(
            presets::ultra_125h(),
            vec![AcceleratorSpec::npu(), AcceleratorSpec::igpu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert_eq!(lease.accels().len(), 1, "{:?}", lease.units);
        }
    }

    #[test]
    fn pinned_accelerator_stays_until_its_stream_departs() {
        let mut c = Coordinator::with_accelerators(
            presets::core_12900k(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Pinned,
        );
        c.admit(0);
        let owner = c.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        c.admit(1);
        c.admit(2);
        c.rebalance();
        let still = c.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        assert_eq!(owner, still, "pinned accelerator moved across rebalances");
        c.finish(owner);
        let next = c.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        assert_ne!(next, owner, "released pin was not re-assigned");
    }

    #[test]
    fn affinity_none_leases_no_accelerators_and_reserves_no_bus() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::with_accelerators(
            spec.clone(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::None,
        );
        c.admit(0);
        assert!(c.leases().all(|l| l.accels().is_empty()));
        // the policy-idled device must not steal bus share: a single
        // cores-only stream still behaves exactly like the raw machine
        let lease = c.lease(0).unwrap();
        assert!(
            (lease.bus_share_gbps - spec.bus_bw_gbps).abs() < 1e-9,
            "idle NPU stole bus: {} vs {}",
            lease.bus_share_gbps,
            spec.bus_bw_gbps
        );
    }

    #[test]
    fn accelerator_never_strands_on_a_coreless_lease() {
        // 2 cores, 3 streams: one stream waits core-less — the NPU must
        // not be wasted on it
        let machine = presets::core_12900k().subset(&[0, 8], 8.0);
        let mut c = Coordinator::with_accelerators(
            machine,
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            if !lease.accels().is_empty() {
                assert!(!lease.is_empty(), "accelerator stranded on {:?}", lease);
            }
        }
    }

    #[test]
    fn observe_folds_device_timings_into_the_strength_table() {
        let spec = presets::homogeneous(4);
        let mut c = Coordinator::with_accelerators(
            spec.clone(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        let lease = c.admit(0); // whole machine + NPU
        assert_eq!(lease.accels(), vec![0]);
        let npu_idx = spec.n_cores();
        let seed = c.strengths()[npu_idx];
        // equal units everywhere, device twice as fast as any core: its
        // strength must grow relative to the cores'
        let res = RunResult {
            per_core_secs: vec![Some(1.0), Some(1.0), Some(1.0), Some(1.0), Some(0.5)],
            wall_secs: 1.0,
            units_done: vec![100, 100, 100, 100, 100],
        };
        for _ in 0..10 {
            let cur = c.lease(0).unwrap().clone();
            assert!(c.observe(&cur, &res));
        }
        let s = c.strengths();
        assert!(
            (s[npu_idx] / s[0] - 2.0).abs() < 0.05,
            "device:core ratio {} (seed {seed})",
            s[npu_idx] / s[0]
        );
    }

    #[test]
    fn strength_skew_flags_asymmetric_degradation_only() {
        let machine = presets::core_12900k();
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        assert!((c.strength_skew() - 1.0).abs() < 1e-9, "healthy skew {}", c.strength_skew());
        // stream 0's P-cores run at half rate; its E-cores at full rate —
        // mass-preserving updates shift strength inside lease 0 only
        let l0 = c.lease(0).unwrap().clone();
        let times: Vec<Option<f64>> = (0..l0.n_cores())
            .map(|i| {
                let g = l0.global_core(i);
                let kind = c.machine().cores[g].kind;
                let rate = if kind == CoreKind::Performance { 2.649 / 2.0 } else { 1.0 };
                Some(100.0 / rate)
            })
            .collect();
        let res = RunResult {
            wall_secs: 1.0,
            units_done: vec![100; l0.n_cores()],
            per_core_secs: times,
        };
        for _ in 0..12 {
            assert!(c.observe(&l0, &res));
        }
        let skew = c.strength_skew();
        assert!(skew > 1.25, "drift not visible: skew {skew}");
        // rebalance mixes the degraded cores evenly → skew collapses
        c.rebalance();
        let post = c.strength_skew();
        assert!(post < 1.05, "rebalance did not equalize: skew {post}");
    }
}
