//! Multi-stream **coordinator** — the serving-level half of the paper's
//! coordination story (its §2 runtime balances one kernel across all cores;
//! this module decides *which compute units each concurrent stream gets*
//! before that per-kernel proportional split runs).
//!
//! The [`Coordinator`] owns the machine's **compute units** — its CPU cores
//! ([`CpuSpec`]) *and* its accelerators ([`AcceleratorSpec`]: NPU / iGPU
//! class devices on the same bus) — and hands each admitted stream a
//! [`Lease`]: a disjoint subset of units ([`ComputeUnit`]) plus a
//! proportional share of the shared memory bus. A lease can therefore be
//! heterogeneous — "2 P-cores + the NPU" — and materializes as an executor:
//! [`Lease::sim_executor`] for a cores-only lease on the deterministic
//! hybrid-CPU simulator, [`Lease::xpu_executor`] for a lease that owns
//! accelerators (cross-device dispatch through [`crate::sim::xpu`]), or
//! [`Lease::host_pool`] for real core-pinned threads. One
//! `Engine`/`ParallelRuntime` per stream runs the paper's dynamic loop
//! *inside* its lease while the coordinator rebalances *between* leases.
//!
//! Rebalancing reuses the paper's own mechanism one level up: every
//! [`Coordinator::observe`] folds a kernel's measured per-unit rates —
//! cores and accelerator devices alike — into a **class-keyed** per-unit
//! strength table (one row per [`KernelClass`], mirroring the device-ratio
//! tables of [`crate::sim::xpu::XpuSim`]) with the same mass-preserving
//! EWMA as `perf::PerfTable` (eq. 2). Keeping GEMM and GEMV rows apart is
//! what phase-disaggregated serving steers by: an E-core can be 0.4× a
//! P-core on compute-bound prefill GEMMs yet 0.9× on bandwidth-bound
//! decode GEMVs, and one blended number would hide exactly that
//! difference. [`Coordinator::rebalance`] re-partitions units so each
//! stream's total blended strength is as equal as the topology allows,
//! while [`Coordinator::phase_leases`] splits one stream's lease into a
//! GEMM-steered prefill side and a GEMV-steered decode side
//! ([`ExecMode::Disaggregated`]). A background process
//! stealing half of one lease's P-cores is therefore detected from timing
//! alone and answered by spreading the degraded cores across streams (see
//! `rust/tests/coordinator_integration.rs`). [`Coordinator::strength_skew`]
//! condenses that drift into one observable — the serving layer's
//! `DriftMonitor` triggers a live rebalance when it crosses a threshold.
//!
//! Accelerator placement is a policy dimension of its own
//! ([`XpuAffinity`]): devices can be excluded from leasing (`None`), follow
//! the strength balance on every epoch (`Floating`, the default), or stick
//! with the stream that first received them (`Pinned`).
//!
//! Allocation invariants (property-tested in `rust/tests/prop_invariants.rs`):
//! * leases are pairwise **disjoint**;
//! * their union **covers** every core of the machine (work-conserving);
//! * each accelerator is owned by **at most one** lease, and never by a
//!   lease that holds no cores (an accelerator cannot run the model alone);
//! * under [`AllocPolicy::Balanced`] with uniform strengths, each core
//!   *kind* (P / E / LPE) is split across streams to within one core
//!   (**topology-aware** — every stream gets its fair share of fast cores);
//! * no lease is empty while another holds two or more cores.
//!
//! Strength values are mass-preserving *within* a lease per observation
//! (only co-measured units are comparable, exactly like the paper's ratio
//! table); cross-lease drift washes out over successive rebalances as unit
//! membership mixes.

use std::collections::BTreeMap;

use crate::cpu::{CoreKind, CpuSpec, Isa};
use crate::exec::RunResult;
use crate::kernels::KernelClass;
use crate::pool::HostPool;
use crate::sched::largest_remainder_split;
use crate::sim::bw::{waterfill, Contender};
use crate::sim::xpu::{AcceleratorSpec, XpuDispatch, XpuExecutor, XpuSim};
use crate::sim::{BackgroundLoad, SimConfig, SimExecutor};

/// Caller-chosen identity of one serving stream.
pub type StreamId = u64;

/// One leasable compute resource of the machine.
///
/// The derived ordering — all cores (ascending id) before all accelerators
/// (ascending index) — is the canonical unit order inside a [`Lease`]:
/// lease-local worker `i` of an executor maps to `lease.units[i]`, for
/// cores *and* for the appended accelerator entries of an
/// [`XpuExecutor`]'s result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ComputeUnit {
    /// global core id (index into the machine [`CpuSpec`])
    Core(usize),
    /// accelerator index (into the coordinator's [`AcceleratorSpec`] list)
    Xpu(usize),
}

impl ComputeUnit {
    pub fn is_core(&self) -> bool {
        matches!(self, ComputeUnit::Core(_))
    }
}

/// How accelerators participate in leasing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum XpuAffinity {
    /// accelerators are never leased — cores-only serving
    None,
    /// an accelerator stays with the stream that first received it for as
    /// long as that stream lives (stable placement: no device-state
    /// migration across rebalances)
    Pinned,
    /// accelerators are re-placed on every epoch onto the stream with the
    /// least total strength — they follow the balance like cores do
    #[default]
    Floating,
}

/// How a heterogeneous lease (cores + accelerator) turns its units into
/// token throughput.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// The paper's §2 split: every kernel's range is partitioned across
    /// cores *and* devices so all units finish in lockstep. Best when a
    /// single kernel is large enough to amortize the device launch.
    #[default]
    IntraKernel,
    /// APEX-style parallel-batch execution: the accelerator runs one
    /// sub-batcher's whole token rounds while the cores run another's,
    /// concurrently. Admissions are routed by [`Coordinator::split_ratio`]
    /// and the ratio is re-learned online via
    /// [`Coordinator::observe_round`]. Wins when per-kernel device time is
    /// dominated by launch overhead (small models / short rows) — the
    /// intra-kernel split then serializes launches that `AsyncBatch`
    /// overlaps with CPU compute.
    AsyncBatch,
    /// Phase-disaggregated serving (PAPI-style): the lease is split by
    /// [`Coordinator::phase_leases`] into a **prefill** sub-lease on the
    /// units whose GEMM-class strength row is strongest (P-cores plus
    /// GEMM-favouring accelerators) and a **decode** sub-lease on the
    /// bandwidth-rich remainder steered by the GEMV row. Admissions enter
    /// the prefill side; sessions whose prompt is fully prefetched are
    /// handed off — KV cache and all, bit-identically — to the decode
    /// side, so compute-bound and bandwidth-bound phases stop sharing
    /// hardware they degrade each other on.
    Disaggregated,
}

/// One complete serving strategy the router can put the fleet on: the
/// coordinator-side execution mode plus the batcher shape that mode is
/// served with. [`Coordinator::strategy_candidates`] enumerates the
/// strategies valid for a machine; [`Coordinator::apply_strategy`]
/// switches live leases onto one (epoch bump → fleet rebuild → bit-identical
/// session migration, the same path a membership change takes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Strategy {
    pub mode: ExecMode,
    /// batch slots per batcher under this strategy
    pub max_batch: usize,
    /// prefill chunk (tokens) under this strategy
    pub prefill_chunk: usize,
}

/// The memory-bus bandwidth (GB/s) the given cores can claim for
/// themselves: proportional to their waterfilled allocation when every core
/// of the machine streams flat out. Leasing *all* cores returns the full
/// bus, so a single-stream lease behaves exactly like the raw machine.
pub fn bus_share(machine: &CpuSpec, cores: &[usize]) -> f64 {
    let units: Vec<ComputeUnit> = cores.iter().map(|&c| ComputeUnit::Core(c)).collect();
    bus_share_units(machine, &[], &units)
}

/// Heterogeneous generalization of [`bus_share`]: cores *and* accelerators
/// contend for the machine bus (accelerator DMA engines carry their own
/// contention weight), and a lease's share is the waterfilled allocation of
/// exactly the units it owns.
pub fn bus_share_units(
    machine: &CpuSpec,
    accels: &[AcceleratorSpec],
    units: &[ComputeUnit],
) -> f64 {
    let mut contenders: Vec<Contender> = machine
        .cores
        .iter()
        .map(|c| Contender { weight: c.mem_weight, cap: c.mem_bw_gbps })
        .collect();
    for a in accels {
        contenders.push(Contender { weight: a.mem_weight, cap: a.mem_bw_gbps });
    }
    let alloc = waterfill(&contenders, machine.bus_bw_gbps);
    let total: f64 = alloc.iter().sum();
    if total <= 0.0 {
        return machine.bus_bw_gbps;
    }
    let n_cores = machine.n_cores();
    let share: f64 = units
        .iter()
        .map(|u| match u {
            ComputeUnit::Core(g) => alloc[*g],
            ComputeUnit::Xpu(a) => alloc[n_cores + *a],
        })
        .sum();
    machine.bus_bw_gbps * share / total
}

/// A disjoint reservation of compute units for one stream.
///
/// Leases are snapshots: any membership change or rebalance bumps the
/// coordinator [`Coordinator::epoch`] and re-issues every lease, so holders
/// compare `lease.epoch` against `coordinator.epoch()` and rebuild their
/// executor when it lags. Next to the unit set, a lease carries the
/// per-unit learned strengths at issue time (executor seeds) and its
/// proportional share of the memory bus.
#[derive(Clone, Debug, PartialEq)]
pub struct Lease {
    pub stream: StreamId,
    /// owned units in canonical order: cores ascending, then accelerators
    /// ascending — lease-local index `i` is executor worker `i`
    pub units: Vec<ComputeUnit>,
    /// learned blended strength of each unit when the lease was issued
    /// (parallel to `units`) — seeds the device-level split of
    /// [`Lease::xpu_executor`]
    pub strengths: Vec<f64>,
    /// class-keyed strength rows at issue time (each parallel to `units`):
    /// only the classes the coordinator has actually observed appear here.
    /// [`Lease::xpu_executor_mode`] seeds each device-ratio class row from
    /// its matching entry so a collapsed GEMV row never poisons the GEMM
    /// seed, and [`Coordinator::phase_leases`] steers by the GEMM/GEMV
    /// rows.
    pub class_strengths: BTreeMap<KernelClass, Vec<f64>>,
    /// this lease's proportional share of the machine bus (GB/s)
    pub bus_share_gbps: f64,
    /// allocation epoch this lease was issued under
    pub epoch: u64,
    /// how a hetero lease executes ([`ExecMode`]); cores-only leases
    /// ignore it
    pub mode: ExecMode,
}

impl Lease {
    /// A cores-only lease with flat strengths — for tests and for
    /// replaying foreign/stale observations; executors built from it fall
    /// back to recomputing the bus share from the machine.
    pub fn cores_only(stream: StreamId, cores: Vec<usize>, epoch: u64) -> Lease {
        let units: Vec<ComputeUnit> = cores.into_iter().map(ComputeUnit::Core).collect();
        let strengths = vec![1.0; units.len()];
        Lease {
            stream,
            units,
            strengths,
            class_strengths: BTreeMap::new(),
            bus_share_gbps: 0.0,
            epoch,
            mode: ExecMode::IntraKernel,
        }
    }

    pub fn n_units(&self) -> usize {
        self.units.len()
    }

    pub fn n_cores(&self) -> usize {
        self.units.iter().filter(|u| u.is_core()).count()
    }

    /// Global core ids (ascending) — the executor-facing CPU subset.
    pub fn cores(&self) -> Vec<usize> {
        self.units
            .iter()
            .filter_map(|u| match u {
                ComputeUnit::Core(g) => Some(*g),
                ComputeUnit::Xpu(_) => None,
            })
            .collect()
    }

    /// Owned accelerator indices (ascending).
    pub fn accels(&self) -> Vec<usize> {
        self.units
            .iter()
            .filter_map(|u| match u {
                ComputeUnit::Xpu(a) => Some(*a),
                ComputeUnit::Core(_) => None,
            })
            .collect()
    }

    /// True when the machine had fewer cores than streams and this stream
    /// is waiting for capacity. A lease without cores must not build
    /// executors (an accelerator alone cannot run the model — the
    /// coordinator never issues an accelerator to a core-less lease).
    pub fn is_empty(&self) -> bool {
        self.n_cores() == 0
    }

    /// Total learned strength of the owned units.
    pub fn strength_sum(&self) -> f64 {
        self.strengths.iter().sum()
    }

    /// Global core id of lease-local worker `local`. Panics if `local`
    /// addresses an accelerator entry — device workers have no core id.
    pub fn global_core(&self, local: usize) -> usize {
        match self.units[local] {
            ComputeUnit::Core(g) => g,
            ComputeUnit::Xpu(a) => panic!("local worker {local} is accelerator {a}, not a core"),
        }
    }

    /// Lease-local worker index of global core `global`, if leased here.
    pub fn local_index(&self, global: usize) -> Option<usize> {
        self.units.iter().position(|&u| u == ComputeUnit::Core(global))
    }

    /// The executor-facing sub-machine: leased cores re-indexed `0..n`
    /// with this lease's proportional share of the memory bus.
    pub fn spec(&self, machine: &CpuSpec) -> CpuSpec {
        let cores = self.cores();
        let bus = if self.bus_share_gbps > 0.0 {
            self.bus_share_gbps
        } else {
            bus_share(machine, &cores)
        };
        machine.subset(&cores, bus)
    }

    /// Simulator executor over exactly the leased cores — the cores-only
    /// fast path. A lease that owns accelerators should materialize
    /// [`Lease::xpu_executor`] instead (debug builds assert this).
    pub fn sim_executor(&self, machine: &CpuSpec, cfg: SimConfig) -> SimExecutor {
        debug_assert!(
            self.accels().is_empty(),
            "lease owns accelerators {:?}; materialize xpu_executor() or they idle",
            self.accels()
        );
        SimExecutor::new(self.spec(machine), cfg)
    }

    /// Heterogeneous executor: the leased cores plus every owned
    /// accelerator, dispatched cross-device by [`crate::sim::xpu::XpuSim`]
    /// with device-level ratio learning seeded from this lease's strengths
    /// (CPU seed = summed core strength). Device seeds are floored at 5%
    /// of the CPU seed: a device whose learned strength collapsed still
    /// gets a non-zero first split on every fresh executor, so each epoch
    /// re-auditions it per kernel class instead of inheriting a frozen
    /// write-off. With no owned accelerator this is exactly the cores-only
    /// simulator path.
    pub fn xpu_executor(
        &self,
        machine: &CpuSpec,
        accels: &[AcceleratorSpec],
        cfg: SimConfig,
    ) -> XpuExecutor {
        self.xpu_executor_mode(machine, accels, cfg, XpuDispatch::Split)
    }

    /// [`Lease::xpu_executor`] with an explicit [`XpuDispatch`]: `Split` is
    /// the intra-kernel default; `CpuOnly` / `DeviceOnly` build the two
    /// halves of an [`ExecMode::AsyncBatch`] batcher pair, where each
    /// executor runs whole kernels on one side of the lease while the other
    /// side runs its own batch concurrently.
    pub fn xpu_executor_mode(
        &self,
        machine: &CpuSpec,
        accels: &[AcceleratorSpec],
        cfg: SimConfig,
        dispatch: XpuDispatch,
    ) -> XpuExecutor {
        let owned: Vec<AcceleratorSpec> =
            self.accels().iter().map(|&a| accels[a].clone()).collect();
        let seeds = Lease::device_seeds(&self.units, &self.strengths);
        let mut sim = XpuSim::new(self.spec(machine), cfg, owned).with_device_seeds(seeds);
        if !self.class_strengths.is_empty() {
            // classes the coordinator has observed seed their own device
            // rows: a launch-collapsed GEMV row must not write off the
            // device for prefill GEMMs (and vice versa)
            let class_seeds: BTreeMap<KernelClass, Vec<f64>> = self
                .class_strengths
                .iter()
                .map(|(&cl, row)| (cl, Lease::device_seeds(&self.units, row)))
                .collect();
            sim = sim.with_class_seeds(class_seeds);
        }
        XpuExecutor::with_dispatch(sim, dispatch)
    }

    /// Device-level seed vector `[cpu, dev...]` from one strength row
    /// (parallel to `units`): CPU seed = summed core strength, device
    /// seeds floored at 5% of it so a collapsed device re-auditions.
    fn device_seeds(units: &[ComputeUnit], row: &[f64]) -> Vec<f64> {
        let cpu_strength: f64 =
            units.iter().zip(row).filter(|(u, _)| u.is_core()).map(|(_, s)| s).sum();
        let cpu_seed = cpu_strength.max(1e-9);
        let mut seeds = vec![cpu_seed];
        for (u, s) in units.iter().zip(row) {
            if !u.is_core() {
                seeds.push(s.max(cpu_seed * 0.05));
            }
        }
        seeds
    }

    /// Real-thread executor: one worker per leased core, pinned to the
    /// lease's *global* core ids.
    pub fn host_pool(&self) -> HostPool {
        HostPool::with_cores(&self.cores())
    }

    /// Background-load entries for this lease's simulator: one per leased
    /// core whose *global* id appears in `degraded_globals`, mapped to the
    /// lease-local index and stealing `fraction` of that core's cycles for
    /// the whole run. Degraded globals not leased here are skipped — the
    /// load follows the physical core, not the lease — and every produced
    /// entry is guarded to address a core worker (never an accelerator).
    pub fn background_for(&self, degraded_globals: &[usize], fraction: f64) -> Vec<BackgroundLoad> {
        let n_cores = self.n_cores();
        degraded_globals
            .iter()
            .filter_map(|&g| self.local_index(g))
            .map(|local| {
                debug_assert!(
                    local < n_cores,
                    "degraded global mapped to non-core worker {local}"
                );
                BackgroundLoad { core: local, start: 0.0, end: 1e9, fraction }
            })
            .collect()
    }
}

/// How the coordinator partitions cores across streams. Accelerator
/// placement is the orthogonal [`XpuAffinity`] dimension.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Split every core kind evenly across streams and balance measured
    /// strength — fair multi-tenant serving (default).
    #[default]
    Balanced,
    /// Give the strongest cores to the earliest-admitted streams in
    /// contiguous blocks — latency-tiered serving.
    Packed,
}

/// Owns the machine's compute units and leases disjoint subsets to streams.
pub struct Coordinator {
    spec: CpuSpec,
    policy: AllocPolicy,
    affinity: XpuAffinity,
    exec_mode: ExecMode,
    accels: Vec<AcceleratorSpec>,
    /// EWMA gain α for strength updates (weight of the old value, like
    /// `PerfConfig::alpha`; paper uses 0.3).
    pub alpha: f64,
    /// per-unit strength seed: cores (global order) then accelerators,
    /// from the spec's ideal VNNI compute ratios (slowest core = 1.0) —
    /// the starting row for every kernel class
    seed: Vec<f64>,
    /// class-keyed measured strengths (each row parallel to `seed`),
    /// lazily seeded on a class's first observation — same shape as the
    /// device-ratio tables in [`crate::sim::xpu::XpuSim`]. Classes never
    /// observed read the seed row.
    strength: BTreeMap<KernelClass, Vec<f64>>,
    /// `Pinned` affinity: accelerator → owning stream while it lives
    pinned: Vec<Option<StreamId>>,
    /// admitted streams in admission order
    streams: Vec<StreamId>,
    leases: BTreeMap<StreamId, Lease>,
    epoch: u64,
    observations: u64,
}

impl Coordinator {
    /// Cores-only coordinator (no accelerators leased).
    pub fn new(spec: CpuSpec, policy: AllocPolicy) -> Coordinator {
        Coordinator::with_accelerators(spec, Vec::new(), policy, XpuAffinity::None)
    }

    /// Heterogeneous coordinator: cores plus accelerators, with the given
    /// placement affinity. Accelerator strengths are seeded from their
    /// spec'd int8 throughput on the same scale as the core ratios
    /// (slowest core = 1.0).
    pub fn with_accelerators(
        spec: CpuSpec,
        accels: Vec<AcceleratorSpec>,
        policy: AllocPolicy,
        affinity: XpuAffinity,
    ) -> Coordinator {
        spec.validate().expect("invalid CpuSpec");
        let mut seed = spec.ideal_ratios(Isa::AvxVnni);
        let slowest = spec
            .cores
            .iter()
            .map(|c| c.compute_rate(Isa::AvxVnni))
            .fold(f64::INFINITY, f64::min)
            .max(1e-30);
        for a in &accels {
            seed.push((a.ops_per_sec / slowest).max(1e-9));
        }
        let pinned = vec![None; accels.len()];
        Coordinator {
            spec,
            policy,
            affinity,
            exec_mode: ExecMode::IntraKernel,
            accels,
            alpha: 0.3,
            seed,
            strength: BTreeMap::new(),
            pinned,
            streams: Vec::new(),
            leases: BTreeMap::new(),
            epoch: 0,
            observations: 0,
        }
    }

    pub fn machine(&self) -> &CpuSpec {
        &self.spec
    }

    pub fn accelerators(&self) -> &[AcceleratorSpec] {
        &self.accels
    }

    /// Execution mode stamped on every issued hetero lease.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// Switch the execution mode for all future leases. Live leases are
    /// re-issued (epoch bump) so holders pick up the new mode on their
    /// next refresh.
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        if self.exec_mode != mode {
            self.exec_mode = mode;
            if !self.streams.is_empty() {
                self.assign();
            }
        }
    }

    /// Every [`Strategy`] this machine can serve with at the given batcher
    /// shape, in preference order for decode-heavy traffic: the blended
    /// intra-kernel split always works; `AsyncBatch` needs at least one
    /// leasable accelerator to run the parallel-batch pair;
    /// `Disaggregated` needs ≥ 2 cores to split a phase pair from
    /// (with fewer, [`Coordinator::phase_leases`] returns `None` and the
    /// mode silently degenerates to a blended lease).
    pub fn strategy_candidates(&self, max_batch: usize, prefill_chunk: usize) -> Vec<Strategy> {
        let mut out = vec![Strategy { mode: ExecMode::IntraKernel, max_batch, prefill_chunk }];
        if !self.accels.is_empty() && self.affinity != XpuAffinity::None {
            out.push(Strategy { mode: ExecMode::AsyncBatch, max_batch, prefill_chunk });
        }
        if self.spec.n_cores() >= 2 {
            out.push(Strategy { mode: ExecMode::Disaggregated, max_batch, prefill_chunk });
        }
        out
    }

    /// Put the coordinator on the given strategy. A mode change re-issues
    /// every live lease (epoch bump via [`Coordinator::set_exec_mode`]) so
    /// the serving layer's rebuild-and-migrate machinery moves every
    /// in-flight session bit-identically; returns whether the mode actually
    /// changed. The strategy's batcher shape is the *caller's* side of the
    /// switch — the coordinator only owns lease issuance.
    pub fn apply_strategy(&mut self, strategy: &Strategy) -> bool {
        let changed = self.exec_mode != strategy.mode;
        self.set_exec_mode(strategy.mode);
        changed
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Bumped on every admit/finish/rebalance; stale leases carry an older
    /// value and must be refreshed via [`Coordinator::lease`].
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The machine's full memory-bus bandwidth (GB/s) — the reference
    /// every lease's `bus_share_gbps` is a proportional slice of, and the
    /// denominator of the serving-side bandwidth-utilization export.
    pub fn bus_reference_gbps(&self) -> f64 {
        self.spec.bus_bw_gbps
    }

    /// Current measured per-unit strengths **blended across kernel
    /// classes** (the mean over every observed class row; the seed row
    /// when nothing was observed yet): cores in global order, then one
    /// entry per accelerator. Allocation balances this blend; phase
    /// routing reads the per-class rows via
    /// [`Coordinator::class_strengths`].
    pub fn strengths(&self) -> Vec<f64> {
        if self.strength.is_empty() {
            return self.seed.clone();
        }
        let mut blend = vec![0.0f64; self.seed.len()];
        for row in self.strength.values() {
            for (b, v) in blend.iter_mut().zip(row) {
                *b += v;
            }
        }
        let k = self.strength.len() as f64;
        for b in &mut blend {
            *b /= k;
        }
        blend
    }

    /// The per-unit strength row of one kernel class (the seed row until
    /// that class is first observed) — same unit order as
    /// [`Coordinator::strengths`].
    pub fn class_strengths(&self, class: KernelClass) -> Vec<f64> {
        self.row(class).to_vec()
    }

    fn row(&self, class: KernelClass) -> &[f64] {
        self.strength.get(&class).map(|r| &r[..]).unwrap_or(&self.seed)
    }

    fn row_mut(&mut self, class: KernelClass) -> &mut Vec<f64> {
        let seed = &self.seed;
        self.strength.entry(class).or_insert_with(|| seed.clone())
    }

    /// Lifetime count of accepted observations — the serving layer's
    /// drift monitor uses this as its cooldown clock.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    fn strength_index(&self, unit: ComputeUnit) -> usize {
        match unit {
            ComputeUnit::Core(g) => g,
            ComputeUnit::Xpu(a) => self.spec.n_cores() + a,
        }
    }

    /// Admit a new stream and return its lease. Re-partitions every
    /// existing lease (epoch bump). Panics on a duplicate stream id.
    pub fn admit(&mut self, stream: StreamId) -> Lease {
        assert!(!self.streams.contains(&stream), "stream {stream} already admitted");
        self.streams.push(stream);
        self.assign();
        self.leases[&stream].clone()
    }

    /// Release a stream's units back to the pool (no-op for unknown ids);
    /// remaining leases grow to cover the machine again. Accelerators
    /// pinned to the departing stream become assignable again.
    pub fn finish(&mut self, stream: StreamId) {
        let before = self.streams.len();
        self.streams.retain(|&s| s != stream);
        if self.streams.len() != before {
            self.assign();
        }
    }

    /// The current lease of `stream`, if admitted.
    pub fn lease(&self, stream: StreamId) -> Option<&Lease> {
        self.leases.get(&stream)
    }

    /// All current leases (stream-id order).
    pub fn leases(&self) -> impl Iterator<Item = &Lease> {
        self.leases.values()
    }

    /// `lease` is acceptable for an observation when it is the stream's
    /// exact current lease, or a **phase sub-lease** of it
    /// ([`Coordinator::phase_leases`]): same stream and epoch with a unit
    /// set contained in the current lease's. An equal epoch implies the
    /// same global partition, so a sub-lease's local→unit mapping is
    /// still valid; anything from an older epoch (or an unknown stream)
    /// is stale and must be dropped rather than mis-attributed.
    fn lease_current(&self, lease: &Lease) -> bool {
        match self.leases.get(&lease.stream) {
            Some(current) if current == lease => true,
            Some(current) => {
                current.epoch == lease.epoch
                    && lease.units.iter().all(|u| current.units.contains(u))
            }
            None => false,
        }
    }

    /// Fold one kernel's measured per-unit result into the strength row
    /// of its kernel `class` (the serving layer reads the class off
    /// `ParallelRuntime::last_class`). `lease` must be the lease the
    /// measuring executor was built from — the current lease or one of
    /// its phase sub-leases (see [`Coordinator::phase_leases`]); stale or
    /// foreign leases are silently dropped rather than mis-attributed to
    /// units the stream no longer owns. Entries past the lease's core
    /// count map onto its accelerators (the [`XpuExecutor`] result
    /// layout), so device timings feed the same table as core timings.
    /// Mirrors the paper's eq. 2: participating units' rates are rescaled
    /// so their strength mass is preserved, then EWMA-filtered with
    /// `alpha`. A single participant carries no relative information and
    /// is skipped, and non-finite or zero per-unit walls are rejected
    /// before they can divide a NaN into the table — one poisoned timing
    /// would otherwise panic every later rebalance sort.
    ///
    /// Returns `true` when the observation was folded into the strength
    /// table, `false` when it was dropped (stale epoch, foreign stream or
    /// degenerate measurement) — the serving layer uses this to count
    /// epoch-stale measurements racing a rebuild.
    pub fn observe(&mut self, lease: &Lease, class: KernelClass, res: &RunResult) -> bool {
        if !self.lease_current(lease) {
            return false;
        }
        let mut mass = 0.0f64;
        let mut rates: Vec<(usize, f64)> = Vec::new();
        {
            let row = self.row(class);
            for (local, t) in res.per_core_secs.iter().enumerate() {
                let Some(t) = t else { continue };
                if !(t.is_finite() && *t > 0.0) {
                    // a 0-second or NaN/∞ per-unit wall marks the whole
                    // measurement as corrupt — drop it wholesale instead
                    // of folding the surviving entries of a bad sample
                    return false;
                }
                let units = res.units_done.get(local).copied().unwrap_or(0);
                if units > 0 && local < lease.units.len() {
                    let idx = match lease.units[local] {
                        ComputeUnit::Core(g) => g,
                        ComputeUnit::Xpu(a) => self.spec.n_cores() + a,
                    };
                    mass += row[idx];
                    rates.push((idx, units as f64 / t));
                }
            }
        }
        if rates.len() < 2 {
            return false;
        }
        let rate_sum: f64 = rates.iter().map(|(_, r)| r).sum();
        if !(rate_sum.is_finite() && rate_sum > 0.0 && mass > 0.0 && mass.is_finite()) {
            return false;
        }
        let scale = mass / rate_sum;
        let alpha = self.alpha;
        let row = self.row_mut(class);
        for (idx, r) in rates {
            row[idx] = alpha * row[idx] + (1.0 - alpha) * r * scale;
        }
        self.observations += 1;
        true
    }

    /// The fraction of a hetero lease's admissions that should be routed
    /// to its accelerator-path sub-batcher under
    /// [`ExecMode::AsyncBatch`]: the *live* accelerator share of the
    /// lease's total learned strength, clamped to `[0.05, 0.95]` so
    /// neither side is ever starved of the traffic it needs to keep its
    /// timings observable. Cores-only leases route everything to the CPU
    /// path (0.0). Reads the blended strengths — under `AsyncBatch` the
    /// paired rounds fold into the decode (GEMV) row, which then *is* the
    /// blend's live component.
    pub fn split_ratio(&self, lease: &Lease) -> f64 {
        let blend = self.strengths();
        let mut cpu = 0.0f64;
        let mut dev = 0.0f64;
        for &u in &lease.units {
            let s = blend[self.strength_index(u)];
            if u.is_core() {
                cpu += s;
            } else {
                dev += s;
            }
        }
        if dev <= 0.0 {
            return 0.0;
        }
        (dev / (cpu + dev).max(1e-30)).clamp(0.05, 0.95)
    }

    /// Fold one [`ExecMode::AsyncBatch`] round — the CPU sub-batcher's and
    /// the device sub-batcher's most recent `(wall_secs, tokens)` — into
    /// the same strength table that [`Coordinator::observe`] feeds. The
    /// two batchers never co-measure inside one kernel, so their raw round
    /// walls carry no relative signal once both run saturated; instead the
    /// per-path *token rates* `R = tokens / wall` are distributed over the
    /// path's units in proportion to their current strengths and folded
    /// through the usual mass-preserving EWMA. Algebraically the learned
    /// device share then converges geometrically (its residual shrinking
    /// by the old-value weight `α` each round) to
    /// `R_dev / (R_cpu + R_dev)` — the true device throughput
    /// share — independent of batch occupancy, which is exactly what
    /// [`Coordinator::split_ratio`] reads back. The fold lands in the
    /// given `class`'s row (serving passes the round's dominant kernel
    /// class — [`KernelClass::GemvQ4`] for decode-dominated token
    /// rounds). Stale or foreign leases are dropped like in `observe`;
    /// non-finite or zero walls are rejected before they divide; returns
    /// whether the round was folded.
    pub fn observe_round(
        &mut self,
        lease: &Lease,
        class: KernelClass,
        cpu: (f64, usize),
        dev: (f64, usize),
    ) -> bool {
        if !self.lease_current(lease) {
            return false;
        }
        let (cpu_wall, cpu_tokens) = cpu;
        let (dev_wall, dev_tokens) = dev;
        if !(cpu_wall.is_finite() && cpu_wall > 0.0 && dev_wall.is_finite() && dev_wall > 0.0) {
            return false;
        }
        if cpu_tokens == 0 || dev_tokens == 0 {
            return false;
        }
        let r_cpu = cpu_tokens as f64 / cpu_wall;
        let r_dev = dev_tokens as f64 / dev_wall;
        let cores: Vec<usize> = lease
            .units
            .iter()
            .filter(|u| u.is_core())
            .map(|&u| self.strength_index(u))
            .collect();
        let devs: Vec<usize> = lease
            .units
            .iter()
            .filter(|u| !u.is_core())
            .map(|&u| self.strength_index(u))
            .collect();
        if cores.is_empty() || devs.is_empty() {
            return false;
        }
        let row = self.row(class);
        let cpu_mass: f64 = cores.iter().map(|&i| row[i]).sum();
        let dev_mass: f64 = devs.iter().map(|&i| row[i]).sum();
        if !(cpu_mass > 0.0 && dev_mass > 0.0 && cpu_mass.is_finite() && dev_mass.is_finite()) {
            return false;
        }
        // per-unit rates: each path's token rate split strength-
        // proportionally over its units, then the standard fold
        let mut mass = 0.0f64;
        let mut rates: Vec<(usize, f64)> = Vec::new();
        for &i in &cores {
            mass += row[i];
            rates.push((i, r_cpu * row[i] / cpu_mass));
        }
        for &i in &devs {
            mass += row[i];
            rates.push((i, r_dev * row[i] / dev_mass));
        }
        let rate_sum: f64 = rates.iter().map(|(_, r)| r).sum();
        if !(rate_sum.is_finite() && rate_sum > 0.0) {
            return false;
        }
        let scale = mass / rate_sum;
        let alpha = self.alpha;
        let row = self.row_mut(class);
        for (idx, r) in rates {
            row[idx] = alpha * row[idx] + (1.0 - alpha) * r * scale;
        }
        self.observations += 1;
        true
    }

    /// Cross-lease strength drift, condensed to one ratio: for every core
    /// kind held by two or more leases, compare the leases' *mean* learned
    /// strength of that kind and take the worst max/min ratio over kinds.
    /// A freshly balanced (or healthy converged) partition sits near 1.0;
    /// a background load degrading part of one lease pushes the ratio up
    /// because mass-preserving per-lease updates re-scale that lease's
    /// kinds against everyone else's. Accelerators are machine singletons
    /// (never co-held), so they cannot contribute a cross-lease ratio.
    ///
    /// The signal needs co-held kinds: under [`AllocPolicy::Packed`] a
    /// partition can tier each kind entirely into one lease (8P / 8E),
    /// leaving no cross-lease comparison — the skew then stays 1.0 and
    /// the drift monitor is blind. Use `Balanced` (the default) when live
    /// drift rebalancing matters.
    pub fn strength_skew(&self) -> f64 {
        self.strength_skew_for(None)
    }

    /// [`Coordinator::strength_skew`] over one class's strength row
    /// (`Some(class)`) or over the cross-class blend (`None`) — phase
    /// routing can watch GEMM-row drift without decode noise, and vice
    /// versa.
    pub fn strength_skew_for(&self, class: Option<KernelClass>) -> f64 {
        let strengths = match class {
            Some(c) => self.row(c).to_vec(),
            None => self.strengths(),
        };
        let mut skew = 1.0f64;
        for kind in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
            let mut means: Vec<f64> = Vec::new();
            for lease in self.leases.values() {
                let vals: Vec<f64> = lease
                    .units
                    .iter()
                    .filter_map(|u| match u {
                        ComputeUnit::Core(g) if self.spec.cores[*g].kind == kind => {
                            Some(strengths[*g])
                        }
                        _ => None,
                    })
                    .collect();
                if !vals.is_empty() {
                    means.push(vals.iter().sum::<f64>() / vals.len() as f64);
                }
            }
            if means.len() >= 2 {
                let mx = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mn = means.iter().cloned().fold(f64::INFINITY, f64::min);
                if mn > 0.0 {
                    skew = skew.max(mx / mn);
                }
            }
        }
        skew
    }

    /// Split one stream's lease into a **(prefill, decode)** pair of
    /// phase sub-leases for [`ExecMode::Disaggregated`].
    ///
    /// Cores are ranked by their GEMM:GEMV strength-row ratio — how much
    /// better the unit is at compute-bound prefill GEMMs than at
    /// bandwidth-bound decode GEMVs — and the split point is chosen to
    /// maximize `(prefill GEMM mass) × (decode GEMV mass)`, i.e. neither
    /// phase is starved while each keeps the units it is relatively
    /// strongest on (with uniform rows this degenerates to an equal-mass
    /// split, P-cores on the prefill side). Each accelerator joins the
    /// decode side only when its GEMV row beats its GEMM row — the usual
    /// launch-overhead verdict keeps NPUs with the prefill GEMMs they
    /// amortize on. Both sides carry the parent's stream, epoch and mode,
    /// so [`Coordinator::observe`] accepts their measurements as phase
    /// sub-leases. Returns `None` when the lease has fewer than two cores
    /// (nothing to disaggregate — serve it blended).
    pub fn phase_leases(&self, lease: &Lease) -> Option<(Lease, Lease)> {
        let cores = lease.cores();
        if cores.len() < 2 {
            return None;
        }
        let gemm = self.row(KernelClass::GemmI8);
        let gemv = self.row(KernelClass::GemvQ4);
        let mut order = cores;
        order.sort_by(|&a, &b| {
            let ra = gemm[a] / gemv[a].max(1e-30);
            let rb = gemm[b] / gemv[b].max(1e-30);
            rb.total_cmp(&ra).then(a.cmp(&b))
        });
        let mut best_k = 1usize;
        let mut best = f64::NEG_INFINITY;
        for k in 1..order.len() {
            let pf: f64 = order[..k].iter().map(|&c| gemm[c]).sum();
            let dc: f64 = order[k..].iter().map(|&c| gemv[c]).sum();
            let score = pf * dc;
            if score > best {
                best = score;
                best_k = k;
            }
        }
        let (pf_cores, dc_cores) = order.split_at(best_k);
        let mut pf_accels: Vec<usize> = Vec::new();
        let mut dc_accels: Vec<usize> = Vec::new();
        let n_cores = self.spec.n_cores();
        for a in lease.accels() {
            let idx = n_cores + a;
            if gemv[idx] > gemm[idx] {
                dc_accels.push(a);
            } else {
                pf_accels.push(a); // ties: stay with the GEMM engines
            }
        }
        Some((
            self.sub_lease(lease, pf_cores, &pf_accels),
            self.sub_lease(lease, dc_cores, &dc_accels),
        ))
    }

    /// A phase sub-lease: a subset of `parent`'s units re-snapshotted
    /// with current strengths and its own proportional bus share (the two
    /// phase shares sum to the parent's — bus shares are additive over
    /// units).
    fn sub_lease(&self, parent: &Lease, cores: &[usize], accels: &[usize]) -> Lease {
        let mut units: Vec<ComputeUnit> = cores.iter().map(|&c| ComputeUnit::Core(c)).collect();
        units.extend(accels.iter().map(|&a| ComputeUnit::Xpu(a)));
        units.sort();
        let blend = self.strengths();
        let strengths: Vec<f64> =
            units.iter().map(|&u| blend[self.strength_index(u)]).collect();
        let class_strengths: BTreeMap<KernelClass, Vec<f64>> = self
            .strength
            .iter()
            .map(|(&cl, row)| {
                (cl, units.iter().map(|&u| row[self.strength_index(u)]).collect())
            })
            .collect();
        let contending: &[AcceleratorSpec] = match self.affinity {
            XpuAffinity::None => &[],
            _ => &self.accels,
        };
        Lease {
            stream: parent.stream,
            units: units.clone(),
            strengths,
            class_strengths,
            bus_share_gbps: bus_share_units(&self.spec, contending, &units),
            epoch: parent.epoch,
            mode: parent.mode,
        }
    }

    /// Re-partition units across the admitted streams using the current
    /// strengths (epoch bump). Call after enough [`Coordinator::observe`]s
    /// have shifted the table — e.g. when [`Coordinator::strength_skew`]
    /// crosses the serving layer's drift threshold.
    pub fn rebalance(&mut self) {
        self.assign();
    }

    fn assign(&mut self) {
        self.epoch += 1;
        self.leases.clear();
        // release pins held by departed streams
        for p in self.pinned.iter_mut() {
            if let Some(owner) = p {
                if !self.streams.contains(owner) {
                    *p = None;
                }
            }
        }
        let k = self.streams.len();
        if k == 0 {
            return;
        }
        let n_cores = self.spec.n_cores();
        // partition on the cross-class blend (total_cmp throughout: a NaN
        // smuggled into a strength row must degrade one pick, not panic
        // the whole rebalance)
        let blend = self.strengths();
        let mut cores_per_stream: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut accels_per_stream: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut strength_sum = vec![0.0f64; k];

        // ---- accelerators first: their strength steers the core picks ----
        if self.affinity != XpuAffinity::None {
            // strongest device first; ties toward the lower index
            let mut order: Vec<usize> = (0..self.accels.len()).collect();
            order.sort_by(|&a, &b| {
                let (sa, sb) = (blend[n_cores + a], blend[n_cores + b]);
                sb.total_cmp(&sa).then(a.cmp(&b))
            });
            for a in order {
                let pinned_slot = match self.affinity {
                    XpuAffinity::Pinned => self.pinned[a]
                        .and_then(|owner| self.streams.iter().position(|&s| s == owner)),
                    _ => None,
                };
                let s = pinned_slot.unwrap_or_else(|| {
                    // weakest strength sum so far; ties toward admission order
                    (0..k)
                        .min_by(|&x, &y| {
                            strength_sum[x].total_cmp(&strength_sum[y]).then(x.cmp(&y))
                        })
                        .unwrap()
                });
                if self.affinity == XpuAffinity::Pinned {
                    self.pinned[a] = Some(self.streams[s]);
                }
                accels_per_stream[s].push(a);
                strength_sum[s] += blend[n_cores + a];
            }
        }

        match self.policy {
            AllocPolicy::Packed => {
                let mut order: Vec<usize> = (0..n_cores).collect();
                order.sort_by(|&a, &b| blend[b].total_cmp(&blend[a]).then(a.cmp(&b)));
                let sizes = largest_remainder_split(order.len(), &vec![1.0; k]);
                let mut cursor = 0;
                for (s, &sz) in sizes.iter().enumerate() {
                    for &core in &order[cursor..cursor + sz] {
                        cores_per_stream[s].push(core);
                        strength_sum[s] += blend[core];
                    }
                    cursor += sz;
                }
            }
            AllocPolicy::Balanced => {
                for kind in [CoreKind::Performance, CoreKind::Efficiency, CoreKind::LowPower] {
                    let mut pool: Vec<usize> = self
                        .spec
                        .cores
                        .iter()
                        .filter(|c| c.kind == kind)
                        .map(|c| c.id)
                        .collect();
                    if pool.is_empty() {
                        continue;
                    }
                    // strongest first; ties toward the lower core id
                    pool.sort_by(|&a, &b| blend[b].total_cmp(&blend[a]).then(a.cmp(&b)));
                    // every stream gets its fair share of this kind (±1)
                    let mut quota = largest_remainder_split(pool.len(), &vec![1.0; k]);
                    for &core in &pool {
                        // among streams with quota left, the weakest so far;
                        // ties toward admission order
                        let mut best: Option<usize> = None;
                        for s in 0..k {
                            if quota[s] == 0 {
                                continue;
                            }
                            best = match best {
                                None => Some(s),
                                Some(b) if strength_sum[s] < strength_sum[b] - 1e-12 => Some(s),
                                other => other,
                            };
                        }
                        let s = best.expect("kind quotas sum to the kind's core count");
                        quota[s] -= 1;
                        cores_per_stream[s].push(core);
                        strength_sum[s] += blend[core];
                    }
                }
            }
        }

        // repair: no stream may be core-less while another holds ≥ 2 cores
        // (possible when a kind has fewer cores than there are streams)
        loop {
            let Some(empty) = (0..k).find(|&s| cores_per_stream[s].is_empty()) else { break };
            let rich = (0..k)
                .filter(|&s| cores_per_stream[s].len() >= 2)
                .max_by(|&a, &b| {
                    let by_strength =
                        strength_sum[a].total_cmp(&strength_sum[b]).then(b.cmp(&a));
                    cores_per_stream[a].len().cmp(&cores_per_stream[b].len()).then(by_strength)
                });
            let Some(rich) = rich else { break };
            let pos = (0..cores_per_stream[rich].len())
                .min_by(|&i, &j| {
                    let (a, b) = (cores_per_stream[rich][i], cores_per_stream[rich][j]);
                    blend[a].total_cmp(&blend[b]).then(a.cmp(&b))
                })
                .unwrap();
            let core = cores_per_stream[rich].remove(pos);
            strength_sum[rich] -= blend[core];
            strength_sum[empty] += blend[core];
            cores_per_stream[empty].push(core);
        }

        // an accelerator must not strand on a core-less lease (it cannot
        // run the model alone): move it to the weakest lease that has cores
        for s in 0..k {
            if !cores_per_stream[s].is_empty() || accels_per_stream[s].is_empty() {
                continue;
            }
            let accels = std::mem::take(&mut accels_per_stream[s]);
            for a in accels {
                strength_sum[s] -= blend[n_cores + a];
                let target = (0..k)
                    .filter(|&t| !cores_per_stream[t].is_empty())
                    .min_by(|&x, &y| {
                        strength_sum[x].total_cmp(&strength_sum[y]).then(x.cmp(&y))
                    });
                let Some(t) = target else { break };
                if self.affinity == XpuAffinity::Pinned {
                    self.pinned[a] = Some(self.streams[t]);
                }
                accels_per_stream[t].push(a);
                strength_sum[t] += blend[n_cores + a];
            }
        }

        // accelerators kept off the lease pool by policy are guaranteed
        // idle: they must not contend for bus in anyone's share (a
        // single-stream cores-only lease still gets the whole bus)
        let contending: &[AcceleratorSpec] = match self.affinity {
            XpuAffinity::None => &[],
            _ => &self.accels,
        };
        for (s, &stream) in self.streams.iter().enumerate() {
            let mut units: Vec<ComputeUnit> = std::mem::take(&mut cores_per_stream[s])
                .into_iter()
                .map(ComputeUnit::Core)
                .collect();
            let mut accels = std::mem::take(&mut accels_per_stream[s]);
            accels.sort_unstable();
            units.extend(accels.into_iter().map(ComputeUnit::Xpu));
            units.sort();
            let strengths: Vec<f64> =
                units.iter().map(|&u| blend[self.strength_index(u)]).collect();
            // snapshot each *observed* class row in unit order, so the
            // executor can seed per-class device ratios and phase routing
            // can steer by GEMM vs GEMV strength
            let class_strengths: BTreeMap<KernelClass, Vec<f64>> = self
                .strength
                .iter()
                .map(|(&cl, row)| {
                    (cl, units.iter().map(|&u| row[self.strength_index(u)]).collect())
                })
                .collect();
            let bus = if units.iter().any(ComputeUnit::is_core) {
                bus_share_units(&self.spec, contending, &units)
            } else {
                0.0
            };
            self.leases.insert(
                stream,
                Lease {
                    stream,
                    units,
                    strengths,
                    class_strengths,
                    bus_share_gbps: bus,
                    epoch: self.epoch,
                    mode: self.exec_mode,
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::presets;

    fn kinds(spec: &CpuSpec, lease: &Lease, kind: CoreKind) -> usize {
        lease.cores().iter().filter(|&&c| spec.cores[c].kind == kind).count()
    }

    fn assert_disjoint_covering(c: &Coordinator) {
        let mut seen = vec![false; c.machine().n_cores()];
        let mut accel_owner = vec![0usize; c.accelerators().len()];
        for lease in c.leases() {
            for &core in &lease.cores() {
                assert!(!seen[core], "core {core} leased twice");
                seen[core] = true;
            }
            for &a in &lease.accels() {
                accel_owner[a] += 1;
            }
        }
        if c.n_streams() > 0 {
            assert!(seen.iter().all(|&s| s), "not covering: {seen:?}");
        }
        assert!(accel_owner.iter().all(|&n| n <= 1), "accelerator leased twice");
    }

    #[test]
    fn single_stream_gets_the_whole_machine() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let lease = c.admit(7);
        assert_eq!(lease.cores(), (0..16).collect::<Vec<_>>());
        // full machine → full bus: lease spec behaves like the raw machine
        let sub = lease.spec(&spec);
        assert_eq!(sub.n_cores(), 16);
        assert!((sub.bus_bw_gbps - spec.bus_bw_gbps).abs() < 1e-9);
    }

    #[test]
    fn two_streams_split_both_kinds_evenly() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let l1 = c.lease(1).cloned();
        assert!(l1.is_none());
        let l1 = c.admit(1);
        // l0 from admit(0) is stale (epoch moved); refresh
        assert!(l0.epoch < c.epoch());
        let l0 = c.lease(0).unwrap().clone();
        assert_disjoint_covering(&c);
        for l in [&l0, &l1] {
            assert_eq!(l.n_cores(), 8);
            assert_eq!(kinds(&spec, l, CoreKind::Performance), 4);
            assert_eq!(kinds(&spec, l, CoreKind::Efficiency), 4);
            // equal halves of an equal-weight machine → half the bus
            let sub = l.spec(&spec);
            assert!((sub.bus_bw_gbps - spec.bus_bw_gbps / 2.0).abs() < 1e-9, "{}", sub.bus_bw_gbps);
        }
    }

    #[test]
    fn three_streams_on_125h_are_topology_aware() {
        let spec = presets::ultra_125h();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert!(!lease.is_empty());
            // 4 P / 3 streams → 1–2 each; 8 E → 2–3 each; 2 LPE → 0–1
            let p = kinds(&spec, lease, CoreKind::Performance);
            let e = kinds(&spec, lease, CoreKind::Efficiency);
            assert!((1..=2).contains(&p), "P={p}");
            assert!((2..=3).contains(&e), "E={e}");
        }
    }

    #[test]
    fn finish_returns_cores_to_the_survivors() {
        let mut c = Coordinator::new(presets::core_12900k(), AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        let epoch = c.epoch();
        c.finish(0);
        assert!(c.epoch() > epoch);
        assert!(c.lease(0).is_none());
        assert_eq!(c.lease(1).unwrap().n_cores(), 16);
        // unknown stream: quiet no-op, no epoch churn
        let epoch = c.epoch();
        c.finish(99);
        assert_eq!(c.epoch(), epoch);
    }

    #[test]
    fn packed_policy_tiers_the_fast_cores() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Packed);
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        let l0 = c.lease(0).unwrap();
        let l1 = c.lease(1).unwrap();
        // stream 0 holds all 8 P-cores, stream 1 all 8 E-cores
        assert_eq!(kinds(&spec, l0, CoreKind::Performance), 8);
        assert_eq!(kinds(&spec, l1, CoreKind::Efficiency), 8);
    }

    #[test]
    fn more_streams_than_a_kind_still_covers_without_empties() {
        // 2P + 2E sub-machine, 3 streams: per-kind quotas alone would leave
        // stream 2 empty; the repair pass must fill it
        let machine = presets::core_12900k().subset(&[0, 1, 8, 9], 17.0);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert!(!lease.is_empty(), "empty lease {:?}", lease);
        }
    }

    #[test]
    fn more_streams_than_cores_leaves_overflow_waiting() {
        let machine = presets::core_12900k().subset(&[0, 8], 8.0);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        let empties = c.leases().filter(|l| l.is_empty()).count();
        assert_eq!(empties, 1);
        let total: usize = c.leases().map(|l| l.n_cores()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn observe_learns_a_slow_core_and_rebalance_spreads_it() {
        // homogeneous 4-core machine, 2 streams → 2 cores each
        let machine = presets::homogeneous(4);
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        let l0 = c.lease(0).unwrap().clone();
        // stream 0's first core runs at half rate: equal units, double time
        for _ in 0..20 {
            let res = RunResult {
                per_core_secs: vec![Some(2.0), Some(1.0)],
                wall_secs: 2.0,
                units_done: vec![100, 100],
                bytes: 0.0,
            };
            c.observe(&l0, KernelClass::GemvQ4, &res);
        }
        assert_eq!(c.observations(), 20);
        let slow = l0.global_core(0);
        let fast = l0.global_core(1);
        assert!(
            c.strengths()[slow] < 0.6 * c.strengths()[fast],
            "strengths {:?}",
            c.strengths()
        );
        c.rebalance();
        assert_disjoint_covering(&c);
        // the slow core's lease also holds the strongest remaining core —
        // strength sums are balanced, not left lopsided
        let sums: Vec<f64> = c
            .leases()
            .map(|l| l.cores().iter().map(|&g| c.strengths()[g]).sum::<f64>())
            .collect();
        let (a, b) = (sums[0], sums[1]);
        assert!((a - b).abs() / a.max(b) < 0.35, "sums {sums:?}");
    }

    #[test]
    fn observe_ignores_degenerate_and_stale_results() {
        let mut c = Coordinator::new(presets::homogeneous(4), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let before = c.strengths().to_vec();
        // single participant: no relative information
        let accepted = c.observe(
            &l0,
            KernelClass::GemvQ4,
            &RunResult {
                per_core_secs: vec![Some(1.0), None, None, None],
                wall_secs: 1.0,
                units_done: vec![10, 0, 0, 0],
                bytes: 0.0,
            },
        );
        assert!(!accepted);
        // lease for a stream the coordinator never admitted: ignored
        let foreign = Lease::cores_only(9, vec![0, 1], 0);
        let skewed = RunResult {
            per_core_secs: vec![Some(1.0), Some(4.0)],
            wall_secs: 4.0,
            units_done: vec![100, 100],
            bytes: 0.0,
        };
        assert!(!c.observe(&foreign, KernelClass::GemvQ4, &skewed));
        assert_eq!(c.strengths(), &before[..]);
        assert_eq!(c.observations(), 0);
        // stale lease: admitting stream 1 re-partitions, so a result
        // measured under the old 4-core lease must not be mis-mapped onto
        // the new 2-core lease's globals
        c.admit(1);
        let before = c.strengths().to_vec();
        assert!(!c.observe(&l0, KernelClass::GemvQ4, &skewed));
        assert_eq!(c.strengths(), &before[..]);
        // the refreshed lease is accepted
        let fresh = c.lease(0).unwrap().clone();
        assert!(c.observe(&fresh, KernelClass::GemvQ4, &skewed));
        assert_ne!(c.strengths(), &before[..]);
        assert_eq!(c.observations(), 1);
    }

    #[test]
    fn background_for_maps_globals_to_lease_locals() {
        let lease = Lease::cores_only(0, vec![1, 4, 9, 12], 1);
        // global 4 → local 1, global 12 → local 3; global 5 leased elsewhere
        let bg = lease.background_for(&[4, 12, 5], 0.5);
        let cores: Vec<usize> = bg.iter().map(|b| b.core).collect();
        assert_eq!(cores, vec![1, 3]);
        assert!(bg.iter().all(|b| b.fraction == 0.5 && b.start == 0.0 && b.end == 1e9));
        assert!(lease.background_for(&[], 0.5).is_empty());
    }

    #[test]
    fn background_for_skips_globals_on_a_hetero_lease() {
        // a lease owning an accelerator maps background loads exactly like
        // a cores-only lease: only its own cores, always to core workers
        let mut c = Coordinator::with_accelerators(
            presets::core_12900k(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        c.admit(0);
        c.admit(1);
        let with_npu = c.leases().find(|l| !l.accels().is_empty()).unwrap().clone();
        let other = c.leases().find(|l| l.accels().is_empty()).unwrap().clone();
        let foreign: Vec<usize> = other.cores();
        // degraded cores leased to the *other* stream: all skipped
        assert!(with_npu.background_for(&foreign, 0.5).is_empty());
        // its own first two cores map to locals 0 and 1
        let own: Vec<usize> = with_npu.cores().into_iter().take(2).collect();
        let bg = with_npu.background_for(&own, 0.25);
        assert_eq!(bg.iter().map(|b| b.core).collect::<Vec<_>>(), vec![0, 1]);
        assert!(bg.iter().all(|b| b.core < with_npu.n_cores()));
    }

    #[test]
    fn lease_local_global_maps_roundtrip() {
        let mut c = Coordinator::new(presets::ultra_125h(), AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        for lease in c.leases() {
            for local in 0..lease.n_cores() {
                let g = lease.global_core(local);
                assert_eq!(lease.local_index(g), Some(local));
            }
            assert_eq!(lease.local_index(999), None);
        }
    }

    // ---- heterogeneous (accelerator) leasing ----

    #[test]
    fn floating_accelerator_lands_on_one_lease_and_steers_cores() {
        let spec = presets::ultra_125h();
        let mut c = Coordinator::with_accelerators(
            spec.clone(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        let owners: Vec<StreamId> =
            c.leases().filter(|l| !l.accels().is_empty()).map(|l| l.stream).collect();
        assert_eq!(owners.len(), 1, "exactly one lease owns the NPU");
        // per-kind core quotas still hold on both leases
        for l in c.leases() {
            assert_eq!(kinds(&spec, l, CoreKind::Performance), 2);
            assert_eq!(kinds(&spec, l, CoreKind::Efficiency), 4);
        }
        // the lease snapshot carries the device strength and a bus share
        let with_npu = c.leases().find(|l| !l.accels().is_empty()).unwrap();
        assert_eq!(with_npu.units.len(), with_npu.strengths.len());
        assert!(with_npu.strength_sum() > 10.0, "NPU strength missing");
        assert!(with_npu.bus_share_gbps > 0.0);
    }

    #[test]
    fn two_accelerators_float_to_different_leases() {
        let mut c = Coordinator::with_accelerators(
            presets::ultra_125h(),
            vec![AcceleratorSpec::npu(), AcceleratorSpec::igpu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        c.admit(0);
        c.admit(1);
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            assert_eq!(lease.accels().len(), 1, "{:?}", lease.units);
        }
    }

    #[test]
    fn pinned_accelerator_stays_until_its_stream_departs() {
        let mut c = Coordinator::with_accelerators(
            presets::core_12900k(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Pinned,
        );
        c.admit(0);
        let owner = c.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        c.admit(1);
        c.admit(2);
        c.rebalance();
        let still = c.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        assert_eq!(owner, still, "pinned accelerator moved across rebalances");
        c.finish(owner);
        let next = c.leases().find(|l| !l.accels().is_empty()).unwrap().stream;
        assert_ne!(next, owner, "released pin was not re-assigned");
    }

    #[test]
    fn affinity_none_leases_no_accelerators_and_reserves_no_bus() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::with_accelerators(
            spec.clone(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::None,
        );
        c.admit(0);
        assert!(c.leases().all(|l| l.accels().is_empty()));
        // the policy-idled device must not steal bus share: a single
        // cores-only stream still behaves exactly like the raw machine
        let lease = c.lease(0).unwrap();
        assert!(
            (lease.bus_share_gbps - spec.bus_bw_gbps).abs() < 1e-9,
            "idle NPU stole bus: {} vs {}",
            lease.bus_share_gbps,
            spec.bus_bw_gbps
        );
    }

    #[test]
    fn accelerator_never_strands_on_a_coreless_lease() {
        // 2 cores, 3 streams: one stream waits core-less — the NPU must
        // not be wasted on it
        let machine = presets::core_12900k().subset(&[0, 8], 8.0);
        let mut c = Coordinator::with_accelerators(
            machine,
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        for s in 0..3 {
            c.admit(s);
        }
        assert_disjoint_covering(&c);
        for lease in c.leases() {
            if !lease.accels().is_empty() {
                assert!(!lease.is_empty(), "accelerator stranded on {:?}", lease);
            }
        }
    }

    #[test]
    fn observe_folds_device_timings_into_the_strength_table() {
        let spec = presets::homogeneous(4);
        let mut c = Coordinator::with_accelerators(
            spec.clone(),
            vec![AcceleratorSpec::npu()],
            AllocPolicy::Balanced,
            XpuAffinity::Floating,
        );
        let lease = c.admit(0); // whole machine + NPU
        assert_eq!(lease.accels(), vec![0]);
        let npu_idx = spec.n_cores();
        let seed = c.strengths()[npu_idx];
        // equal units everywhere, device twice as fast as any core: its
        // strength must grow relative to the cores'
        let res = RunResult {
            per_core_secs: vec![Some(1.0), Some(1.0), Some(1.0), Some(1.0), Some(0.5)],
            wall_secs: 1.0,
            units_done: vec![100, 100, 100, 100, 100],
            bytes: 0.0,
        };
        for _ in 0..10 {
            let cur = c.lease(0).unwrap().clone();
            assert!(c.observe(&cur, KernelClass::GemvQ4, &res));
        }
        let s = c.strengths();
        assert!(
            (s[npu_idx] / s[0] - 2.0).abs() < 0.05,
            "device:core ratio {} (seed {seed})",
            s[npu_idx] / s[0]
        );
    }

    #[test]
    fn strength_skew_flags_asymmetric_degradation_only() {
        let machine = presets::core_12900k();
        let mut c = Coordinator::new(machine, AllocPolicy::Balanced);
        c.admit(0);
        c.admit(1);
        assert!((c.strength_skew() - 1.0).abs() < 1e-9, "healthy skew {}", c.strength_skew());
        // stream 0's P-cores run at half rate; its E-cores at full rate —
        // mass-preserving updates shift strength inside lease 0 only
        let l0 = c.lease(0).unwrap().clone();
        let times: Vec<Option<f64>> = (0..l0.n_cores())
            .map(|i| {
                let g = l0.global_core(i);
                let kind = c.machine().cores[g].kind;
                let rate = if kind == CoreKind::Performance { 2.649 / 2.0 } else { 1.0 };
                Some(100.0 / rate)
            })
            .collect();
        let res = RunResult {
            wall_secs: 1.0,
            units_done: vec![100; l0.n_cores()],
            bytes: 0.0,
            per_core_secs: times,
        };
        for _ in 0..12 {
            assert!(c.observe(&l0, KernelClass::GemvQ4, &res));
        }
        let skew = c.strength_skew();
        assert!(skew > 1.25, "drift not visible: skew {skew}");
        // rebalance mixes the degraded cores evenly → skew collapses
        c.rebalance();
        let post = c.strength_skew();
        assert!(post < 1.05, "rebalance did not equalize: skew {post}");
    }

    #[test]
    fn observe_rejects_zero_and_nonfinite_timings() {
        // a single 0-second (or NaN/∞) timing used to mint a NaN strength
        // that panicked every later rebalance sort — it must be rejected
        // wholesale, leaving the table untouched
        let mut c = Coordinator::new(presets::homogeneous(4), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let before = c.strengths();
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let res = RunResult {
                per_core_secs: vec![Some(bad), Some(1.0), Some(1.0), Some(1.0)],
                wall_secs: 1.0,
                units_done: vec![100, 100, 100, 100],
                bytes: 0.0,
            };
            assert!(!c.observe(&l0, KernelClass::GemvQ4, &res), "accepted t={bad}");
            assert!(!c.observe_round(&l0, KernelClass::GemvQ4, (bad, 100), (1.0, 100)));
        }
        assert_eq!(c.strengths(), &before[..]);
        assert_eq!(c.observations(), 0);
        // ...and a poisoned table (injected via a valid fold then a
        // rebalance) must never panic: total_cmp sorts NaN, not unwrap
        c.rebalance();
    }

    #[test]
    fn class_rows_learn_independently() {
        // degrade core 0 on the GEMM row only: the GEMV row must not move,
        // and per-class reads see different pictures
        let mut c = Coordinator::new(presets::homogeneous(4), AllocPolicy::Balanced);
        let l0 = c.admit(0);
        let res = RunResult {
            per_core_secs: vec![Some(4.0), Some(1.0), Some(1.0), Some(1.0)],
            wall_secs: 4.0,
            units_done: vec![100, 100, 100, 100],
            bytes: 0.0,
        };
        let gemv_before = c.class_strengths(KernelClass::GemvQ4);
        for _ in 0..15 {
            assert!(c.observe(&l0, KernelClass::GemmI8, &res));
        }
        let gemm = c.class_strengths(KernelClass::GemmI8);
        assert!(gemm[0] < 0.5 * gemm[1], "GEMM row did not learn: {gemm:?}");
        assert_eq!(
            c.class_strengths(KernelClass::GemvQ4),
            gemv_before,
            "GEMM observations leaked into the GEMV row"
        );
        // the blend sits between the seed row and the degraded GEMM row
        let blend = c.strengths();
        assert!(blend[0] < 1.0 && blend[0] > gemm[0]);
    }

    #[test]
    fn phase_leases_split_covering_and_steer_by_class() {
        let spec = presets::core_12900k();
        let mut c = Coordinator::new(spec.clone(), AllocPolicy::Balanced);
        let lease = c.admit(0);
        // teach the table: P-cores dominate GEMM (compute), E-cores close
        // the gap on GEMV (bandwidth-bound — per-core compute barely counts)
        let gemm_res = RunResult {
            per_core_secs: (0..16)
                .map(|g| Some(if spec.cores[g].kind == CoreKind::Performance { 0.5 } else { 2.0 }))
                .collect(),
            wall_secs: 2.0,
            units_done: vec![100; 16],
            bytes: 0.0,
        };
        let gemv_res = RunResult {
            per_core_secs: vec![Some(1.0); 16],
            wall_secs: 1.0,
            units_done: vec![100; 16],
            bytes: 0.0,
        };
        for _ in 0..15 {
            assert!(c.observe(&lease, KernelClass::GemmI8, &gemm_res));
            assert!(c.observe(&lease, KernelClass::GemvQ4, &gemv_res));
        }
        let (pf, dc) = c.phase_leases(&lease).expect("16 cores are splittable");
        // disjoint + covering split of the parent's units
        let mut all: Vec<ComputeUnit> = pf.units.iter().chain(&dc.units).copied().collect();
        all.sort();
        assert_eq!(all, lease.units);
        // GEMM-strong P-cores land on the prefill side
        let pf_p = kinds(&spec, &pf, CoreKind::Performance);
        assert_eq!(pf_p, pf.n_cores(), "prefill side holds E-cores: {:?}", pf.units);
        assert!(kinds(&spec, &dc, CoreKind::Efficiency) > 0);
        // both sides stay observable as phase sub-leases of the parent
        assert_eq!((pf.epoch, pf.stream), (lease.epoch, lease.stream));
        let sub_res = RunResult {
            per_core_secs: vec![Some(1.0); dc.n_cores()],
            wall_secs: 1.0,
            units_done: vec![10; dc.n_cores()],
            bytes: 0.0,
        };
        assert!(c.observe(&dc, KernelClass::GemvQ4, &sub_res));
        // bus shares are proportional and sum to the parent's
        assert!(pf.bus_share_gbps > 0.0 && dc.bus_share_gbps > 0.0);
        assert!(
            (pf.bus_share_gbps + dc.bus_share_gbps - lease.bus_share_gbps).abs()
                < 1e-6 * lease.bus_share_gbps.max(1.0),
            "phase bus shares {} + {} != parent {}",
            pf.bus_share_gbps,
            dc.bus_share_gbps,
            lease.bus_share_gbps
        );
        // a 1-core lease cannot disaggregate
        let tiny = Lease::cores_only(0, vec![0], c.epoch());
        assert!(c.phase_leases(&tiny).is_none());
    }
}
