//! Elementwise kernels: RMSNorm, softmax, SwiGLU, residual add, copy.
//!
//! Semantics mirror `python/compile/kernels/ref.py` (same eps, same maths)
//! so native and PJRT logits stay comparable.

use std::ops::Range;

/// y = x / sqrt(mean(x²) + eps) · w
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, y: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), y.len());
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((yv, &xv), &wv) in y.iter_mut().zip(x).zip(w) {
        *yv = xv * inv * wv;
    }
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    let mx = x.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// out = silu(gate) · up  (SwiGLU)
pub fn silu_mul(gate: &[f32], up: &[f32], out: &mut [f32]) {
    assert_eq!(gate.len(), up.len());
    assert_eq!(gate.len(), out.len());
    for ((o, &g), &u) in out.iter_mut().zip(gate).zip(up) {
        *o = g / (1.0 + (-g).exp()) * u;
    }
}

/// x += r (residual add)
pub fn add_inplace(x: &mut [f32], r: &[f32]) {
    assert_eq!(x.len(), r.len());
    for (a, &b) in x.iter_mut().zip(r) {
        *a += b;
    }
}

/// Range-based parallel copy: copies `elems[range]` — the paper's "tensor
/// copying" kernel, scheduled like any other.
pub fn copy_range(src: &[f32], dst: &mut [f32], range: Range<usize>) {
    dst[range.clone()].copy_from_slice(&src[range]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    }

    #[test]
    fn rmsnorm_unit_weight_normalizes() {
        let x = rand(64, 1);
        let w = vec![1.0f32; 64];
        let mut y = vec![0.0f32; 64];
        rmsnorm(&x, &w, 1e-5, &mut y);
        let ms = y.iter().map(|&v| v * v).sum::<f32>() / 64.0;
        assert!((ms - 1.0).abs() < 1e-3, "ms={ms}");
    }

    #[test]
    fn rmsnorm_scales_with_weight() {
        let x = rand(32, 2);
        let mut w = vec![1.0f32; 32];
        w[5] = 2.0;
        let mut y1 = vec![0.0f32; 32];
        let mut y2 = vec![0.0f32; 32];
        rmsnorm(&x, &vec![1.0; 32], 1e-5, &mut y1);
        rmsnorm(&x, &w, 1e-5, &mut y2);
        assert!((y2[5] - 2.0 * y1[5]).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_monotone() {
        let mut x = rand(40, 3);
        let orig = x.clone();
        softmax_inplace(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // larger logit → larger prob
        for i in 0..40 {
            for j in 0..40 {
                if orig[i] > orig[j] {
                    assert!(x[i] >= x[j]);
                }
            }
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1000.0f32, 1000.0, -1000.0];
        softmax_inplace(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-5 && x[2] < 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn silu_known_values() {
        let gate = [0.0f32, 1.0, -1.0];
        let up = [2.0f32, 2.0, 2.0];
        let mut out = [0.0f32; 3];
        silu_mul(&gate, &up, &mut out);
        assert!((out[0] - 0.0).abs() < 1e-6);
        assert!((out[1] - 2.0 / (1.0 + (-1.0f32).exp())).abs() < 1e-5);
        assert!(out[2] < 0.0);
    }

    #[test]
    fn add_and_copy() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        add_inplace(&mut x, &[10.0, 20.0, 30.0]);
        assert_eq!(x, vec![11.0, 22.0, 33.0]);
        let src = rand(100, 4);
        let mut dst = vec![0.0f32; 100];
        copy_range(&src, &mut dst, 10..60);
        assert_eq!(&dst[10..60], &src[10..60]);
        assert!(dst[..10].iter().all(|&v| v == 0.0));
        assert!(dst[60..].iter().all(|&v| v == 0.0));
    }
}
