//! Fused Q4_0-dequant GEMV / matmul — the decode-phase hot path.
//!
//! Three variants, all splitting the weight-row dimension N:
//! * [`gemv_q4_f32_range`] — f32 accumulation (llama.cpp's AVX2 path)
//! * [`gemv_q8q4_range`]   — dynamic-quant int8 activation × Q4 weight,
//!   per-block integer dot (Neural Speed's AVX-VNNI path; the paper's
//!   "complete computation" for the GEMV benchmark)
//! * [`qmatmul_f32_range`] — S-row matmul for prefill chunks (dequantizes
//!   each weight row once, reuses it across the S activation rows)

use std::ops::Range;

use crate::quant::{BlockQ4_0, MatQ4, QuantizedRow, QK};

/// Per-block sums of `x` — hoists the `(q − 8)` offset out of the inner
/// loop: `Σ (q−8)·x = Σ q·x − 8·Σx`, with `Σx` shared by *all* weight rows.
#[inline]
fn block_sums_f32(x: &[f32]) -> Vec<f32> {
    x.chunks_exact(QK).map(|c| c.iter().sum()).collect()
}

/// y[n] = Σ_k w[n,k] · x[k], f32 path, rows `rows` of `w`.
pub fn gemv_q4_f32_range(w: &MatQ4, x: &[f32], y: &mut [f32], rows: Range<usize>) {
    assert_eq!(x.len(), w.cols, "x length mismatch");
    assert_eq!(y.len(), w.rows, "y length mismatch");
    let xsums = block_sums_f32(x);
    for n in rows {
        y[n] = dot_row_f32(w.row(n), x, &xsums);
    }
}

#[inline]
fn dot_row_f32(blocks: &[BlockQ4_0], x: &[f32], xsums: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (bi, b) in blocks.iter().enumerate() {
        let xs = &x[bi * QK..(bi + 1) * QK];
        let (xlo, xhi) = xs.split_at(QK / 2);
        // two nibble banks as independent loops (see dot_row_q8q4);
        // the (q − 8) offset is folded into xsums
        let mut lo = 0.0f32;
        for (&byte, &xl) in b.qs.iter().zip(xlo) {
            lo += (byte & 0x0F) as f32 * xl;
        }
        let mut hi = 0.0f32;
        for (&byte, &xh) in b.qs.iter().zip(xhi) {
            hi += (byte >> 4) as f32 * xh;
        }
        acc += b.scale() * (lo + hi - 8.0 * xsums[bi]);
    }
    acc
}

/// Per-block sums of the quantized activation (shared by all rows).
#[inline]
fn block_sums_i32(xq: &[i8]) -> Vec<i32> {
    xq.chunks_exact(QK).map(|c| c.iter().map(|&v| v as i32).sum()).collect()
}

/// Integer path: y[n] = xscale · Σ_blocks d_b · Σ_j (q_j − 8) · xq_j.
pub fn gemv_q8q4_range(w: &MatQ4, xq: &QuantizedRow, y: &mut [f32], rows: Range<usize>) {
    assert_eq!(xq.q.len(), w.cols);
    assert_eq!(y.len(), w.rows);
    let xsums = block_sums_i32(&xq.q);
    for n in rows {
        y[n] = dot_row_q8q4(w.row(n), &xq.q, &xsums) * xq.scale;
    }
}

#[inline]
fn dot_row_q8q4(blocks: &[BlockQ4_0], xq: &[i8], xsums: &[i32]) -> f32 {
    let mut acc = 0.0f32;
    for (bi, b) in blocks.iter().enumerate() {
        let xs = &xq[bi * QK..(bi + 1) * QK];
        let (xlo, xhi) = xs.split_at(QK / 2);
        // two independent single-bank loops — each autovectorizes to
        // widening int8 multiplies (vpmaddubsw/vpdpbusd class)
        let mut dlo = 0i32;
        for (&byte, &xl) in b.qs.iter().zip(xlo) {
            dlo += (byte & 0x0F) as i32 * xl as i32;
        }
        let mut dhi = 0i32;
        for (&byte, &xh) in b.qs.iter().zip(xhi) {
            dhi += (byte >> 4) as i32 * xh as i32;
        }
        acc += b.scale() * (dlo + dhi - 8 * xsums[bi]) as f32;
    }
    acc
}

/// Prefill matmul: out[s, n] = Σ_k x[s, k] · w[n, k] for rows `rows` of w.
/// `x` is S×K row-major, `out` is S×N row-major. Each weight row is
/// dequantized once into `scratch` (len K) and reused for all S rows.
pub fn qmatmul_f32_range(
    w: &MatQ4,
    x: &[f32],
    s: usize,
    out: &mut [f32],
    scratch: &mut [f32],
    rows: Range<usize>,
) {
    let k = w.cols;
    let n_total = w.rows;
    assert_eq!(x.len(), s * k);
    assert_eq!(out.len(), s * n_total);
    assert!(scratch.len() >= k);
    for n in rows {
        crate::quant::dequantize_row_q4_0(w.row(n), &mut scratch[..k]);
        for si in 0..s {
            let xrow = &x[si * k..(si + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xrow.iter().zip(scratch[..k].iter()) {
                acc += a * b;
            }
            out[si * n_total + n] = acc;
        }
    }
}

/// Range-relative variants: write only the rows in `rows` into an output
/// slice of length `rows.len()` — the form the scheduled engine uses so
/// each worker owns a disjoint output sub-slice.
pub fn gemv_q4_f32_rows_into(w: &MatQ4, x: &[f32], rows: Range<usize>, out: &mut [f32]) {
    assert_eq!(out.len(), rows.len());
    let xsums = block_sums_f32(x);
    for (o, n) in out.iter_mut().zip(rows) {
        *o = dot_row_f32(w.row(n), x, &xsums);
    }
}

pub fn gemv_q8q4_rows_into(w: &MatQ4, xq: &QuantizedRow, rows: Range<usize>, out: &mut [f32]) {
    assert_eq!(out.len(), rows.len());
    assert_eq!(xq.q.len(), w.cols);
    let xsums = block_sums_i32(&xq.q);
    for (o, n) in out.iter_mut().zip(rows) {
        *o = dot_row_q8q4(w.row(n), &xq.q, &xsums) * xq.scale;
    }
}

/// Prefill variant with *transposed* output: `out_t[(n - rows.start)·s + si]`
/// so each worker's rows are contiguous in its own output window.
pub fn qmatmul_f32_rows_into_t(
    w: &MatQ4,
    x: &[f32],
    s: usize,
    rows: Range<usize>,
    out_t: &mut [f32],
    scratch: &mut [f32],
) {
    let k = w.cols;
    assert_eq!(x.len(), s * k);
    assert_eq!(out_t.len(), rows.len() * s);
    assert!(scratch.len() >= k);
    for (ri, n) in rows.enumerate() {
        crate::quant::dequantize_row_q4_0(w.row(n), &mut scratch[..k]);
        for si in 0..s {
            let xrow = &x[si * k..(si + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xrow.iter().zip(scratch[..k].iter()) {
                acc += a * b;
            }
            out_t[ri * s + si] = acc;
        }
    }
}

/// Convenience single-threaded wrappers.
pub fn gemv_q4_f32(w: &MatQ4, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0; w.rows];
    gemv_q4_f32_range(w, x, &mut y, 0..w.rows);
    y
}

pub fn gemv_q8q4(w: &MatQ4, xq: &QuantizedRow) -> Vec<f32> {
    let mut y = vec![0.0; w.rows];
    gemv_q8q4_range(w, xq, &mut y, 0..w.rows);
    y
}

pub fn qmatmul_f32(w: &MatQ4, x: &[f32], s: usize) -> Vec<f32> {
    let mut out = vec![0.0; s * w.rows];
    let mut scratch = vec![0.0; w.cols];
    qmatmul_f32_range(w, x, s, &mut out, &mut scratch, 0..w.rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::randn_mat;
    use crate::quant::quantize_q8_dynamic;
    use crate::util::rng::Rng;

    fn setup(n: usize, k: usize, seed: u64) -> (MatQ4, Vec<f32>, Vec<f32>) {
        let wf = randn_mat(n, k, seed);
        let w = MatQ4::quantize(&wf.data, n, k);
        let deq = w.dequantize();
        let mut rng = Rng::new(seed + 100);
        let mut x = vec![0.0f32; k];
        rng.fill_normal_f32(&mut x, 1.0);
        (w, deq, x)
    }

    fn oracle_gemv(deq: &[f32], x: &[f32], n: usize, k: usize) -> Vec<f32> {
        (0..n).map(|r| (0..k).map(|c| deq[r * k + c] * x[c]).sum()).collect()
    }

    #[test]
    fn f32_path_matches_dequant_oracle() {
        let (w, deq, x) = setup(64, 128, 1);
        let y = gemv_q4_f32(&w, &x);
        let want = oracle_gemv(&deq, &x, 64, 128);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn int_path_tracks_f32_path() {
        let (w, _, x) = setup(128, 256, 2);
        let xq = quantize_q8_dynamic(&x);
        let yi = gemv_q8q4(&w, &xq);
        let yf = gemv_q4_f32(&w, &x);
        let denom = yf.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
        for (a, b) in yi.iter().zip(&yf) {
            assert!((a - b).abs() / denom < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn int_path_matches_python_semantics() {
        // exact per-block integer dot — mirror of ref_gemv_q8q4
        let (w, _, x) = setup(32, 64, 3);
        let xq = quantize_q8_dynamic(&x);
        let y = gemv_q8q4(&w, &xq);
        for n in 0..32 {
            let mut acc = 0.0f32;
            for (bi, b) in w.row(n).iter().enumerate() {
                let mut isum = 0i32;
                for i in 0..QK {
                    isum += (b.code(i) as i32 - 8) * xq.q[bi * QK + i] as i32;
                }
                acc += b.scale() * isum as f32;
            }
            let want = acc * xq.scale;
            assert!((y[n] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn range_partition_covers_whole() {
        let (w, _, x) = setup(96, 64, 4);
        let whole = gemv_q4_f32(&w, &x);
        let mut y = vec![0.0; 96];
        gemv_q4_f32_range(&w, &x, &mut y, 0..31);
        gemv_q4_f32_range(&w, &x, &mut y, 31..64);
        gemv_q4_f32_range(&w, &x, &mut y, 64..96);
        assert_eq!(y, whole);
    }

    #[test]
    fn qmatmul_rows_match_gemv() {
        let (w, _, _) = setup(64, 96, 5);
        let mut rng = Rng::new(42);
        let s = 3;
        let mut x = vec![0.0f32; s * 96];
        rng.fill_normal_f32(&mut x, 1.0);
        let out = qmatmul_f32(&w, &x, s);
        for si in 0..s {
            let y = gemv_q4_f32(&w, &x[si * 96..(si + 1) * 96]);
            for n in 0..64 {
                assert!((out[si * 64 + n] - y[n]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn zero_x_gives_zero_y() {
        let (w, _, _) = setup(16, 32, 6);
        let y = gemv_q4_f32(&w, &vec![0.0; 32]);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
