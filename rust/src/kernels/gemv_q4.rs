//! Fused Q4_0-dequant GEMV / matmul — the decode-phase hot path.
//!
//! Three variants, all splitting the weight-row dimension N:
//! * [`gemv_q4_f32_range`] — f32 accumulation (llama.cpp's AVX2 path)
//! * [`gemv_q8q4_range`]   — dynamic-quant int8 activation × Q4 weight,
//!   per-block integer dot (Neural Speed's AVX-VNNI path; the paper's
//!   "complete computation" for the GEMV benchmark)
//! * [`qmatmul_f32_range`] — S-row matmul for prefill chunks (dequantizes
//!   each weight row once, reuses it across the S activation rows)

use std::ops::Range;

use crate::cpu::CoreKind;
use crate::quant::{BlockQ4_0, MatQ4, QuantizedRow, QK};

/// Per-block sums of `x` — hoists the `(q − 8)` offset out of the inner
/// loop: `Σ (q−8)·x = Σ q·x − 8·Σx`, with `Σx` shared by *all* weight rows.
#[inline]
fn block_sums_f32(x: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    block_sums_f32_into(x, &mut out);
    out
}

/// Allocation-free form of the block sums: the engine computes them once
/// per kernel on the leader into a persistent buffer instead of once per
/// worker into a fresh `Vec`.
pub fn block_sums_f32_into(x: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(x.chunks_exact(QK).map(|c| c.iter().sum::<f32>()));
}

/// One Q4_0 block's contribution to a row dot product. Kept as the single
/// shared inner kernel so every caller — scalar rows, tiled rows, fused
/// multi-matrix rows — accumulates bit-identical per-row sums.
#[inline]
fn block_term_f32(b: &BlockQ4_0, xs: &[f32], xsum: f32) -> f32 {
    let (xlo, xhi) = xs.split_at(QK / 2);
    // two nibble banks as independent loops (see block_term_q8q4);
    // the (q − 8) offset is folded into xsum
    let mut lo = 0.0f32;
    for (&byte, &xl) in b.qs.iter().zip(xlo) {
        lo += (byte & 0x0F) as f32 * xl;
    }
    let mut hi = 0.0f32;
    for (&byte, &xh) in b.qs.iter().zip(xhi) {
        hi += (byte >> 4) as f32 * xh;
    }
    b.scale() * (lo + hi - 8.0 * xsum)
}

/// y[n] = Σ_k w[n,k] · x[k], f32 path, rows `rows` of `w`.
pub fn gemv_q4_f32_range(w: &MatQ4, x: &[f32], y: &mut [f32], rows: Range<usize>) {
    assert_eq!(x.len(), w.cols, "x length mismatch");
    assert_eq!(y.len(), w.rows, "y length mismatch");
    let xsums = block_sums_f32(x);
    for n in rows {
        y[n] = dot_row_f32(w.row(n), x, &xsums);
    }
}

#[inline]
fn dot_row_f32(blocks: &[BlockQ4_0], x: &[f32], xsums: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (bi, b) in blocks.iter().enumerate() {
        acc += block_term_f32(b, &x[bi * QK..(bi + 1) * QK], xsums[bi]);
    }
    acc
}

/// Per-block sums of the quantized activation (shared by all rows).
#[inline]
fn block_sums_i32(xq: &[i8]) -> Vec<i32> {
    let mut out = Vec::new();
    block_sums_i32_into(xq, &mut out);
    out
}

/// Allocation-free integer block sums (see [`block_sums_f32_into`]).
pub fn block_sums_i32_into(xq: &[i8], out: &mut Vec<i32>) {
    out.clear();
    out.extend(xq.chunks_exact(QK).map(|c| c.iter().map(|&v| v as i32).sum::<i32>()));
}

#[inline]
fn block_term_q8q4(b: &BlockQ4_0, xs: &[i8], xsum: i32) -> f32 {
    let (xlo, xhi) = xs.split_at(QK / 2);
    // two independent single-bank loops — each autovectorizes to
    // widening int8 multiplies (vpmaddubsw/vpdpbusd class)
    let mut dlo = 0i32;
    for (&byte, &xl) in b.qs.iter().zip(xlo) {
        dlo += (byte & 0x0F) as i32 * xl as i32;
    }
    let mut dhi = 0i32;
    for (&byte, &xh) in b.qs.iter().zip(xhi) {
        dhi += (byte >> 4) as i32 * xh as i32;
    }
    b.scale() * (dlo + dhi - 8 * xsum) as f32
}

/// Integer path: y[n] = xscale · Σ_blocks d_b · Σ_j (q_j − 8) · xq_j.
pub fn gemv_q8q4_range(w: &MatQ4, xq: &QuantizedRow, y: &mut [f32], rows: Range<usize>) {
    assert_eq!(xq.q.len(), w.cols);
    assert_eq!(y.len(), w.rows);
    let xsums = block_sums_i32(&xq.q);
    for n in rows {
        y[n] = dot_row_q8q4(w.row(n), &xq.q, &xsums) * xq.scale;
    }
}

#[inline]
fn dot_row_q8q4(blocks: &[BlockQ4_0], xq: &[i8], xsums: &[i32]) -> f32 {
    let mut acc = 0.0f32;
    for (bi, b) in blocks.iter().enumerate() {
        acc += block_term_q8q4(b, &xq[bi * QK..(bi + 1) * QK], xsums[bi]);
    }
    acc
}

// ---- core-class-tuned microkernels ----
//
// The register-blocking width that pays off differs per core class: wide
// P-cores amortize one activation-block load over 4 weight rows, E-cores
// over 2, and the low-power island runs the plain row-at-a-time loop.
// Per-row accumulation order is untouched by the tile width (rows are
// interleaved, each row still sums its blocks in ascending order through
// [`block_term_f32`]), so any tile mix produces bit-identical outputs.

/// GEMV row-tile width for a core class (see [`CoreKind`]).
pub fn tile_for(kind: CoreKind) -> usize {
    match kind {
        CoreKind::Performance => 4,
        CoreKind::Efficiency => 2,
        CoreKind::LowPower => 1,
    }
}

/// Fused multi-matrix GEMV, f32 path, with caller-precomputed block sums.
/// The matrices are stacked row-wise (all sharing `x`): global row `g`
/// resolves to row `g % seg` of `ws[g / seg]`, so one scheduled kernel
/// covers e.g. the whole QKV projection. `out` is the `rows` window.
pub fn gemv_q4_f32_multi_rows_pre(
    ws: &[&MatQ4],
    x: &[f32],
    xsums: &[f32],
    rows: Range<usize>,
    out: &mut [f32],
    tile: usize,
) {
    let seg = ws[0].rows;
    let k = ws[0].cols;
    debug_assert!(ws.iter().all(|w| w.rows == seg && w.cols == k), "stacked mats must agree");
    assert_eq!(x.len(), k);
    assert_eq!(out.len(), rows.len());
    let nb = k / QK;
    let tile = tile.clamp(1, 4);
    let mut g = rows.start;
    let mut o = 0usize;
    while g < rows.end {
        let span = tile.min(rows.end - g);
        let mut rowq: [&[BlockQ4_0]; 4] = [&[]; 4];
        for (i, rq) in rowq.iter_mut().enumerate().take(span) {
            *rq = ws[(g + i) / seg].row((g + i) % seg);
        }
        let mut accs = [0.0f32; 4];
        for bi in 0..nb {
            let xs = &x[bi * QK..(bi + 1) * QK];
            let xsum = xsums[bi];
            for (i, acc) in accs.iter_mut().enumerate().take(span) {
                *acc += block_term_f32(&rowq[i][bi], xs, xsum);
            }
        }
        out[o..o + span].copy_from_slice(&accs[..span]);
        g += span;
        o += span;
    }
}

/// Integer twin of [`gemv_q4_f32_multi_rows_pre`] (q8 activation codes +
/// scale passed split so the caller's persistent buffers can be borrowed).
pub fn gemv_q8q4_multi_rows_pre(
    ws: &[&MatQ4],
    xq: &[i8],
    xscale: f32,
    xsums: &[i32],
    rows: Range<usize>,
    out: &mut [f32],
    tile: usize,
) {
    let seg = ws[0].rows;
    let k = ws[0].cols;
    debug_assert!(ws.iter().all(|w| w.rows == seg && w.cols == k), "stacked mats must agree");
    assert_eq!(xq.len(), k);
    assert_eq!(out.len(), rows.len());
    let nb = k / QK;
    let tile = tile.clamp(1, 4);
    let mut g = rows.start;
    let mut o = 0usize;
    while g < rows.end {
        let span = tile.min(rows.end - g);
        let mut rowq: [&[BlockQ4_0]; 4] = [&[]; 4];
        for (i, rq) in rowq.iter_mut().enumerate().take(span) {
            *rq = ws[(g + i) / seg].row((g + i) % seg);
        }
        let mut accs = [0.0f32; 4];
        for bi in 0..nb {
            let xs = &xq[bi * QK..(bi + 1) * QK];
            let xsum = xsums[bi];
            for (i, acc) in accs.iter_mut().enumerate().take(span) {
                *acc += block_term_q8q4(&rowq[i][bi], xs, xsum);
            }
        }
        for i in 0..span {
            out[o + i] = accs[i] * xscale;
        }
        g += span;
        o += span;
    }
}

/// Prefill matmul: out[s, n] = Σ_k x[s, k] · w[n, k] for rows `rows` of w.
/// `x` is S×K row-major, `out` is S×N row-major. Each weight row is
/// dequantized once into `scratch` (len K) and reused for all S rows.
pub fn qmatmul_f32_range(
    w: &MatQ4,
    x: &[f32],
    s: usize,
    out: &mut [f32],
    scratch: &mut [f32],
    rows: Range<usize>,
) {
    let k = w.cols;
    let n_total = w.rows;
    assert_eq!(x.len(), s * k);
    assert_eq!(out.len(), s * n_total);
    assert!(scratch.len() >= k);
    for n in rows {
        crate::quant::dequantize_row_q4_0(w.row(n), &mut scratch[..k]);
        for si in 0..s {
            let xrow = &x[si * k..(si + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xrow.iter().zip(scratch[..k].iter()) {
                acc += a * b;
            }
            out[si * n_total + n] = acc;
        }
    }
}

/// Range-relative variants: write only the rows in `rows` into an output
/// slice of length `rows.len()` — the form the scheduled engine uses so
/// each worker owns a disjoint output sub-slice.
pub fn gemv_q4_f32_rows_into(w: &MatQ4, x: &[f32], rows: Range<usize>, out: &mut [f32]) {
    assert_eq!(out.len(), rows.len());
    let xsums = block_sums_f32(x);
    for (o, n) in out.iter_mut().zip(rows) {
        *o = dot_row_f32(w.row(n), x, &xsums);
    }
}

pub fn gemv_q8q4_rows_into(w: &MatQ4, xq: &QuantizedRow, rows: Range<usize>, out: &mut [f32]) {
    assert_eq!(out.len(), rows.len());
    assert_eq!(xq.q.len(), w.cols);
    let xsums = block_sums_i32(&xq.q);
    for (o, n) in out.iter_mut().zip(rows) {
        *o = dot_row_q8q4(w.row(n), &xq.q, &xsums) * xq.scale;
    }
}

/// Prefill variant with *transposed* output: `out_t[(n - rows.start)·s + si]`
/// so each worker's rows are contiguous in its own output window.
pub fn qmatmul_f32_rows_into_t(
    w: &MatQ4,
    x: &[f32],
    s: usize,
    rows: Range<usize>,
    out_t: &mut [f32],
    scratch: &mut [f32],
) {
    let k = w.cols;
    assert_eq!(x.len(), s * k);
    assert_eq!(out_t.len(), rows.len() * s);
    assert!(scratch.len() >= k);
    for (ri, n) in rows.enumerate() {
        crate::quant::dequantize_row_q4_0(w.row(n), &mut scratch[..k]);
        for si in 0..s {
            let xrow = &x[si * k..(si + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xrow.iter().zip(scratch[..k].iter()) {
                acc += a * b;
            }
            out_t[ri * s + si] = acc;
        }
    }
}

/// Fused multi-matrix prefill matmul with transposed output: global row
/// `g` resolves to row `g % seg` of `ws[g / seg]` (all matrices share the
/// activation chunk `x`), so QKV or gate/up run as one scheduled kernel.
/// Per-row math is identical to [`qmatmul_f32_rows_into_t`]. The dequant
/// `scratch` is caller-owned (one persistent slab window per worker).
pub fn qmatmul_f32_multi_rows_into_t(
    ws: &[&MatQ4],
    x: &[f32],
    s: usize,
    rows: Range<usize>,
    out_t: &mut [f32],
    scratch: &mut [f32],
) {
    let seg = ws[0].rows;
    let k = ws[0].cols;
    debug_assert!(ws.iter().all(|w| w.rows == seg && w.cols == k), "stacked mats must agree");
    assert_eq!(x.len(), s * k);
    assert_eq!(out_t.len(), rows.len() * s);
    assert!(scratch.len() >= k);
    for (ri, g) in rows.enumerate() {
        crate::quant::dequantize_row_q4_0(ws[g / seg].row(g % seg), &mut scratch[..k]);
        for si in 0..s {
            let xrow = &x[si * k..(si + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xrow.iter().zip(scratch[..k].iter()) {
                acc += a * b;
            }
            out_t[ri * s + si] = acc;
        }
    }
}

/// Convenience single-threaded wrappers.
pub fn gemv_q4_f32(w: &MatQ4, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0; w.rows];
    gemv_q4_f32_range(w, x, &mut y, 0..w.rows);
    y
}

pub fn gemv_q8q4(w: &MatQ4, xq: &QuantizedRow) -> Vec<f32> {
    let mut y = vec![0.0; w.rows];
    gemv_q8q4_range(w, xq, &mut y, 0..w.rows);
    y
}

pub fn qmatmul_f32(w: &MatQ4, x: &[f32], s: usize) -> Vec<f32> {
    let mut out = vec![0.0; s * w.rows];
    let mut scratch = vec![0.0; w.cols];
    qmatmul_f32_range(w, x, s, &mut out, &mut scratch, 0..w.rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::randn_mat;
    use crate::quant::quantize_q8_dynamic;
    use crate::util::rng::Rng;

    fn setup(n: usize, k: usize, seed: u64) -> (MatQ4, Vec<f32>, Vec<f32>) {
        let wf = randn_mat(n, k, seed);
        let w = MatQ4::quantize(&wf.data, n, k);
        let deq = w.dequantize();
        let mut rng = Rng::new(seed + 100);
        let mut x = vec![0.0f32; k];
        rng.fill_normal_f32(&mut x, 1.0);
        (w, deq, x)
    }

    fn oracle_gemv(deq: &[f32], x: &[f32], n: usize, k: usize) -> Vec<f32> {
        (0..n).map(|r| (0..k).map(|c| deq[r * k + c] * x[c]).sum()).collect()
    }

    #[test]
    fn f32_path_matches_dequant_oracle() {
        let (w, deq, x) = setup(64, 128, 1);
        let y = gemv_q4_f32(&w, &x);
        let want = oracle_gemv(&deq, &x, 64, 128);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn int_path_tracks_f32_path() {
        let (w, _, x) = setup(128, 256, 2);
        let xq = quantize_q8_dynamic(&x);
        let yi = gemv_q8q4(&w, &xq);
        let yf = gemv_q4_f32(&w, &x);
        let denom = yf.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
        for (a, b) in yi.iter().zip(&yf) {
            assert!((a - b).abs() / denom < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn int_path_matches_python_semantics() {
        // exact per-block integer dot — mirror of ref_gemv_q8q4
        let (w, _, x) = setup(32, 64, 3);
        let xq = quantize_q8_dynamic(&x);
        let y = gemv_q8q4(&w, &xq);
        for n in 0..32 {
            let mut acc = 0.0f32;
            for (bi, b) in w.row(n).iter().enumerate() {
                let mut isum = 0i32;
                for i in 0..QK {
                    isum += (b.code(i) as i32 - 8) * xq.q[bi * QK + i] as i32;
                }
                acc += b.scale() * isum as f32;
            }
            let want = acc * xq.scale;
            assert!((y[n] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn range_partition_covers_whole() {
        let (w, _, x) = setup(96, 64, 4);
        let whole = gemv_q4_f32(&w, &x);
        let mut y = vec![0.0; 96];
        gemv_q4_f32_range(&w, &x, &mut y, 0..31);
        gemv_q4_f32_range(&w, &x, &mut y, 31..64);
        gemv_q4_f32_range(&w, &x, &mut y, 64..96);
        assert_eq!(y, whole);
    }

    #[test]
    fn qmatmul_rows_match_gemv() {
        let (w, _, _) = setup(64, 96, 5);
        let mut rng = Rng::new(42);
        let s = 3;
        let mut x = vec![0.0f32; s * 96];
        rng.fill_normal_f32(&mut x, 1.0);
        let out = qmatmul_f32(&w, &x, s);
        for si in 0..s {
            let y = gemv_q4_f32(&w, &x[si * 96..(si + 1) * 96]);
            for n in 0..64 {
                assert!((out[si * 64 + n] - y[n]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn zero_x_gives_zero_y() {
        let (w, _, _) = setup(16, 32, 6);
        let y = gemv_q4_f32(&w, &vec![0.0; 32]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tiled_pre_is_bit_identical_for_every_tile_width() {
        // the core-class microkernel contract: tile width changes register
        // blocking, never the per-row accumulation order
        let (w, _, x) = setup(67, 128, 7); // odd row count → ragged last tile
        let base = gemv_q4_f32(&w, &x);
        let mut xsums = Vec::new();
        block_sums_f32_into(&x, &mut xsums);
        for tile in [1usize, 2, 3, 4, 9] {
            let mut y = vec![0.0f32; 67];
            gemv_q4_f32_multi_rows_pre(&[&w], &x, &xsums, 0..67, &mut y, tile);
            assert_eq!(y, base, "tile={tile} diverged");
        }
    }

    #[test]
    fn tiled_pre_int_is_bit_identical_for_every_tile_width() {
        let (w, _, x) = setup(50, 96, 8);
        let xq = quantize_q8_dynamic(&x);
        let base = gemv_q8q4(&w, &xq);
        let mut xsums = Vec::new();
        block_sums_i32_into(&xq.q, &mut xsums);
        for tile in [1usize, 2, 4] {
            let mut y = vec![0.0f32; 50];
            gemv_q8q4_multi_rows_pre(&[&w], &xq.q, xq.scale, &xsums, 0..50, &mut y, tile);
            assert_eq!(y, base, "tile={tile} diverged");
        }
    }

    #[test]
    fn fused_multi_matches_separate_gemvs_bitwise() {
        let (wa, _, x) = setup(64, 128, 9);
        let wb = MatQ4::quantize(&randn_mat(64, 128, 10).data, 64, 128);
        let wc = MatQ4::quantize(&randn_mat(64, 128, 11).data, 64, 128);
        let mut xsums = Vec::new();
        block_sums_f32_into(&x, &mut xsums);
        let mut fused = vec![0.0f32; 3 * 64];
        // split across an awkward boundary straddling two matrices
        gemv_q4_f32_multi_rows_pre(&[&wa, &wb, &wc], &x, &xsums, 0..70, &mut fused[..70], 4);
        gemv_q4_f32_multi_rows_pre(&[&wa, &wb, &wc], &x, &xsums, 70..192, &mut fused[70..], 2);
        let mut want = gemv_q4_f32(&wa, &x);
        want.extend(gemv_q4_f32(&wb, &x));
        want.extend(gemv_q4_f32(&wc, &x));
        assert_eq!(fused, want);
    }

    #[test]
    fn fused_multi_qmatmul_matches_separate_bitwise() {
        let (wa, _, _) = setup(48, 64, 12);
        let wb = MatQ4::quantize(&randn_mat(48, 64, 13).data, 48, 64);
        let s = 3;
        let mut rng = Rng::new(77);
        let mut x = vec![0.0f32; s * 64];
        rng.fill_normal_f32(&mut x, 1.0);
        let mut scratch = vec![0.0f32; 64];
        let mut fused_t = vec![0.0f32; 96 * s];
        qmatmul_f32_multi_rows_into_t(&[&wa, &wb], &x, s, 0..96, &mut fused_t, &mut scratch);
        for (m, w) in [&wa, &wb].into_iter().enumerate() {
            let mut sep_t = vec![0.0f32; 48 * s];
            qmatmul_f32_rows_into_t(w, &x, s, 0..48, &mut sep_t, &mut scratch);
            assert_eq!(&fused_t[m * 48 * s..(m + 1) * 48 * s], &sep_t[..]);
        }
    }

    #[test]
    fn tile_widths_follow_core_class() {
        assert_eq!(tile_for(CoreKind::Performance), 4);
        assert_eq!(tile_for(CoreKind::Efficiency), 2);
        assert_eq!(tile_for(CoreKind::LowPower), 1);
    }
}
