//! u8 × i8 → i32 GEMM — the AVX-VNNI (`vpdpbusd`) micro-kernel analog.
//!
//! `C[M, N] = A[M, K] (u8) · B[K, N] (i8)`, accumulated in i32. B is taken
//! pre-transposed (`bt[N, K]`) so the inner loop is a contiguous dot
//! product, which is both cache-friendly and what the VNNI kernel's
//! register blocking amounts to. The parallel dimension is M (rows of A) —
//! the dimension the paper's scheduler splits.

use std::ops::Range;

use crate::cpu::CoreKind;
use crate::tensor::{MatI8, MatU8};

/// Column-block width (B rows fed per pass over an A row) tuned per core
/// class: P-cores carry 4 accumulator lanes comfortably, E-cores 2, and
/// the low-power island degrades to the plain dot product. Accumulation
/// is exact i32, so the block width never changes results.
pub fn col_block_for(kind: CoreKind) -> usize {
    match kind {
        CoreKind::Performance => 4,
        CoreKind::Efficiency => 2,
        CoreKind::LowPower => 1,
    }
}

/// Dot product of one u8 row with one i8 row (K elements), i32 accumulate.
/// Unrolled by 4 to expose ILP; the autovectorizer maps this to pmaddubsw-
/// style sequences on AVX2 targets.
#[inline]
fn dot_u8i8(a: &[u8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc0 += a[j] as i32 * b[j] as i32;
        acc1 += a[j + 1] as i32 * b[j + 1] as i32;
        acc2 += a[j + 2] as i32 * b[j + 2] as i32;
        acc3 += a[j + 3] as i32 * b[j + 3] as i32;
    }
    for j in chunks * 4..a.len() {
        acc0 += a[j] as i32 * b[j] as i32;
    }
    acc0 + acc1 + acc2 + acc3
}

/// Compute rows `rows` of C. `c` is the full M×N output buffer.
/// Column-blocked by 4: each pass over the A row feeds four B rows, so
/// A-row loads are amortized 4× (the register-blocking idea of the VNNI
/// micro-kernel, expressed scalar).
pub fn gemm_i8_range(a: &MatU8, bt: &MatI8, c: &mut [i32], n: usize, rows: Range<usize>) {
    gemm_i8_range_blocked(a, bt, c, n, rows, 4);
}

/// [`gemm_i8_range`] with an explicit column-block width (see
/// [`col_block_for`]). i32 sums are order-independent, so every width
/// yields the identical C.
pub fn gemm_i8_range_blocked(
    a: &MatU8,
    bt: &MatI8,
    c: &mut [i32],
    n: usize,
    rows: Range<usize>,
    col_block: usize,
) {
    assert_eq!(a.cols, bt.cols, "K mismatch");
    assert_eq!(bt.rows, n, "N mismatch");
    assert_eq!(c.len(), a.rows * n, "C shape mismatch");
    let k = a.cols;
    let cb = col_block.clamp(1, 4);
    for m in rows {
        let arow = a.row(m);
        let crow = &mut c[m * n..(m + 1) * n];
        let mut j = 0;
        if cb >= 4 {
            while j + 4 <= n {
                let b0 = bt.row(j);
                let b1 = bt.row(j + 1);
                let b2 = bt.row(j + 2);
                let b3 = bt.row(j + 3);
                let mut acc0 = 0i32;
                let mut acc1 = 0i32;
                let mut acc2 = 0i32;
                let mut acc3 = 0i32;
                for p in 0..k {
                    let av = arow[p] as i32;
                    acc0 += av * b0[p] as i32;
                    acc1 += av * b1[p] as i32;
                    acc2 += av * b2[p] as i32;
                    acc3 += av * b3[p] as i32;
                }
                crow[j] = acc0;
                crow[j + 1] = acc1;
                crow[j + 2] = acc2;
                crow[j + 3] = acc3;
                j += 4;
            }
        }
        if cb >= 2 {
            while j + 2 <= n {
                let b0 = bt.row(j);
                let b1 = bt.row(j + 1);
                let mut acc0 = 0i32;
                let mut acc1 = 0i32;
                for p in 0..k {
                    let av = arow[p] as i32;
                    acc0 += av * b0[p] as i32;
                    acc1 += av * b1[p] as i32;
                }
                crow[j] = acc0;
                crow[j + 1] = acc1;
                j += 2;
            }
        }
        for (j, cv) in crow.iter_mut().enumerate().skip(j) {
            *cv = dot_u8i8(arow, bt.row(j));
        }
    }
}

/// Whole-matrix convenience entry (single-threaded reference).
pub fn gemm_i8(a: &MatU8, bt: &MatI8) -> Vec<i32> {
    let mut c = vec![0i32; a.rows * bt.rows];
    gemm_i8_range(a, bt, &mut c, bt.rows, 0..a.rows);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::testutil::{rand_i8, rand_u8};

    /// naive i64 oracle
    fn oracle(a: &MatU8, bt: &MatI8) -> Vec<i32> {
        let (m, k, n) = (a.rows, a.cols, bt.rows);
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for p in 0..k {
                    acc += a.data[i * k + p] as i64 * bt.data[j * k + p] as i64;
                }
                c[i * n + j] = acc as i32;
            }
        }
        c
    }

    #[test]
    fn matches_oracle() {
        let a = rand_u8(13, 40, 1);
        let bt = rand_i8(9, 40, 2);
        assert_eq!(gemm_i8(&a, &bt), oracle(&a, &bt));
    }

    #[test]
    fn range_partition_covers_whole() {
        let a = rand_u8(16, 32, 3);
        let bt = rand_i8(8, 32, 4);
        let whole = gemm_i8(&a, &bt);
        let mut c = vec![0i32; 16 * 8];
        gemm_i8_range(&a, &bt, &mut c, 8, 0..5);
        gemm_i8_range(&a, &bt, &mut c, 8, 5..11);
        gemm_i8_range(&a, &bt, &mut c, 8, 11..16);
        assert_eq!(c, whole);
    }

    #[test]
    fn extreme_values_accumulate_exactly() {
        // 255 · 127 · K stays well inside i32 for K ≤ 66000
        let mut a = MatU8::zeros(1, 64);
        a.data.fill(255);
        let mut bt = MatI8::zeros(1, 64);
        bt.data.fill(127);
        assert_eq!(gemm_i8(&a, &bt)[0], 255 * 127 * 64);
        bt.data.fill(-128);
        assert_eq!(gemm_i8(&a, &bt)[0], 255 * -128 * 64);
    }

    #[test]
    fn odd_k_tail_handled() {
        let a = rand_u8(3, 37, 5);
        let bt = rand_i8(4, 37, 6);
        assert_eq!(gemm_i8(&a, &bt), oracle(&a, &bt));
    }

    #[test]
    fn every_col_block_width_matches_oracle() {
        let a = rand_u8(7, 29, 9);
        let bt = rand_i8(11, 29, 10); // n=11: ragged tail for every width
        let want = oracle(&a, &bt);
        for cb in [1usize, 2, 4, 9] {
            let mut c = vec![0i32; 7 * 11];
            gemm_i8_range_blocked(&a, &bt, &mut c, 11, 0..7, cb);
            assert_eq!(c, want, "col_block={cb}");
        }
    }

    #[test]
    fn col_block_widths_follow_core_class() {
        assert_eq!(col_block_for(CoreKind::Performance), 4);
        assert_eq!(col_block_for(CoreKind::Efficiency), 2);
        assert_eq!(col_block_for(CoreKind::LowPower), 1);
    }

    #[test]
    fn empty_range_is_noop() {
        let a = rand_u8(4, 16, 7);
        let bt = rand_i8(4, 16, 8);
        let mut c = vec![-1i32; 16];
        gemm_i8_range(&a, &bt, &mut c, 4, 2..2);
        assert!(c.iter().all(|&v| v == -1));
    }
}
