//! Rotary position embedding — interleaved-pair formulation matching
//! `ref.ref_rope` (pairs `(x[2i], x[2i+1])`, angle `pos / theta^(2i/dh)`).

/// Rotate one head vector `x` (len dh, even) in place for position `pos`.
pub fn rope_inplace(x: &mut [f32], pos: i32, theta: f32) {
    let dh = x.len();
    debug_assert!(dh % 2 == 0);
    let half = dh / 2;
    for i in 0..half {
        let freq = 1.0 / theta.powf(i as f32 * 2.0 / dh as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let x0 = x[2 * i];
        let x1 = x[2 * i + 1];
        x[2 * i] = x0 * cos - x1 * sin;
        x[2 * i + 1] = x0 * sin + x1 * cos;
    }
}

/// Apply RoPE to all `h` heads laid out contiguously `[h, dh]`.
pub fn rope_heads(x: &mut [f32], h: usize, dh: usize, pos: i32, theta: f32) {
    assert_eq!(x.len(), h * dh);
    for head in 0..h {
        rope_inplace(&mut x[head * dh..(head + 1) * dh], pos, theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pos_zero_is_identity() {
        let mut rng = Rng::new(1);
        let mut x = vec![0.0f32; 16];
        rng.fill_normal_f32(&mut x, 1.0);
        let orig = x.clone();
        rope_inplace(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rotation_preserves_pair_norms() {
        let mut rng = Rng::new(2);
        let mut x = vec![0.0f32; 32];
        rng.fill_normal_f32(&mut x, 1.0);
        let orig = x.clone();
        rope_inplace(&mut x, 17, 10000.0);
        for i in 0..16 {
            let n0 = orig[2 * i].hypot(orig[2 * i + 1]);
            let n1 = x[2 * i].hypot(x[2 * i + 1]);
            assert!((n0 - n1).abs() < 1e-5);
        }
    }

    #[test]
    fn first_pair_rotates_by_pos_radians() {
        // freq of pair 0 is 1.0 → angle = pos
        let mut x = vec![1.0f32, 0.0, 0.0, 0.0];
        rope_inplace(&mut x, 1, 10000.0);
        assert!((x[0] - 1f32.cos()).abs() < 1e-6);
        assert!((x[1] - 1f32.sin()).abs() < 1e-6);
    }

    #[test]
    fn relative_property_dot_depends_on_distance() {
        // RoPE's core property: <R(p)q, R(p+d)k> depends only on d (per pair)
        let q = [0.3f32, -0.7];
        let k = [0.9f32, 0.2];
        let dot_at = |p: i32, d: i32| {
            let mut qq = q;
            let mut kk = k;
            rope_inplace(&mut qq, p, 10000.0);
            rope_inplace(&mut kk, p + d, 10000.0);
            qq[0] * kk[0] + qq[1] * kk[1]
        };
        assert!((dot_at(0, 3) - dot_at(11, 3)).abs() < 1e-5);
    }

    #[test]
    fn heads_rotate_independently() {
        let mut rng = Rng::new(3);
        let mut x = vec![0.0f32; 2 * 8];
        rng.fill_normal_f32(&mut x, 1.0);
        let mut head0 = x[..8].to_vec();
        rope_heads(&mut x, 2, 8, 5, 10000.0);
        rope_inplace(&mut head0, 5, 10000.0);
        assert_eq!(&x[..8], head0.as_slice());
    }
}
