//! Decode-phase multi-head attention over the KV cache.
//!
//! Parallel dimension: heads. Semantics mirror `ref.ref_attn_decode`:
//! masked scaled-dot-product with softmax over positions `0..=pos`.

use std::ops::Range;

/// KV cache for one layer: `[h, t_max, dh]` row-major f32.
#[derive(Clone, Debug)]
pub struct KvLayer {
    pub h: usize,
    pub t_max: usize,
    pub dh: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvLayer {
    pub fn new(h: usize, t_max: usize, dh: usize) -> KvLayer {
        KvLayer { h, t_max, dh, k: vec![0.0; h * t_max * dh], v: vec![0.0; h * t_max * dh] }
    }

    #[inline]
    fn off(&self, head: usize, t: usize) -> usize {
        (head * self.t_max + t) * self.dh
    }

    /// Write the K/V vectors of `head` at position `t`.
    pub fn write(&mut self, head: usize, t: usize, kvec: &[f32], vvec: &[f32]) {
        let o = self.off(head, t);
        self.k[o..o + self.dh].copy_from_slice(kvec);
        self.v[o..o + self.dh].copy_from_slice(vvec);
    }

    #[inline]
    pub fn k_at(&self, head: usize, t: usize) -> &[f32] {
        let o = self.off(head, t);
        &self.k[o..o + self.dh]
    }

    #[inline]
    pub fn v_at(&self, head: usize, t: usize) -> &[f32] {
        let o = self.off(head, t);
        &self.v[o..o + self.dh]
    }
}

/// Attention for heads in `heads`: q is `[h, dh]`, out is `[h, dh]`,
/// attending over cache positions `0..=pos`. `scratch` holds `pos+1` scores.
pub fn attention_decode_range(
    q: &[f32],
    cache: &KvLayer,
    pos: usize,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
    heads: Range<usize>,
) {
    let dh = cache.dh;
    assert_eq!(q.len(), cache.h * dh);
    assert_eq!(out.len(), cache.h * dh);
    assert!(pos < cache.t_max);
    let scale = 1.0 / (dh as f32).sqrt();
    let t_len = pos + 1;
    scratch.resize(t_len, 0.0);
    for head in heads {
        let qh = &q[head * dh..(head + 1) * dh];
        for t in 0..t_len {
            let kv = cache.k_at(head, t);
            let mut dot = 0.0f32;
            for (a, b) in qh.iter().zip(kv) {
                dot += a * b;
            }
            scratch[t] = dot * scale;
        }
        super::elementwise::softmax_inplace(&mut scratch[..t_len]);
        let oh = &mut out[head * dh..(head + 1) * dh];
        oh.fill(0.0);
        for t in 0..t_len {
            let p = scratch[t];
            let vv = cache.v_at(head, t);
            for (o, &v) in oh.iter_mut().zip(vv) {
                *o += p * v;
            }
        }
    }
}

/// Window-relative, allocation-free decode attention: `out` is the
/// `heads.len()·dh` window the caller's worker owns, and `scores` is a
/// caller-provided slab window (≥ `pos+1` floats; one per worker in the
/// engine's persistent arena). Per-head math is identical to
/// [`attention_decode_range`], so results are bit-identical.
pub fn attention_decode_rows_into(
    q: &[f32],
    cache: &KvLayer,
    pos: usize,
    heads: Range<usize>,
    out: &mut [f32],
    scores: &mut [f32],
) {
    let dh = cache.dh;
    assert_eq!(q.len(), cache.h * dh);
    assert_eq!(out.len(), heads.len() * dh);
    assert!(pos < cache.t_max);
    let scale = 1.0 / (dh as f32).sqrt();
    let t_len = pos + 1;
    assert!(scores.len() >= t_len);
    for (hi, head) in heads.enumerate() {
        let qh = &q[head * dh..(head + 1) * dh];
        for t in 0..t_len {
            let kv = cache.k_at(head, t);
            let mut dot = 0.0f32;
            for (a, b) in qh.iter().zip(kv) {
                dot += a * b;
            }
            scores[t] = dot * scale;
        }
        super::elementwise::softmax_inplace(&mut scores[..t_len]);
        let oh = &mut out[hi * dh..(hi + 1) * dh];
        oh.fill(0.0);
        for t in 0..t_len {
            let p = scores[t];
            let vv = cache.v_at(head, t);
            for (o, &v) in oh.iter_mut().zip(vv) {
                *o += p * v;
            }
        }
    }
}

/// Batched prefill attention: one kernel covers a whole chunk of `s` new
/// positions instead of one dispatch per position. The parallel dimension
/// is `(si, head)` flattened as `u = si·h + head`; unit `u` runs causal
/// attention for chunk row `si` (cache position `pos0 + si`) and writes
/// `out[(u − units.start)·dh ..]` — with `u` ordered si-major that is
/// exactly the worker's window of the `[s, h·dh]` output. The KV cache
/// must already hold all chunk positions. Per-head math matches
/// [`attention_decode_range`] bit for bit.
pub fn attention_prefill_units_into(
    q: &[f32],
    cache: &KvLayer,
    pos0: usize,
    s: usize,
    units: Range<usize>,
    out: &mut [f32],
    scores: &mut [f32],
) {
    let (h, dh) = (cache.h, cache.dh);
    assert_eq!(q.len(), s * h * dh);
    assert_eq!(out.len(), units.len() * dh);
    assert!(pos0 + s <= cache.t_max);
    let scale = 1.0 / (dh as f32).sqrt();
    assert!(scores.len() >= pos0 + s);
    for (ui, u) in units.enumerate() {
        let (si, head) = (u / h, u % h);
        let t_len = pos0 + si + 1;
        let qh = &q[u * dh..(u + 1) * dh];
        for t in 0..t_len {
            let kv = cache.k_at(head, t);
            let mut dot = 0.0f32;
            for (a, b) in qh.iter().zip(kv) {
                dot += a * b;
            }
            scores[t] = dot * scale;
        }
        super::elementwise::softmax_inplace(&mut scores[..t_len]);
        let oh = &mut out[ui * dh..(ui + 1) * dh];
        oh.fill(0.0);
        for t in 0..t_len {
            let p = scores[t];
            let vv = cache.v_at(head, t);
            for (o, &v) in oh.iter_mut().zip(vv) {
                *o += p * v;
            }
        }
    }
}

/// Whole-kernel convenience wrapper.
pub fn attention_decode(q: &[f32], cache: &KvLayer, pos: usize) -> Vec<f32> {
    let mut out = vec![0.0; cache.h * cache.dh];
    let mut scratch = Vec::new();
    attention_decode_range(q, cache, pos, &mut out, &mut scratch, 0..cache.h);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn filled_cache(h: usize, t_max: usize, dh: usize, upto: usize, seed: u64) -> KvLayer {
        let mut rng = Rng::new(seed);
        let mut c = KvLayer::new(h, t_max, dh);
        for head in 0..h {
            for t in 0..=upto {
                let mut k = vec![0.0f32; dh];
                let mut v = vec![0.0f32; dh];
                rng.fill_normal_f32(&mut k, 1.0);
                rng.fill_normal_f32(&mut v, 1.0);
                c.write(head, t, &k, &v);
            }
        }
        c
    }

    #[test]
    fn pos0_returns_v0() {
        let c = filled_cache(2, 8, 4, 0, 1);
        let mut rng = Rng::new(2);
        let mut q = vec![0.0f32; 2 * 4];
        rng.fill_normal_f32(&mut q, 1.0);
        let out = attention_decode(&q, &c, 0);
        for head in 0..2 {
            for i in 0..4 {
                assert!((out[head * 4 + i] - c.v_at(head, 0)[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn output_is_convex_combination_of_v() {
        let c = filled_cache(4, 16, 8, 15, 3);
        let mut rng = Rng::new(4);
        let mut q = vec![0.0f32; 4 * 8];
        rng.fill_normal_f32(&mut q, 1.0);
        let out = attention_decode(&q, &c, 15);
        for head in 0..4 {
            for i in 0..8 {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for t in 0..16 {
                    lo = lo.min(c.v_at(head, t)[i]);
                    hi = hi.max(c.v_at(head, t)[i]);
                }
                let o = out[head * 8 + i];
                assert!(o >= lo - 1e-5 && o <= hi + 1e-5);
            }
        }
    }

    #[test]
    fn future_positions_are_ignored() {
        let mut c = filled_cache(1, 8, 4, 3, 5);
        let mut rng = Rng::new(6);
        let mut q = vec![0.0f32; 4];
        rng.fill_normal_f32(&mut q, 1.0);
        let out1 = attention_decode(&q, &c, 3);
        // poison positions 4.. — must not change the result
        c.write(0, 5, &[100.0; 4], &[100.0; 4]);
        let out2 = attention_decode(&q, &c, 3);
        assert_eq!(out1, out2);
    }

    #[test]
    fn head_range_partition_matches_whole() {
        let c = filled_cache(6, 12, 8, 11, 7);
        let mut rng = Rng::new(8);
        let mut q = vec![0.0f32; 6 * 8];
        rng.fill_normal_f32(&mut q, 1.0);
        let whole = attention_decode(&q, &c, 11);
        let mut out = vec![0.0f32; 6 * 8];
        let mut scratch = Vec::new();
        attention_decode_range(&q, &c, 11, &mut out, &mut scratch, 0..2);
        attention_decode_range(&q, &c, 11, &mut out, &mut scratch, 2..5);
        attention_decode_range(&q, &c, 11, &mut out, &mut scratch, 5..6);
        assert_eq!(out, whole);
    }

    #[test]
    fn window_relative_rows_match_full_buffer_bitwise() {
        let c = filled_cache(6, 12, 8, 11, 17);
        let mut rng = Rng::new(18);
        let mut q = vec![0.0f32; 6 * 8];
        rng.fill_normal_f32(&mut q, 1.0);
        let whole = attention_decode(&q, &c, 11);
        let mut scores = vec![0.0f32; 12];
        for (a, b) in [(0usize, 2usize), (2, 5), (5, 6)] {
            let mut win = vec![0.0f32; (b - a) * 8];
            attention_decode_rows_into(&q, &c, 11, a..b, &mut win, &mut scores);
            assert_eq!(&win[..], &whole[a * 8..b * 8]);
        }
    }

    #[test]
    fn prefill_units_match_per_position_decode_bitwise() {
        // chunk of s=3 rows starting at cache position 2
        let (h, dh, s, pos0) = (4usize, 8usize, 3usize, 2usize);
        let c = filled_cache(h, 16, dh, pos0 + s - 1, 19);
        let mut rng = Rng::new(20);
        let mut q = vec![0.0f32; s * h * dh];
        rng.fill_normal_f32(&mut q, 1.0);
        let mut scores = vec![0.0f32; pos0 + s];
        // fused kernel, split at an awkward unit boundary inside a row
        let mut fused = vec![0.0f32; s * h * dh];
        attention_prefill_units_into(&q, &c, pos0, s, 0..5, &mut fused[..5 * dh], &mut scores);
        attention_prefill_units_into(&q, &c, pos0, s, 5..s * h, &mut fused[5 * dh..], &mut scores);
        for si in 0..s {
            let want = attention_decode(&q[si * h * dh..(si + 1) * h * dh], &c, pos0 + si);
            assert_eq!(&fused[si * h * dh..(si + 1) * h * dh], &want[..], "row {si}");
        }
    }

    #[test]
    fn sharp_query_selects_matching_key() {
        // make key at t=2 align with q strongly → output ≈ v at t=2
        let mut c = KvLayer::new(1, 4, 4);
        for t in 0..4 {
            let k = if t == 2 { [50.0f32; 4] } else { [0.0; 4] };
            let v = [t as f32; 4];
            c.write(0, t, &k, &v);
        }
        let q = [1.0f32; 4];
        let out = attention_decode(&q, &c, 3);
        for &o in &out {
            assert!((o - 2.0).abs() < 1e-3, "o={o}");
        }
    }
}
