//! Native Rust compute kernels (the Neural Speed micro-kernel analogs).
//!
//! Every kernel exposes a *range-based* entry point over its parallel
//! dimension — the unit the paper's scheduler splits across cores — plus a
//! [`cost::WorkCost`] describing flops/bytes per unit for the simulator.
//! Each kernel declares a primary [`Isa`](crate::cpu::Isa) (paper §2.1:
//! "we've designated a primary ISA for each kernel").

pub mod attention;
pub mod cost;
pub mod elementwise;
pub mod gemm_i8;
pub mod gemv_q4;
pub mod rope;

pub use cost::{KernelClass, WorkCost};

#[cfg(test)]
pub(crate) mod testutil {
    use crate::tensor::{MatF32, MatI8, MatU8};
    use crate::util::rng::Rng;

    pub fn randn_mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = Rng::new(seed);
        MatF32::randn(rows, cols, 1.0, &mut rng)
    }

    pub fn rand_u8(rows: usize, cols: usize, seed: u64) -> MatU8 {
        let mut rng = Rng::new(seed);
        let mut m = MatU8::zeros(rows, cols);
        rng.fill_u8(&mut m.data, 0, 256);
        m
    }

    pub fn rand_i8(rows: usize, cols: usize, seed: u64) -> MatI8 {
        let mut rng = Rng::new(seed);
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_i8(&mut m.data, -127, 128);
        m
    }
}
